"""AOT exporter: lower the JAX/Pallas model to HLO text + weight blobs.

This is the *only* python entry point in the build (``make artifacts``).
It produces, under ``artifacts/``:

* ``resnet18_seg_<name>.hlo.txt``  — one HLO module per model segment
  (stem, 8 basic blocks, head) at the paper's 224×224 input.
* ``resnet18_full.hlo.txt``        — the whole network as one module.
* ``resnet18_tiny_*.hlo.txt``      — 32×32-input variants (fast CI paths
  for the rust integration tests; same code, smaller spatial dims).
* ``weights_<segment>.bin``        — flat int8 parameter blobs (the rust
  runtime feeds them back as the second argument of each segment).
* ``gemm16.hlo.txt`` / ``gemm128.hlo.txt`` — standalone GEMM micro-kernel
  artifacts: the VTA Table-I 16×16 geometry and the TPU-adapted 128×128
  MXU tile.
* ``manifest.json``                — machine-readable index: shapes,
  dtypes, MACs, parameter bytes, per-layer inventory. The rust side
  cross-checks its own graph IR against these numbers.

Interchange is HLO **text**: jax ≥ 0.5 serialized protos use 64-bit ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import gemm as gemm_mod


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(shape, dtype) -> dict:
    return {"shape": list(shape), "dtype": str(dtype)}


def export_segments(cfg: model.ModelConfig, out_dir: str, tag: str) -> list[dict]:
    """Lower each segment; write HLO + weights; return manifest entries."""
    specs = model.build_segment_specs(cfg)
    entries = []
    for spec in specs:
        fn = model.segment_fn(cfg, spec)
        x_spec = _spec(spec.in_shape, jnp.int8)
        w_spec = _spec((spec.param_bytes,), jnp.int8)
        t0 = time.time()
        lowered = jax.jit(fn).lower(x_spec, w_spec)
        text = to_hlo_text(lowered)
        hlo_name = f"resnet18_{tag}seg_{spec.name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_name), "w") as f:
            f.write(text)

        weights = model.init_segment_weights(cfg, spec)
        wname = f"weights_{tag}{spec.name}.bin"
        weights.tofile(os.path.join(out_dir, wname))

        entries.append(
            {
                "name": f"resnet18_{tag}seg_{spec.name}",
                "file": hlo_name,
                "kind": "segment",
                "segment": spec.name,
                "segment_index": spec.index,
                "inputs": [
                    _io_entry(spec.in_shape, "int8"),
                    _io_entry((spec.param_bytes,), "int8"),
                ],
                "outputs": [_io_entry(spec.out_shape, spec.out_dtype)],
                "macs": spec.macs,
                "param_bytes": spec.param_bytes,
                "weights_file": wname,
                "impl": cfg.impl,
                "block": cfg.block,
                "input_hw": cfg.input_hw,
            }
        )
        print(
            f"  exported {hlo_name:44s} macs={spec.macs/1e6:9.1f}M "
            f"params={spec.param_bytes/1024:7.1f}KiB "
            f"hlo={len(text)/1024:7.0f}KiB  ({time.time()-t0:.1f}s)"
        )
    return entries


def export_full(cfg: model.ModelConfig, out_dir: str, tag: str) -> dict:
    specs = model.build_segment_specs(cfg)
    fn = model.full_fn(cfg, specs)
    arg_specs = [_spec(specs[0].in_shape, jnp.int8)] + [
        _spec((s.param_bytes,), jnp.int8) for s in specs
    ]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    hlo_name = f"resnet18_{tag}full.hlo.txt"
    with open(os.path.join(out_dir, hlo_name), "w") as f:
        f.write(text)
    entry = {
        "name": f"resnet18_{tag}full",
        "file": hlo_name,
        "kind": "full",
        "inputs": [_io_entry(specs[0].in_shape, "int8")]
        + [_io_entry((s.param_bytes,), "int8") for s in specs],
        "outputs": [_io_entry(specs[-1].out_shape, specs[-1].out_dtype)],
        "macs": sum(s.macs for s in specs),
        "param_bytes": sum(s.param_bytes for s in specs),
        "weights_files": [f"weights_{tag}{s.name}.bin" for s in specs],
        "impl": cfg.impl,
        "block": cfg.block,
        "input_hw": cfg.input_hw,
    }
    print(
        f"  exported {hlo_name:44s} macs={entry['macs']/1e6:9.1f}M "
        f"hlo={len(text)/1024:7.0f}KiB  ({time.time()-t0:.1f}s)"
    )
    return entry


def export_gemm_microkernels(out_dir: str) -> list[dict]:
    """Standalone GEMM artifacts: VTA 16-geometry + TPU 128-tile."""
    entries = []
    for name, (m, k, n), block in [
        ("gemm16", (64, 64, 64), 16),
        ("gemm128", (256, 256, 256), 128),
    ]:
        def fn(x, w, _block=block):
            return (gemm_mod.gemm(x, w, block_m=_block, block_n=_block, block_k=_block),)

        x_spec = _spec((m, k), jnp.int8)
        w_spec = _spec((n, k), jnp.int8)
        lowered = jax.jit(fn).lower(x_spec, w_spec)
        text = to_hlo_text(lowered)
        hlo_name = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_name), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": hlo_name,
                "kind": "gemm_microkernel",
                "inputs": [_io_entry((m, k), "int8"), _io_entry((n, k), "int8")],
                "outputs": [_io_entry((m, n), "int32")],
                "macs": m * k * n,
                "block": block,
            }
        )
        print(f"  exported {hlo_name:44s} block={block}")
    return entries


def export_test_vectors(cfg: model.ModelConfig, out_dir: str, tag: str) -> list[dict]:
    """Deterministic input/output fixtures for the rust runtime tests.

    For every segment (and the full model) of the given config, write the
    raw little-endian row-major bytes of a fixed random input and of the
    model's output. The rust integration tests load the HLO artifact,
    execute it via PJRT, and require bit-exact agreement — this closes the
    python→HLO-text→rust loop that cannot be closed inside python (jaxlib
    has no HLO-text compile API).
    """
    specs = model.build_segment_specs(cfg)
    entries = []
    rng = np.random.default_rng(4242)
    x0 = rng.integers(-128, 128, specs[0].in_shape, dtype=np.int8)

    x = jnp.asarray(x0)
    ws = [model.init_segment_weights(cfg, s) for s in specs]
    for spec, w in zip(specs, ws):
        fn = model.segment_fn(cfg, spec)
        xin = np.asarray(x, dtype=np.int8)
        (y,) = jax.jit(fn)(x, jnp.asarray(w))
        in_name = f"tv_{tag}{spec.name}_in.bin"
        out_name = f"tv_{tag}{spec.name}_out.bin"
        np.asarray(xin).tofile(os.path.join(out_dir, in_name))
        np.asarray(y).tofile(os.path.join(out_dir, out_name))
        entries.append(
            {
                "name": f"tv_{tag}{spec.name}",
                "kind": "test_vector",
                "artifact": f"resnet18_{tag}seg_{spec.name}",
                "input_file": in_name,
                "output_file": out_name,
                "in_shape": list(spec.in_shape),
                "out_shape": list(spec.out_shape),
                "out_dtype": spec.out_dtype,
            }
        )
        x = y
    # x is now the full-model output for x0 — record it for the full module.
    np.asarray(x).tofile(os.path.join(out_dir, f"tv_{tag}full_out.bin"))
    entries.append(
        {
            "name": f"tv_{tag}full",
            "kind": "test_vector",
            "artifact": f"resnet18_{tag}full",
            "input_file": f"tv_{tag}stem_in.bin",
            "output_file": f"tv_{tag}full_out.bin",
            "in_shape": list(specs[0].in_shape),
            "out_shape": list(specs[-1].out_shape),
            "out_dtype": specs[-1].out_dtype,
        }
    )
    print(f"  exported {len(entries)} test vectors ({tag or 'full-size'})")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--impl",
        default="pallas",
        choices=["pallas", "ref"],
        help="GEMM backing for the model artifacts",
    )
    ap.add_argument("--seed", type=int, default=2023)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    artifacts: list[dict] = []

    print("[aot] gemm micro-kernels")
    artifacts += export_gemm_microkernels(args.out)

    print("[aot] resnet18 @224 segments (paper workload)")
    cfg = model.ModelConfig(input_hw=224, impl=args.impl, seed=args.seed)
    artifacts += export_segments(cfg, args.out, tag="")
    artifacts.append(export_full(cfg, args.out, tag=""))

    print("[aot] resnet18 @32 tiny variant (fast integration tests)")
    tiny = model.ModelConfig(input_hw=32, impl=args.impl, seed=args.seed)
    artifacts += export_segments(tiny, args.out, tag="tiny_")
    artifacts.append(export_full(tiny, args.out, tag="tiny_"))
    artifacts += export_test_vectors(tiny, args.out, tag="tiny_")

    # Serving-optimized variants: same numerics through the pure-jnp GEMM
    # (pallas == ref is enforced bit-exactly by pytest), but without the
    # interpret-mode pallas_call emulation overhead on CPU PJRT — the
    # §Perf L2 optimization. The rust coordinator selects these via the
    # "fast_" prefix; the pallas artifacts above stay the correctness
    # reference. The test vectors apply to both (identical outputs).
    print("[aot] resnet18 serving-optimized (ref-impl) variants")
    fast224 = model.ModelConfig(input_hw=224, impl="ref", seed=args.seed)
    artifacts += export_segments(fast224, args.out, tag="fast_")
    artifacts.append(export_full(fast224, args.out, tag="fast_"))
    fast32 = model.ModelConfig(input_hw=32, impl="ref", seed=args.seed)
    artifacts += export_segments(fast32, args.out, tag="fast_tiny_")
    artifacts.append(export_full(fast32, args.out, tag="fast_tiny_"))

    specs = model.build_segment_specs(cfg)
    manifest = {
        "version": 1,
        "generator": "python/compile/aot.py",
        "model": {
            "name": "resnet18",
            "input_hw": cfg.input_hw,
            "impl": cfg.impl,
            "block": cfg.block,
            "seed": cfg.seed,
            "segments": model.SEGMENT_NAMES,
            "total_macs": sum(s.macs for s in specs),
            "total_param_bytes": sum(s.param_bytes for s in specs),
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(artifacts)} artifacts "
          f"in {time.time()-t0:.1f}s total")


if __name__ == "__main__":
    main()
