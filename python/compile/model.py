"""Layer-2: int8 ResNet-18 in JAX, built on the Layer-1 VTA kernels.

This is the workload of the paper's evaluation (§III): ResNet-18 with
(N, 224, 224, 3) inputs, int8 weights/activations and int32 accumulation —
the dataflow TVM produces for VTA. Every conv/dense goes through
``kernels.conv2d`` (im2col + the Pallas GEMM core) and every element-wise
op through ``kernels.alu``, so the AOT-lowered HLO contains exactly the
kernel pipeline the accelerator would run.

The model is partitioned into **10 segments** (stem, 8 basic blocks, head)
— the cut points the paper's pipeline / fused schedules use. The rust
coordinator composes contiguous segments per execution plan, so any
pipeline depth from 1 to 10 stages is expressible from the same artifacts.

Weights are synthetic (deterministic RNG; the paper's timing claims are
weight-independent) and are passed as one flat int8 argument per segment,
shipped alongside the HLO as ``weights_<segment>.bin`` — keeping the HLO
text small and letting the rust side own parameter storage.

Quantization: per-layer power-of-two requantization shifts chosen from the
layer's accumulation depth K so activations keep a healthy int8 dynamic
range (VTA/TVM use the same shift-based scheme; exact scale values are
irrelevant to the reproduction).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .kernels import alu, conv2d as conv_mod, ref


# --------------------------------------------------------------------------
# Architecture description
# --------------------------------------------------------------------------

# (name, in_ch, out_ch, stride) for the 8 basic blocks of ResNet-18.
BASIC_BLOCKS = [
    ("s1b1", 64, 64, 1),
    ("s1b2", 64, 64, 1),
    ("s2b1", 64, 128, 2),
    ("s2b2", 128, 128, 1),
    ("s3b1", 128, 256, 2),
    ("s3b2", 256, 256, 1),
    ("s4b1", 256, 512, 2),
    ("s4b2", 512, 512, 1),
]

NUM_CLASSES = 1000
SEGMENT_NAMES = ["stem"] + [b[0] for b in BASIC_BLOCKS] + ["head"]


def shift_for_k(k: int) -> int:
    """Requantization shift for accumulation depth K.

    Products of two ~uniform int8 values have std ≈ 74²; summing K of them
    scales std by √K. Shifting by ``6 + log2(√K)`` keeps the steady-state
    activation std in the 18–42 range through all 8 blocks (verified by
    ``test_activations_not_saturated``) without collapsing to zero.
    """
    return 6 + max(0, round(0.5 * math.log2(max(k, 1))))


#: Requantization shift applied after the residual add. The sum of two
#: int8 paths needs only a clip (shift 0) — shifting by 1 would halve the
#: signal every block and collapse deep activations.
RESIDUAL_SHIFT = 0


@dataclass
class ModelConfig:
    """Knobs shared by the AOT exporter, pytest and the rust manifest."""

    input_hw: int = 224
    batch: int = 1
    num_classes: int = NUM_CLASSES
    impl: str = "pallas"  # "pallas" | "ref" — backing GEMM implementation
    block: int = 128  # Pallas GEMM tile (TPU MXU-native 128; VTA core is 16)
    seed: int = 2023

    def __post_init__(self):
        assert self.impl in ("pallas", "ref")
        assert self.input_hw >= 32 and self.input_hw % 32 == 0


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


@dataclass
class ParamSpec:
    name: str
    shape: tuple
    offset: int  # into the segment's flat weight vector

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclass
class SegmentSpec:
    """Everything the exporter + rust runtime need to know about a segment."""

    name: str
    index: int
    in_shape: tuple
    out_shape: tuple
    out_dtype: str
    params: list[ParamSpec] = field(default_factory=list)
    macs: int = 0

    @property
    def param_bytes(self) -> int:
        return sum(p.size for p in self.params)


def _conv_macs(oh: int, ow: int, oc: int, kh: int, kw: int, c: int, n: int = 1) -> int:
    return n * oh * ow * oc * kh * kw * c


def _head_hw(hw: int) -> int:
    """Spatial size entering the head = input_hw / 32 (stem /4, stages /8)."""
    return hw // 32


def build_segment_specs(cfg: ModelConfig) -> list[SegmentSpec]:
    """Static shape/param/MAC inventory for all 10 segments."""
    specs: list[SegmentSpec] = []
    hw = cfg.input_hw
    n = cfg.batch

    # --- stem: conv7x7/2 (pad 3) + maxpool3x3/2 (pad 1)
    stem_out_hw = hw // 4
    stem = SegmentSpec(
        name="stem",
        index=0,
        in_shape=(n, hw, hw, 3),
        out_shape=(n, stem_out_hw, stem_out_hw, 64),
        out_dtype="int8",
    )
    stem.params.append(ParamSpec("conv1", (64, 7, 7, 3), 0))
    stem.macs = _conv_macs(hw // 2, hw // 2, 64, 7, 7, 3, n)
    specs.append(stem)

    # --- 8 basic blocks
    cur_hw = stem_out_hw
    for i, (bname, cin, cout, stride) in enumerate(BASIC_BLOCKS):
        out_hw = cur_hw // stride
        seg = SegmentSpec(
            name=bname,
            index=i + 1,
            in_shape=(n, cur_hw, cur_hw, cin),
            out_shape=(n, out_hw, out_hw, cout),
            out_dtype="int8",
        )
        off = 0
        w1 = ParamSpec("conv1", (cout, 3, 3, cin), off)
        off += w1.size
        w2 = ParamSpec("conv2", (cout, 3, 3, cout), off)
        off += w2.size
        seg.params = [w1, w2]
        if stride != 1 or cin != cout:
            wd = ParamSpec("downsample", (cout, 1, 1, cin), off)
            off += wd.size
            seg.params.append(wd)
        seg.macs = (
            _conv_macs(out_hw, out_hw, cout, 3, 3, cin, n)
            + _conv_macs(out_hw, out_hw, cout, 3, 3, cout, n)
            + (
                _conv_macs(out_hw, out_hw, cout, 1, 1, cin, n)
                if len(seg.params) == 3
                else 0
            )
        )
        specs.append(seg)
        cur_hw = out_hw

    # --- head: global avgpool + dense
    head = SegmentSpec(
        name="head",
        index=9,
        in_shape=(n, cur_hw, cur_hw, 512),
        out_shape=(n, cfg.num_classes),
        out_dtype="int32",
    )
    head.params = [ParamSpec("fc", (cfg.num_classes, 512), 0)]
    head.macs = n * 512 * cfg.num_classes
    specs.append(head)
    return specs


def init_segment_weights(cfg: ModelConfig, spec: SegmentSpec) -> np.ndarray:
    """Deterministic flat int8 weight vector for one segment."""
    rng = np.random.default_rng(cfg.seed * 1000 + spec.index)
    return rng.integers(-128, 128, spec.param_bytes, dtype=np.int8)


def _unpack(wflat: jnp.ndarray, p: ParamSpec) -> jnp.ndarray:
    return wflat[p.offset : p.offset + p.size].reshape(p.shape)


# --------------------------------------------------------------------------
# Forward functions (per segment)
# --------------------------------------------------------------------------


def _relu(acc: jnp.ndarray, impl: str) -> jnp.ndarray:
    return alu.relu(acc) if impl == "pallas" else ref.relu_ref(acc)


def _requant(acc: jnp.ndarray, shift: int, impl: str) -> jnp.ndarray:
    if impl == "pallas":
        return alu.requantize(acc, shift)
    return ref.requantize_ref(acc, shift)


def _conv(x, w, stride, pad, cfg: ModelConfig) -> jnp.ndarray:
    return conv_mod.conv2d(x, w, stride=stride, pad=pad, impl=cfg.impl, block=cfg.block)


def stem_fn(cfg: ModelConfig, spec: SegmentSpec) -> Callable:
    (p_conv1,) = spec.params
    k = 7 * 7 * 3
    shift = shift_for_k(k)

    def fn(x: jnp.ndarray, wflat: jnp.ndarray):
        w = _unpack(wflat, p_conv1)
        acc = _conv(x, w, stride=2, pad=3, cfg=cfg)
        acc = _relu(acc, cfg.impl)
        y = _requant(acc, shift, cfg.impl)
        return (ref.maxpool_ref(y, k=3, stride=2, pad=1),)

    return fn


def basic_block_fn(cfg: ModelConfig, spec: SegmentSpec, stride: int) -> Callable:
    has_down = len(spec.params) == 3
    p1, p2 = spec.params[0], spec.params[1]
    pd = spec.params[2] if has_down else None
    k1 = int(np.prod(p1.shape[1:]))
    k2 = int(np.prod(p2.shape[1:]))
    s1, s2 = shift_for_k(k1), shift_for_k(k2)

    def fn(x: jnp.ndarray, wflat: jnp.ndarray):
        w1 = _unpack(wflat, p1)
        w2 = _unpack(wflat, p2)
        acc1 = _conv(x, w1, stride=stride, pad=1, cfg=cfg)
        acc1 = _relu(acc1, cfg.impl)
        y1 = _requant(acc1, s1, cfg.impl)

        acc2 = _conv(y1, w2, stride=1, pad=1, cfg=cfg)
        y2 = _requant(acc2, s2, cfg.impl)

        if has_down:
            wd = _unpack(wflat, pd)
            kd = int(np.prod(pd.shape[1:]))
            iden = _requant(_conv(x, wd, stride=stride, pad=0, cfg=cfg),
                            shift_for_k(kd), cfg.impl)
        else:
            iden = x

        # residual: int32 add, ReLU, clip back to int8
        s = y2.astype(jnp.int32) + iden.astype(jnp.int32)
        s = _relu(s, cfg.impl)
        return (_requant(s, RESIDUAL_SHIFT, cfg.impl),)

    return fn


def head_fn(cfg: ModelConfig, spec: SegmentSpec) -> Callable:
    (p_fc,) = spec.params

    def fn(x: jnp.ndarray, wflat: jnp.ndarray):
        wfc = _unpack(wflat, p_fc)
        pooled = ref.global_avgpool_ref(x)  # (N, 512) int32
        act = _requant(pooled, 0, cfg.impl)  # avg of int8 is already in range
        logits = conv_mod.dense(act, wfc, impl=cfg.impl, block=cfg.block)
        return (logits,)

    return fn


def segment_fn(cfg: ModelConfig, spec: SegmentSpec) -> Callable:
    """Forward function ``(x, wflat) -> (y,)`` for one segment."""
    if spec.name == "stem":
        return stem_fn(cfg, spec)
    if spec.name == "head":
        return head_fn(cfg, spec)
    stride = next(b[3] for b in BASIC_BLOCKS if b[0] == spec.name)
    return basic_block_fn(cfg, spec, stride)


def full_fn(cfg: ModelConfig, specs: list[SegmentSpec]) -> Callable:
    """Whole-network forward: ``(x, w0, w1, ..., w9) -> (logits,)``."""
    fns = [segment_fn(cfg, s) for s in specs]

    def fn(x: jnp.ndarray, *wflats: jnp.ndarray):
        assert len(wflats) == len(fns)
        y = x
        for f, w in zip(fns, wflats):
            (y,) = f(y, w)
        return (y,)

    return fn


# --------------------------------------------------------------------------
# Reference end-to-end (oracle for tests)
# --------------------------------------------------------------------------


def run_reference(cfg: ModelConfig, x: np.ndarray, weights: list[np.ndarray]) -> np.ndarray:
    """Run the whole model with impl='ref' regardless of cfg.impl."""
    ref_cfg = ModelConfig(
        input_hw=cfg.input_hw,
        batch=cfg.batch,
        num_classes=cfg.num_classes,
        impl="ref",
        block=cfg.block,
        seed=cfg.seed,
    )
    specs = build_segment_specs(ref_cfg)
    y = jnp.asarray(x)
    for spec, w in zip(specs, weights):
        (y,) = segment_fn(ref_cfg, spec)(y, jnp.asarray(w))
    return np.asarray(y)
