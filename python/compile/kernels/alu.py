"""VTA ALU (vector unit) as a Pallas kernel.

VTA's second tensor engine is an element-wise ALU over the int32
accumulator register file: ADD / MAX / MIN with a tensor or immediate
second operand, and SHR (arithmetic shift right) for fixed-point
requantization. On TPU these are VPU (8×128 vector lane) operations; the
kernel tiles the flattened accumulator into (rows, 128)-lane blocks in
VMEM.

All ops match :mod:`.ref` bit-exactly (pytest enforces it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-native tile is 8 sublanes × 128 lanes; one grid step processes a
# (256, 128) block = 32 VPU tiles (128 KiB of int32 — comfortably inside
# VMEM, and few enough grid steps that interpret-mode stays fast).
_TILE_ROWS = 256
_TILE_LANES = 128

OPS = ("add", "max", "min", "shr")


def _alu_tt_kernel(a_ref, b_ref, o_ref, *, op: str):
    a = a_ref[...]
    b = b_ref[...]
    if op == "add":
        o_ref[...] = a + b
    elif op == "max":
        o_ref[...] = jnp.maximum(a, b)
    elif op == "min":
        o_ref[...] = jnp.minimum(a, b)
    elif op == "shr":
        o_ref[...] = jnp.right_shift(a, b)
    else:  # pragma: no cover - guarded by OPS check in alu()
        raise ValueError(op)


def _alu_imm_kernel(a_ref, o_ref, *, op: str, imm: int):
    a = a_ref[...]
    b = jnp.full_like(a, imm)
    if op == "add":
        o_ref[...] = a + b
    elif op == "max":
        o_ref[...] = jnp.maximum(a, b)
    elif op == "min":
        o_ref[...] = jnp.minimum(a, b)
    elif op == "shr":
        o_ref[...] = jnp.right_shift(a, b)
    else:  # pragma: no cover
        raise ValueError(op)


def _to_lanes(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Flatten to (rows, _TILE_LANES), zero-padding the tail."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = max(1, -(-n // _TILE_LANES))
    rows = -(-rows // _TILE_ROWS) * _TILE_ROWS
    padded = jnp.pad(flat, (0, rows * _TILE_LANES - n))
    return padded.reshape(rows, _TILE_LANES), n


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def alu(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    op: str,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tensor-tensor ALU op on int32 accumulators. Shapes must match."""
    assert op in OPS, f"unknown ALU op {op!r}"
    assert a.shape == b.shape, f"ALU operand shapes differ: {a.shape} vs {b.shape}"
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    at, n = _to_lanes(a32)
    bt, _ = _to_lanes(b32)
    grid = (at.shape[0] // _TILE_ROWS,)
    out = pl.pallas_call(
        functools.partial(_alu_tt_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0)),
            pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(at.shape, jnp.int32),
        interpret=interpret,
    )(at, bt)
    return out.reshape(-1)[:n].reshape(a.shape)


@functools.partial(jax.jit, static_argnames=("op", "imm", "interpret"))
def alu_imm(
    a: jnp.ndarray,
    *,
    op: str,
    imm: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tensor-immediate ALU op (VTA's IMM-mode instructions)."""
    assert op in OPS, f"unknown ALU op {op!r}"
    a32 = a.astype(jnp.int32)
    at, n = _to_lanes(a32)
    grid = (at.shape[0] // _TILE_ROWS,)
    out = pl.pallas_call(
        functools.partial(_alu_imm_kernel, op=op, imm=imm),
        grid=grid,
        in_specs=[pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(at.shape, jnp.int32),
        interpret=interpret,
    )(at)
    return out.reshape(-1)[:n].reshape(a.shape)


def relu(a: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """ReLU = ALU MAX immediate 0, as TVM lowers it for VTA."""
    return alu_imm(a, op="max", imm=0, interpret=interpret)


def _requant_kernel(a_ref, o_ref, *, shift: int):
    """Fused VTA requant micro-sequence: ADD bias → SHR → clip.

    VTA issues these as three ALU instructions on the resident accumulator
    tile; fusing them into one kernel mirrors that residency (one VMEM
    round-trip) instead of three HBM round-trips.
    """
    x = a_ref[...]
    if shift > 0:
        x = x + (1 << (shift - 1))
        x = jnp.right_shift(x, shift)
    x = jnp.minimum(x, 127)
    x = jnp.maximum(x, -128)
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("shift", "interpret"))
def requantize(
    acc: jnp.ndarray, shift: int, *, interpret: bool = True
) -> jnp.ndarray:
    """int32 → int8: round-half-up shift + clip (== ref.requantize_ref)."""
    x = acc.astype(jnp.int32)
    at, n = _to_lanes(x)
    grid = (at.shape[0] // _TILE_ROWS,)
    out = pl.pallas_call(
        functools.partial(_requant_kernel, shift=shift),
        grid=grid,
        in_specs=[pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_TILE_ROWS, _TILE_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(at.shape, jnp.int32),
        interpret=interpret,
    )(at)
    return out.reshape(-1)[:n].reshape(acc.shape).astype(jnp.int8)
