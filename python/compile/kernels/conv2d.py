"""int8 conv2d lowered onto the VTA GEMM core (im2col + Pallas GEMM).

TVM lowers 2-D convolutions for VTA by blocking them into the GEMM tensor
intrinsic; we do the same: an im2col patch-matrix (the layout the VTA load
module produces when it walks the input feature map) followed by the
:mod:`.gemm` Pallas kernel.

``impl`` selects the backing GEMM:

* ``"pallas"`` — the real Pallas kernel (interpret=True on CPU). Used for
  kernel-level artifacts and correctness tests.
* ``"ref"``    — the pure-jnp oracle. Numerically identical; XLA fuses it
  into a dense int32 matmul, which is what the full-model artifacts use so
  the CPU-PJRT serving path stays fast. The choice is recorded per
  artifact in its manifest (see aot.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .gemm import gemm


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
    *,
    impl: str = "pallas",
    block: int = 16,
) -> jnp.ndarray:
    """int8 NHWC conv: x (N,H,W,C), w (OC,KH,KW,C) → int32 (N,OH,OW,OC)."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    assert impl in ("pallas", "ref"), impl
    n, h, width, c = x.shape
    oc, kh, kw, wc = w.shape
    assert wc == c, f"channel mismatch {wc} != {c}"
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (width + 2 * pad - kw) // stride + 1

    patches = ref.im2col_ref(x, kh, kw, stride, pad)  # (N·OH·OW, KH·KW·C)
    wmat = w.reshape(oc, kh * kw * c)
    if impl == "pallas":
        acc = gemm(patches, wmat, block_m=block, block_n=block, block_k=block)
    else:
        acc = ref.gemm_ref(patches, wmat)
    return acc.reshape(n, oh, ow, oc)


def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    *,
    impl: str = "pallas",
    block: int = 16,
) -> jnp.ndarray:
    """Dense layer on the GEMM core: (M,K)·(N,K)ᵀ + bias → int32 (M,N)."""
    assert impl in ("pallas", "ref"), impl
    if impl == "pallas":
        acc = gemm(x, w, block_m=block, block_n=block, block_k=block)
    else:
        acc = ref.gemm_ref(x, w)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    return acc
