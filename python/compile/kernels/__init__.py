"""Layer-1 kernels: the VTA compute engines as Pallas kernels.

* :mod:`.gemm`   — the GEMM tensor core (int8×int8→int32, Table I geometry)
* :mod:`.alu`    — the element-wise ALU / requantization engine
* :mod:`.conv2d` — conv/dense lowered onto the GEMM core (im2col)
* :mod:`.ref`    — pure-jnp oracles (ground truth for pytest + rust fsim)
"""

from . import alu, conv2d, gemm, ref  # noqa: F401
from .alu import alu as alu_op  # noqa: F401
from .alu import alu_imm, relu, requantize  # noqa: F401
from .conv2d import conv2d as conv2d_op  # noqa: F401
from .conv2d import dense as dense_op  # noqa: F401
from .gemm import gemm as gemm_op  # noqa: F401
