"""Pure-jnp reference oracles for the VTA-style kernels.

These are the *correctness ground truth* for the Pallas kernels in
``gemm.py`` / ``alu.py`` / ``conv2d.py`` and for the rust functional
simulator (``rust/src/vta/fsim.rs``): every implementation must match these
semantics bit-exactly.

VTA semantics (Moreau et al., IEEE Micro'19, mirrored by the paper's
Table I):

* GEMM: ``acc[i, j] += sum_k inp[i, k] * wgt[j, k]`` — inputs int8,
  accumulator int32, weight matrix stored **output-major** (OC, IC).
* ALU: element-wise ops on the int32 accumulator register file:
  ADD / MAX / MIN with tensor or immediate operand, SHR (arithmetic
  shift right, used for fixed-point requantization).
* Requantize: arithmetic shift with round-half-up followed by clip to
  int8 — the sequence TVM emits for VTA.
"""

from __future__ import annotations

import jax.numpy as jnp

INT8_MIN = -128
INT8_MAX = 127


def gemm_ref(inp: jnp.ndarray, wgt: jnp.ndarray) -> jnp.ndarray:
    """VTA GEMM: ``(M, K) int8 × (N, K) int8 → (M, N) int32``.

    Weight is output-major ``(N, K)`` exactly as in the VTA weight buffer,
    so the contraction is ``inp @ wgt.T``.
    """
    assert inp.dtype == jnp.int8 and wgt.dtype == jnp.int8
    return jnp.matmul(inp.astype(jnp.int32), wgt.astype(jnp.int32).T)


def alu_add_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """VTA ALU ADD over int32 accumulators (wrapping, as in hardware)."""
    return (a.astype(jnp.int32) + b.astype(jnp.int32)).astype(jnp.int32)


def alu_max_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(a.astype(jnp.int32), b.astype(jnp.int32))


def alu_min_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(a.astype(jnp.int32), b.astype(jnp.int32))


def alu_shr_ref(a: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Arithmetic shift right (the VTA SHR opcode). ``shift`` may be 0."""
    return jnp.right_shift(a.astype(jnp.int32), shift)


def relu_ref(a: jnp.ndarray) -> jnp.ndarray:
    """ReLU as VTA lowers it: ALU MAX with immediate 0."""
    return alu_max_ref(a, jnp.zeros((), jnp.int32))


def requantize_ref(acc: jnp.ndarray, shift: int) -> jnp.ndarray:
    """int32 accumulator → int8 activation.

    Round-half-up via ``+ (1 << (shift-1))`` then arithmetic shift, then
    clip to the int8 range — the sequence TVM emits for VTA.
    """
    acc = acc.astype(jnp.int32)
    if shift > 0:
        acc = acc + (1 << (shift - 1))
        acc = jnp.right_shift(acc, shift)
    return jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


def dense_ref(
    inp: jnp.ndarray, wgt: jnp.ndarray, bias: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Dense layer: GEMM + optional int32 bias, returns int32 accumulators."""
    acc = gemm_ref(inp, wgt)
    if bias is not None:
        acc = alu_add_ref(acc, bias.astype(jnp.int32)[None, :])
    return acc


def im2col_ref(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int) -> jnp.ndarray:
    """NHWC → (N·OH·OW, KH·KW·C) patch matrix (int8), zero-padded.

    This is the exact layout ``conv2d.py`` feeds to the GEMM kernel and the
    layout the rust lowering assumes when counting DRAM traffic.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            cols.append(patch.reshape(n * oh * ow, c))
    # (N·OH·OW, KH·KW·C) with kernel position-major, channel-minor order.
    return jnp.concatenate(cols, axis=1)


def conv2d_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
) -> jnp.ndarray:
    """int8 NHWC conv: x (N,H,W,C), w (OC,KH,KW,C) → int32 (N,OH,OW,OC).

    Implemented as im2col + GEMM so it is structurally identical to the
    Pallas path (and to how TVM lowers conv onto the VTA GEMM core).
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    n, h, width, c = x.shape
    oc, kh, kw, wc = w.shape
    assert wc == c, f"channel mismatch {wc} != {c}"
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (width + 2 * pad - kw) // stride + 1
    patches = im2col_ref(x, kh, kw, stride, pad)  # (N·OH·OW, KH·KW·C)
    wmat = w.reshape(oc, kh * kw * c)
    acc = gemm_ref(patches, wmat)  # (N·OH·OW, OC)
    return acc.reshape(n, oh, ow, oc)


def maxpool_ref(x: jnp.ndarray, k: int, stride: int, pad: int = 0) -> jnp.ndarray:
    """Max-pool on int8 NHWC (VTA runs pooling on the ALU)."""
    n, h, w, c = x.shape
    xp = jnp.pad(
        x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), constant_values=INT8_MIN
    )
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = jnp.full((n, oh, ow, c), INT8_MIN, jnp.int8)
    for i in range(k):
        for j in range(k):
            patch = xp[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            out = jnp.maximum(out, patch)
    return out


def global_avgpool_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool, integer arithmetic: int32 sum then floor-divide.

    VTA lowers this as an ALU ADD reduction + SHR; the kernel implementation
    uses the same integer sum-then-divide so results are bit-exact.
    """
    n, h, w, c = x.shape
    s = jnp.sum(x.astype(jnp.int32), axis=(1, 2))
    return (s // (h * w)).astype(jnp.int32)
