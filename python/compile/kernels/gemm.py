"""VTA GEMM core as a Pallas kernel.

The paper's compute hot-spot is VTA's GEMM tensor intrinsic: a
``BATCH × BLOCK_IN × BLOCK_OUT`` int8 matrix-multiply with int32
accumulation, fed from on-chip SRAM buffers (Table I: BLOCK = 16,
INPUT_WIDTH = WEIGHT_WIDTH = 8 bit, ACCUMULATOR_WIDTH = 32 bit).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): on TPU the
intrinsic maps onto the MXU systolic array, and the input/weight/acc SRAM
buffers map onto VMEM blocks expressed through ``BlockSpec``. The grid
iterates output tiles (i, j) and reduction tiles (k); Pallas pipelines the
HBM→VMEM loads against compute exactly as VTA's load/compute modules
overlap through their RAW/WAR dependency queues.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md). The kernel is still
written as it would lower for a real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VTA Table I geometry: BLOCK_SIZE=16 → a 16×16 GEMM core. The Pallas tile
# defaults mirror that; the autotuned "big config" of §IV uses 32.
DEFAULT_BLOCK = 16


def _gemm_kernel(x_ref, w_ref, o_ref):
    """One grid step: accumulate an (bm, bk)·(bn, bk)ᵀ tile product.

    ``o_ref`` maps to the same output tile for every reduction step ``k``
    (its index_map ignores the k axis), mirroring VTA's resident
    accumulator buffer: initialise at k == 0, accumulate afterwards.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # int8 × int8 → int32 contraction — the MXU-native form
    # (preferred_element_type=int32 is what VTA's accumulator width means).
    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def _ceil_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """``(M, K) int8 × (N, K) int8 → (M, N) int32`` via the Pallas kernel.

    Semantics identical to :func:`ref.gemm_ref` (weight output-major, as in
    the VTA weight buffer). Arbitrary shapes are zero-padded up to tile
    multiples and sliced back — zero padding is exact for integer GEMM.
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[1], (
        f"gemm shape mismatch: {x.shape} vs {w.shape}"
    )
    m, k = x.shape
    n, _ = w.shape
    mp, np_, kp = _ceil_to(m, block_m), _ceil_to(n, block_n), _ceil_to(k, block_k)
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, np_, kp)

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            # input buffer tile: row tile i, reduction tile k
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            # weight buffer tile: output-channel tile j, reduction tile k
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
        ],
        # accumulator tile is resident across the reduction axis
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def gemm_vmem_bytes(block_m: int, block_n: int, block_k: int) -> dict:
    """Static VMEM footprint of one grid step, for the §Perf analysis.

    Mirrors VTA's buffer budget: input tile (int8) + weight tile (int8) +
    accumulator tile (int32), double-buffered by the Pallas pipeline.
    """
    inp = block_m * block_k  # int8
    wgt = block_n * block_k  # int8
    acc = block_m * block_n * 4  # int32
    return {
        "input_bytes": inp,
        "weight_bytes": wgt,
        "acc_bytes": acc,
        "total_bytes": inp + wgt + acc,
        "double_buffered_bytes": 2 * (inp + wgt) + acc,
    }
