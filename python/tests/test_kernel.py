"""Pallas GEMM kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (bucketed so the jit cache is reused), block
geometries (including VTA Table I BLOCK=16 and the §IV big-config 32), and
extreme int8 values. Equality is exact: integer GEMM has one right answer.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm, ref

# Bucketed dims: exercise 1, sub-block, exact-block, off-by-one and
# multi-block shapes while keeping the jit/trace cache warm.
DIMS = st.sampled_from([1, 2, 7, 8, 15, 16, 17, 31, 32, 33, 48])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int8))


@settings(max_examples=30, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (m, k))
    w = _rand_i8(rng, (n, k))
    got = gemm.gemm(x, w)
    want = ref.gemm_ref(x, w)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    block=st.sampled_from([8, 16, 32]),
    seed=SEEDS,
)
def test_gemm_block_geometries(block, seed):
    """Table I BLOCK=16 and §IV big-config BLOCK=32 (plus 8) agree."""
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (24, 40))
    w = _rand_i8(rng, (18, 40))
    got = gemm.gemm(x, w, block_m=block, block_n=block, block_k=block)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.gemm_ref(x, w))
    )


def test_gemm_mixed_block_shape():
    """Rectangular tiles (the TPU adaptation uses (128,128) MXU tiles)."""
    rng = np.random.default_rng(7)
    x = _rand_i8(rng, (130, 260))
    w = _rand_i8(rng, (70, 260))
    got = gemm.gemm(x, w, block_m=128, block_n=128, block_k=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gemm_ref(x, w)))


def test_gemm_extreme_values_saturate_nothing():
    """All-(-128) × all-(-128): largest magnitude products, int32 exact."""
    k = 64
    x = jnp.full((16, k), -128, jnp.int8)
    w = jnp.full((16, k), -128, jnp.int8)
    got = gemm.gemm(x, w)
    assert int(got[0, 0]) == (-128) * (-128) * k
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gemm_ref(x, w)))


def test_gemm_identity():
    """x @ Iᵀ == x (weight is output-major so identity works directly)."""
    rng = np.random.default_rng(3)
    x = _rand_i8(rng, (16, 16))
    eye = jnp.eye(16, dtype=jnp.int8)
    got = gemm.gemm(x, eye)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x, dtype=np.int32))


def test_gemm_zero_weight():
    x = _rand_i8(np.random.default_rng(4), (17, 23))
    w = jnp.zeros((9, 23), jnp.int8)
    assert not np.asarray(gemm.gemm(x, w)).any()


def test_gemm_rejects_shape_mismatch():
    x = jnp.zeros((4, 8), jnp.int8)
    w = jnp.zeros((4, 9), jnp.int8)
    with pytest.raises(AssertionError):
        gemm.gemm(x, w)


def test_gemm_vmem_budget_table1():
    """Table I buffer budget: a 16×16×16 step fits trivially; report it."""
    fp = gemm.gemm_vmem_bytes(16, 16, 16)
    assert fp["input_bytes"] == 256
    assert fp["weight_bytes"] == 256
    assert fp["acc_bytes"] == 1024
    # Paper buffers: input 32 Kb, weight 256 Kb, acc 128 Kb (kilobits).
    assert fp["input_bytes"] <= 32 * 1024 // 8
    assert fp["weight_bytes"] <= 256 * 1024 // 8
    assert fp["acc_bytes"] <= 128 * 1024 // 8
