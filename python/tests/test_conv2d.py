"""conv2d / dense on the GEMM core: Pallas path vs oracle, plus pooling."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, ref

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand_i8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int8))


@settings(max_examples=20, deadline=None)
@given(
    hw=st.sampled_from([4, 7, 8, 14]),
    c=st.sampled_from([1, 3, 8]),
    oc=st.sampled_from([4, 16]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1]),
    seed=SEEDS,
)
def test_conv2d_pallas_matches_ref(hw, c, oc, k, stride, pad, seed):
    if hw + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (1, hw, hw, c))
    w = _rand_i8(rng, (oc, k, k, c))
    got = conv2d.conv2d(x, w, stride=stride, pad=pad, impl="pallas")
    want = ref.conv2d_ref(x, w, stride=stride, pad=pad)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_ref_impl_identical_to_pallas_impl():
    """impl='ref' and impl='pallas' must be interchangeable per-artifact."""
    rng = np.random.default_rng(11)
    x = _rand_i8(rng, (2, 9, 9, 5))
    w = _rand_i8(rng, (7, 3, 3, 5))
    a = conv2d.conv2d(x, w, stride=1, pad=1, impl="pallas")
    b = conv2d.conv2d(x, w, stride=1, pad=1, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_conv2d_batch_dim():
    rng = np.random.default_rng(12)
    x = _rand_i8(rng, (3, 8, 8, 4))
    w = _rand_i8(rng, (6, 3, 3, 4))
    got = conv2d.conv2d(x, w, stride=1, pad=1, impl="pallas")
    assert got.shape == (3, 8, 8, 6)
    # each batch element independent
    one = conv2d.conv2d(x[1:2], w, stride=1, pad=1, impl="ref")
    np.testing.assert_array_equal(np.asarray(got[1:2]), np.asarray(one))


def test_conv2d_1x1_is_pointwise_gemm():
    rng = np.random.default_rng(13)
    x = _rand_i8(rng, (1, 6, 6, 8))
    w = _rand_i8(rng, (10, 1, 1, 8))
    got = conv2d.conv2d(x, w, impl="pallas")
    want = ref.gemm_ref(x.reshape(36, 8), w.reshape(10, 8)).reshape(1, 6, 6, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 16, 30]),
    k=st.sampled_from([8, 16, 33]),
    n=st.sampled_from([10, 16]),
    with_bias=st.booleans(),
    seed=SEEDS,
)
def test_dense_matches_ref(m, k, n, with_bias, seed):
    rng = np.random.default_rng(seed)
    x = _rand_i8(rng, (m, k))
    w = _rand_i8(rng, (n, k))
    bias = (
        jnp.asarray(rng.integers(-(2**15), 2**15, (n,), dtype=np.int32))
        if with_bias
        else None
    )
    got = conv2d.dense(x, w, bias, impl="pallas")
    want = ref.dense_ref(x, w, bias)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_layout_contract():
    """The (kernel-position-major, channel-minor) layout the rust lowering
    assumes when counting buffer traffic: column block (i·KW + j)·C + c."""
    x = jnp.arange(2 * 3 * 3 * 2, dtype=jnp.int8).reshape(2, 3, 3, 2) % 100
    p = ref.im2col_ref(x, kh=2, kw=2, stride=1, pad=0)
    assert p.shape == (2 * 2 * 2, 2 * 2 * 2)
    # patch (n=0, oh=0, ow=0), kernel pos (1,1), channel 1 == x[0,1,1,1]
    col = (1 * 2 + 1) * 2 + 1
    assert int(p[0, col]) == int(x[0, 1, 1, 1])


def test_maxpool_matches_naive():
    rng = np.random.default_rng(14)
    x = _rand_i8(rng, (1, 6, 6, 3))
    got = np.asarray(ref.maxpool_ref(x, k=2, stride=2))
    xn = np.asarray(x)
    for i in range(3):
        for j in range(3):
            win = xn[0, 2 * i : 2 * i + 2, 2 * j : 2 * j + 2, :]
            np.testing.assert_array_equal(got[0, i, j], win.max(axis=(0, 1)))


def test_maxpool_padding_uses_int8_min():
    x = jnp.full((1, 2, 2, 1), -100, jnp.int8)
    out = ref.maxpool_ref(x, k=3, stride=2, pad=1)
    # window centred on data must ignore the -128 padding
    assert int(out.max()) == -100


def test_global_avgpool_integer_division():
    x = jnp.ones((1, 7, 7, 4), jnp.int8) * 3
    out = ref.global_avgpool_ref(x)
    assert out.shape == (1, 4)
    assert int(out[0, 0]) == 3  # (3·49)//49
    # floor division check: values summing to 50 over 49 elements -> 1
    x2 = np.zeros((1, 7, 7, 1), np.int8)
    x2[0, 0, 0, 0] = 50
    assert int(ref.global_avgpool_ref(jnp.asarray(x2))[0, 0]) == 1
