"""L2 model tests: segment specs, weight packing, forward semantics."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_cfg():
    return model.ModelConfig(input_hw=32, impl="ref")


@pytest.fixture(scope="module")
def tiny_specs(tiny_cfg):
    return model.build_segment_specs(tiny_cfg)


def test_segment_inventory(tiny_specs):
    assert [s.name for s in tiny_specs] == model.SEGMENT_NAMES
    assert len(tiny_specs) == 10
    assert [s.index for s in tiny_specs] == list(range(10))


def test_segment_shapes_chain(tiny_specs):
    """Each segment's output shape must equal the next segment's input."""
    for a, b in zip(tiny_specs, tiny_specs[1:]):
        assert a.out_shape == b.in_shape, (a.name, b.name)


def test_resnet18_total_macs_224():
    """ResNet-18 @224 is ~1.81 GMACs (the standard published figure)."""
    specs = model.build_segment_specs(model.ModelConfig(input_hw=224))
    total = sum(s.macs for s in specs)
    assert 1.7e9 < total < 1.9e9, total


def test_resnet18_total_params():
    """~11.2M conv+fc weights (no biases/BN in the int8 deployment)."""
    specs = model.build_segment_specs(model.ModelConfig(input_hw=224))
    total = sum(s.param_bytes for s in specs)
    assert 10.5e6 < total < 12e6, total


def test_param_offsets_are_dense(tiny_specs):
    """Flat weight vectors must be exactly covered by the param specs."""
    for s in tiny_specs:
        off = 0
        for p in s.params:
            assert p.offset == off, (s.name, p.name)
            off += p.size
        assert off == s.param_bytes


def test_downsample_blocks_have_three_params(tiny_specs):
    by_name = {s.name: s for s in tiny_specs}
    for bname, cin, cout, stride in model.BASIC_BLOCKS:
        expected = 3 if (stride != 1 or cin != cout) else 2
        assert len(by_name[bname].params) == expected, bname


def test_weights_deterministic(tiny_cfg, tiny_specs):
    a = model.init_segment_weights(tiny_cfg, tiny_specs[3])
    b = model.init_segment_weights(tiny_cfg, tiny_specs[3])
    np.testing.assert_array_equal(a, b)
    c = model.init_segment_weights(
        model.ModelConfig(input_hw=32, impl="ref", seed=7), tiny_specs[3]
    )
    assert not np.array_equal(a, c)


def test_shift_for_k_monotone():
    ks = [1, 9, 64, 576, 1152, 4608]
    shifts = [model.shift_for_k(k) for k in ks]
    assert shifts == sorted(shifts)
    assert shifts[0] >= 6 and shifts[-1] <= 13


def test_segment_forward_shapes(tiny_cfg, tiny_specs):
    rng = np.random.default_rng(0)
    for spec in tiny_specs:
        x = jnp.asarray(rng.integers(-128, 128, spec.in_shape, dtype=np.int8))
        w = jnp.asarray(model.init_segment_weights(tiny_cfg, spec))
        (y,) = model.segment_fn(tiny_cfg, spec)(x, w)
        assert tuple(y.shape) == spec.out_shape, spec.name
        assert str(y.dtype) == spec.out_dtype, spec.name


def test_full_fn_equals_segment_chain(tiny_cfg, tiny_specs):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-128, 128, tiny_specs[0].in_shape, dtype=np.int8))
    ws = [
        jnp.asarray(model.init_segment_weights(tiny_cfg, s)) for s in tiny_specs
    ]
    (full,) = model.full_fn(tiny_cfg, tiny_specs)(x, *ws)
    y = x
    for spec, w in zip(tiny_specs, ws):
        (y,) = model.segment_fn(tiny_cfg, spec)(y, w)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(y))


def test_pallas_impl_matches_ref_impl_tiny():
    """The headline L2 signal: pallas-backed model == ref-backed model."""
    cfg_p = model.ModelConfig(input_hw=32, impl="pallas")
    specs = model.build_segment_specs(cfg_p)
    ws = [model.init_segment_weights(cfg_p, s) for s in specs]
    rng = np.random.default_rng(2)
    x = rng.integers(-128, 128, (1, 32, 32, 3), dtype=np.int8)

    y = jnp.asarray(x)
    for spec, w in zip(specs, ws):
        (y,) = model.segment_fn(cfg_p, spec)(y, jnp.asarray(w))
    want = model.run_reference(cfg_p, x, ws)
    np.testing.assert_array_equal(np.asarray(y), want)


def test_activations_not_saturated(tiny_cfg, tiny_specs):
    """Requant shifts must keep activations in a healthy dynamic range:
    neither all-clipped (|x|=127 everywhere) nor collapsed to zero."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-128, 128, tiny_specs[0].in_shape, dtype=np.int8))
    y = x
    for spec, in_spec in zip(tiny_specs[:-1], tiny_specs[:-1]):
        w = jnp.asarray(model.init_segment_weights(tiny_cfg, spec))
        (y,) = model.segment_fn(tiny_cfg, spec)(y, w)
        vals = np.asarray(y)
        frac_clipped = np.mean(np.abs(vals) == 127)
        assert frac_clipped < 0.8, (spec.name, frac_clipped)
        assert vals.std() > 1.0, (spec.name, vals.std())


def test_residual_identity_path():
    """Non-downsample block with zero conv weights == relu(x): the identity
    path must pass through untouched (clip is a no-op on int8 values)."""
    cfg = model.ModelConfig(input_hw=32, impl="ref")
    specs = model.build_segment_specs(cfg)
    s1b2 = specs[2]
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(-128, 128, s1b2.in_shape, dtype=np.int8))
    w = jnp.zeros((s1b2.param_bytes,), jnp.int8)
    (y,) = model.segment_fn(cfg, s1b2)(x, w)
    want = ref.requantize_ref(ref.relu_ref(x.astype(jnp.int32)), model.RESIDUAL_SHIFT)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
