"""AOT exporter tests: HLO text round-trips and manifest integrity.

These run the actual lowering path on the tiny model (the 224 variant is
exercised by `make artifacts`); they verify the HLO text parses back and
executes with the right numerics *in python*, which is exactly the contract
the rust loader (`rust/src/runtime/`) relies on.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_cfg():
    # ref impl: identical numerics to pallas (enforced elsewhere), fast to lower.
    return model.ModelConfig(input_hw=32, impl="ref")


def test_to_hlo_text_roundtrip_parses(tiny_cfg):
    """Lower a segment, parse the text back, and check the program shape.

    jaxlib exposes no HLO-text *compile* API, so numeric execution of the
    text is verified on the rust side (`rust/tests/integration_runtime.rs`)
    against the test vectors exported by aot.py. Here we close the
    structural half: the text must re-parse into a module whose entry
    signature matches the lowered function.
    """
    specs = model.build_segment_specs(tiny_cfg)
    spec = specs[1]  # s1b1
    fn = model.segment_fn(tiny_cfg, spec)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(spec.in_shape, jnp.int8),
        jax.ShapeDtypeStruct((spec.param_bytes,), jnp.int8),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text

    mod = xc._xla.hlo_module_from_text(text)  # raises on parse error
    comp = xc._xla.XlaComputation(mod.as_serialized_hlo_module_proto())
    ps = comp.program_shape()
    # two int8 parameters: activation tensor + flat weights
    assert len(ps.parameter_shapes()) == 2
    assert list(ps.parameter_shapes()[0].dimensions()) == list(spec.in_shape)
    assert list(ps.parameter_shapes()[1].dimensions()) == [spec.param_bytes]
    # tuple-wrapped single int8 output of the segment's shape
    (out,) = ps.result_shape().tuple_shapes()
    assert list(out.dimensions()) == list(spec.out_shape)


def test_hlo_text_has_no_serialized_proto_markers(tiny_cfg):
    """Guard the interchange contract: text, parseable, single module."""
    specs = model.build_segment_specs(tiny_cfg)
    fn = model.segment_fn(tiny_cfg, specs[0])
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(specs[0].in_shape, jnp.int8),
        jax.ShapeDtypeStruct((specs[0].param_bytes,), jnp.int8),
    )
    text = aot.to_hlo_text(lowered)
    assert text.count("HloModule") == 1
    assert text.startswith("HloModule")
    # ROOT of entry must be a tuple (return_tuple=True contract with rust)
    assert "ROOT" in text


ARTIFACTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS_DIR, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for a in manifest["artifacts"]:
            if a["kind"] == "test_vector":
                files = [a["input_file"], a["output_file"]]
            else:
                files = [a["file"]]
                files += a.get("weights_files", [])
                if "weights_file" in a:
                    files.append(a["weights_file"])
            for f in files:
                assert os.path.exists(os.path.join(ARTIFACTS_DIR, f)), (a["name"], f)

    def test_test_vectors_reference_real_artifacts(self, manifest):
        names = {a["name"] for a in manifest["artifacts"] if a["kind"] != "test_vector"}
        tvs = [a for a in manifest["artifacts"] if a["kind"] == "test_vector"]
        assert len(tvs) == 11  # 10 segments + full
        for tv in tvs:
            assert tv["artifact"] in names, tv["name"]

    def test_weights_files_match_param_bytes(self, manifest):
        for a in manifest["artifacts"]:
            if "weights_file" in a:
                sz = os.path.getsize(os.path.join(ARTIFACTS_DIR, a["weights_file"]))
                assert sz == a["param_bytes"], a["name"]

    def test_segment_chain_shapes(self, manifest):
        segs = sorted(
            (a for a in manifest["artifacts"]
             if a["kind"] == "segment" and a["input_hw"] == 224
             and "fast_" not in a["name"]),
            key=lambda a: a["segment_index"],
        )
        assert [s["segment"] for s in segs] == model.SEGMENT_NAMES
        for a, b in zip(segs, segs[1:]):
            assert a["outputs"][0]["shape"] == b["inputs"][0]["shape"]

    def test_total_macs_matches_model(self, manifest):
        segs = [
            a for a in manifest["artifacts"]
            if a["kind"] == "segment" and a["input_hw"] == 224
            and "fast_" not in a["name"]
        ]
        assert sum(s["macs"] for s in segs) == manifest["model"]["total_macs"]

    def test_fast_variant_complete(self, manifest):
        fast = [
            a for a in manifest["artifacts"]
            if a["kind"] == "segment" and "fast_" in a["name"]
        ]
        # 10 segments × two input sizes (224 + tiny 32)
        assert len(fast) == 20
        assert all(a["impl"] == "ref" for a in fast)
