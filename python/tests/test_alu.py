"""VTA ALU Pallas kernel vs oracle: add/max/min/shr, imm mode, requantize."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import alu, ref

SHAPES = st.sampled_from([(1,), (5,), (128,), (129,), (7, 9), (16, 16), (3, 4, 5)])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)
OPS = st.sampled_from(["add", "max", "min"])


def _rand_i32(rng, shape, lo=-(2**24), hi=2**24):
    return jnp.asarray(rng.integers(lo, hi, shape, dtype=np.int32))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, op=OPS, seed=SEEDS)
def test_alu_tensor_tensor(shape, op, seed):
    rng = np.random.default_rng(seed)
    a = _rand_i32(rng, shape)
    b = _rand_i32(rng, shape)
    got = alu.alu(a, b, op=op)
    want = {"add": ref.alu_add_ref, "max": ref.alu_max_ref, "min": ref.alu_min_ref}[
        op
    ](a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, shift=st.integers(min_value=0, max_value=31), seed=SEEDS)
def test_alu_shr(shape, shift, seed):
    rng = np.random.default_rng(seed)
    a = _rand_i32(rng, shape, lo=-(2**30), hi=2**30)
    got = alu.alu_imm(a, op="shr", imm=shift)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.alu_shr_ref(a, shift))
    )


@settings(max_examples=20, deadline=None)
@given(
    shape=SHAPES,
    op=OPS,
    imm=st.integers(min_value=-1000, max_value=1000),
    seed=SEEDS,
)
def test_alu_immediate(shape, op, imm, seed):
    rng = np.random.default_rng(seed)
    a = _rand_i32(rng, shape)
    b = jnp.full(shape, imm, jnp.int32)
    got = alu.alu_imm(a, op=op, imm=imm)
    want = {"add": ref.alu_add_ref, "max": ref.alu_max_ref, "min": ref.alu_min_ref}[
        op
    ](a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, shift=st.integers(min_value=0, max_value=16), seed=SEEDS)
def test_requantize(shape, shift, seed):
    rng = np.random.default_rng(seed)
    a = _rand_i32(rng, shape)
    got = alu.requantize(a, shift)
    want = ref.requantize_ref(a, shift)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requantize_shift_zero_is_pure_clip():
    a = jnp.asarray([-1000, -128, -1, 0, 1, 127, 1000], jnp.int32)
    got = alu.requantize(a, 0)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray([-128, -128, -1, 0, 1, 127, 127], np.int8)
    )


def test_requantize_rounds_half_up():
    # 3 >> 1 with +1 rounding bias: (3+1)>>1 = 2 ; plain >> gives 1.
    a = jnp.asarray([3], jnp.int32)
    assert int(alu.requantize(a, 1)[0]) == 2
    # negative: (-3+1)>>1 = -1 (arithmetic shift floors)
    a = jnp.asarray([-3], jnp.int32)
    assert int(alu.requantize(a, 1)[0]) == -1


def test_relu_matches_ref():
    a = jnp.asarray([[-5, 0, 7], [2**20, -(2**20), 1]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(alu.relu(a)), np.asarray(ref.relu_ref(a))
    )


def test_alu_add_wraps_like_hardware():
    """int32 overflow wraps (two's complement), same as the VTA datapath."""
    a = jnp.asarray([2**31 - 1], jnp.int32)
    b = jnp.asarray([1], jnp.int32)
    got = alu.alu(a, b, op="add")
    assert int(got[0]) == -(2**31)
