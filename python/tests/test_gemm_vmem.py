"""§Perf L1: static VMEM/MXU analysis of the Pallas GEMM kernel.

interpret=True gives no hardware timings, so the kernel's TPU efficiency
is assessed structurally (DESIGN.md §9): tile shapes must be MXU-native,
VMEM footprints must fit the budget, and the VTA Table-I geometry must
map onto it. These tests pin that analysis.
"""

from hypothesis import given, settings, strategies as st

from compile.kernels import gemm

# A real TPU core has ~16 MiB VMEM; a production kernel double-buffers
# inputs and keeps the accumulator resident.
TPU_VMEM_BYTES = 16 * 1024 * 1024


def test_vta_geometry_footprint():
    fp = gemm.gemm_vmem_bytes(16, 16, 16)
    assert fp["total_bytes"] == 256 + 256 + 1024
    assert fp["double_buffered_bytes"] == 2 * 512 + 1024


def test_mxu_native_tile_fits_comfortably():
    # the TPU-adapted 128×128×128 tile used by the model artifacts
    fp = gemm.gemm_vmem_bytes(128, 128, 128)
    assert fp["double_buffered_bytes"] < TPU_VMEM_BYTES // 100


@settings(max_examples=50, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128, 256]),
    bn=st.sampled_from([8, 16, 32, 64, 128, 256]),
    bk=st.sampled_from([8, 16, 32, 64, 128, 256]),
)
def test_footprint_formula_consistent(bm, bn, bk):
    fp = gemm.gemm_vmem_bytes(bm, bn, bk)
    assert fp["input_bytes"] == bm * bk
    assert fp["weight_bytes"] == bn * bk
    assert fp["acc_bytes"] == bm * bn * 4
    assert (
        fp["double_buffered_bytes"]
        == 2 * (fp["input_bytes"] + fp["weight_bytes"]) + fp["acc_bytes"]
    )
    # any tile up to 256³ is far inside VMEM
    assert fp["double_buffered_bytes"] < TPU_VMEM_BYTES


def test_arithmetic_intensity_grows_with_tile():
    """MXU utilization estimate: MACs per VMEM byte moved per step must
    grow with the tile edge — the roofline argument for 128-tiles."""
    def intensity(b):
        fp = gemm.gemm_vmem_bytes(b, b, b)
        return (b * b * b) / fp["total_bytes"]

    assert intensity(128) > intensity(32) > intensity(16)
    # 16³ tile: 4096 MACs / 1536 B ≈ 2.7 MAC/B; 128³: ≈ 21 MAC/B
    assert intensity(16) < 4.0
    assert intensity(128) > 20.0
