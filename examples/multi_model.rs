//! Multi-model workloads on one shared cluster (DESIGN.md §7, E9).
//!
//! ```bash
//! cargo run --release --example multi_model
//! cargo run --release --example multi_model -- --nodes 16
//! ```
//!
//! The paper's cluster "can simultaneously execute diverse Neural
//! Network models". This example walks that claim end to end with the
//! workload registry:
//!
//! 1. every zoo model is scheduled by all four §II-C strategies on the
//!    same cluster, showing the best strategy is *model-dependent*;
//! 2. three tenants (ResNet-18, LeNet-5, the MLP) then share one node
//!    budget — the budget is split by service demand, each tenant keeps
//!    its own strategy, and the calibrated simulator prices every
//!    pipeline, yielding a per-model serving report.

use vta_cluster::config::{BoardFamily, Calibration, VtaConfig};
use vta_cluster::coordinator::{simulate_tenants, TenantRequest};
use vta_cluster::exp::runner::Bench;
use vta_cluster::graph::zoo;
use vta_cluster::runtime::artifacts_dir;
use vta_cluster::sched::Strategy;
use vta_cluster::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("multi_model", "multi-model / multi-tenant demo")
        .opt("nodes", "12", "shared node budget")
        .opt("images", "32", "images per tenant")
        .opt("seed", "7", "seed for the loaded-latency DES runs")
        .parse()?;
    let budget = args.get_usize("nodes")?;
    let images = args.get_usize("images")?;
    let seed = args.get_u64("seed")?;
    let calib = Calibration::load_or_default(&artifacts_dir());

    // ---- 1. per-model strategy comparison -----------------------------
    println!("=== every zoo model × every §II-C strategy (4 nodes, Zynq-7000) ===");
    for spec in &zoo::MODELS {
        let mut b = Bench::for_model(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            calib.clone(),
            spec.name,
            0,
        )?;
        b.images = images;
        print!("{:16}", spec.name);
        let mut best = (f64::INFINITY, Strategy::ScatterGather);
        for s in Strategy::all() {
            let ms = b.cell(s, 4)?.ms_per_image;
            if ms < best.0 {
                best = (ms, s);
            }
            print!("  {:>10.3}", ms);
        }
        println!("  ← best: {}", best.1);
    }
    println!(
        "{:16}  {:>10}  {:>10}  {:>10}  {:>10}   (ms/image)\n",
        "", "sg", "ai-core", "pipeline", "fused"
    );

    // ---- 2. three tenants share one budget ----------------------------
    println!("=== {budget}-node budget shared by three tenants ===");
    let tenants = [
        TenantRequest {
            model: "resnet18".into(),
            input_hw: 224,
            strategy: Strategy::Fused,
            images,
        },
        TenantRequest {
            model: "lenet5".into(),
            input_hw: 0,
            strategy: Strategy::ScatterGather,
            images,
        },
        TenantRequest {
            model: "mlp".into(),
            input_hw: 0,
            strategy: Strategy::Pipeline,
            images,
        },
    ];
    let out = simulate_tenants(
        BoardFamily::Zynq7000,
        VtaConfig::table1_zynq7000(),
        calib,
        budget,
        &tenants,
        seed,
    )?;
    for t in &out {
        println!(
            "{:16} {:2} nodes  {:22} {:>9.3} ms/image  {:>9.2} img/s  latency {:>8.3} ms  p99 {:>8.3} ms",
            t.model,
            t.nodes,
            t.plan.strategy.to_string(),
            t.sim.ms_per_image,
            t.report.throughput_img_per_sec,
            t.report.mean_latency_ms,
            t.report.p99_latency_ms,
        );
    }
    let used: usize = out.iter().map(|t| t.nodes).sum();
    println!("budget used: {used}/{budget} nodes  (loaded-latency DES seed {seed})");
    Ok(())
}
