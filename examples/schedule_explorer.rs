//! AutoTVM-analog schedule exploration, interactively.
//!
//! ```bash
//! cargo run --release --example schedule_explorer -- --m 784 --k 1152 --n 128
//! ```
//!
//! Enumerates every feasible VTA tiling for a GEMM shape on both Table-I
//! configurations and the §IV big config, prices each with the cycle
//! model, and prints the Pareto view (cycles vs DRAM traffic) plus the
//! winner — the exploration §III credits for the 27.34 ms micro-kernel.

use vta_cluster::compiler::{candidate_tilings, lower_gemm, GemmShape};
use vta_cluster::config::{BoardProfile, Calibration, VtaConfig};
use vta_cluster::runtime::artifacts_dir;
use vta_cluster::util::cli::Cli;
use vta_cluster::vta::timing::TimingModel;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("schedule_explorer", "VTA GEMM schedule search")
        .opt("m", "784", "GEMM M (rows)")
        .opt("k", "1152", "GEMM K (reduction)")
        .opt("n", "128", "GEMM N (output channels)")
        .opt("top", "8", "show the best T schedules")
        .parse()?;
    let shape = GemmShape {
        m: args.get_u64("m")?,
        k: args.get_u64("k")?,
        n: args.get_u64("n")?,
    };
    let top = args.get_usize("top")?;
    let calib = Calibration::load_or_default(&artifacts_dir());

    for (cfg, board) in [
        (VtaConfig::table1_zynq7000(), BoardProfile::zynq7020()),
        (VtaConfig::table1_ultrascale(), BoardProfile::zu_mpsoc()),
        (VtaConfig::big_config_200mhz(), BoardProfile::zu_mpsoc()),
    ] {
        let model = TimingModel::new(cfg.clone(), board, calib.clone());
        let (mr, kb, nb) = shape.blocks(&cfg);
        let cands = candidate_tilings(&cfg, mr, kb, nb);
        let mut scored = Vec::new();
        for tiling in cands {
            let prog = lower_gemm("explore", shape, tiling, &cfg)?;
            let report = model.price(&prog)?;
            scored.push((tiling, report));
        }
        scored.sort_by_key(|(_, r)| r.total_cycles);
        println!(
            "\n=== {} — GEMM ({}, {}, {}): {} feasible schedules ===",
            cfg.name, shape.m, shape.k, shape.n,
            scored.len()
        );
        println!(
            "{:>18} | {:>10} | {:>10} | {:>6} | {:>9}",
            "tiling (tm,tk,tn)", "kcycles", "DRAM KiB", "util%", "bound"
        );
        for (tiling, r) in scored.iter().take(top) {
            println!(
                "{:>18} | {:>10.1} | {:>10.1} | {:>5.1} | {:>9}",
                format!("({},{},{})", tiling.tm, tiling.tk, tiling.tn),
                r.total_cycles as f64 / 1e3,
                r.dram_bytes as f64 / 1024.0,
                r.compute_utilization() * 100.0,
                if r.memory_bound() { "memory" } else { "compute" },
            );
        }
        let (best, best_r) = &scored[0];
        let (worst, worst_r) = &scored[scored.len() - 1];
        println!(
            "search win: {:.1}x (best ({},{},{}) vs worst ({},{},{}))",
            worst_r.total_cycles as f64 / best_r.total_cycles as f64,
            best.tm, best.tk, best.tn,
            worst.tm, worst.tk, worst.tn,
        );
    }
    Ok(())
}
