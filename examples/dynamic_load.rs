//! Dynamic load and online reconfiguration (DESIGN.md §10, E10).
//!
//! ```bash
//! cargo run --release --example dynamic_load
//! cargo run --release --example dynamic_load -- --nodes 4 --seed 7
//! ```
//!
//! The paper's cluster is *reconfigurable*: when the load changes, the
//! boards can be reprogrammed with a different schedule. This example
//! makes "when is it worth reconfiguring?" measurable:
//!
//! 1. price the four §II-C strategies analytically (capacity + unloaded
//!    latency) — the controller's candidate set;
//! 2. drive the paper's small-N worst case (AI core assignment) with a
//!    bursty MMPP arrival stream through the discrete-event simulator,
//!    once with the reconfiguration controller off and once with it on;
//! 3. compare p99 latency: the controller switches to the
//!    highest-capacity plan when the burst overloads the standing plan,
//!    paying the modeled bitstream-load + warm-up downtime, and the tail
//!    collapses — the downtime is visible in the report.

use vta_cluster::config::{
    BoardFamily, BoardProfile, Calibration, ClusterConfig, ReconfigCost, VtaConfig,
};
use vta_cluster::graph::zoo;
use vta_cluster::runtime::artifacts_dir;
use vta_cluster::sched::{plan_options, ControllerConfig, OnlineController, Strategy};
use vta_cluster::sim::{run_des, ArrivalProcess, CostModel, DesConfig, DesResult};
use vta_cluster::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("dynamic_load", "DES + online reconfiguration walkthrough")
        .opt("model", "resnet18", "zoo model to serve")
        .opt("nodes", "4", "cluster size")
        .opt("horizon", "20000", "simulated horizon, ms")
        .opt("seed", "7", "RNG seed (same seed → bit-identical run)")
        .parse()?;
    let model = args.get("model");
    let nodes = args.get_usize("nodes")?;
    let horizon_ms = args.get_f64("horizon")?;
    let seed = args.get_u64("seed")?;

    // 1. candidate plans, priced by the steady-state simulator
    let family = BoardFamily::Zynq7000;
    let calib = Calibration::load_or_default(&artifacts_dir());
    let g = zoo::build(model, 0)?;
    let vta = VtaConfig::table1_zynq7000();
    let mut cost = CostModel::new(vta.clone(), BoardProfile::for_family(family), calib);
    let cluster = ClusterConfig::homogeneous(family, nodes).with_vta(vta);
    let options = plan_options(&g, &cluster, &mut cost, &Strategy::all())?;
    println!("candidate plans for {model} on {nodes} nodes:");
    for o in &options {
        println!(
            "  {:22} capacity {:8.1} img/s  unloaded latency {:7.3} ms",
            o.plan.strategy.to_string(),
            o.capacity_img_per_sec,
            o.latency_ms
        );
    }

    // 2. a bursty stream sized against the *initial* (mismatched) plan
    let initial = options
        .iter()
        .position(|o| o.plan.strategy == Strategy::CoreAssign)
        .unwrap();
    let cap0 = options[initial].capacity_img_per_sec;
    // the same stream `vtacluster load --arrival burst --rate 0` runs
    let arrival = ArrivalProcess::parse("burst", 0.55 * cap0, 4.0)?;
    println!("\narrival: {}  (initial plan: ai-core-assignment)", arrival.describe());
    let cfg = DesConfig::new(arrival, horizon_ms, seed);

    let run = |cost: &mut CostModel, ctrl: Option<&mut OnlineController>| {
        run_des(&options, initial, &cluster, cost, &g, &cfg, ctrl)
    };
    let report = |tag: &str, r: &DesResult| {
        println!(
            "{tag:16} completed {:5}/{:5}  p50 {:8.2} ms  p99 {:9.2} ms  \
             reconfigs {} (downtime {:.0} ms)",
            r.completed,
            r.offered,
            r.latency_ms.p50(),
            r.latency_ms.p99(),
            r.reconfigs.len(),
            r.downtime_ms,
        );
    };

    // 3. controller off vs on — same seed, same arrivals
    let off = run(&mut cost, None)?;
    let mut ctrl = OnlineController::new(
        ControllerConfig::default(),
        ReconfigCost::for_family(family),
    )?;
    let on = run(&mut cost, Some(&mut ctrl))?;
    println!();
    report("controller off", &off);
    report("controller on", &on);
    for e in &on.reconfigs {
        println!(
            "    at {:7.0} ms: {} → {} ({:.0} ms downtime) — {}",
            e.at_ms, e.from_strategy, e.to_strategy, e.downtime_ms, e.reason
        );
    }
    if on.latency_ms.p99() < off.latency_ms.p99() {
        println!(
            "\nreconfiguring paid off: p99 {:.1} ms → {:.1} ms ({:.1}× better) \
             for {:.0} ms of charged downtime",
            off.latency_ms.p99(),
            on.latency_ms.p99(),
            off.latency_ms.p99() / on.latency_ms.p99(),
            on.downtime_ms,
        );
    } else {
        println!("\nthe standing plan survived this trace — no tail win to collect");
    }
    Ok(())
}
