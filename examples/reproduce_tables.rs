//! Reproduce every table and figure of the paper in one run.
//!
//! ```bash
//! cargo run --release --example reproduce_tables
//! ```
//!
//! Prints Fig. 3(a) (Zynq-7000, 1–12 FPGAs), Fig. 4(a) (UltraScale+,
//! 1–5), and the §IV scaling experiments, each next to the paper's
//! published numbers with per-cell relative error — the same output the
//! `cargo bench` targets produce, packaged as a single runnable example.

use vta_cluster::config::{BoardFamily, Calibration, VtaConfig};
use vta_cluster::exp::runner::Bench;
use vta_cluster::exp::{paper, table};
use vta_cluster::runtime::artifacts_dir;
use vta_cluster::sched::Strategy;

fn main() -> anyhow::Result<()> {
    let calib = Calibration::load_or_default(&artifacts_dir());
    println!("calibration: {}\n", calib.to_json().to_string_compact());

    // ---- Fig. 3 -------------------------------------------------------
    let mut zynq = Bench::zynq(calib.clone());
    zynq.images = 64;
    let rows3 = zynq.sweep(12)?;
    println!(
        "{}",
        table::render_vs_paper(
            "Fig. 3(a) Zynq-7000: execution time (ms)",
            &rows3,
            &paper::FIG3_ZYNQ7000_MS
        )
    );

    // ---- Fig. 4 -------------------------------------------------------
    let mut us = Bench::ultrascale(calib.clone());
    us.images = 64;
    let rows4 = us.sweep(5)?;
    println!(
        "{}",
        table::render_vs_paper(
            "Fig. 4(a) UltraScale+: execution time (ms)",
            &rows4,
            &paper::FIG4_ULTRASCALE_MS
        )
    );

    // ---- §IV ----------------------------------------------------------
    let single = |vta: VtaConfig| -> anyhow::Result<f64> {
        let mut b = Bench::new(BoardFamily::UltraScalePlus, vta, calib.clone());
        b.images = 32;
        Ok(b.cell(Strategy::ScatterGather, 1)?.ms_per_image)
    };
    let base = single(VtaConfig::table1_ultrascale())?;
    let at350 = single(VtaConfig::ultrascale_350mhz())?;
    let big = single(VtaConfig::big_config_200mhz())?;
    println!("§IV scaling (UltraScale+ single node):");
    println!("  Table I @300 MHz : {base:6.2} ms   (paper 25.15)");
    println!(
        "  350 MHz          : {at350:6.2} ms   ({:+.1}%; paper ≈{:.1}%)",
        (base - at350) / base * 100.0,
        paper::CLOCK_350_SPEEDUP * 100.0
    );
    println!(
        "  big config       : {big:6.2} ms   ({:+.1}%; paper ≈{:.1}%)",
        (base - big) / base * 100.0,
        paper::BIG_CONFIG_SPEEDUP * 100.0
    );

    // ---- summary ------------------------------------------------------
    let e3 = table::errors(&rows3, &paper::FIG3_ZYNQ7000_MS);
    let e4 = table::errors(&rows4, &paper::FIG4_ULTRASCALE_MS);
    println!("\nreproduction quality (mean rel. error per strategy):");
    println!("  Fig.3: SG {:4.0}% | AI {:4.0}% | Pipe {:4.0}% | Fused {:4.0}%", e3[0]*100.0, e3[1]*100.0, e3[2]*100.0, e3[3]*100.0);
    println!("  Fig.4: SG {:4.0}% | AI {:4.0}% | Pipe {:4.0}% | Fused {:4.0}%", e4[0]*100.0, e4[1]*100.0, e4[2]*100.0, e4[3]*100.0);
    Ok(())
}
