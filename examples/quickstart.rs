//! Quickstart: build a cluster, pick a strategy, get the paper's metric.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the public API end to end: workload graph → calibrated cost
//! model → execution plan → cluster simulation, printing the simulated
//! per-image inference time for a 4-board Zynq-7000 stack under each of
//! the paper's four scheduling strategies.

use vta_cluster::config::{BoardProfile, Calibration, ClusterConfig, VtaConfig};
use vta_cluster::graph::resnet::build_resnet18;
use vta_cluster::runtime::artifacts_dir;
use vta_cluster::scenario::{ScenarioSpec, Session};
use vta_cluster::sched::{build_plan_priced, Strategy};
use vta_cluster::sim::{simulate, CostModel, SimConfig};

fn main() -> anyhow::Result<()> {
    // 1. the workload: int8 ResNet-18 at the paper's 224×224 input
    let graph = build_resnet18(224)?;
    println!(
        "workload: {} ({:.2} GMACs, {} segments)",
        graph.name,
        graph.total_macs() as f64 / 1e9,
        graph.segment_order().len()
    );

    // 2. the cluster: four Zynq-7020 boards, Table-I VTA bitstream,
    //    1 Gb/s switch — §II of the paper
    let n = 4;
    let cluster = ClusterConfig::zynq_stack(n);
    cluster.validate()?;
    println!("cluster: {} ({} nodes)", cluster.name, cluster.num_nodes());

    // 3. the calibrated node cost model (fitted constants are loaded from
    //    artifacts/calibration.json if `vtacluster calibrate` has run)
    let calib = Calibration::load_or_default(&artifacts_dir());
    let mut cost = CostModel::new(
        VtaConfig::table1_zynq7000(),
        BoardProfile::zynq7020(),
        calib,
    );
    let t1 = cost.graph_time_ns(&graph)? as f64 / 1e6;
    println!("single-node compute: {t1:.2} ms/image\n");

    // 4. all four strategies over the same cluster, priced through the
    //    shared segment-cost table (a missing label is a reported error)
    let seg_costs = cost.seg_cost_table(&graph)?;
    for strategy in Strategy::all() {
        let plan = build_plan_priced(strategy, &graph, n, &seg_costs)?;
        let result = simulate(&plan, &cluster, &mut cost, &graph, &SimConfig::default())?;
        println!(
            "{:22} {:6.2} ms/image  (latency {:6.2} ms, busiest node {:3.0}%)",
            strategy.to_string(),
            result.ms_per_image,
            result.latency_ms.mean(),
            result.node_utilization.iter().fold(0.0f64, |a, &b| a.max(b)) * 100.0
        );
    }

    // 5. the same cell as a declarative scenario (DESIGN.md §12): one
    //    JSON-round-trippable spec → Session → unified Report
    let spec = ScenarioSpec::parse(
        r#"{"model": "resnet18", "strategy": "pipeline", "family": "zynq", "nodes": 4}"#,
    )?;
    let report = Session::new(spec)?.run()?;
    let row = &report.rows[0];
    println!(
        "\nscenario '{}': {} → {:.2} ms/image, p99 {:.2} ms, {:.1} W, {:.4} J/image",
        report.scenario, row.strategy, row.ms_per_image, row.p99_ms, row.cluster_avg_w,
        row.j_per_image
    );
    Ok(())
}
