//! End-to-end driver (DESIGN.md E8): the full three-layer system serving
//! real batched inference requests.
//!
//! ```bash
//! make artifacts && cargo run --release --example pipeline_serving
//! # paper-size input (slower):
//! cargo run --release --example pipeline_serving -- --input-hw 224 --images 8
//! ```
//!
//! This is the proof that the layers compose: JAX/Pallas AOT artifacts
//! (L1+L2) are loaded through PJRT and served by the rust coordinator
//! (L3) under a real pipeline execution plan — batched requests, worker
//! threads per simulated FPGA node, latency/throughput reported, and the
//! logits verified against the python-exported reference vector.

use vta_cluster::coordinator::Coordinator;
use vta_cluster::graph::resnet::{build_resnet18, segment_macs};
use vta_cluster::graph::tensor::DType;
use vta_cluster::runtime::{artifacts_dir, Manifest, TensorData};
use vta_cluster::sched::{pipeline, scatter_gather};
use vta_cluster::util::cli::Cli;
use vta_cluster::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("pipeline_serving", "end-to-end PJRT serving demo")
        .opt("input-hw", "32", "input size (32 tiny / 224 paper)")
        .opt("images", "64", "batch size")
        .opt("stages", "4", "pipeline depth")
        .parse()?;
    let input_hw: u64 = args.get_u64("input-hw")?;
    let images = args.get_usize("images")?;
    let stages = args.get_usize("stages")?;

    anyhow::ensure!(
        artifacts_dir().join("manifest.json").exists(),
        "run `make artifacts` first (artifacts at {})",
        artifacts_dir().display()
    );

    // MAC-balanced pipeline plan over the graph's 10 segments
    let g = build_resnet18(input_hw)?;
    let macs = segment_macs(&g);
    let cost = |l: &str| macs.iter().find(|(x, _)| x == l).unwrap().1 as f64;
    let plan = pipeline(&g, stages, cost)?;
    println!("{}", plan.describe());

    // serving-optimized artifacts (numerics identical to the pallas
    // reference — enforced by the integration tests)
    let coord = Coordinator::start_fast(artifacts_dir(), &plan, input_hw)?;

    let hw = input_hw as usize;
    let mut rng = Rng::new(7);
    let batch: Vec<TensorData> = (0..images)
        .map(|_| TensorData::i8(vec![1, hw, hw, 3], rng.i8_vec(hw * hw * 3)).unwrap())
        .collect();
    println!("serving {images} images of {hw}×{hw}×3 ...");
    let t0 = std::time::Instant::now();
    let (outs, report) = coord.run_batch(batch)?;
    println!(
        "pipeline×{stages}: {:.2} img/s | mean latency {:.1} ms | p99 {:.1} ms | wall {:.0} ms",
        report.throughput_img_per_sec,
        report.mean_latency_ms,
        report.p99_latency_ms,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // compare against single-stage scatter-gather on 2 replicas
    let sg_plan = scatter_gather(&g, 2)?;
    let sg = Coordinator::start_fast(artifacts_dir(), &sg_plan, input_hw)?;
    let mut rng = Rng::new(7);
    let batch: Vec<TensorData> = (0..images)
        .map(|_| TensorData::i8(vec![1, hw, hw, 3], rng.i8_vec(hw * hw * 3)).unwrap())
        .collect();
    let (_, sg_report) = sg.run_batch(batch)?;
    println!(
        "scatter-gather×2: {:.2} img/s | mean latency {:.1} ms",
        sg_report.throughput_img_per_sec, sg_report.mean_latency_ms
    );

    // verify numerics against the python-exported vector (tiny only —
    // the 224 reference vectors are not exported to keep artifacts small)
    if input_hw == 32 {
        let manifest = Manifest::load(&artifacts_dir())?;
        let tv = manifest
            .test_vectors
            .iter()
            .find(|t| t.name == "tv_tiny_full")
            .expect("test vector");
        let input = TensorData::from_bytes(
            tv.in_shape.clone(),
            DType::I8,
            &manifest.read_blob(&tv.input_file)?,
        )?;
        let want = TensorData::from_bytes(
            tv.out_shape.clone(),
            tv.out_dtype,
            &manifest.read_blob(&tv.output_file)?,
        )?;
        let (outs2, _) = coord.run_batch(vec![input])?;
        anyhow::ensure!(outs2[0] == want, "logits diverge from python reference!");
        println!("numerics: logits bit-exact vs python-exported reference ✓");
    }

    let l0 = outs[0].as_i32()?;
    let argmax = l0.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
    println!("first image: argmax class {argmax} (logit {})", l0[argmax]);
    Ok(())
}
