//! Power-capped serving under burst load (DESIGN.md §11, E11).
//!
//! ```bash
//! cargo run --release --example power_budget
//! cargo run --release --example power_budget -- --nodes 4 --budget 14
//! ```
//!
//! Edge deployments are usually wall-power-limited before they are
//! compute-limited. This example drives the same overloaded burst trace
//! through the DES twice:
//!
//! 1. **uncapped** — the online controller chases throughput and parks
//!    on the highest-capacity plan, saturating every node; the cluster
//!    draws its hungriest plan's wattage for the whole run;
//! 2. **power-capped** — the controller watches the EMA'd measured draw
//!    and sheds watts the moment it crosses `--budget`, downshifting to
//!    the lowest-saturated-draw candidate and refusing upgrades that
//!    would bust the budget.
//!
//! The printout shows the trade in both directions: the capped run
//! stays under budget (fewer watts, better J/image) while completing
//! fewer images — the Pareto frontier of `vtacluster power`, lived at
//! run time.

use vta_cluster::config::{
    BoardFamily, BoardProfile, Calibration, ClusterConfig, ReconfigCost, VtaConfig,
};
use vta_cluster::graph::zoo;
use vta_cluster::runtime::artifacts_dir;
use vta_cluster::sched::{plan_options, ControllerConfig, OnlineController, Strategy};
use vta_cluster::sim::{run_des, ArrivalProcess, CostModel, DesConfig, DesResult};
use vta_cluster::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("power_budget", "power-capped online reconfiguration walkthrough")
        .opt("model", "resnet18", "zoo model to serve")
        .opt("nodes", "4", "cluster size")
        .opt("budget", "0", "cluster power budget in W (0 = midpoint of the candidate draws)")
        .opt("horizon", "20000", "simulated horizon, ms")
        .opt("seed", "7", "RNG seed (same seed → bit-identical run)")
        .parse()?;
    let model = args.get("model");
    let nodes = args.get_usize("nodes")?;
    let horizon_ms = args.get_f64("horizon")?;
    let seed = args.get_u64("seed")?;

    let family = BoardFamily::Zynq7000;
    let calib = Calibration::load_or_default(&artifacts_dir());
    let g = zoo::build(model, 0)?;
    let vta = VtaConfig::table1_zynq7000();
    let mut cost = CostModel::new(vta.clone(), BoardProfile::for_family(family), calib);
    let cluster = ClusterConfig::homogeneous(family, nodes).with_vta(vta);
    let options = plan_options(&g, &cluster, &mut cost, &Strategy::all())?;
    println!("candidate plans for {model} on {nodes} nodes:");
    for o in &options {
        println!(
            "  {:22} capacity {:8.1} img/s  {:6.1} W saturated  {:7.4} J/image",
            o.plan.strategy.to_string(),
            o.capacity_img_per_sec,
            o.avg_power_w,
            o.j_per_image,
        );
    }

    // budget default: halfway between the frugal and hungry candidates
    let min_w = options.iter().map(|o| o.avg_power_w).fold(f64::INFINITY, f64::min);
    let max_w = options.iter().map(|o| o.avg_power_w).fold(0.0f64, f64::max);
    let budget = match args.get_f64("budget")? {
        b if b > 0.0 => b,
        _ => (min_w + max_w) / 2.0,
    };

    // a burst stream that keeps even the fastest plan overloaded: the
    // throughput-greedy controller has every reason to run hot
    let cap_best = options.iter().map(|o| o.capacity_img_per_sec).fold(0.0f64, f64::max);
    let initial = options
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.avg_power_w.partial_cmp(&b.1.avg_power_w).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let arrival = ArrivalProcess::Burst {
        base_per_sec: 1.2 * cap_best,
        burst_per_sec: 2.4 * cap_best,
        mean_on_ms: 1500.0,
        mean_off_ms: 2500.0,
    };
    println!(
        "\narrival: {}  — budget {budget:.1} W, initial plan {}",
        arrival.describe(),
        options[initial].plan.strategy,
    );
    let cfg = DesConfig::new(arrival, horizon_ms, seed);

    let mut run = |budget_w: Option<f64>| -> anyhow::Result<DesResult> {
        let mut ctrl = OnlineController::new(
            ControllerConfig { power_budget_w: budget_w, ..Default::default() },
            ReconfigCost::for_family(family),
        )?;
        run_des(&options, initial, &cluster, &mut cost, &g, &cfg, Some(&mut ctrl))
    };
    let uncapped = run(None)?;
    let capped = run(Some(budget))?;

    let report = |tag: &str, r: &DesResult| {
        println!(
            "{tag:16} completed {:5}/{:5}  avg {:6.1} W  peak {:6.1} W  \
             {:7.4} J/img  p99 {:9.2} ms  reconfigs {}",
            r.completed,
            r.offered,
            r.power.avg_cluster_w,
            r.power.peak_window_w,
            r.power.j_per_image,
            r.latency_ms.p99(),
            r.reconfigs.len(),
        );
    };
    println!();
    report("uncapped", &uncapped);
    report("capped", &capped);
    for e in &capped.reconfigs {
        println!(
            "    at {:7.0} ms: {} → {} — {}",
            e.at_ms, e.from_strategy, e.to_strategy, e.reason
        );
    }
    println!();
    if capped.power.avg_cluster_w <= budget {
        println!(
            "the cap held: {:.1} W ≤ {budget:.1} W budget (uncapped drew {:.1} W), \
             at the cost of {} fewer completed images",
            capped.power.avg_cluster_w,
            uncapped.power.avg_cluster_w,
            uncapped.completed.saturating_sub(capped.completed),
        );
    } else {
        println!(
            "cap missed on this trace: {:.1} W vs {budget:.1} W — rare with a budget \
             between the candidate draws; try a longer --horizon",
            capped.power.avg_cluster_w,
        );
    }
    Ok(())
}
