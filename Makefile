# Build entry points referenced throughout the docs and source comments.
#
#   make artifacts   — run the L2 AOT exporter (JAX/Pallas → HLO text +
#                      weight blobs + manifest) into rust/artifacts/,
#                      where the rust runtime and tests look for them
#                      ($VTA_ARTIFACTS overrides).
#   make test        — tier-1 verify (rust) + python unit tests if pytest
#                      is available.
#   make bench       — run the tracked bench suites and gate them against
#                      the checked-in baselines (rust/benches/baselines/,
#                      DESIGN.md §15).

ARTIFACTS ?= ../rust/artifacts

.PHONY: artifacts test rust-test python-test bench

artifacts:
	cd python && python3 -m compile.aot --out $(ARTIFACTS)

test: rust-test python-test

rust-test:
	cd rust && cargo build --release && cargo test -q

python-test:
	-python3 -m pytest -q python/tests

bench:
	cd rust && cargo build --release && ./target/release/vtacluster bench --check
