# Build entry points referenced throughout the docs and source comments.
#
#   make artifacts   — run the L2 AOT exporter (JAX/Pallas → HLO text +
#                      weight blobs + manifest) into rust/artifacts/,
#                      where the rust runtime and tests look for them
#                      ($VTA_ARTIFACTS overrides).
#   make test        — tier-1 verify (rust) + python unit tests if pytest
#                      is available.

ARTIFACTS ?= ../rust/artifacts

.PHONY: artifacts test rust-test python-test

artifacts:
	cd python && python3 -m compile.aot --out $(ARTIFACTS)

test: rust-test python-test

rust-test:
	cd rust && cargo build --release && cargo test -q

python-test:
	-python3 -m pytest -q python/tests
