//! Ethernet link model: serialization time of a message at line rate,
//! accounting for frame segmentation overhead.
//!
//! Every 1500-byte MTU payload carries 38 bytes of overhead on the wire
//! (14 header + 4 FCS + 8 preamble/SFD + 12 IFG), plus IP+TCP headers
//! (40 bytes) inside the payload — the usable payload per frame is 1460
//! bytes and the wire cost per frame is 1538 bytes.

use crate::util::units::{transfer_ns, Nanos};

pub const MTU_PAYLOAD: u64 = 1460; // TCP MSS
pub const WIRE_BYTES_PER_FRAME: u64 = 1538; // incl. preamble + IFG

#[derive(Debug, Clone, PartialEq)]
pub struct LinkModel {
    /// Line rate in bits/s (1 Gb/s in the paper's cluster).
    pub bits_per_sec: u64,
}

impl LinkModel {
    pub fn gigabit() -> Self {
        LinkModel { bits_per_sec: 1_000_000_000 }
    }

    pub fn new(bits_per_sec: u64) -> Self {
        LinkModel { bits_per_sec }
    }

    /// Number of Ethernet frames for a message payload.
    pub fn frames(&self, payload_bytes: u64) -> u64 {
        payload_bytes.div_ceil(MTU_PAYLOAD).max(1)
    }

    /// Bytes actually occupying the wire for a payload.
    pub fn wire_bytes(&self, payload_bytes: u64) -> u64 {
        self.frames(payload_bytes) * WIRE_BYTES_PER_FRAME
    }

    /// Serialization time of a payload at line rate.
    pub fn serialize_ns(&self, payload_bytes: u64) -> Nanos {
        transfer_ns(self.wire_bytes(payload_bytes), self.bits_per_sec)
    }

    /// Effective goodput in bytes/s (payload ÷ time), for reporting.
    pub fn goodput_bytes_per_sec(&self, payload_bytes: u64) -> f64 {
        let t = self.serialize_ns(payload_bytes) as f64 / 1e9;
        payload_bytes as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_counts() {
        let l = LinkModel::gigabit();
        assert_eq!(l.frames(1), 1);
        assert_eq!(l.frames(1460), 1);
        assert_eq!(l.frames(1461), 2);
        assert_eq!(l.frames(150_528), 104); // one 224×224×3 int8 image
    }

    #[test]
    fn gigabit_serialization_times() {
        let l = LinkModel::gigabit();
        // one full frame = 1538 B × 8 / 1e9 ≈ 12.3 µs
        let t = l.serialize_ns(1460);
        assert!((12_000..13_000).contains(&t), "{t}");
        // a 224² image ≈ 104 frames ≈ 1.28 ms
        let img = l.serialize_ns(224 * 224 * 3);
        assert!((1_200_000..1_350_000).contains(&img), "{img} ns");
    }

    #[test]
    fn goodput_below_line_rate() {
        let l = LinkModel::gigabit();
        let g = l.goodput_bytes_per_sec(1_000_000);
        assert!(g < 125_000_000.0, "goodput {g} ≥ line rate");
        assert!(g > 110_000_000.0, "goodput {g} implausibly low");
    }

    #[test]
    fn tiny_message_is_one_frame() {
        let l = LinkModel::gigabit();
        assert_eq!(l.wire_bytes(1), WIRE_BYTES_PER_FRAME);
        assert_eq!(l.wire_bytes(0), WIRE_BYTES_PER_FRAME); // control msg
    }
}
