//! Ethernet cluster network substrate.
//!
//! The paper attributes every scaling anomaly to this layer (§III): 1 Gb/s
//! links through a store-and-forward switch, blocking-MPI messages, and
//! the FPGA PS CPU having to DMA buffers out of the PL and push them
//! through the kernel network stack.
//!
//! * [`link`]   — Ethernet frame math: per-frame overhead at line rate
//! * [`mpi`]    — blocking send/recv cost model (rendezvous + DMA + wire)
//! * [`switch`] — store-and-forward switch with per-port contention

pub mod link;
pub mod mpi;
pub mod switch;

pub use link::LinkModel;
pub use mpi::MpiModel;
pub use switch::{Flow, SwitchSim};
