//! Store-and-forward Ethernet switch with per-port contention.
//!
//! The cluster shares one switch: the master's single 1 Gb/s port is the
//! serialization point for scatter/gather traffic (why scatter-gather
//! stops scaling past ~10 nodes), and node-to-node pipeline transfers
//! contend on their own ports. Modeled as one FIFO server per output
//! port at line rate — a message occupies its source's ingress port and
//! its destination's egress port for its wire time.

use super::link::LinkModel;
use crate::util::units::Nanos;

/// Endpoint id: the master host or a numbered FPGA node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Endpoint {
    Master,
    Node(usize),
}

/// One message to schedule through the switch.
#[derive(Debug, Clone)]
pub struct Flow {
    pub src: Endpoint,
    pub dst: Endpoint,
    pub bytes: u64,
    /// Earliest time the payload is ready to leave the sender.
    pub ready_ns: Nanos,
}

/// Result of scheduling a flow.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowTiming {
    /// When the last bit arrives at the destination.
    pub arrival_ns: Nanos,
    /// Time spent waiting for port availability (contention).
    pub queueing_ns: Nanos,
}

/// Incremental port-contention simulator. Feed flows in any order; each
/// `schedule` call books wire time on the source ingress and destination
/// egress ports and returns the arrival time.
#[derive(Debug, Clone)]
pub struct SwitchSim {
    link: LinkModel,
    forward_latency_ns: Nanos,
    /// Next-free time per endpoint port (ingress/egress modeled jointly —
    /// full-duplex is approximated by separate in/out bookkeeping).
    egress_free: std::collections::HashMap<Endpoint, Nanos>,
    ingress_free: std::collections::HashMap<Endpoint, Nanos>,
}

impl SwitchSim {
    pub fn new(link: LinkModel, forward_latency_ns: Nanos) -> Self {
        SwitchSim {
            link,
            forward_latency_ns,
            egress_free: Default::default(),
            ingress_free: Default::default(),
        }
    }

    /// Book a flow; returns arrival time at the destination.
    pub fn schedule(&mut self, flow: &Flow) -> FlowTiming {
        let wire = self.link.serialize_ns(flow.bytes);
        let src_free = *self.egress_free.get(&flow.src).unwrap_or(&0);
        let dst_free = *self.ingress_free.get(&flow.dst).unwrap_or(&0);
        let start = flow.ready_ns.max(src_free).max(dst_free);
        let queueing = start - flow.ready_ns;
        // store-and-forward: sender occupies its port for `wire`, the
        // switch forwards after latency, receiver port busy for `wire`.
        let sender_done = start + wire;
        let arrival = sender_done + self.forward_latency_ns;
        self.egress_free.insert(flow.src, sender_done);
        self.ingress_free.insert(flow.dst, arrival);
        FlowTiming { arrival_ns: arrival, queueing_ns: queueing }
    }

    /// When an endpoint's egress port frees up (for blocking senders).
    pub fn egress_free_at(&self, ep: Endpoint) -> Nanos {
        *self.egress_free.get(&ep).unwrap_or(&0)
    }

    pub fn reset(&mut self) {
        self.egress_free.clear();
        self.ingress_free.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SwitchSim {
        SwitchSim::new(LinkModel::gigabit(), 10_000)
    }

    #[test]
    fn single_flow_is_wire_plus_latency() {
        let mut s = sim();
        let f = Flow { src: Endpoint::Master, dst: Endpoint::Node(0), bytes: 1460, ready_ns: 0 };
        let t = s.schedule(&f);
        let wire = LinkModel::gigabit().serialize_ns(1460);
        assert_eq!(t.arrival_ns, wire + 10_000);
        assert_eq!(t.queueing_ns, 0);
    }

    #[test]
    fn master_scatter_serializes_on_master_port() {
        // master → N nodes: each flow queues behind the previous on the
        // master's egress port (the paper's scatter bottleneck).
        let mut s = sim();
        let wire = LinkModel::gigabit().serialize_ns(150_528);
        let mut last_arrival = 0;
        for n in 0..4 {
            let f = Flow {
                src: Endpoint::Master,
                dst: Endpoint::Node(n),
                bytes: 150_528,
                ready_ns: 0,
            };
            let t = s.schedule(&f);
            assert_eq!(t.queueing_ns, n as u64 * wire);
            assert!(t.arrival_ns > last_arrival);
            last_arrival = t.arrival_ns;
        }
        // 4th image waits for 3 previous serializations
        assert_eq!(last_arrival, 4 * wire + 10_000);
    }

    #[test]
    fn distinct_node_pairs_do_not_contend() {
        let mut s = sim();
        let a = s.schedule(&Flow {
            src: Endpoint::Node(0),
            dst: Endpoint::Node(1),
            bytes: 100_000,
            ready_ns: 0,
        });
        let b = s.schedule(&Flow {
            src: Endpoint::Node(2),
            dst: Endpoint::Node(3),
            bytes: 100_000,
            ready_ns: 0,
        });
        assert_eq!(a.arrival_ns, b.arrival_ns);
        assert_eq!(b.queueing_ns, 0);
    }

    #[test]
    fn gather_contends_on_master_ingress() {
        let mut s = sim();
        let wire = LinkModel::gigabit().serialize_ns(50_000);
        let t1 = s.schedule(&Flow {
            src: Endpoint::Node(0),
            dst: Endpoint::Master,
            bytes: 50_000,
            ready_ns: 0,
        });
        let t2 = s.schedule(&Flow {
            src: Endpoint::Node(1),
            dst: Endpoint::Master,
            bytes: 50_000,
            ready_ns: 0,
        });
        assert!(t2.arrival_ns >= t1.arrival_ns + wire);
        assert!(t2.queueing_ns > 0);
    }

    #[test]
    fn ready_time_respected() {
        let mut s = sim();
        let t = s.schedule(&Flow {
            src: Endpoint::Node(0),
            dst: Endpoint::Node(1),
            bytes: 1000,
            ready_ns: 5_000_000,
        });
        assert!(t.arrival_ns > 5_000_000);
        assert_eq!(t.queueing_ns, 0);
    }

    #[test]
    fn reset_clears_bookings() {
        let mut s = sim();
        s.schedule(&Flow { src: Endpoint::Master, dst: Endpoint::Node(0), bytes: 1e6 as u64, ready_ns: 0 });
        assert!(s.egress_free_at(Endpoint::Master) > 0);
        s.reset();
        assert_eq!(s.egress_free_at(Endpoint::Master), 0);
    }
}
