//! Blocking-MPI message cost model.
//!
//! §III: "buffers are sent as blocking call MPI messages, which also
//! affect the overall node message-passing handshake", and "the FPGA
//! CPU's need to DMA data buffers from the FPGA's logic and transmit
//! them through the network" dominates multi-node overhead.
//!
//! A blocking send from node A to node B costs:
//!
//! ```text
//!   handshake (rendezvous RTT, calibrated)
//! + sender CPU: DMA PL→DDR + memcpy into socket  (bytes × c_dma)
//! + wire serialization (LinkModel, frame overhead)
//! + switch store-and-forward latency
//! + receiver CPU: memcpy out + DMA DDR→PL        (bytes × c_dma)
//! ```
//!
//! CPU costs scale inversely with the PS clock relative to the Zynq-A9
//! baseline (the A53 at 1.5 GHz stages the same buffer faster).

use super::link::LinkModel;
use crate::config::{BoardProfile, Calibration};
use crate::util::units::{us_to_ns, Nanos};

/// Reference PS clock for the calibrated per-byte CPU cost.
const BASELINE_CPU_HZ: f64 = 650_000_000.0;

#[derive(Debug, Clone)]
pub struct MpiModel {
    pub link: LinkModel,
    /// Switch store-and-forward latency per message.
    pub switch_latency_ns: Nanos,
    /// Rendezvous handshake (calibrated).
    pub handshake_ns: Nanos,
    /// CPU staging cost per byte at the baseline 650 MHz PS clock.
    pub dma_cpu_ns_per_byte: f64,
}

impl MpiModel {
    pub fn from_calibration(calib: &Calibration, switch_latency_ns: Nanos) -> Self {
        MpiModel {
            link: LinkModel::gigabit(),
            switch_latency_ns,
            handshake_ns: us_to_ns(calib.mpi_handshake_us),
            dma_cpu_ns_per_byte: calib.dma_cpu_ns_per_byte,
        }
    }

    /// CPU staging time for one side of the transfer on a given board.
    pub fn cpu_stage_ns(&self, bytes: u64, board: &BoardProfile) -> Nanos {
        let scale = BASELINE_CPU_HZ / board.cpu_hz as f64;
        (bytes as f64 * self.dma_cpu_ns_per_byte * scale).round() as Nanos
    }

    /// End-to-end blocking transfer time between two boards.
    /// `src`/`dst` are `None` for the master host PC (fast CPU: staging
    /// cost treated as negligible next to the embedded PS).
    pub fn transfer_ns(
        &self,
        bytes: u64,
        src: Option<&BoardProfile>,
        dst: Option<&BoardProfile>,
    ) -> Nanos {
        let mut t = self.handshake_ns + self.switch_latency_ns;
        t += self.link.serialize_ns(bytes);
        if let Some(b) = src {
            t += self.cpu_stage_ns(bytes, b);
        }
        if let Some(b) = dst {
            t += self.cpu_stage_ns(bytes, b);
        }
        t
    }

    /// Sender-side occupancy: how long the sender is blocked (same as the
    /// transfer for blocking MPI — the defining inefficiency).
    pub fn sender_busy_ns(
        &self,
        bytes: u64,
        src: Option<&BoardProfile>,
        dst: Option<&BoardProfile>,
    ) -> Nanos {
        self.transfer_ns(bytes, src, dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Calibration;

    fn model() -> MpiModel {
        MpiModel::from_calibration(
            &Calibration {
                mpi_handshake_us: 500.0,
                dma_cpu_ns_per_byte: 8.0,
                ..Default::default()
            },
            10_000,
        )
    }

    #[test]
    fn transfer_decomposition() {
        let m = model();
        let z = BoardProfile::zynq7020();
        let bytes = 224 * 224 * 3u64; // one image
        let t = m.transfer_ns(bytes, None, Some(&z));
        // handshake 500 µs + switch 10 µs + wire ≈1.28 ms + CPU ≈1.2 ms
        assert!(t > 2_500_000, "{t}");
        assert!(t < 4_500_000, "{t}");
    }

    #[test]
    fn faster_ps_stages_faster() {
        let m = model();
        let z = BoardProfile::zynq7020();
        let u = BoardProfile::zu_mpsoc();
        let bytes = 1_000_000;
        assert!(m.cpu_stage_ns(bytes, &u) < m.cpu_stage_ns(bytes, &z));
        // 650 MHz / 1.5 GHz ≈ 0.43×
        let ratio = m.cpu_stage_ns(bytes, &u) as f64 / m.cpu_stage_ns(bytes, &z) as f64;
        assert!((0.40..0.47).contains(&ratio), "{ratio}");
    }

    #[test]
    fn fpga_to_fpga_pays_both_sides() {
        let m = model();
        let z = BoardProfile::zynq7020();
        let b = 500_000u64;
        let one = m.transfer_ns(b, None, Some(&z));
        let both = m.transfer_ns(b, Some(&z), Some(&z));
        assert!(both > one);
        assert_eq!(both - one, m.cpu_stage_ns(b, &z));
    }

    #[test]
    fn handshake_dominates_small_messages() {
        let m = model();
        let t = m.transfer_ns(100, None, None);
        // ≈ handshake + switch + 1 frame
        assert!((500_000 + 10_000 + 12_000..540_000).contains(&t), "{t}");
    }
}
