//! Experiment runners: one per paper table/figure (see DESIGN.md §5).
//!
//! * [`paper`]        — the published numbers (Fig. 3/4 tables, §IV claims)
//! * [`runner`]       — shared machinery: strategy sweep over cluster sizes
//! * [`calibrate`]    — fits the calibration constants to the anchors
//! * [`table`]        — text-table rendering used by benches and examples
//! * [`bench_suites`] — the tracked BENCH_*.json suites behind
//!   `vtacluster bench --check` (DESIGN.md §15)

pub mod bench_suites;
pub mod calibrate;
pub mod paper;
pub mod runner;
pub mod table;

pub use runner::{run_cell, sweep, SweepRow};
