//! Shared experiment machinery: run one (strategy, n) cell or a whole
//! strategy × cluster-size sweep of the paper's tables.

use crate::config::{BoardFamily, BoardProfile, Calibration, ClusterConfig, VtaConfig};
use crate::graph::resnet::build_resnet18;
use crate::graph::{zoo, Graph};
use crate::sched::{build_plan_priced, Strategy};
use crate::sim::{simulate, CostModel, SimConfig, SimResult};

/// One table row: cluster size × the four strategies (ms/image).
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub n: usize,
    pub ms: [f64; 4], // STRATEGY_ORDER
}

/// Everything needed to run cells of one table. Owns one [`CostModel`]
/// shared by every cell — autotuned GEMM schedules are computed once per
/// (config, shape) and reused across strategies and cluster sizes.
pub struct Bench {
    pub graph: Graph,
    pub family: BoardFamily,
    pub vta: VtaConfig,
    pub calib: Calibration,
    pub images: usize,
    cost: CostModel,
}

impl Bench {
    /// Bench over the paper's evaluation workload (ResNet-18 @224).
    pub fn new(family: BoardFamily, vta: VtaConfig, calib: Calibration) -> Self {
        Self::with_graph(family, vta, calib, build_resnet18(224).unwrap())
    }

    /// Bench over any registered zoo model (`input_hw == 0` → the
    /// model's default input size).
    pub fn for_model(
        family: BoardFamily,
        vta: VtaConfig,
        calib: Calibration,
        model: &str,
        input_hw: u64,
    ) -> anyhow::Result<Self> {
        Ok(Self::with_graph(family, vta, calib, zoo::build(model, input_hw)?))
    }

    /// Bench over an explicit workload graph.
    pub fn with_graph(
        family: BoardFamily,
        vta: VtaConfig,
        calib: Calibration,
        graph: Graph,
    ) -> Self {
        let cost =
            CostModel::new(vta.clone(), BoardProfile::for_family(family), calib.clone());
        Bench { graph, family, vta, calib, images: 64, cost }
    }

    pub fn zynq(calib: Calibration) -> Self {
        Self::new(BoardFamily::Zynq7000, VtaConfig::table1_zynq7000(), calib)
    }

    pub fn ultrascale(calib: Calibration) -> Self {
        Self::new(BoardFamily::UltraScalePlus, VtaConfig::table1_ultrascale(), calib)
    }

    /// Whole-graph single-node compute time (ms), κ applied.
    pub fn graph_time_ms(&mut self) -> anyhow::Result<f64> {
        Ok(self.cost.graph_time_ns(&self.graph)? as f64 / 1e6)
    }

    /// Split access to the workload graph and the shared memoized cost
    /// model, for callers composing further analyses on top of `cell`
    /// (e.g. the CLI's loaded-DES pass) without rebuilding the caches.
    pub fn graph_and_cost_mut(&mut self) -> (&Graph, &mut CostModel) {
        (&self.graph, &mut self.cost)
    }

    /// Simulated ms/image for one (strategy, n) cell.
    pub fn cell(&mut self, strategy: Strategy, n: usize) -> anyhow::Result<SimResult> {
        let cost = &mut self.cost;
        // seg_cost oracle for the planners: single-split segment times
        let seg_costs = cost.seg_cost_table(&self.graph)?;
        let plan = build_plan_priced(strategy, &self.graph, n, &seg_costs)?;
        let cluster =
            ClusterConfig::homogeneous(self.family, n).with_vta(self.vta.clone());
        simulate(&plan, &cluster, cost, &self.graph, &SimConfig { images: self.images })
    }

    /// Full sweep over `1..=max_n` × all four strategies.
    pub fn sweep(&mut self, max_n: usize) -> anyhow::Result<Vec<SweepRow>> {
        let mut rows = Vec::with_capacity(max_n);
        for n in 1..=max_n {
            let mut ms = [0.0; 4];
            for (i, s) in super::paper::STRATEGY_ORDER.iter().enumerate() {
                ms[i] = self.cell(*s, n)?.ms_per_image;
            }
            rows.push(SweepRow { n, ms });
        }
        Ok(rows)
    }
}

/// Convenience wrappers used by the benches.
pub fn run_cell(
    family: BoardFamily,
    vta: VtaConfig,
    calib: Calibration,
    strategy: Strategy,
    n: usize,
) -> anyhow::Result<SimResult> {
    Bench::new(family, vta, calib).cell(strategy, n)
}

pub fn sweep(
    family: BoardFamily,
    vta: VtaConfig,
    calib: Calibration,
    max_n: usize,
) -> anyhow::Result<Vec<SweepRow>> {
    Bench::new(family, vta, calib).sweep(max_n)
}

/// Single-node compute + overhead decomposition, used by the calibrator:
/// returns `(compute_ms_at_current_kappa, overhead_ms)` where
/// `total = compute + overhead` for the SG n=1 cell.
pub fn single_node_decomposition(bench: &mut Bench) -> anyhow::Result<(f64, f64)> {
    let compute = bench.graph_time_ms()?;
    let total = bench.cell(Strategy::ScatterGather, 1)?.ms_per_image;
    Ok((compute, (total - compute).max(0.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zynq_cell_runs() {
        let mut b = Bench::zynq(Calibration::default());
        let r = b.cell(Strategy::ScatterGather, 2).unwrap();
        assert!(r.ms_per_image > 1.0 && r.ms_per_image < 200.0);
    }

    #[test]
    fn zoo_model_cell_runs() {
        let mut b = Bench::for_model(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            Calibration::default(),
            "lenet5",
            0,
        )
        .unwrap();
        b.images = 8;
        let r = b.cell(Strategy::Pipeline, 3).unwrap();
        assert!(r.ms_per_image > 0.0 && r.ms_per_image.is_finite());
        assert!(Bench::for_model(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            Calibration::default(),
            "nope",
            0
        )
        .is_err());
    }

    #[test]
    fn sweep_rows_are_complete() {
        let mut b = Bench::zynq(Calibration::default());
        b.images = 16; // fast
        let rows = b.sweep(3).unwrap();
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.ms.iter().all(|&v| v > 0.0)));
        // n=1 uniform across strategies
        let r1 = &rows[0];
        for w in r1.ms.windows(2) {
            assert!((w[0] - w[1]).abs() / w[0] < 0.02, "{:?}", r1.ms);
        }
    }
}
