//! The paper's published numbers, transcribed verbatim.
//!
//! Fig. 3(a): Zynq-7000 stack, execution time (ms) per image, 1–12 FPGAs.
//! Fig. 4(a): UltraScale+ stack, 1–5 FPGAs.
//! §IV: 350 MHz ⇒ ≈5.7 % faster; big config ⇒ ≈43.86 % faster.
//!
//! Column order everywhere: [Scatter-Gather, AI Core Assignment,
//! Pipeline Scheduling, Fused Schedule].

use crate::sched::Strategy;

pub const STRATEGY_ORDER: [Strategy; 4] = [
    Strategy::ScatterGather,
    Strategy::CoreAssign,
    Strategy::Pipeline,
    Strategy::Fused,
];

/// Fig. 3(a): rows n=1..=12, columns in [`STRATEGY_ORDER`], milliseconds.
pub const FIG3_ZYNQ7000_MS: [[f64; 4]; 12] = [
    [27.34, 27.34, 27.34, 27.34],
    [17.53, 36.85, 20.43, 19.32],
    [12.33, 28.32, 15.59, 16.87],
    [7.87, 20.31, 11.29, 9.13],
    [6.44, 15.40, 9.03, 7.37],
    [5.66, 9.63, 7.33, 6.62],
    [4.78, 4.55, 5.93, 4.92],
    [3.94, 3.98, 4.22, 4.01],
    [3.17, 2.46, 3.88, 3.45],
    [2.84, 2.11, 3.22, 2.94],
    [2.71, 1.93, 2.94, 2.74],
    [2.58, 1.84, 2.62, 2.66],
];

/// Fig. 4(a): rows n=1..=5, columns in [`STRATEGY_ORDER`], milliseconds.
pub const FIG4_ULTRASCALE_MS: [[f64; 4]; 5] = [
    [25.15, 25.15, 25.15, 25.15],
    [16.73, 33.96, 19.03, 18.28],
    [11.78, 26.24, 14.57, 16.04],
    [7.42, 18.70, 10.88, 8.63],
    [6.01, 14.14, 8.58, 6.93],
];

/// §III single-FPGA anchors (ms).
pub const SINGLE_ZYNQ_MS: f64 = 27.34;
pub const SINGLE_ULTRASCALE_MS: f64 = 25.15;

/// §IV: UltraScale+ at 350 MHz — "a speedup of approximately 5.7 %".
pub const CLOCK_350_SPEEDUP: f64 = 0.057;

/// §IV: BLOCK=32 / doubled buffers / 200 MHz — "approximately 43.86 %".
pub const BIG_CONFIG_SPEEDUP: f64 = 0.4386;

/// Qualitative claims the reproduction must preserve (checked by the
/// integration tests and reported in EXPERIMENTS.md):
///
/// 1. AI-core assignment is *slower than a single node* at n=2–3;
/// 2. AI-core assignment becomes the best strategy at large n (paper: n≥9);
/// 3. scatter-gather scales near-linearly early, flattening at high n;
/// 4. the US+ single node is only ~6–8 % faster despite a 3× clock;
/// 5. both §IV variants speed up, the big config far more than 350 MHz.
pub const QUALITATIVE_CLAIMS: usize = 5;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_are_complete() {
        assert_eq!(FIG3_ZYNQ7000_MS.len(), 12);
        assert_eq!(FIG4_ULTRASCALE_MS.len(), 5);
        for row in FIG3_ZYNQ7000_MS.iter().chain(FIG4_ULTRASCALE_MS.iter()) {
            for &v in row {
                assert!(v > 0.0 && v < 100.0);
            }
        }
    }

    #[test]
    fn n1_rows_are_uniform() {
        assert!(FIG3_ZYNQ7000_MS[0].iter().all(|&v| v == SINGLE_ZYNQ_MS));
        assert!(FIG4_ULTRASCALE_MS[0].iter().all(|&v| v == SINGLE_ULTRASCALE_MS));
    }

    #[test]
    fn paper_anomalies_present_in_transcription() {
        // AI-core @2,3 worse than single node (the headline anomaly)
        assert!(FIG3_ZYNQ7000_MS[1][1] > SINGLE_ZYNQ_MS);
        assert!(FIG3_ZYNQ7000_MS[2][1] > SINGLE_ZYNQ_MS);
        // AI-core best at n ≥ 9
        for n in [9, 10, 11, 12] {
            let row = FIG3_ZYNQ7000_MS[n - 1];
            assert!(row[1] <= row[0] && row[1] <= row[2] && row[1] <= row[3], "n={n}");
        }
        // US+ ~6 % faster single-node
        let gain = (SINGLE_ZYNQ_MS - SINGLE_ULTRASCALE_MS) / SINGLE_ZYNQ_MS;
        assert!((0.05..0.11).contains(&gain));
    }
}
