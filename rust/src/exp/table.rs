//! Text-table rendering for benches and examples, matching the layout of
//! the paper's Fig. 3(a)/4(a), with optional paper-vs-ours comparison.

use super::runner::SweepRow;
use crate::util::stats::rel_err;

pub const HEADERS: [&str; 4] =
    ["Scatter-Gather", "AI Core Assign", "Pipeline", "Fused"];

/// Render a sweep as the paper's table shape.
pub fn render(title: &str, rows: &[SweepRow]) -> String {
    let mut s = format!("{title}\n");
    s.push_str(&format!(
        "{:>4} | {:>15} | {:>15} | {:>15} | {:>15}\n",
        "N", HEADERS[0], HEADERS[1], HEADERS[2], HEADERS[3]
    ));
    s.push_str(&format!("{}\n", "-".repeat(4 + 4 * 18 + 3)));
    for r in rows {
        s.push_str(&format!(
            "{:>4} | {:>15.2} | {:>15.2} | {:>15.2} | {:>15.2}\n",
            r.n, r.ms[0], r.ms[1], r.ms[2], r.ms[3]
        ));
    }
    s
}

/// Render ours next to the paper's numbers with per-cell relative error.
pub fn render_vs_paper(title: &str, rows: &[SweepRow], paper: &[[f64; 4]]) -> String {
    let mut s = format!("{title} — ours (paper, rel.err)\n");
    s.push_str(&format!(
        "{:>4} | {:>26} | {:>26} | {:>26} | {:>26}\n",
        "N", HEADERS[0], HEADERS[1], HEADERS[2], HEADERS[3]
    ));
    s.push_str(&format!("{}\n", "-".repeat(4 + 4 * 29 + 3)));
    for r in rows {
        let p = &paper[r.n - 1];
        s.push_str(&format!("{:>4}", r.n));
        for i in 0..4 {
            s.push_str(&format!(
                " | {:>9.2} ({:>6.2}, {:>4.0}%)",
                r.ms[i],
                p[i],
                rel_err(r.ms[i], p[i]) * 100.0
            ));
        }
        s.push('\n');
    }
    s
}

/// Per-strategy mean relative error vs the paper table.
pub fn errors(rows: &[SweepRow], paper: &[[f64; 4]]) -> [f64; 4] {
    let mut out = [0.0; 4];
    for i in 0..4 {
        let mut sum = 0.0;
        for r in rows {
            sum += rel_err(r.ms[i], paper[r.n - 1][i]);
        }
        out[i] = sum / rows.len() as f64;
    }
    out
}

/// Shape checks: does the winner-per-row ordering match the paper?
pub fn winner_agreement(rows: &[SweepRow], paper: &[[f64; 4]]) -> f64 {
    let argmin = |v: &[f64; 4]| {
        v.iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    let agree = rows
        .iter()
        .filter(|r| argmin(&r.ms) == argmin(&paper[r.n - 1]))
        .count();
    agree as f64 / rows.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<SweepRow> {
        vec![
            SweepRow { n: 1, ms: [27.0, 27.0, 27.0, 27.0] },
            SweepRow { n: 2, ms: [17.0, 37.0, 20.0, 19.0] },
        ]
    }

    #[test]
    fn render_contains_cells() {
        let s = render("t", &rows());
        assert!(s.contains("27.00"));
        assert!(s.contains("37.00"));
        assert!(s.contains("Scatter-Gather"));
    }

    #[test]
    fn errors_zero_on_exact_match() {
        let paper = [[27.0, 27.0, 27.0, 27.0], [17.0, 37.0, 20.0, 19.0]];
        let e = errors(&rows(), &paper);
        assert!(e.iter().all(|&x| x < 1e-12));
        assert_eq!(winner_agreement(&rows(), &paper), 1.0);
    }

    #[test]
    fn winner_agreement_detects_mismatch() {
        let paper = [[27.0, 27.0, 27.0, 27.0], [37.0, 17.0, 20.0, 19.0]];
        assert!(winner_agreement(&rows(), &paper) < 1.0);
    }

    #[test]
    fn render_vs_paper_shows_err() {
        let paper = [[27.0; 4], [17.0, 37.0, 20.0, 19.0]];
        let s = render_vs_paper("t", &rows(), &paper);
        assert!(s.contains('%'));
    }

    #[test]
    fn strategy_order_matches_headers() {
        assert_eq!(super::super::paper::STRATEGY_ORDER.len(), HEADERS.len());
    }
}
