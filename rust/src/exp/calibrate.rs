//! Calibration fit (E6): anchor the timing model to the paper's measured
//! points, then let everything else be *prediction*.
//!
//! Stage A — compute split: grid-search `(gemm_efficiency,
//!   dram_efficiency)` so the §IV percentages come out right on the US+
//!   stack (350 MHz ⇒ ~5.7 %, big config ⇒ ~43.86 %). These two
//!   percentages pin down how much of a node's time is clock-bound vs
//!   memory-bound — exactly what the two §IV experiments measure.
//!
//! Stage B — absolute anchors: solve κ per family so the simulated
//!   single-FPGA time equals 27.34 ms (Zynq) / 25.15 ms (US+). The
//!   single-node total is `κ·C + O` (compute + overhead), linear in κ.
//!
//! Stage C — network constants: grid-search `(mpi_handshake_us,
//!   dma_cpu_ns_per_byte)` against the Fig. 3 anomaly region (n=2..6,
//!   all four strategies), where the paper says blocking MPI and PS DMA
//!   dominate.
//!
//! The fitted constants and residuals are written to
//! `artifacts/calibration.json` and EXPERIMENTS.md §Calibration.

use super::paper;
use super::runner::{single_node_decomposition, Bench};
use crate::config::{BoardFamily, Calibration, VtaConfig};
use crate::sched::Strategy;
use crate::util::stats::rel_err;

/// Result of the calibration fit.
#[derive(Debug, Clone)]
pub struct FitReport {
    pub calib: Calibration,
    /// |measured − paper| / paper at the four anchor points.
    pub residual_single_zynq: f64,
    pub residual_single_us: f64,
    pub residual_350: f64,
    pub residual_big: f64,
    /// Mean rel. error over the Fig. 3 n=2..6 block after stage C.
    pub residual_network: f64,
    pub log: String,
}

fn anchor(family: BoardFamily) -> f64 {
    match family {
        BoardFamily::Zynq7000 => paper::SINGLE_ZYNQ_MS,
        BoardFamily::UltraScalePlus => paper::SINGLE_ULTRASCALE_MS,
    }
}

fn table1(family: BoardFamily) -> VtaConfig {
    match family {
        BoardFamily::Zynq7000 => VtaConfig::table1_zynq7000(),
        BoardFamily::UltraScalePlus => VtaConfig::table1_ultrascale(),
    }
}

/// Solve κ for a family: with κ=1, total = C + O; κ* = (anchor − O)/C.
/// Returns (κ, overhead_ms).
fn solve_kappa(calib: &Calibration, family: BoardFamily) -> anyhow::Result<(f64, f64)> {
    let mut unit = calib.clone();
    unit.kappa_zynq = 1.0;
    unit.kappa_ultrascale = 1.0;
    let mut bench = Bench::new(family, table1(family), unit);
    bench.images = 16;
    let (compute, overhead) = single_node_decomposition(&mut bench)?;
    Ok((((anchor(family) - overhead) / compute).max(0.001), overhead))
}

/// Predicted §IV speedups (κ_us and overhead supplied).
fn section4_speedups(
    calib: &Calibration,
    kappa_us: f64,
    overhead: f64,
) -> anyhow::Result<(f64, f64)> {
    let fam = BoardFamily::UltraScalePlus;
    let mut unit = calib.clone();
    unit.kappa_zynq = 1.0;
    unit.kappa_ultrascale = 1.0;
    let t = |vta: VtaConfig| -> anyhow::Result<f64> {
        Bench::new(fam, vta, unit.clone()).graph_time_ms()
    };
    let base = t(VtaConfig::table1_ultrascale())?;
    let at350 = t(VtaConfig::ultrascale_350mhz())?;
    let big = t(VtaConfig::big_config_200mhz())?;
    let total = |c: f64| kappa_us * c + overhead;
    Ok((1.0 - total(at350) / total(base), 1.0 - total(big) / total(base)))
}

/// Run the fit. `quick` shrinks the grids (used by tests).
pub fn fit(quick: bool) -> anyhow::Result<FitReport> {
    let mut log = String::new();
    let mut calib = Calibration::default();

    // ---- stage A: efficiency split against the §IV percentages -------
    let gemm_grid: Vec<f64> =
        if quick { vec![0.55] } else { vec![0.35, 0.45, 0.55, 0.7, 0.85] };
    let dram_grid: Vec<f64> =
        if quick { vec![0.45] } else { vec![0.15, 0.25, 0.35, 0.5, 0.7, 0.9] };
    let mut best = (f64::INFINITY, calib.gemm_efficiency, calib.dram_efficiency);
    for &ge in &gemm_grid {
        for &de in &dram_grid {
            let mut c = calib.clone();
            c.gemm_efficiency = ge;
            c.dram_efficiency = de;
            let (kappa_us, overhead) = solve_kappa(&c, BoardFamily::UltraScalePlus)?;
            let (s350, sbig) = section4_speedups(&c, kappa_us, overhead)?;
            let score = (s350 - paper::CLOCK_350_SPEEDUP).abs()
                + (sbig - paper::BIG_CONFIG_SPEEDUP).abs();
            if score < best.0 {
                best = (score, ge, de);
            }
        }
    }
    calib.gemm_efficiency = best.1;
    calib.dram_efficiency = best.2;
    log.push_str(&format!(
        "stage A: gemm_eff={:.2} dram_eff={:.2} (score {:.4})\n",
        best.1, best.2, best.0
    ));

    // ---- stage B: κ anchors ------------------------------------------
    calib.kappa_zynq = solve_kappa(&calib, BoardFamily::Zynq7000)?.0;
    calib.kappa_ultrascale = solve_kappa(&calib, BoardFamily::UltraScalePlus)?.0;
    log.push_str(&format!(
        "stage B: kappa_zynq={:.4} kappa_ultrascale={:.4}\n",
        calib.kappa_zynq, calib.kappa_ultrascale
    ));

    // ---- stage C: network + overlap constants against Fig. 3 ---------
    // The anomaly region n=2..6 pins down the blocking costs; the tail
    // n=9..12 pins down how much of a transfer overlaps compute.
    let hs_grid: Vec<f64> =
        if quick { vec![300.0] } else { vec![100.0, 250.0, 400.0, 600.0] };
    let dma_grid: Vec<f64> = if quick { vec![2.0] } else { vec![0.5, 1.0, 2.0, 4.0] };
    let beta_grid: Vec<f64> = if quick { vec![0.4] } else { vec![0.1, 0.25, 0.4, 0.6, 1.0] };
    let drv_grid: Vec<f64> = if quick { vec![1500.0] } else { vec![300.0, 800.0, 1500.0] };
    let rows: Vec<usize> = if quick { vec![2] } else { vec![2, 3, 4, 6, 9, 12] };
    let mut bestc = (
        f64::INFINITY,
        calib.mpi_handshake_us,
        calib.dma_cpu_ns_per_byte,
        calib.ps_serial_frac,
        calib.driver_overhead_us,
    );
    for &hs in &hs_grid {
        for &dma in &dma_grid {
            for &beta in &beta_grid {
                for &drv in &drv_grid {
                    let mut c = calib.clone();
                    c.mpi_handshake_us = hs;
                    c.dma_cpu_ns_per_byte = dma;
                    c.ps_serial_frac = beta;
                    c.driver_overhead_us = drv;
                    // κ depends on overhead → re-anchor for fairness
                    c.kappa_zynq = solve_kappa(&c, BoardFamily::Zynq7000)?.0;
                    let mut b = Bench::zynq(c.clone());
                    b.images = 32;
                    let mut err = 0.0;
                    let mut weight_sum = 0.0;
                    for &n in &rows {
                        for (i, s) in paper::STRATEGY_ORDER.iter().enumerate() {
                            let got = b.cell(*s, n)?.ms_per_image;
                            // the AI-core slowdown at n=2..3 is the
                            // paper's headline anomaly — weight it so the
                            // fit cannot trade it away for tail accuracy
                            let w = if *s == crate::sched::Strategy::CoreAssign && n <= 3
                            {
                                4.0
                            } else {
                                1.0
                            };
                            err += w * rel_err(got, paper::FIG3_ZYNQ7000_MS[n - 1][i]);
                            weight_sum += w;
                        }
                    }
                    let score = err / weight_sum;
                    if score < bestc.0 {
                        bestc = (score, hs, dma, beta, drv);
                    }
                }
            }
        }
    }
    calib.mpi_handshake_us = bestc.1;
    calib.dma_cpu_ns_per_byte = bestc.2;
    calib.ps_serial_frac = bestc.3;
    calib.driver_overhead_us = bestc.4;
    calib.kappa_zynq = solve_kappa(&calib, BoardFamily::Zynq7000)?.0;
    calib.kappa_ultrascale = solve_kappa(&calib, BoardFamily::UltraScalePlus)?.0;
    log.push_str(&format!(
        "stage C: handshake={:.0}µs dma={:.1}ns/B serial_frac={:.2} driver={:.0}µs (mean rel err {:.3})\n",
        bestc.1, bestc.2, bestc.3, bestc.4, bestc.0
    ));

    // ---- residuals ----------------------------------------------------
    let mut bz = Bench::zynq(calib.clone());
    bz.images = 32;
    let single_z = bz.cell(Strategy::ScatterGather, 1)?.ms_per_image;
    let mut bu = Bench::ultrascale(calib.clone());
    bu.images = 32;
    let single_u = bu.cell(Strategy::ScatterGather, 1)?.ms_per_image;
    let (kappa_us, overhead_us_fam) = solve_kappa(&calib, BoardFamily::UltraScalePlus)?;
    let (s350, sbig) = section4_speedups(&calib, kappa_us, overhead_us_fam)?;
    calib.validate()?;
    Ok(FitReport {
        residual_single_zynq: rel_err(single_z, paper::SINGLE_ZYNQ_MS),
        residual_single_us: rel_err(single_u, paper::SINGLE_ULTRASCALE_MS),
        residual_350: (s350 - paper::CLOCK_350_SPEEDUP).abs(),
        residual_big: (sbig - paper::BIG_CONFIG_SPEEDUP).abs(),
        residual_network: bestc.0,
        calib,
        log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fit_hits_single_node_anchors() {
        let r = fit(true).unwrap();
        assert!(
            r.residual_single_zynq < 0.05,
            "zynq anchor residual {} (log: {})",
            r.residual_single_zynq,
            r.log
        );
        assert!(r.residual_single_us < 0.05, "us anchor residual {}", r.residual_single_us);
        r.calib.validate().unwrap();
    }
}
