//! The five tracked bench suites behind `vtacluster bench` and the
//! `cargo bench` wrappers (DESIGN.md §15).
//!
//! Each suite runs a fixed set of seeded scenarios and returns a
//! [`BenchReport`] in the stable `BENCH_*.json` schema:
//!
//! * [`des_suite`]       — E10 dynamic-load DES + controller trajectory
//!   (`BENCH_des.json`)
//! * [`scenarios_suite`] — E12 scenario-layer wall/row trajectory over
//!   `examples/scenarios/` (`BENCH_scenarios.json`)
//! * [`faults_suite`]    — E14 chaos figures: availability, attainment,
//!   recovery tails (`BENCH_faults.json`)
//! * [`serve_suite`]     — E16 serving front end: batched goodput at
//!   saturation, tail-drop shedding, trace replay (`BENCH_serve.json`)
//! * [`search_suite`]    — E17 plan-search engine: E1-grid dominance
//!   over the heuristics, J/image vs eco, re-planning throughput at
//!   fleet scale (`BENCH_search.json`)
//!
//! The deterministic `metrics` of each entry are what
//! `vtacluster bench --check` gates against the checked-in baselines in
//! `rust/benches/baselines/` with a relative tolerance; `wall` figures
//! ride along ungated. `VTA_BENCH_FAST=1` shrinks horizons (recorded in
//! the report's `fast` flag so mismatched modes are never compared).

use crate::config::{
    BoardFamily, BoardProfile, Calibration, ClusterConfig, ReconfigCost, VtaConfig,
};
use crate::graph::zoo;
use crate::scenario::{Report, ScenarioSpec, Session, Sweep};
use crate::sched::{plan_options, ControllerConfig, OnlineController, Strategy};
use crate::serve::{AdmissionConfig, BatchConfig, ShedPolicy};
use crate::sim::{run_des, ArrivalProcess, CostModel, DesConfig, DesResult};
use crate::util::bench::{Bench, BenchEntry, BenchReport};
use crate::util::json::{self, Json};
use std::path::Path;

/// All suites, in canonical order: `(file stem, builder)`.
pub const SUITE_NAMES: [&str; 5] = ["des", "scenarios", "faults", "serve", "search"];

fn des_entry(name: &str, r: &DesResult) -> BenchEntry {
    BenchEntry::new(name)
        .metric("offered", r.offered as f64)
        .metric("completed", r.completed as f64)
        .metric("img_per_sec", r.throughput_img_per_sec)
        .metric("p50_ms", r.latency_ms.percentile(50.0).unwrap_or(f64::NAN))
        .metric("p95_ms", r.latency_ms.percentile(95.0).unwrap_or(f64::NAN))
        .metric("p99_ms", r.latency_ms.percentile(99.0).unwrap_or(f64::NAN))
        .metric("max_backlog", r.max_backlog as f64)
        .metric("reconfigs", r.reconfigs.len() as f64)
        .metric("downtime_ms", r.downtime_ms)
        .metric("events_processed", r.events_processed as f64)
        .metric("events_per_sec", r.events_per_sec)
        .wall(
            "events_per_sec_wall",
            if r.wall_ms > 0.0 { r.events_processed as f64 / (r.wall_ms / 1e3) } else { 0.0 },
        )
        .wall("wall_ms", r.wall_ms)
}

/// E10: ResNet-18 on a 4-node Zynq stack through three load scenarios —
/// steady poisson, burst with the controller off, burst with it on.
pub fn des_suite(calib: &Calibration) -> anyhow::Result<BenchReport> {
    let mut b = Bench::new("des_reconfig");
    let mut report = BenchReport::new("des");
    let horizon_ms = if report.fast { 6000.0 } else { 20000.0 };
    let seed = 7u64;

    let family = BoardFamily::Zynq7000;
    let g = zoo::build("resnet18", 0)?;
    let vta = VtaConfig::table1_zynq7000();
    let mut cost = CostModel::new(vta.clone(), BoardProfile::for_family(family), calib.clone());
    let cluster = ClusterConfig::homogeneous(family, 4).with_vta(vta);
    let options = plan_options(&g, &cluster, &mut cost, &Strategy::all())?;
    for o in &options {
        b.row(&format!(
            "candidate {:22} capacity {:8.1} img/s  latency {:7.3} ms",
            o.plan.strategy.to_string(),
            o.capacity_img_per_sec,
            o.latency_ms
        ));
    }
    let initial = options
        .iter()
        .position(|o| o.plan.strategy == Strategy::CoreAssign)
        .expect("core-assign is always a candidate");
    let cap0 = options[initial].capacity_img_per_sec;

    let mut results: Vec<(&str, DesResult)> = Vec::new();

    // steady poisson at 70% of the initial plan's capacity
    let cfg = DesConfig::new(
        ArrivalProcess::Poisson { rate_per_sec: 0.7 * cap0 },
        horizon_ms,
        seed,
    );
    let r = run_des(&options, initial, &cluster, &mut cost, &g, &cfg, None)?;
    results.push(("poisson_steady", r));

    // bursty MMPP that overloads the initial plan during bursts — the
    // same stream `vtacluster load --arrival burst --rate 0` generates
    let burst = ArrivalProcess::parse("burst", 0.55 * cap0, 4.0)?;
    let cfg = DesConfig::new(burst, horizon_ms, seed);
    let r = run_des(&options, initial, &cluster, &mut cost, &g, &cfg, None)?;
    results.push(("burst_controller_off", r));

    let mut ctrl =
        OnlineController::new(ControllerConfig::default(), ReconfigCost::for_family(family))?;
    let r = run_des(&options, initial, &cluster, &mut cost, &g, &cfg, Some(&mut ctrl))?;
    results.push(("burst_controller_on", r));

    for (name, r) in &results {
        b.row(&format!(
            "{name:22} seed {seed}: {:5}/{:5} images, {:7.1} img/s, p50 {:8.2} ms, \
             p99 {:9.2} ms, reconfigs {} ({:.0} ms downtime)",
            r.completed,
            r.offered,
            r.throughput_img_per_sec,
            r.latency_ms.percentile(50.0).unwrap_or(0.0),
            r.latency_ms.percentile(99.0).unwrap_or(0.0),
            r.reconfigs.len(),
            r.downtime_ms,
        ));
        b.row(&format!(
            "{name:22} engine: {} events, {:.0} ev/sim-s, {:.0} ev/wall-s ({:.1} ms wall)",
            r.events_processed,
            r.events_per_sec,
            if r.wall_ms > 0.0 { r.events_processed as f64 / (r.wall_ms / 1e3) } else { 0.0 },
            r.wall_ms,
        ));
        report.push(des_entry(name, r));
    }
    b.finish();
    Ok(report)
}

/// E12: every `examples/scenarios/*.json` through the scenario layer —
/// the perf trajectory of the API seam itself (spec resolution, sweep
/// expansion, report assembly).
pub fn scenarios_suite(dir: &Path, calib: &Calibration) -> anyhow::Result<BenchReport> {
    let mut b = Bench::new("scenario_suite");
    let mut report = BenchReport::new("scenarios");
    let mut entries: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("scenario dir {}: {e}", dir.display()))?
        .map(|e| Ok(e?.path()))
        .collect::<anyhow::Result<Vec<_>>>()?;
    entries.retain(|p| p.extension().and_then(|e| e.to_str()) == Some("json"));
    entries.sort();
    anyhow::ensure!(!entries.is_empty(), "no scenarios in {}", dir.display());

    for path in &entries {
        let name = path.file_stem().unwrap_or_default().to_string_lossy().to_string();
        let doc = json::from_file(path)?;
        let t0 = std::time::Instant::now();
        let rep = run_doc(&doc, calib).map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let completed: u64 = rep.rows.iter().map(|r| r.completed).sum();
        b.row(&format!(
            "{name:24} {:>3} row(s)  {:>3} event(s)  {completed:>6} images  {wall_ms:>8.1} ms wall",
            rep.rows.len(),
            rep.events.len(),
        ));
        report.push(
            BenchEntry::new(&name)
                .metric("rows", rep.rows.len() as f64)
                .metric("events", rep.events.len() as f64)
                .metric("completed", completed as f64)
                .wall("wall_ms", wall_ms),
        );
    }
    b.finish();
    Ok(report)
}

fn run_doc(doc: &Json, calib: &Calibration) -> anyhow::Result<Report> {
    match Sweep::from_doc(doc)? {
        Some(sweep) => sweep.run(calib),
        None => Session::new(ScenarioSpec::from_json(doc)?)?
            .with_calibration(calib.clone())
            .run(),
    }
}

fn chaos_spec(controller: bool) -> String {
    format!(
        r#"{{
          "name": "bench-chaos-crash", "engine": "des",
          "model": "lenet5", "strategy": "pipeline", "family": "zynq", "nodes": 3,
          "arrival": {{"kind": "poisson"}}, "slo_ms": 60,
          "controller": {{"enabled": {controller}}},
          "faults": {{"crashes": [{{"node": 1, "at_ms": 600, "down_ms": 700}}]}},
          "horizon_ms": 2400, "seed": 21
        }}"#
    )
}

/// E14: seeded chaos runs — the failover controller's value under a
/// mid-run crash (controller-on vs -off on the same seed), a random
/// crash process, and a persistent straggler.
pub fn faults_suite(calib: &Calibration) -> anyhow::Result<BenchReport> {
    let mut b = Bench::new("chaos_faults");
    let mut report = BenchReport::new("faults");

    for (tag, text) in [
        ("crash-controller-on", chaos_spec(true)),
        ("crash-controller-off", chaos_spec(false)),
        (
            "random-crashes",
            r#"{
              "name": "bench-chaos-random", "engine": "des",
              "model": "lenet5", "strategy": "sg", "family": "zynq", "nodes": 4,
              "arrival": {"kind": "poisson"}, "slo_ms": 80,
              "controller": {"enabled": true},
              "faults": {"crash_mean_up_ms": 1500, "crash_mean_down_ms": 250},
              "horizon_ms": 2400, "seed": 33
            }"#
            .to_string(),
        ),
        (
            "stragglers",
            r#"{
              "name": "bench-chaos-straggler", "engine": "des",
              "model": "lenet5", "strategy": "sg", "family": "zynq", "nodes": 4,
              "arrival": {"kind": "poisson"}, "slo_ms": 80,
              "controller": {"enabled": true},
              "faults": {"stragglers": 1, "straggler_factor": 3.0},
              "horizon_ms": 2400, "seed": 33
            }"#
            .to_string(),
        ),
    ] {
        let t0 = std::time::Instant::now();
        let rep = Session::new(ScenarioSpec::parse(&text)?)?
            .with_calibration(calib.clone())
            .run()
            .map_err(|e| anyhow::anyhow!("{tag}: {e}"))?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let r = &rep.rows[0];
        b.row(&format!(
            "{tag:22} avail {:>6.4}  slo {:>6}  recovery p50 {:>8}  stalled {:>2}  completed {:>5}",
            r.availability,
            if r.slo_attainment.is_finite() {
                format!("{:.3}", r.slo_attainment)
            } else {
                "n/a".to_string()
            },
            if r.recovery_p50_ms.is_finite() {
                format!("{:.1}ms", r.recovery_p50_ms)
            } else {
                "n/a".to_string()
            },
            r.stalled_windows,
            r.completed,
        ));
        report.push(
            BenchEntry::new(tag)
                .metric("availability", r.availability)
                .metric("slo_attainment", r.slo_attainment)
                .metric("recovery_p50_ms", r.recovery_p50_ms)
                .metric("recovery_p99_ms", r.recovery_p99_ms)
                .metric("stalled_windows", r.stalled_windows as f64)
                .metric("completed", r.completed as f64)
                .metric("reconfigs", r.reconfigs as f64)
                .metric("p99_ms", r.p99_ms)
                .wall("wall_ms", wall_ms),
        );
    }
    b.finish();
    Ok(report)
}

/// E16: the serving front end — batched dispatch at saturation (the
/// latency-vs-throughput trade the batch former buys), tail-drop
/// admission under overload, and a two-tenant trace replay through the
/// per-tenant rate gate.
pub fn serve_suite(calib: &Calibration) -> anyhow::Result<BenchReport> {
    let mut b = Bench::new("serve_front_end");
    let mut report = BenchReport::new("serve");
    let horizon_ms = if report.fast { 2500.0 } else { 8000.0 };
    let seed = 17u64;

    let family = BoardFamily::Zynq7000;
    let g = zoo::build("lenet5", 0)?;
    let vta = VtaConfig::table1_zynq7000();
    let mut cost = CostModel::new(vta.clone(), BoardProfile::for_family(family), calib.clone());
    let cluster = ClusterConfig::homogeneous(family, 2).with_vta(vta);
    let options = plan_options(&g, &cluster, &mut cost, &Strategy::all())?;
    let initial = options
        .iter()
        .position(|o| o.plan.strategy == Strategy::Pipeline)
        .expect("pipeline is always a candidate");
    let cap0 = options[initial].capacity_img_per_sec;

    // 1.6x overload: at saturation, batching must buy goodput (amortized
    // weight fetches), not merely shift latency around.
    let mut goodput = [0.0f64; 2];
    for (i, (tag, max_size)) in [("batch1_saturated", 1usize), ("batch8_saturated", 8)]
        .into_iter()
        .enumerate()
    {
        let mut cfg = DesConfig::new(
            ArrivalProcess::Poisson { rate_per_sec: 1.6 * cap0 },
            horizon_ms,
            seed,
        );
        if max_size > 1 {
            cfg.serve.batch = Some(BatchConfig { max_size, max_wait_ms: 2.0 });
        }
        let r = run_des(&options, initial, &cluster, &mut cost, &g, &cfg, None)?;
        let batch_mean = if r.batches_dispatched > 0 {
            r.batch_members as f64 / r.batches_dispatched as f64
        } else {
            f64::NAN
        };
        goodput[i] = r.throughput_img_per_sec;
        b.row(&format!(
            "{tag:22} seed {seed}: {:5}/{:5} images, {:7.1} img/s goodput, \
             batch mean {batch_mean:5.2}, p99 {:9.2} ms",
            r.completed,
            r.offered,
            r.throughput_img_per_sec,
            r.latency_ms.percentile(99.0).unwrap_or(0.0),
        ));
        report.push(
            BenchEntry::new(tag)
                .metric("offered", r.offered as f64)
                .metric("completed", r.completed as f64)
                .metric("goodput_img_per_sec", r.throughput_img_per_sec)
                .metric("batch_mean", batch_mean)
                .metric("p99_ms", r.latency_ms.percentile(99.0).unwrap_or(f64::NAN))
                .wall("wall_ms", r.wall_ms),
        );
    }
    anyhow::ensure!(
        goodput[1] > goodput[0],
        "batched dispatch must raise saturated goodput (batch8 {:.1} <= batch1 {:.1} img/s)",
        goodput[1],
        goodput[0]
    );

    // tail-drop at 2x overload: the queue stays bounded and the sheds
    // account for everything the bound refused
    let mut cfg = DesConfig::new(
        ArrivalProcess::Poisson { rate_per_sec: 2.0 * cap0 },
        horizon_ms,
        seed,
    );
    cfg.serve.admission = Some(AdmissionConfig {
        policy: ShedPolicy::TailDrop,
        queue_cap: 12,
        deadline_ns: 0,
        tenant_rate: 0.0,
        tenant_burst: 16.0,
    });
    let r = run_des(&options, initial, &cluster, &mut cost, &g, &cfg, None)?;
    b.row(&format!(
        "{:22} seed {seed}: shed {:5}/{:5}, backlog max {:3}, p99 {:9.2} ms",
        "tail_drop_overload",
        r.shed,
        r.offered,
        r.max_backlog,
        r.latency_ms.percentile(99.0).unwrap_or(0.0),
    ));
    report.push(
        BenchEntry::new("tail_drop_overload")
            .metric("offered", r.offered as f64)
            .metric("completed", r.completed as f64)
            .metric("shed", r.shed as f64)
            .metric(
                "shed_rate",
                if r.offered > 0 { r.shed as f64 / r.offered as f64 } else { 0.0 },
            )
            .metric("max_backlog", r.max_backlog as f64)
            .metric("p99_ms", r.latency_ms.percentile(99.0).unwrap_or(f64::NAN))
            .wall("wall_ms", r.wall_ms),
    );

    // the shipped two-tenant trace through the scenario layer, with the
    // token-bucket gate throttling the bursty tenant
    let text = r#"{
      "name": "bench-trace-replay", "engine": "des",
      "model": "lenet5", "strategy": "pipeline", "family": "zynq", "nodes": 2,
      "arrival": {"kind": "trace", "path": "examples/traces/burst_2tenant.jsonl"},
      "admission": {"policy": "none", "tenant_rate_img_per_sec": 25, "tenant_burst": 6},
      "horizon_ms": 4000, "seed": 5
    }"#;
    let t0 = std::time::Instant::now();
    let rep = Session::new(ScenarioSpec::parse(text)?)?
        .with_calibration(calib.clone())
        .run()
        .map_err(|e| anyhow::anyhow!("trace-replay: {e}"))?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let row = &rep.rows[0];
    let shed_rate_limit: u64 = rep.serve.iter().map(|t| t.shed_rate_limit).sum();
    b.row(&format!(
        "{:22} {:3} tenant row(s): {:5}/{:5} images, rate-limit shed {:4}",
        "trace_replay",
        rep.serve.len(),
        row.completed,
        row.offered,
        shed_rate_limit,
    ));
    report.push(
        BenchEntry::new("trace_replay")
            .metric("offered", row.offered as f64)
            .metric("completed", row.completed as f64)
            .metric("shed_rate", row.shed_rate)
            .metric("shed_rate_limit", shed_rate_limit as f64)
            .metric("goodput_img_per_sec", row.goodput_img_per_sec)
            .metric("tenant_rows", rep.serve.len() as f64)
            .wall("wall_ms", wall_ms),
    );

    b.finish();
    Ok(report)
}

/// E17: the plan-search engine (DESIGN.md §17). Three families of
/// entries, each property-checked *inside* the suite so a regression
/// fails the bench run itself, not only `--check`:
///
/// * `e1_n{2,4,8,12}`  — `Strategy::Search` latency vs the best §II-C
///   heuristic on every E1 grid cell (search must never lose);
/// * `eco_j_n{...}`    — J/image of the right-sizing J-objective search
///   vs the eco selector on the same cells (search must strictly win on
///   at least one cell — surplus boards get powered off);
/// * `fleet_n{16,64,256}` — re-planning latency with a warm cost model
///   at fleet scale; the n = 256 plan must land in under a second.
pub fn search_suite(calib: &Calibration) -> anyhow::Result<BenchReport> {
    use crate::power::eco_plan;
    use crate::search::{search_plan, Objective, SearchConfig};
    use crate::sim::{simulate, SimConfig};

    let mut b = Bench::new("plan_search");
    let mut report = BenchReport::new("search");
    let reps = if report.fast { 2usize } else { 5 };

    let family = BoardFamily::Zynq7000;
    let g = zoo::build("resnet18", 0)?;
    let vta = VtaConfig::table1_zynq7000();
    let mut cost =
        CostModel::new(vta.clone(), BoardProfile::for_family(family), calib.clone());

    // E1 dominance: search ≤ best heuristic on every grid cell
    for n in [2usize, 4, 8, 12] {
        let cluster = ClusterConfig::homogeneous(family, n).with_vta(vta.clone());
        let seg_costs = cost.seg_cost_table(&g)?;
        let mut best_heur = f64::INFINITY;
        let mut best_name = "";
        for s in Strategy::all() {
            let plan = crate::sched::build_plan_priced(s, &g, n, &seg_costs)?;
            let sim = simulate(&plan, &cluster, &mut cost, &g, &SimConfig { images: 16 })?;
            if sim.latency_ms.mean() < best_heur {
                best_heur = sim.latency_ms.mean();
                best_name = s.as_str();
            }
        }
        let out = search_plan(&g, &cluster, &mut cost, &SearchConfig::default())?;
        anyhow::ensure!(
            out.latency_ms <= best_heur * 1.0001,
            "E1 n={n}: best heuristic {best_name} ({best_heur:.3} ms) beats \
             search ({:.3} ms via {})",
            out.latency_ms,
            out.via
        );
        let gap_pct = (best_heur - out.latency_ms) / best_heur * 100.0;
        b.row(&format!(
            "e1_n{n:<3} search {:8.3} ms via {:8} vs best heuristic {best_name:8} \
             {best_heur:8.3} ms  (gap {gap_pct:5.2}%)",
            out.latency_ms, out.via,
        ));
        report.push(
            BenchEntry::new(&format!("e1_n{n}"))
                .metric("search_latency_ms", out.latency_ms)
                .metric("best_heuristic_ms", best_heur)
                .metric("gap_pct", gap_pct),
        );
    }

    // J/image: the right-sizing search vs eco on the same cells; the
    // acceptance property is ≥ 1 strict win
    let mut j_wins = 0usize;
    for n in [2usize, 4, 8, 12] {
        let cluster = ClusterConfig::homogeneous(family, n).with_vta(vta.clone());
        let eco = eco_plan(&g, &cluster, &mut cost, None)?;
        let cfg = SearchConfig {
            objective: Objective::JPerImage,
            rightsize: true,
            ..Default::default()
        };
        let out = search_plan(&g, &cluster, &mut cost, &cfg)?;
        anyhow::ensure!(
            out.j_per_image <= eco.j_per_image * 1.0001,
            "n={n}: eco ({:.4} J) beats the J-objective search ({:.4} J)",
            eco.j_per_image,
            out.j_per_image
        );
        let strict = out.j_per_image < eco.j_per_image * 0.9999;
        j_wins += strict as usize;
        b.row(&format!(
            "eco_j_n{n:<2} search {:7.4} J/img via {:6} on {:>2} node(s) vs eco {:7.4} J/img{}",
            out.j_per_image,
            out.via,
            out.nodes_used,
            eco.j_per_image,
            if strict { "  STRICT WIN" } else { "" },
        ));
        report.push(
            BenchEntry::new(&format!("eco_j_n{n}"))
                .metric("search_j_per_image", out.j_per_image)
                .metric("eco_j_per_image", eco.j_per_image)
                .metric("search_wins", strict as u64 as f64)
                .metric("nodes_used", out.nodes_used as f64),
        );
    }
    anyhow::ensure!(
        j_wins >= 1,
        "the J-objective search must strictly beat eco on ≥ 1 E1 cell (0 wins)"
    );

    // re-planning throughput at fleet scale: warm the cost model with
    // one unmeasured search, then time `reps` re-plans
    for n in [16usize, 64, 256] {
        let cluster = ClusterConfig::homogeneous(family, n).with_vta(vta.clone());
        let cfg = SearchConfig::default();
        let warm = search_plan(&g, &cluster, &mut cost, &cfg)?;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            search_plan(&g, &cluster, &mut cost, &cfg)?;
        }
        let plan_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        if n == 256 {
            anyhow::ensure!(
                plan_ms < 1000.0,
                "fleet re-planning at n=256 took {plan_ms:.0} ms (must be < 1 s)"
            );
        }
        b.row(&format!(
            "fleet_n{n:<4} {plan_ms:8.1} ms/plan ({:6.1} plans/s)  via {:6}  \
             latency {:8.3} ms  explored {:6} pruned {:6}",
            1e3 / plan_ms,
            warm.via,
            warm.latency_ms,
            warm.stats.explored,
            warm.stats.pruned,
        ));
        report.push(
            BenchEntry::new(&format!("fleet_n{n}"))
                .metric("latency_ms", warm.latency_ms)
                .metric("explored", warm.stats.explored as f64)
                .wall("plan_ms", plan_ms)
                .wall("plans_per_sec", 1e3 / plan_ms),
        );
    }

    b.finish();
    Ok(report)
}

/// Build one suite by name (the `vtacluster bench --suite` dispatch).
pub fn run_suite(
    name: &str,
    scenarios_dir: &Path,
    calib: &Calibration,
) -> anyhow::Result<BenchReport> {
    match name {
        "des" => des_suite(calib),
        "scenarios" => scenarios_suite(scenarios_dir, calib),
        "faults" => faults_suite(calib),
        "serve" => serve_suite(calib),
        "search" => search_suite(calib),
        other => anyhow::bail!(
            "unknown bench suite '{other}' (des|scenarios|faults|serve|search|all)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_suite_is_deterministic_and_fills_the_schema() {
        std::env::set_var("VTA_BENCH_FAST", "1");
        let calib = Calibration::default();
        let a = faults_suite(&calib).unwrap();
        let b = faults_suite(&calib).unwrap();
        assert_eq!(a.suite, "faults");
        assert_eq!(a.entries.len(), 4);
        assert_eq!(a.entries[0].name, "crash-controller-on");
        // deterministic metrics → a self-check passes at zero tolerance
        let (notes, failures) = a.check_against(&b, 0.0);
        assert!(failures.is_empty(), "{failures:?}");
        assert!(notes.is_empty(), "{notes:?}");
        // wall figures present but never part of the gate
        assert!(a.entries.iter().all(|e| !e.wall.is_empty()));
        // JSON roundtrip through the stable schema (string-compare: NaN
        // metrics travel as null, and NaN != NaN under PartialEq)
        let back = BenchReport::from_json(&a.to_json()).unwrap();
        assert_eq!(json::pretty(&back.to_json()), json::pretty(&a.to_json()));
    }

    #[test]
    fn serve_suite_is_deterministic_and_batching_buys_goodput() {
        std::env::set_var("VTA_BENCH_FAST", "1");
        let calib = Calibration::default();
        let a = serve_suite(&calib).unwrap();
        let b = serve_suite(&calib).unwrap();
        assert_eq!(a.suite, "serve");
        assert_eq!(a.entries.len(), 4);
        assert_eq!(a.entries[0].name, "batch1_saturated");
        assert_eq!(a.entries[3].name, "trace_replay");
        let (notes, failures) = a.check_against(&b, 0.0);
        assert!(failures.is_empty(), "{failures:?}");
        assert!(notes.is_empty(), "{notes:?}");
        // the E16 acceptance property, also enforced inside the suite:
        // at saturation, batched goodput strictly beats max_size=1
        let goodput = |i: usize| -> f64 {
            a.entries[i]
                .metrics
                .iter()
                .find(|(k, _)| k == "goodput_img_per_sec")
                .expect("goodput metric")
                .1
        };
        assert!(goodput(1) > goodput(0), "{} <= {}", goodput(1), goodput(0));
        // the replayed trace offers exactly its line count, and the rate
        // gate sheds some of the bursty tenant's wave
        let trace = &a.entries[3];
        let m = |k: &str| trace.metrics.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(m("offered"), 88.0);
        assert_eq!(m("tenant_rows"), 2.0);
        assert!(m("shed_rate_limit") > 0.0);
        let back = BenchReport::from_json(&a.to_json()).unwrap();
        assert_eq!(json::pretty(&back.to_json()), json::pretty(&a.to_json()));
    }

    #[test]
    fn search_suite_dominates_and_is_deterministic() {
        std::env::set_var("VTA_BENCH_FAST", "1");
        let calib = Calibration::default();
        // the suite's own ensure!s are the E17 acceptance gate: search
        // never loses an E1 cell and strictly beats eco's J somewhere
        let a = search_suite(&calib).unwrap();
        assert_eq!(a.suite, "search");
        assert_eq!(a.entries.len(), 4 + 4 + 3);
        assert_eq!(a.entries[0].name, "e1_n2");
        assert_eq!(a.entries[10].name, "fleet_n256");
        let wins: f64 = a
            .entries
            .iter()
            .flat_map(|e| e.metrics.iter())
            .filter(|(k, _)| k == "search_wins")
            .map(|(_, v)| v)
            .sum();
        assert!(wins >= 1.0, "no strict J/image win recorded");
        // deterministic metrics → a re-run self-checks at zero tolerance
        let b = search_suite(&calib).unwrap();
        let (notes, failures) = a.check_against(&b, 0.0);
        assert!(failures.is_empty(), "{failures:?}");
        assert!(notes.is_empty(), "{notes:?}");
        let back = BenchReport::from_json(&a.to_json()).unwrap();
        assert_eq!(json::pretty(&back.to_json()), json::pretty(&a.to_json()));
    }

    #[test]
    fn suite_dispatch_rejects_unknown_names() {
        let calib = Calibration::default();
        let e = run_suite("quantum", Path::new("."), &calib).unwrap_err().to_string();
        assert!(e.contains("quantum"), "{e}");
    }
}
