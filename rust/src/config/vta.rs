//! VTA accelerator configuration — the paper's Table I plus the §IV
//! scaling variants.
//!
//! | PARAMETER                     | Table I value |
//! |-------------------------------|---------------|
//! | CLOCK_FREQUENCY (Zynq-7000)   | 100 MHz       |
//! | CLOCK_FREQUENCY (UltraScale+) | 300 MHz       |
//! | INPUT_WIDTH / WEIGHT_WIDTH    | 8 bit         |
//! | ACCUMULATOR_WIDTH             | 32 bit        |
//! | BATCH_SIZE                    | 1             |
//! | BLOCK_SIZE                    | 16            |
//! | MICRO_OP_BUFFER_SIZE          | 32 Kb         |
//! | INPUT_BUFFER_SIZE             | 32 Kb         |
//! | WEIGHT_BUFFER_SIZE            | 256 Kb        |
//! | ACCUMULATOR_BUFFER_SIZE       | 128 Kb        |
//!
//! §IV additionally evaluates: (a) UltraScale+ at 350 MHz (timing-closure
//! limit), ≈5.7 % faster; (b) BLOCK=32 with doubled buffers at 200 MHz,
//! ≈43.86 % faster. Both are constructors here and rows in the
//! `discussion_scaling` bench.

use crate::util::json::{self, Json};

/// Buffer sizes in Table I are written in **kilobits** (Kb).
const KBIT: u64 = 1024;

#[derive(Debug, Clone, PartialEq)]
pub struct VtaConfig {
    /// Human-readable variant name (appears in bench output).
    pub name: String,
    /// PL clock in Hz (Table I: 100 MHz Zynq / 300 MHz US+).
    pub clock_hz: u64,
    /// Input operand width in bits (8).
    pub input_width: u32,
    /// Weight operand width in bits (8).
    pub weight_width: u32,
    /// Accumulator width in bits (32).
    pub acc_width: u32,
    /// GEMM batch dimension (1).
    pub batch: u32,
    /// GEMM block dimension: the core computes `batch × block × block`
    /// MACs per cycle when fully fed (16; 32 in the §IV big config).
    pub block: u32,
    /// Micro-op buffer capacity in bits.
    pub uop_buffer_bits: u64,
    /// Input SRAM buffer capacity in bits.
    pub input_buffer_bits: u64,
    /// Weight SRAM buffer capacity in bits.
    pub weight_buffer_bits: u64,
    /// Accumulator SRAM buffer capacity in bits.
    pub acc_buffer_bits: u64,
}

impl VtaConfig {
    /// Table I on the Zynq-7000 stack (100 MHz).
    pub fn table1_zynq7000() -> Self {
        VtaConfig {
            name: "table1-zynq7000".into(),
            clock_hz: 100_000_000,
            input_width: 8,
            weight_width: 8,
            acc_width: 32,
            batch: 1,
            block: 16,
            uop_buffer_bits: 32 * KBIT,
            input_buffer_bits: 32 * KBIT,
            weight_buffer_bits: 256 * KBIT,
            acc_buffer_bits: 128 * KBIT,
        }
    }

    /// Table I on the UltraScale+ stack (300 MHz).
    pub fn table1_ultrascale() -> Self {
        VtaConfig {
            name: "table1-ultrascale".into(),
            clock_hz: 300_000_000,
            ..Self::table1_zynq7000()
        }
    }

    /// §IV: UltraScale+ pushed to the 350 MHz timing-closure limit.
    pub fn ultrascale_350mhz() -> Self {
        VtaConfig {
            name: "ultrascale-350mhz".into(),
            clock_hz: 350_000_000,
            ..Self::table1_zynq7000()
        }
    }

    /// §IV big config: BLOCK=32, uop+input 64 Kb, weight 512 Kb,
    /// accumulator 256 Kb, clock reduced to 200 MHz for hold-slack.
    pub fn big_config_200mhz() -> Self {
        VtaConfig {
            name: "big-200mhz".into(),
            clock_hz: 200_000_000,
            block: 32,
            uop_buffer_bits: 64 * KBIT,
            input_buffer_bits: 64 * KBIT,
            weight_buffer_bits: 512 * KBIT,
            acc_buffer_bits: 256 * KBIT,
            ..Self::table1_zynq7000()
        }
    }

    /// Same geometry as Table I at an arbitrary clock (clock sweeps).
    pub fn table1_at_clock(clock_hz: u64) -> Self {
        VtaConfig {
            name: format!("table1-{}mhz", clock_hz / 1_000_000),
            clock_hz,
            ..Self::table1_zynq7000()
        }
    }

    // ----- derived quantities -------------------------------------------

    /// Peak MACs per cycle = batch × block × block (GEMM core width).
    pub fn macs_per_cycle(&self) -> u64 {
        self.batch as u64 * self.block as u64 * self.block as u64
    }

    /// Peak GMAC/s at the configured clock.
    pub fn peak_gmacs(&self) -> f64 {
        self.macs_per_cycle() as f64 * self.clock_hz as f64 / 1e9
    }

    /// Input buffer capacity in **elements** (int8).
    pub fn input_buffer_elems(&self) -> u64 {
        self.input_buffer_bits / self.input_width as u64
    }

    /// Weight buffer capacity in elements (int8).
    pub fn weight_buffer_elems(&self) -> u64 {
        self.weight_buffer_bits / self.weight_width as u64
    }

    /// Accumulator buffer capacity in elements (int32).
    pub fn acc_buffer_elems(&self) -> u64 {
        self.acc_buffer_bits / self.acc_width as u64
    }

    /// How many (block × block) weight tiles fit in the weight buffer.
    pub fn weight_tiles_resident(&self) -> u64 {
        self.weight_buffer_elems() / (self.block as u64 * self.block as u64)
    }

    /// How many (batch × block) input rows fit in the input buffer.
    pub fn input_rows_resident(&self) -> u64 {
        self.input_buffer_elems() / self.block as u64
    }

    /// How many (batch × block) accumulator rows fit.
    pub fn acc_rows_resident(&self) -> u64 {
        self.acc_buffer_elems() / self.block as u64
    }

    /// Validate internal consistency (used on config load).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.clock_hz >= 10_000_000, "clock below 10 MHz is not plausible");
        anyhow::ensure!(self.clock_hz <= 1_000_000_000, "PL clock above 1 GHz is not plausible");
        anyhow::ensure!(self.block.is_power_of_two(), "GEMM block must be a power of two");
        anyhow::ensure!(self.batch >= 1, "batch must be ≥ 1");
        anyhow::ensure!(
            self.input_width == 8 && self.weight_width == 8,
            "only int8 operands supported (paper Table I)"
        );
        anyhow::ensure!(self.acc_width == 32, "only int32 accumulation supported");
        // one weight tile must fit in the weight buffer
        anyhow::ensure!(
            self.weight_tiles_resident() >= 1,
            "weight buffer smaller than one {0}×{0} tile",
            self.block
        );
        anyhow::ensure!(self.input_rows_resident() >= 1, "input buffer < one row");
        anyhow::ensure!(self.acc_rows_resident() >= 1, "acc buffer < one row");
        Ok(())
    }

    // ----- (de)serialization --------------------------------------------

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::str_(&self.name)),
            ("clock_hz", json::int(self.clock_hz as i64)),
            ("input_width", json::int(self.input_width as i64)),
            ("weight_width", json::int(self.weight_width as i64)),
            ("acc_width", json::int(self.acc_width as i64)),
            ("batch", json::int(self.batch as i64)),
            ("block", json::int(self.block as i64)),
            ("uop_buffer_bits", json::int(self.uop_buffer_bits as i64)),
            ("input_buffer_bits", json::int(self.input_buffer_bits as i64)),
            ("weight_buffer_bits", json::int(self.weight_buffer_bits as i64)),
            ("acc_buffer_bits", json::int(self.acc_buffer_bits as i64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let cfg = VtaConfig {
            name: j.get_str("name")?.to_string(),
            clock_hz: j.get_u64("clock_hz")?,
            input_width: j.get_u64("input_width")? as u32,
            weight_width: j.get_u64("weight_width")? as u32,
            acc_width: j.get_u64("acc_width")? as u32,
            batch: j.get_u64("batch")? as u32,
            block: j.get_u64("block")? as u32,
            uop_buffer_bits: j.get_u64("uop_buffer_bits")?,
            input_buffer_bits: j.get_u64("input_buffer_bits")?,
            weight_buffer_bits: j.get_u64("weight_buffer_bits")?,
            acc_buffer_bits: j.get_u64("acc_buffer_bits")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values_match_paper() {
        let c = VtaConfig::table1_zynq7000();
        assert_eq!(c.clock_hz, 100_000_000);
        assert_eq!(c.block, 16);
        assert_eq!(c.input_buffer_bits, 32 * 1024);
        assert_eq!(c.weight_buffer_bits, 256 * 1024);
        assert_eq!(c.acc_buffer_bits, 128 * 1024);
        c.validate().unwrap();
        let u = VtaConfig::table1_ultrascale();
        assert_eq!(u.clock_hz, 300_000_000);
        assert_eq!(u.block, 16);
        u.validate().unwrap();
    }

    #[test]
    fn macs_per_cycle() {
        assert_eq!(VtaConfig::table1_zynq7000().macs_per_cycle(), 256);
        assert_eq!(VtaConfig::big_config_200mhz().macs_per_cycle(), 1024);
    }

    #[test]
    fn peak_gmacs() {
        // 256 MAC/cycle × 100 MHz = 25.6 GMAC/s
        assert!((VtaConfig::table1_zynq7000().peak_gmacs() - 25.6).abs() < 1e-9);
        // big config: 1024 × 200 MHz = 204.8 GMAC/s
        assert!((VtaConfig::big_config_200mhz().peak_gmacs() - 204.8).abs() < 1e-9);
    }

    #[test]
    fn buffer_capacities() {
        let c = VtaConfig::table1_zynq7000();
        // 256 Kb weights / 8 bit = 32768 int8 elements = 128 16×16 tiles
        assert_eq!(c.weight_buffer_elems(), 32 * 1024);
        assert_eq!(c.weight_tiles_resident(), 128);
        // 32 Kb input / 8 = 4096 elements = 256 rows of 16
        assert_eq!(c.input_rows_resident(), 256);
        // 128 Kb acc / 32 = 4096 elements = 256 rows of 16
        assert_eq!(c.acc_rows_resident(), 256);
    }

    #[test]
    fn big_config_buffers_doubled() {
        let c = VtaConfig::big_config_200mhz();
        assert_eq!(c.weight_buffer_bits, 512 * 1024);
        assert_eq!(c.uop_buffer_bits, 64 * 1024);
        assert_eq!(c.acc_buffer_bits, 256 * 1024);
        assert_eq!(c.clock_hz, 200_000_000);
        c.validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            VtaConfig::table1_zynq7000(),
            VtaConfig::table1_ultrascale(),
            VtaConfig::ultrascale_350mhz(),
            VtaConfig::big_config_200mhz(),
        ] {
            let j = cfg.to_json();
            let back = VtaConfig::from_json(&j).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = VtaConfig::table1_zynq7000();
        c.block = 12;
        assert!(c.validate().is_err());
        let mut c = VtaConfig::table1_zynq7000();
        c.weight_buffer_bits = 8; // smaller than one tile
        assert!(c.validate().is_err());
        let mut c = VtaConfig::table1_zynq7000();
        c.clock_hz = 5_000_000;
        assert!(c.validate().is_err());
    }
}
