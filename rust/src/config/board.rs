//! FPGA SoC board profiles for the two cluster variants of §II-A.
//!
//! * **Zynq-7020** (PYNQ-Z1 / ZedBoard): 13,300 logic slices, 630 KB BRAM,
//!   220 DSP slices; PS = 650 MHz dual-core Cortex-A9, DDR3.
//! * **Zynq UltraScale+ MPSoC**: larger PL, PS = 1.5 GHz quad-core
//!   Cortex-A53 (+ R5, Mali GPU), DDR4.
//!
//! The profile carries everything the timing model needs: PL resources
//! (to check a [`VtaConfig`] fits), PS CPU speed (driver + DMA overhead
//! scaling) and DRAM bandwidth (the memory-bound term that explains why
//! the US+ single-node time is only ~6 % better despite a 3× clock).

use super::vta::VtaConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoardFamily {
    Zynq7000,
    UltraScalePlus,
}

impl BoardFamily {
    pub fn as_str(&self) -> &'static str {
        match self {
            BoardFamily::Zynq7000 => "zynq7000",
            BoardFamily::UltraScalePlus => "ultrascale+",
        }
    }

    /// Accepts family names and the concrete board names the docs use
    /// ("pynq-z1", "zedboard" → Zynq-7000; "zcu104" → US+ MPSoC).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "zynq7000" | "zynq-7000" | "zynq7020" | "zynq-7020" | "zynq" | "pynq-z1"
            | "pynq" | "zedboard" => Ok(BoardFamily::Zynq7000),
            "ultrascale+" | "ultrascale" | "zu+" | "mpsoc" | "zcu104" => {
                Ok(BoardFamily::UltraScalePlus)
            }
            other => anyhow::bail!("unknown board family '{other}'"),
        }
    }
}

impl std::fmt::Display for BoardFamily {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Static description of one FPGA SoC board.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardProfile {
    pub name: String,
    pub family: BoardFamily,
    // --- programmable logic resources
    /// LUTs available in the PL.
    pub luts: u64,
    /// Flip-flops available in the PL.
    pub ffs: u64,
    /// Block RAM capacity in kilobits.
    pub bram_kbits: u64,
    /// DSP slices (each does one 8-bit MAC/cycle comfortably).
    pub dsp_slices: u64,
    // --- processing system
    /// Application CPU clock in Hz.
    pub cpu_hz: u64,
    /// CPU core count.
    pub cpu_cores: u32,
    // --- memory system
    /// Peak DRAM bandwidth in bytes/s (DDR3-1066 32-bit ≈ 4.3 GB/s for
    /// Zynq-7020; DDR4 ≈ 19.2 GB/s for ZU+).
    pub dram_bw_bytes_per_sec: u64,
    // --- network
    /// PS GEM Ethernet line rate in bits/s (1 Gb/s on both).
    pub eth_bits_per_sec: u64,
}

impl BoardProfile {
    /// PYNQ-Z1 / ZedBoard (Zynq-7020 APSoC). §II-A figures.
    pub fn zynq7020() -> Self {
        BoardProfile {
            name: "zynq-7020".into(),
            family: BoardFamily::Zynq7000,
            luts: 53_200,
            ffs: 106_400,
            bram_kbits: 630 * 8, // 630 KB
            dsp_slices: 220,
            cpu_hz: 650_000_000,
            cpu_cores: 2,
            dram_bw_bytes_per_sec: 4_264_000_000, // DDR3-1066 × 32 bit
            eth_bits_per_sec: 1_000_000_000,
        }
    }

    /// Zynq UltraScale+ MPSoC (ZU3EG-class figure set).
    pub fn zu_mpsoc() -> Self {
        BoardProfile {
            name: "zynq-ultrascale+".into(),
            family: BoardFamily::UltraScalePlus,
            luts: 154_350,
            ffs: 308_700,
            bram_kbits: 7_600,
            dsp_slices: 1_728,
            cpu_hz: 1_500_000_000,
            cpu_cores: 4,
            dram_bw_bytes_per_sec: 19_200_000_000, // DDR4-2400 × 64 bit
            eth_bits_per_sec: 1_000_000_000,
        }
    }

    pub fn for_family(family: BoardFamily) -> Self {
        match family {
            BoardFamily::Zynq7000 => Self::zynq7020(),
            BoardFamily::UltraScalePlus => Self::zu_mpsoc(),
        }
    }

    /// The Table-I clock for this board family (100 / 300 MHz).
    pub fn default_vta(&self) -> VtaConfig {
        match self.family {
            BoardFamily::Zynq7000 => VtaConfig::table1_zynq7000(),
            BoardFamily::UltraScalePlus => VtaConfig::table1_ultrascale(),
        }
    }

    /// Rough PL resource estimate for a VTA configuration, mirroring the
    /// published VTA resource tables: the GEMM core needs ~`block²`
    /// MAC units (DSP-mapped at 2 int8 MACs per DSP48) plus buffers in
    /// BRAM. Used to decide whether a bitstream would fit/close timing.
    pub fn vta_fits(&self, cfg: &VtaConfig) -> anyhow::Result<()> {
        let macs = cfg.macs_per_cycle();
        let dsp_needed = macs / 2; // two int8 MACs per DSP48
        anyhow::ensure!(
            dsp_needed <= self.dsp_slices,
            "VTA '{}' needs ~{dsp_needed} DSP slices, board '{}' has {}",
            cfg.name,
            self.name,
            self.dsp_slices
        );
        let bram_needed_kbits = (cfg.input_buffer_bits
            + cfg.weight_buffer_bits
            + cfg.acc_buffer_bits
            + cfg.uop_buffer_bits)
            / 1024
            * 2; // double-buffering
        anyhow::ensure!(
            bram_needed_kbits <= self.bram_kbits,
            "VTA '{}' needs ~{bram_needed_kbits} Kb BRAM, board '{}' has {} Kb",
            cfg.name,
            self.name,
            self.bram_kbits
        );
        // timing closure: paper found 100 MHz limit on Zynq-7000 and
        // 350 MHz on US+ for BLOCK=16; BLOCK=32 closed at 200 MHz.
        let fmax = self.timing_fmax_hz(cfg.block);
        anyhow::ensure!(
            cfg.clock_hz <= fmax,
            "VTA '{}' at {} MHz exceeds {} timing closure limit (~{} MHz for block {})",
            cfg.name,
            cfg.clock_hz / 1_000_000,
            self.name,
            fmax / 1_000_000,
            cfg.block
        );
        Ok(())
    }

    /// Empirical timing-closure limit per family and GEMM block size
    /// (paper §III: Zynq could not close beyond 100 MHz; §IV: US+ closed
    /// at 350 MHz with BLOCK=16 and 200 MHz with BLOCK=32).
    pub fn timing_fmax_hz(&self, block: u32) -> u64 {
        match (self.family, block) {
            (BoardFamily::Zynq7000, b) if b <= 16 => 100_000_000,
            (BoardFamily::Zynq7000, _) => 50_000_000,
            (BoardFamily::UltraScalePlus, b) if b <= 16 => 350_000_000,
            (BoardFamily::UltraScalePlus, _) => 200_000_000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zynq_profile_matches_paper_text() {
        let b = BoardProfile::zynq7020();
        assert_eq!(b.dsp_slices, 220);
        assert_eq!(b.cpu_hz, 650_000_000);
        assert_eq!(b.cpu_cores, 2);
        assert_eq!(b.eth_bits_per_sec, 1_000_000_000);
    }

    #[test]
    fn table1_fits_both_boards() {
        BoardProfile::zynq7020().vta_fits(&VtaConfig::table1_zynq7000()).unwrap();
        BoardProfile::zu_mpsoc().vta_fits(&VtaConfig::table1_ultrascale()).unwrap();
        BoardProfile::zu_mpsoc().vta_fits(&VtaConfig::ultrascale_350mhz()).unwrap();
        BoardProfile::zu_mpsoc().vta_fits(&VtaConfig::big_config_200mhz()).unwrap();
    }

    #[test]
    fn big_config_rejected_on_zynq() {
        // BLOCK=32 needs 512 DSP slices — more than the 7020's 220.
        let err = BoardProfile::zynq7020()
            .vta_fits(&VtaConfig::big_config_200mhz())
            .unwrap_err()
            .to_string();
        assert!(err.contains("DSP"), "{err}");
    }

    #[test]
    fn overclock_rejected_by_timing_model() {
        let mut cfg = VtaConfig::table1_zynq7000();
        cfg.clock_hz = 200_000_000; // paper: Zynq-7000 could not close beyond 100
        assert!(BoardProfile::zynq7020().vta_fits(&cfg).is_err());
        let mut cfg = VtaConfig::table1_ultrascale();
        cfg.clock_hz = 400_000_000; // §IV: 350 was the limit
        assert!(BoardProfile::zu_mpsoc().vta_fits(&cfg).is_err());
    }

    #[test]
    fn family_parse() {
        assert_eq!(BoardFamily::parse("zynq").unwrap(), BoardFamily::Zynq7000);
        assert_eq!(BoardFamily::parse("ZU+").unwrap(), BoardFamily::UltraScalePlus);
        // concrete board names from the docs are aliases
        assert_eq!(BoardFamily::parse("pynq-z1").unwrap(), BoardFamily::Zynq7000);
        assert_eq!(BoardFamily::parse("ZedBoard").unwrap(), BoardFamily::Zynq7000);
        assert_eq!(BoardFamily::parse("zcu104").unwrap(), BoardFamily::UltraScalePlus);
        assert!(BoardFamily::parse("virtex").is_err());
    }

    #[test]
    fn family_display_matches_as_str() {
        assert_eq!(BoardFamily::Zynq7000.to_string(), "zynq7000");
        assert_eq!(BoardFamily::UltraScalePlus.to_string(), "ultrascale+");
    }

    #[test]
    fn usplus_has_more_of_everything() {
        let z = BoardProfile::zynq7020();
        let u = BoardProfile::zu_mpsoc();
        assert!(u.luts > z.luts);
        assert!(u.dsp_slices > z.dsp_slices);
        assert!(u.cpu_hz > z.cpu_hz);
        assert!(u.dram_bw_bytes_per_sec > z.dram_bw_bytes_per_sec);
    }
}
