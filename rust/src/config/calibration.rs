//! Calibration constants for the timing model.
//!
//! The paper's absolute numbers come from physical boards we do not have;
//! the reproduction targets the *shape* of the results (DESIGN.md §6).
//! The timing model is physical (cycles, DRAM traffic, Ethernet frames)
//! with a small set of free constants fitted once against the paper's
//! anchor measurements:
//!
//! * single-FPGA inference: 27.34 ms (Zynq-7000) / 25.15 ms (US+)  [§III]
//! * US+ at 350 MHz: ≈5.7 % faster                                  [§IV]
//! * US+ big config (BLOCK=32 @200 MHz, 2× buffers): ≈43.86 % faster [§IV]
//! * scatter-gather + AI-core rows at N=2 (network-overhead anchors) [Fig 3]
//!
//! `exp::calibrate` performs the fit and records the residuals in
//! EXPERIMENTS.md. Everything not listed above is *predicted*, not fitted.

use crate::util::json::{self, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Fraction of peak GEMM MACs/cycle the AutoTVM-tuned kernel achieves
    /// (pipeline stalls, edge tiles, dependency-queue bubbles).
    pub gemm_efficiency: f64,
    /// Fraction of peak DRAM bandwidth achieved by VTA load/store DMA.
    pub dram_efficiency: f64,
    /// Fixed PS driver overhead per inference launch, µs (instruction
    /// stream setup, cache flushes, interrupt round-trips).
    pub driver_overhead_us: f64,
    /// Blocking-MPI rendezvous handshake per message, µs (the paper
    /// blames these for the N=2..6 AI-core-assignment slowdown).
    pub mpi_handshake_us: f64,
    /// PS CPU cost to stage one byte between PL DMA buffers and the
    /// network stack, ns/byte (memcpy + checksum + driver).
    pub dma_cpu_ns_per_byte: f64,
    /// Fraction of a blocking transfer during which the node can do no
    /// other work (1.0 = fully serial PS+PL; lower values model the
    /// second A9/A53 core overlapping network I/O with VTA compute).
    pub ps_serial_frac: f64,
    /// Per-family absolute anchor: scales the modeled single-node time to
    /// the paper's measured value. Applied uniformly within a family so
    /// scaling *shapes* are untouched. (paper-ms / model-ms)
    pub kappa_zynq: f64,
    pub kappa_ultrascale: f64,
}

impl Default for Calibration {
    /// Values from the `exp::calibrate` fit (see EXPERIMENTS.md §Calibration).
    fn default() -> Self {
        Calibration {
            gemm_efficiency: 0.55,
            dram_efficiency: 0.45,
            driver_overhead_us: 1500.0,
            mpi_handshake_us: 300.0,
            dma_cpu_ns_per_byte: 2.0,
            ps_serial_frac: 0.4,
            kappa_zynq: 0.113,
            kappa_ultrascale: 0.333,
        }
    }
}

impl Calibration {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.05..=1.0).contains(&self.gemm_efficiency),
            "gemm_efficiency out of range"
        );
        anyhow::ensure!(
            (0.05..=1.0).contains(&self.dram_efficiency),
            "dram_efficiency out of range"
        );
        anyhow::ensure!(self.driver_overhead_us >= 0.0, "negative driver overhead");
        anyhow::ensure!(self.mpi_handshake_us >= 0.0, "negative handshake");
        anyhow::ensure!(self.dma_cpu_ns_per_byte >= 0.0, "negative DMA cost");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.ps_serial_frac),
            "ps_serial_frac out of range"
        );
        anyhow::ensure!(self.kappa_zynq > 0.0 && self.kappa_ultrascale > 0.0, "kappa ≤ 0");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("gemm_efficiency", json::num(self.gemm_efficiency)),
            ("dram_efficiency", json::num(self.dram_efficiency)),
            ("driver_overhead_us", json::num(self.driver_overhead_us)),
            ("mpi_handshake_us", json::num(self.mpi_handshake_us)),
            ("dma_cpu_ns_per_byte", json::num(self.dma_cpu_ns_per_byte)),
            ("ps_serial_frac", json::num(self.ps_serial_frac)),
            ("kappa_zynq", json::num(self.kappa_zynq)),
            ("kappa_ultrascale", json::num(self.kappa_ultrascale)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let c = Calibration {
            gemm_efficiency: j.get_f64("gemm_efficiency")?,
            dram_efficiency: j.get_f64("dram_efficiency")?,
            driver_overhead_us: j.get_f64("driver_overhead_us")?,
            mpi_handshake_us: j.get_f64("mpi_handshake_us")?,
            dma_cpu_ns_per_byte: j.get_f64("dma_cpu_ns_per_byte")?,
            ps_serial_frac: j.get_f64("ps_serial_frac")?,
            kappa_zynq: j.get_f64("kappa_zynq")?,
            kappa_ultrascale: j.get_f64("kappa_ultrascale")?,
        };
        c.validate()?;
        Ok(c)
    }

    /// Load from `artifacts/calibration.json` if present, else defaults.
    /// The calibrate bench writes that file; all other benches pick it up.
    pub fn load_or_default(artifacts_dir: &std::path::Path) -> Self {
        let path = artifacts_dir.join("calibration.json");
        match json::from_file(&path).and_then(|j| Self::from_json(&j)) {
            Ok(c) => c,
            Err(_) => Self::default(),
        }
    }

    pub fn save(&self, artifacts_dir: &std::path::Path) -> anyhow::Result<()> {
        let path = artifacts_dir.join("calibration.json");
        std::fs::write(&path, self.to_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        Calibration::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let c = Calibration { gemm_efficiency: 0.42, ..Default::default() };
        let back = Calibration::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn invalid_rejected() {
        let c = Calibration { gemm_efficiency: 1.5, ..Default::default() };
        assert!(c.validate().is_err());
        let c = Calibration { kappa_zynq: 0.0, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn load_or_default_falls_back() {
        let c = Calibration::load_or_default(std::path::Path::new("/nonexistent"));
        assert_eq!(c, Calibration::default());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("vta-calib-test");
        std::fs::create_dir_all(&dir).unwrap();
        let c = Calibration { mpi_handshake_us: 123.0, ..Default::default() };
        c.save(&dir).unwrap();
        let back = Calibration::load_or_default(&dir);
        assert_eq!(back.mpi_handshake_us, 123.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
