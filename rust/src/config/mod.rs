//! All paper knobs in one place.
//!
//! * [`vta`]         — VTA accelerator parameters (Table I + §IV variants)
//! * [`board`]       — FPGA SoC board profiles (Zynq-7020, ZU+ MPSoC)
//! * [`cluster`]     — cluster topology (boards + Ethernet switch + master)
//! * [`calibration`] — fitted cost-model constants with provenance
//! * [`reconfig`]    — modeled FPGA reconfiguration downtime (bitstream
//!                     load + warm-up) charged by the online controller

pub mod board;
pub mod calibration;
pub mod cluster;
pub mod reconfig;
pub mod vta;

pub use board::{BoardFamily, BoardProfile};
pub use calibration::Calibration;
pub use cluster::ClusterConfig;
pub use reconfig::{ReconfigCost, ReconfigTier};
pub use vta::VtaConfig;
