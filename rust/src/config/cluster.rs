//! Cluster topology: N FPGA boards + a store-and-forward Ethernet switch
//! + a master host PC (§II-A/§II-C).
//!
//! The paper's two deployments are `zynq_stack(n)` (up to 12 Zynq-7020)
//! and `ultrascale_stack(n)` (up to 5 ZU+). The master orchestrates; the
//! boards are accelerator nodes. FPGA↔FPGA traffic also traverses the
//! switch (the paper notes direct FPGA-FPGA channels were not fully
//! implemented — all transfers are PS-Ethernet MPI messages, which is
//! exactly what makes the N=2..6 AI-core-assignment rows slow).

use super::board::{BoardFamily, BoardProfile};
use super::vta::VtaConfig;

/// Ethernet switch model parameters (standard 1 Gb/s Cisco switch).
#[derive(Debug, Clone, PartialEq)]
pub struct SwitchConfig {
    /// Per-port line rate, bits/s.
    pub port_bits_per_sec: u64,
    /// Store-and-forward latency per frame (switching + queuing floor).
    pub forward_latency_ns: u64,
    /// Number of ports (master + nodes must fit).
    pub ports: u32,
}

impl Default for SwitchConfig {
    fn default() -> Self {
        SwitchConfig {
            port_bits_per_sec: 1_000_000_000,
            forward_latency_ns: 10_000, // ~10 µs store-and-forward + queue floor
            ports: 16,
        }
    }
}

/// Full cluster description.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub name: String,
    /// Accelerator boards (index = node id; the master is not in here).
    pub boards: Vec<BoardProfile>,
    /// VTA bitstream configuration per node (same for all in the paper).
    pub vta: VtaConfig,
    pub switch: SwitchConfig,
    /// Master host NIC line rate, bits/s (1 Gb/s RJ-45).
    pub master_bits_per_sec: u64,
}

impl ClusterConfig {
    /// Homogeneous cluster of `n` boards of one family with its Table-I VTA.
    ///
    /// The switch is sized to fit the inventory (`max(16, n + 1)` ports)
    /// so fleet-scale clusters of hundreds of boards (DESIGN.md §17)
    /// validate; at paper scale (≤ 15 boards) this is the default
    /// 16-port switch, unchanged.
    pub fn homogeneous(family: BoardFamily, n: usize) -> Self {
        let board = BoardProfile::for_family(family);
        let vta = board.default_vta();
        let mut switch = SwitchConfig::default();
        switch.ports = switch.ports.max(n as u32 + 1);
        ClusterConfig {
            name: format!("{}-x{}", board.name, n),
            boards: vec![board; n],
            vta,
            switch,
            master_bits_per_sec: 1_000_000_000,
        }
    }

    /// The paper's compute-lite stack: up to 12 Zynq-7020.
    pub fn zynq_stack(n: usize) -> Self {
        assert!((1..=12).contains(&n), "paper evaluates 1..=12 Zynq nodes");
        Self::homogeneous(BoardFamily::Zynq7000, n)
    }

    /// The paper's UltraScale+ stack: up to 5 boards.
    pub fn ultrascale_stack(n: usize) -> Self {
        assert!((1..=5).contains(&n), "paper evaluates 1..=5 US+ nodes");
        Self::homogeneous(BoardFamily::UltraScalePlus, n)
    }

    /// Replace the VTA configuration on every node (§IV variants).
    pub fn with_vta(mut self, vta: VtaConfig) -> Self {
        self.vta = vta;
        self
    }

    pub fn num_nodes(&self) -> usize {
        self.boards.len()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.boards.is_empty(), "cluster has no boards");
        anyhow::ensure!(
            self.boards.len() + 1 <= self.switch.ports as usize,
            "switch has {} ports but cluster needs {} (nodes + master)",
            self.switch.ports,
            self.boards.len() + 1
        );
        self.vta.validate()?;
        for b in &self.boards {
            b.vta_fits(&self.vta)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_stacks_validate() {
        for n in 1..=12 {
            ClusterConfig::zynq_stack(n).validate().unwrap();
        }
        for n in 1..=5 {
            ClusterConfig::ultrascale_stack(n).validate().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "1..=12")]
    fn zynq_stack_bounds() {
        ClusterConfig::zynq_stack(13);
    }

    #[test]
    fn with_vta_override() {
        let c = ClusterConfig::ultrascale_stack(5).with_vta(VtaConfig::big_config_200mhz());
        assert_eq!(c.vta.block, 32);
        c.validate().unwrap();
    }

    #[test]
    fn big_config_on_zynq_is_invalid() {
        let c = ClusterConfig::zynq_stack(4).with_vta(VtaConfig::big_config_200mhz());
        assert!(c.validate().is_err());
    }

    #[test]
    fn fleet_scale_homogeneous_sizes_its_switch() {
        let c = ClusterConfig::homogeneous(BoardFamily::Zynq7000, 200);
        assert_eq!(c.switch.ports, 201);
        c.validate().unwrap();
        // paper scale keeps the default 16-port switch
        assert_eq!(ClusterConfig::zynq_stack(12).switch.ports, 16);
    }

    #[test]
    fn too_many_nodes_for_switch() {
        let mut c = ClusterConfig::zynq_stack(12);
        c.switch.ports = 8;
        assert!(c.validate().is_err());
    }
}
