//! FPGA reconfiguration cost model.
//!
//! The paper's defining feature is that the cluster is *reconfigurable*:
//! pipeline structure can be re-arranged and resources re-allocated to
//! the most computationally intensive layers. Doing that at run time is
//! not free — switching the active [`crate::sched::ExecutionPlan`] means
//! reprogramming the PL (bitstream load over PCAP/ICAP) and
//! re-initialising the VTA driver on every affected node. During that
//! window a node serves nothing, so the online controller
//! ([`crate::sched::online`]) must amortise the downtime against the
//! backlog it expects the new plan to drain.
//!
//! Constants are modeled, not fitted: a Zynq-7020 full bitstream is
//! ~4 MiB and PCAP sustains ~128 MB/s (≈32 ms), plus driver re-init and
//! first-launch instruction-stream setup. ZU+ bitstreams are an order of
//! magnitude larger but the configuration port is faster.
//!
//! Two tiers are modeled (DESIGN.md §14). [`ReconfigTier::Full`] charges
//! the whole-image cost — the conservative bound, and the only option
//! when a node rejoins after a crash (its PL state is gone). A floorplan
//! that confines the plan-dependent logic to a reconfigurable partition
//! unlocks [`ReconfigTier::Partial`]: the partial bitstream is ~5% of
//! the image and the static region (DMA, NoC, driver state) survives, so
//! a plan switch costs a couple of milliseconds instead of tens — which
//! shifts every drain-time break-even the online controller computes.

use super::board::BoardFamily;

/// Which reconfiguration path a plan switch takes (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReconfigTier {
    /// Whole-image reload over PCAP/CSU-DMA + full driver re-init.
    #[default]
    Full,
    /// Partial bitstream into a reconfigurable partition; static region
    /// keeps running, only the swapped partition re-warms.
    Partial,
}

impl ReconfigTier {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReconfigTier::Full => "full",
            ReconfigTier::Partial => "partial",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Ok(ReconfigTier::Full),
            "partial" | "pr" | "dfx" => Ok(ReconfigTier::Partial),
            other => anyhow::bail!("unknown reconfig tier '{other}' (want full|partial)"),
        }
    }
}

impl std::fmt::Display for ReconfigTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Downtime charged when a node switches execution plans.
#[derive(Debug, Clone, PartialEq)]
pub struct ReconfigCost {
    /// Bitstream load over the configuration port, ms.
    pub bitstream_load_ms: f64,
    /// Driver re-init + engine warm-up after reprogramming, ms
    /// (interrupt re-registration, buffer re-pinning, first launch).
    pub warmup_ms: f64,
}

impl Default for ReconfigCost {
    fn default() -> Self {
        Self::zynq7020()
    }
}

impl ReconfigCost {
    /// Zynq-7020: ~4 MiB bitstream over PCAP at ~128 MB/s.
    pub fn zynq7020() -> Self {
        ReconfigCost { bitstream_load_ms: 40.0, warmup_ms: 12.0 }
    }

    /// ZU+ MPSoC: ~30 MiB bitstream, faster CSU DMA configuration path.
    pub fn zu_mpsoc() -> Self {
        ReconfigCost { bitstream_load_ms: 90.0, warmup_ms: 15.0 }
    }

    /// Zynq-7020 partial tier: ~200 KiB partial bitstream over PCAP plus
    /// partition-only warm-up (static region and driver survive).
    pub fn zynq7020_partial() -> Self {
        ReconfigCost { bitstream_load_ms: 1.6, warmup_ms: 0.6 }
    }

    /// ZU+ MPSoC partial tier: larger partition image, faster CSU DMA.
    pub fn zu_mpsoc_partial() -> Self {
        ReconfigCost { bitstream_load_ms: 2.8, warmup_ms: 0.9 }
    }

    pub fn for_family(family: BoardFamily) -> Self {
        match family {
            BoardFamily::Zynq7000 => Self::zynq7020(),
            BoardFamily::UltraScalePlus => Self::zu_mpsoc(),
        }
    }

    /// Tier-aware dispatch: the cost the online controller charges per
    /// plan switch. Crash-rejoin re-flash always pays the full tier
    /// (see [`crate::sim::faults`]) regardless of this selection.
    pub fn for_family_tier(family: BoardFamily, tier: ReconfigTier) -> Self {
        match (family, tier) {
            (BoardFamily::Zynq7000, ReconfigTier::Full) => Self::zynq7020(),
            (BoardFamily::UltraScalePlus, ReconfigTier::Full) => Self::zu_mpsoc(),
            (BoardFamily::Zynq7000, ReconfigTier::Partial) => Self::zynq7020_partial(),
            (BoardFamily::UltraScalePlus, ReconfigTier::Partial) => Self::zu_mpsoc_partial(),
        }
    }

    /// Total per-switch downtime charged to every node (ms). Nodes
    /// reprogram in parallel, so the cluster-wide outage equals the
    /// per-node cost, not its sum.
    pub fn downtime_ms(&self) -> f64 {
        self.bitstream_load_ms + self.warmup_ms
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.bitstream_load_ms >= 0.0 && self.bitstream_load_ms.is_finite(),
            "bitstream_load_ms out of range"
        );
        anyhow::ensure!(
            self.warmup_ms >= 0.0 && self.warmup_ms.is_finite(),
            "warmup_ms out of range"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        for c in [ReconfigCost::zynq7020(), ReconfigCost::zu_mpsoc()] {
            c.validate().unwrap();
            assert!(c.downtime_ms() > 0.0);
        }
    }

    #[test]
    fn family_dispatch() {
        assert_eq!(ReconfigCost::for_family(BoardFamily::Zynq7000), ReconfigCost::zynq7020());
        assert_eq!(
            ReconfigCost::for_family(BoardFamily::UltraScalePlus),
            ReconfigCost::zu_mpsoc()
        );
    }

    #[test]
    fn tier_dispatch_and_partial_strictly_cheaper() {
        for fam in [BoardFamily::Zynq7000, BoardFamily::UltraScalePlus] {
            let full = ReconfigCost::for_family_tier(fam, ReconfigTier::Full);
            let partial = ReconfigCost::for_family_tier(fam, ReconfigTier::Partial);
            assert_eq!(full, ReconfigCost::for_family(fam));
            partial.validate().unwrap();
            // "orders of magnitude": partial is at least 10x cheaper
            assert!(
                partial.downtime_ms() * 10.0 <= full.downtime_ms(),
                "{fam:?}: partial {} vs full {}",
                partial.downtime_ms(),
                full.downtime_ms()
            );
        }
    }

    #[test]
    fn tier_parse_roundtrip() {
        for t in [ReconfigTier::Full, ReconfigTier::Partial] {
            assert_eq!(ReconfigTier::parse(t.as_str()).unwrap(), t);
        }
        assert_eq!(ReconfigTier::parse("dfx").unwrap(), ReconfigTier::Partial);
        assert_eq!(ReconfigTier::default(), ReconfigTier::Full);
        assert!(ReconfigTier::parse("half").is_err());
    }

    #[test]
    fn invalid_rejected() {
        let c = ReconfigCost { bitstream_load_ms: -1.0, warmup_ms: 0.0 };
        assert!(c.validate().is_err());
        let c = ReconfigCost { bitstream_load_ms: f64::NAN, warmup_ms: 0.0 };
        assert!(c.validate().is_err());
    }
}
