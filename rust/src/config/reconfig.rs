//! FPGA reconfiguration cost model.
//!
//! The paper's defining feature is that the cluster is *reconfigurable*:
//! pipeline structure can be re-arranged and resources re-allocated to
//! the most computationally intensive layers. Doing that at run time is
//! not free — switching the active [`crate::sched::ExecutionPlan`] means
//! reprogramming the PL (bitstream load over PCAP/ICAP) and
//! re-initialising the VTA driver on every affected node. During that
//! window a node serves nothing, so the online controller
//! ([`crate::sched::online`]) must amortise the downtime against the
//! backlog it expects the new plan to drain.
//!
//! Constants are modeled, not fitted: a Zynq-7020 full bitstream is
//! ~4 MiB and PCAP sustains ~128 MB/s (≈32 ms), plus driver re-init and
//! first-launch instruction-stream setup. ZU+ bitstreams are an order of
//! magnitude larger but the configuration port is faster. Partial
//! reconfiguration would shrink the load phase; we charge the full-image
//! cost as the conservative bound.

use super::board::BoardFamily;

/// Downtime charged when a node switches execution plans.
#[derive(Debug, Clone, PartialEq)]
pub struct ReconfigCost {
    /// Bitstream load over the configuration port, ms.
    pub bitstream_load_ms: f64,
    /// Driver re-init + engine warm-up after reprogramming, ms
    /// (interrupt re-registration, buffer re-pinning, first launch).
    pub warmup_ms: f64,
}

impl Default for ReconfigCost {
    fn default() -> Self {
        Self::zynq7020()
    }
}

impl ReconfigCost {
    /// Zynq-7020: ~4 MiB bitstream over PCAP at ~128 MB/s.
    pub fn zynq7020() -> Self {
        ReconfigCost { bitstream_load_ms: 40.0, warmup_ms: 12.0 }
    }

    /// ZU+ MPSoC: ~30 MiB bitstream, faster CSU DMA configuration path.
    pub fn zu_mpsoc() -> Self {
        ReconfigCost { bitstream_load_ms: 90.0, warmup_ms: 15.0 }
    }

    pub fn for_family(family: BoardFamily) -> Self {
        match family {
            BoardFamily::Zynq7000 => Self::zynq7020(),
            BoardFamily::UltraScalePlus => Self::zu_mpsoc(),
        }
    }

    /// Total per-switch downtime charged to every node (ms). Nodes
    /// reprogram in parallel, so the cluster-wide outage equals the
    /// per-node cost, not its sum.
    pub fn downtime_ms(&self) -> f64 {
        self.bitstream_load_ms + self.warmup_ms
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.bitstream_load_ms >= 0.0 && self.bitstream_load_ms.is_finite(),
            "bitstream_load_ms out of range"
        );
        anyhow::ensure!(
            self.warmup_ms >= 0.0 && self.warmup_ms.is_finite(),
            "warmup_ms out of range"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        for c in [ReconfigCost::zynq7020(), ReconfigCost::zu_mpsoc()] {
            c.validate().unwrap();
            assert!(c.downtime_ms() > 0.0);
        }
    }

    #[test]
    fn family_dispatch() {
        assert_eq!(ReconfigCost::for_family(BoardFamily::Zynq7000), ReconfigCost::zynq7020());
        assert_eq!(
            ReconfigCost::for_family(BoardFamily::UltraScalePlus),
            ReconfigCost::zu_mpsoc()
        );
    }

    #[test]
    fn invalid_rejected() {
        let c = ReconfigCost { bitstream_load_ms: -1.0, warmup_ms: 0.0 };
        assert!(c.validate().is_err());
        let c = ReconfigCost { bitstream_load_ms: f64::NAN, warmup_ms: 0.0 };
        assert!(c.validate().is_err());
    }
}
