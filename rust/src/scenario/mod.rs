//! The declarative scenario layer (DESIGN.md §12): one API from "an
//! experiment I can describe" to "a report I can diff".
//!
//! * [`spec`]    — [`ScenarioSpec`]: a JSON-round-trippable description
//!                 of a full experiment (tenants, board inventory per
//!                 family, strategy or explicit plan, arrival process,
//!                 controller + power budget, SLO, seed, horizon,
//!                 engine)
//! * [`session`] — [`Session`]: resolves a spec into validated graphs,
//!                 plans, clusters and cost/power models, runs the
//!                 chosen engine (`analytic` | `des`)
//! * [`report`]  — [`Report`]: the unified result schema that subsumes
//!                 steady-state cells, DES runs, per-tenant serving rows
//!                 and Pareto frontier points (one JSON emitter, shared
//!                 keys across engines, snapshot-checked in CI)
//! * [`sweep`]   — [`Sweep`]: cartesian grids over any spec axis, merged
//!                 into one tagged, dominance-marked report
//!
//! The `vtacluster` subcommands `simulate`, `multi`, `load` and `power`
//! are thin adapters over this layer, and `vtacluster run <file.json>`
//! (with `--set key=value` overrides) executes any spec directly — see
//! `examples/scenarios/` for ready-made files.

pub mod report;
pub mod session;
pub mod spec;
pub mod sweep;

pub use report::{EventRow, Report, ReportRow, ServeRow};
pub use session::{CostCache, Session};
pub use spec::{
    AdmissionSpec, ArrivalSpec, BatchSpec, BoardGroup, ControllerSpec, CrashSpec, Engine,
    FaultsSpec, ScenarioSpec, StageSpec, TenantEntry,
};
pub use sweep::{apply_overrides, parse_override, set_path, Sweep};

/// Node ceiling for frontier sweeps over one family: the paper's cluster
/// limits (12 Zynq / 5 US+), clamped by a user maximum (`0` = ceiling).
pub fn pareto_ceiling(family: crate::config::BoardFamily, max_nodes: usize) -> usize {
    let ceiling = crate::power::pareto::family_max_nodes(family);
    if max_nodes == 0 {
        ceiling
    } else {
        max_nodes.min(ceiling)
    }
}
