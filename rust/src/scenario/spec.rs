//! [`ScenarioSpec`] — the declarative, JSON-round-trippable description
//! of one experiment (DESIGN.md §12).
//!
//! A spec names everything a run needs: the tenant workloads (model,
//! strategy or an explicit stage-level plan, stream length), the board
//! inventory per family, the arrival process, the reconfiguration
//! controller and its power budget, the latency SLO, the RNG seed, the
//! DES horizon, and which engine prices it (`analytic` or `des`).
//! [`crate::scenario::Session`] resolves a spec into validated graphs,
//! plans and cost/power models and runs it; `vtacluster run` feeds it
//! from a file.
//!
//! The JSON form accepts a single-tenant / single-family **shorthand**
//! (top-level `model`/`strategy`/`images`/`input_hw`/`plan` instead of a
//! `tenants` array, `family`/`nodes` instead of `boards`) so specs stay
//! copy-pasteable; [`ScenarioSpec::to_json`] always emits the canonical
//! long form, and `parse(pretty(to_json())) == to_json()` exactly.

use crate::config::reconfig::ReconfigCost;
use crate::config::{BoardFamily, ReconfigTier};
use crate::graph::{zoo, Graph};
use crate::sched::{ExecutionPlan, SplitMode, StagePlan, Strategy};
use crate::serve::{AdmissionConfig, BatchConfig, ShedPolicy};
use crate::sim::faults::{FaultsConfig, ScriptedCrash};
use crate::telemetry::{AlertRules, MetricsConfig};
use crate::util::json::{self, Json};
use crate::util::units::ms_to_ns;

/// Which simulator prices the scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Steady-state demands + unloaded latency ([`crate::sim::cluster`]),
    /// percentiles from a seeded loaded DES at the configured arrival.
    Analytic,
    /// Full discrete-event run ([`crate::sim::des`]) with open-loop
    /// arrivals and (optionally) the online reconfiguration controller.
    Des,
}

impl Engine {
    pub fn as_str(&self) -> &'static str {
        match self {
            Engine::Analytic => "analytic",
            Engine::Des => "des",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" | "steady" | "sim" => Ok(Engine::Analytic),
            "des" | "load" | "dynamic" => Ok(Engine::Des),
            other => anyhow::bail!("unknown engine '{other}' (analytic|des)"),
        }
    }
}

/// One stage of an explicit, hand-written plan (the escape hatch past
/// the strategy constructors).
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    pub segments: Vec<String>,
    pub replicas: Vec<usize>,
    /// `"dp"` (data-parallel) or `"spatial"`.
    pub split: SplitMode,
}

/// One workload of the scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantEntry {
    /// Registry name (see [`crate::graph::zoo`]).
    pub model: String,
    /// Input size; `0` → the model's default.
    pub input_hw: u64,
    /// Scheduling strategy (the four §II-C strategies plus `eco`).
    /// Ignored as a constructor when [`TenantEntry::plan`] is given, but
    /// still used as the plan's strategy tag.
    pub strategy: Strategy,
    /// Images in the tenant's stream (analytic engine) / reporting unit.
    pub images: usize,
    /// Explicit stage-level plan instead of a strategy constructor.
    pub plan: Option<Vec<StageSpec>>,
}

/// A homogeneous group of boards; several groups = a heterogeneous
/// inventory (each group becomes its own sub-cluster).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoardGroup {
    pub family: BoardFamily,
    pub n: usize,
}

/// Open-loop arrival knobs (the DES drive; the analytic engine uses it
/// for its loaded-percentile pass).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalSpec {
    /// `poisson` | `burst` | `diurnal` | `trace`.
    pub kind: String,
    /// Base rate, img/s; `0` = auto from plan capacity (70 %, or 55 %
    /// for `burst` so the MMPP high phase overloads it). Ignored by
    /// `trace` replays (the log carries its own timestamps).
    pub rate: f64,
    /// Burst-phase multiplier (only read when `kind == "burst"`).
    pub burst_mult: f64,
    /// JSONL request log to replay (only read when `kind == "trace"`;
    /// DESIGN.md §16). Relative paths resolve against the CWD and its
    /// parent, so `examples/traces/…` works from the repo root and
    /// `rust/`.
    pub path: String,
    /// Trace time compression: recorded timestamps are divided by this,
    /// so `2.0` replays the log at twice the recorded request rate.
    pub time_scale: f64,
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec {
            kind: "poisson".into(),
            rate: 0.0,
            burst_mult: 4.0,
            path: String::new(),
            time_scale: 1.0,
        }
    }
}

/// Declarative admission-control block (DESIGN.md §16): a bounded
/// request queue with a load-shedding policy and per-tenant token-bucket
/// rate isolation. The default is fully off, and an all-default block is
/// semantically identical to no block at all — the property test pins
/// byte-identical reports.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionSpec {
    /// `none` | `tail-drop` | `deadline-drop`.
    pub policy: String,
    /// Backlog bound for `tail-drop`; `0` = unbounded.
    pub queue_cap: usize,
    /// Deadline for `deadline-drop` (and the miss counter), ms;
    /// `0` = inherit the scenario `slo_ms`.
    pub deadline_ms: f64,
    /// Per-tenant token-bucket refill rate, img/s; `0` = no rate gate.
    pub tenant_rate_img_per_sec: f64,
    /// Token-bucket depth (burst allowance), img.
    pub tenant_burst: f64,
}

impl Default for AdmissionSpec {
    fn default() -> Self {
        AdmissionSpec {
            policy: "none".into(),
            queue_cap: 0,
            deadline_ms: 0.0,
            tenant_rate_img_per_sec: 0.0,
            tenant_burst: 16.0,
        }
    }
}

impl AdmissionSpec {
    /// No gate active — the zero-cost default.
    pub fn is_off(&self) -> bool {
        self.policy.eq_ignore_ascii_case("none") && self.tenant_rate_img_per_sec == 0.0
    }

    /// Resolve into the simulator's [`AdmissionConfig`]. `slo_ms` is the
    /// scenario SLO, inherited as the deadline when the block does not
    /// set its own `deadline_ms`.
    pub fn to_config(&self, slo_ms: f64) -> anyhow::Result<Option<AdmissionConfig>> {
        if self.is_off() {
            return Ok(None);
        }
        let deadline_ms = if self.deadline_ms > 0.0 { self.deadline_ms } else { slo_ms };
        Ok(Some(AdmissionConfig {
            policy: ShedPolicy::parse(&self.policy)?,
            queue_cap: self.queue_cap,
            deadline_ns: if deadline_ms > 0.0 { ms_to_ns(deadline_ms) } else { 0 },
            tenant_rate: self.tenant_rate_img_per_sec,
            tenant_burst: self.tenant_burst,
        }))
    }
}

/// Declarative batched-dispatch block (DESIGN.md §16): requests are
/// grouped into batches of up to `max_size`, a partial batch launching
/// after `max_wait_ms`. The default (`max_size = 1`) is fully off, and
/// an all-default block is semantically identical to no block at all —
/// the property test pins byte-identical reports.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchSpec {
    /// Largest batch a single dispatch carries; `1` = no batching.
    pub max_size: usize,
    /// Longest a partial batch waits for co-riders before launching, ms.
    pub max_wait_ms: f64,
}

impl Default for BatchSpec {
    fn default() -> Self {
        BatchSpec { max_size: 1, max_wait_ms: 1.0 }
    }
}

impl BatchSpec {
    /// No batch former active — the zero-cost default.
    pub fn is_off(&self) -> bool {
        self.max_size <= 1
    }

    /// Resolve into the simulator's [`BatchConfig`].
    pub fn to_config(&self) -> Option<BatchConfig> {
        if self.is_off() {
            return None;
        }
        Some(BatchConfig { max_size: self.max_size, max_wait_ms: self.max_wait_ms })
    }
}

/// Online-reconfiguration controller knobs (DES engine only).
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerSpec {
    pub enabled: bool,
    /// Cluster watts cap; `0` = uncapped.
    pub power_budget_w: f64,
    /// Reconfiguration tier the controller's switches are charged at
    /// (DESIGN.md §14): `full` reloads the whole bitstream, `partial`
    /// swaps only the VTA region — orders-of-magnitude cheaper downtime,
    /// which shifts the drain-time break-even toward switching.
    pub reconfig_tier: ReconfigTier,
}

impl Default for ControllerSpec {
    fn default() -> Self {
        ControllerSpec {
            enabled: true,
            power_budget_w: 0.0,
            reconfig_tier: ReconfigTier::Full,
        }
    }
}

/// One scripted crash in a [`FaultsSpec`]: "node `node` dies at `at_ms`
/// for `down_ms`" (re-flash added on top by the simulator).
#[derive(Debug, Clone, PartialEq)]
pub struct CrashSpec {
    pub node: usize,
    pub at_ms: f64,
    pub down_ms: f64,
}

/// Declarative fault-injection block (DESIGN.md §14). The default is
/// fully off, and an all-default block is semantically identical to no
/// block at all — the property test pins byte-identical reports.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsSpec {
    /// Mean up-time between random crashes per node, ms; `0` = off.
    pub crash_mean_up_ms: f64,
    /// Mean outage length per random crash, ms.
    pub crash_mean_down_ms: f64,
    /// Scripted crashes, merged with the random process.
    pub crashes: Vec<CrashSpec>,
    /// Straggler node count (persistent compute slowdown).
    pub stragglers: usize,
    /// Straggler compute multiplier (≥ 1).
    pub straggler_factor: f64,
    /// Degraded switch-port count (persistent wire-time slowdown).
    pub degraded_ports: usize,
    /// Degraded-port wire-time multiplier (≥ 1).
    pub port_factor: f64,
}

impl Default for FaultsSpec {
    fn default() -> Self {
        FaultsSpec {
            crash_mean_up_ms: 0.0,
            crash_mean_down_ms: 0.0,
            crashes: Vec::new(),
            stragglers: 0,
            straggler_factor: 1.0,
            degraded_ports: 0,
            port_factor: 1.0,
        }
    }
}

impl FaultsSpec {
    /// No fault process active — the zero-cost default.
    pub fn is_off(&self) -> bool {
        self.crash_mean_up_ms == 0.0
            && self.crashes.is_empty()
            && self.stragglers == 0
            && self.degraded_ports == 0
    }

    /// Resolve into the simulator's [`FaultsConfig`]. `reflash` is the
    /// rejoin re-flash cost — always a *full*-tier cost for the board
    /// family (a crash loses the PL image, whatever tier the controller
    /// switches at).
    pub fn to_config(&self, reflash: ReconfigCost) -> FaultsConfig {
        FaultsConfig {
            crash_mean_up_ms: self.crash_mean_up_ms,
            crash_mean_down_ms: self.crash_mean_down_ms,
            scripted: self
                .crashes
                .iter()
                .map(|c| ScriptedCrash { node: c.node, at_ms: c.at_ms, down_ms: c.down_ms })
                .collect(),
            stragglers: self.stragglers,
            straggler_factor: self.straggler_factor,
            degraded_ports: self.degraded_ports,
            port_factor: self.port_factor,
            reflash,
        }
    }
}

/// Declarative metrics/alerting block (DESIGN.md §15). The default is
/// fully off, and an all-default block is semantically identical to no
/// block at all — the property test pins byte-identical reports.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySpec {
    /// Master switch for the windowed metrics registry + alert engine.
    pub metrics: bool,
    /// SLO attainment target the burn-rate error budget derives from.
    pub slo_target: f64,
    /// Burn-rate multiple that fires the `slo-burn-rate` alert.
    pub burn_threshold: f64,
    /// Sliding burn-rate window length, in control windows.
    pub burn_windows: usize,
    /// Power budget for the `power-overdraw` alert, W; `0` = inherit
    /// the controller's budget (which may itself be 0 = rule off).
    pub power_budget_w: f64,
    /// Minimum fraction of nodes in service before the
    /// `availability-floor` alert fires.
    pub availability_floor: f64,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec {
            metrics: false,
            slo_target: 0.99,
            burn_threshold: 2.0,
            burn_windows: 10,
            power_budget_w: 0.0,
            availability_floor: 0.999,
        }
    }
}

impl TelemetrySpec {
    /// Metrics registry off — the zero-cost default.
    pub fn is_off(&self) -> bool {
        !self.metrics
    }

    /// Resolve into the simulator's [`MetricsConfig`]. `slo_ms` is the
    /// spec-level latency SLO (drives the violation counter and the
    /// burn-rate rule); `controller_budget_w` is the controller's power
    /// cap, inherited by the overdraw rule unless the block overrides
    /// it with its own `power_budget_w`.
    pub fn to_metrics_config(&self, slo_ms: f64, controller_budget_w: f64) -> MetricsConfig {
        if self.is_off() {
            return MetricsConfig::off();
        }
        let budget =
            if self.power_budget_w > 0.0 { self.power_budget_w } else { controller_budget_w };
        MetricsConfig {
            enabled: true,
            slo_ms,
            rules: AlertRules {
                slo_ms,
                slo_target: self.slo_target,
                burn_threshold: self.burn_threshold,
                burn_windows: self.burn_windows,
                power_budget_w: budget,
                availability_floor: self.availability_floor,
            },
        }
    }
}

/// The full experiment description. See the module docs for the JSON
/// grammar and DESIGN.md §12 for semantics per (tenants × boards ×
/// engine) shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub engine: Engine,
    pub seed: u64,
    pub tenants: Vec<TenantEntry>,
    pub boards: Vec<BoardGroup>,
    pub arrival: ArrivalSpec,
    pub controller: ControllerSpec,
    /// Fault injection (DESIGN.md §14); defaults to fully off.
    pub faults: FaultsSpec,
    /// Windowed metrics + alert rules (DESIGN.md §15); defaults to off.
    pub telemetry: TelemetrySpec,
    /// Admission control + load shedding (DESIGN.md §16); defaults to
    /// off.
    pub admission: AdmissionSpec,
    /// Batched dispatch (DESIGN.md §16); defaults to off (`max_size` 1).
    pub batch: BatchSpec,
    /// Latency SLO, ms; `0` = none. Checked against unloaded latency
    /// (analytic) or p99 (DES); also the eco strategy's constraint.
    pub slo_ms: f64,
    /// DES horizon, ms.
    pub horizon_ms: f64,
}

impl ScenarioSpec {
    /// A minimal single-tenant spec (the programmatic starting point the
    /// CLI adapters build on).
    pub fn single(model: &str, strategy: Strategy, family: BoardFamily, n: usize) -> Self {
        ScenarioSpec {
            name: format!("{model}-{strategy}-{n}x{family}"),
            engine: Engine::Analytic,
            seed: 7,
            tenants: vec![TenantEntry {
                model: model.to_string(),
                input_hw: 0,
                strategy,
                images: 64,
                plan: None,
            }],
            boards: vec![BoardGroup { family, n }],
            arrival: ArrivalSpec::default(),
            controller: ControllerSpec::default(),
            faults: FaultsSpec::default(),
            telemetry: TelemetrySpec::default(),
            admission: AdmissionSpec::default(),
            batch: BatchSpec::default(),
            slo_ms: 0.0,
            horizon_ms: 20_000.0,
        }
    }

    /// Semantic validation (everything that does not need a graph):
    /// known models, sane rates/horizons, and the supported shapes —
    /// multi-tenant *or* heterogeneous inventory, not both.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "scenario has no name");
        anyhow::ensure!(!self.tenants.is_empty(), "scenario has no tenants");
        anyhow::ensure!(!self.boards.is_empty(), "scenario has no boards");
        for (i, t) in self.tenants.iter().enumerate() {
            zoo::lookup(&t.model)
                .map_err(|e| anyhow::anyhow!("tenant {i}: {e}"))?;
            anyhow::ensure!(t.images >= 1, "tenant {i} ('{}'): images must be ≥ 1", t.model);
        }
        for (i, b) in self.boards.iter().enumerate() {
            anyhow::ensure!(b.n >= 1, "board group {i} ({}): n must be ≥ 1", b.family);
        }
        anyhow::ensure!(
            self.tenants.len() == 1 || self.boards.len() == 1,
            "multi-tenant over a heterogeneous inventory is not supported: \
             give each tenant its own scenario or use one board family"
        );
        anyhow::ensure!(
            self.tenants.len() == 1 || self.tenants.iter().all(|t| t.plan.is_none()),
            "explicit plans are only supported for single-tenant scenarios \
             (multi-tenant node allocation would invalidate the hand-written replicas)"
        );
        // the multi-tenant analytic shape delegates to simulate_tenants,
        // whose percentile pass pins a 70 %-capacity Poisson stream — a
        // custom arrival would be silently ignored there, so reject it
        if self.tenants.len() > 1 && self.engine == Engine::Analytic {
            anyhow::ensure!(
                self.arrival.kind.eq_ignore_ascii_case("poisson") && self.arrival.rate == 0.0,
                "multi-tenant analytic runs pin a 70%-capacity Poisson percentile \
                 pass; use engine \"des\" to drive tenants with a custom arrival"
            );
        }
        match self.arrival.kind.to_ascii_lowercase().as_str() {
            "poisson" | "diurnal" => {}
            "burst" | "mmpp" => anyhow::ensure!(
                self.arrival.burst_mult > 1.0,
                "arrival.burst_mult must be > 1 for burst arrivals"
            ),
            "trace" => {
                anyhow::ensure!(
                    !self.arrival.path.is_empty(),
                    "arrival.kind \"trace\" needs an arrival.path (JSONL request log)"
                );
                anyhow::ensure!(
                    self.engine == Engine::Des,
                    "trace replay needs the des engine \
                     (the analytic model has no timeline to replay onto)"
                );
                anyhow::ensure!(
                    self.tenants.len() == 1 && self.boards.len() == 1,
                    "trace replay drives a single workload on one board family \
                     (the log's tenants share the model; give each model its own scenario)"
                );
            }
            other => {
                anyhow::bail!("unknown arrival.kind '{other}' (poisson|burst|diurnal|trace)")
            }
        }
        if !self.arrival.kind.eq_ignore_ascii_case("trace") {
            anyhow::ensure!(
                self.arrival.path.is_empty(),
                "arrival.path is only read when arrival.kind is \"trace\""
            );
        }
        anyhow::ensure!(
            self.arrival.time_scale > 0.0 && self.arrival.time_scale.is_finite(),
            "arrival.time_scale must be finite and > 0"
        );
        anyhow::ensure!(
            self.arrival.rate >= 0.0 && self.arrival.rate.is_finite(),
            "arrival.rate must be ≥ 0 (0 = auto from plan capacity)"
        );
        anyhow::ensure!(
            self.horizon_ms > 0.0 && self.horizon_ms.is_finite(),
            "horizon_ms must be > 0"
        );
        anyhow::ensure!(
            self.slo_ms >= 0.0 && self.slo_ms.is_finite(),
            "slo_ms must be ≥ 0 (0 = none)"
        );
        anyhow::ensure!(
            self.controller.power_budget_w >= 0.0 && self.controller.power_budget_w.is_finite(),
            "controller.power_budget_w must be ≥ 0 (0 = uncapped)"
        );
        if self.engine == Engine::Des {
            anyhow::ensure!(
                self.controller.power_budget_w == 0.0 || self.controller.enabled,
                "a power budget needs the controller enabled \
                 (a static plan cannot shed watts)"
            );
        }
        let f = &self.faults;
        if !f.is_off() {
            anyhow::ensure!(
                self.engine == Engine::Des,
                "fault injection needs the des engine \
                 (the analytic model has no timeline to crash on)"
            );
        }
        anyhow::ensure!(
            f.crash_mean_up_ms >= 0.0 && f.crash_mean_up_ms.is_finite(),
            "faults.crash_mean_up_ms must be ≥ 0 (0 = no random crashes)"
        );
        if f.crash_mean_up_ms > 0.0 {
            anyhow::ensure!(
                f.crash_mean_down_ms > 0.0 && f.crash_mean_down_ms.is_finite(),
                "faults.crash_mean_down_ms must be > 0 when random crashes are on"
            );
        }
        for (i, c) in f.crashes.iter().enumerate() {
            anyhow::ensure!(
                c.at_ms >= 0.0 && c.at_ms.is_finite() && c.down_ms > 0.0 && c.down_ms.is_finite(),
                "faults.crashes[{i}]: at_ms must be ≥ 0 and down_ms > 0"
            );
            let total: usize = self.boards.iter().map(|b| b.n).sum();
            anyhow::ensure!(
                c.node < total,
                "faults.crashes[{i}]: node {} out of range (cluster has {} nodes)",
                c.node,
                total
            );
        }
        if f.stragglers > 0 {
            anyhow::ensure!(
                f.straggler_factor >= 1.0 && f.straggler_factor.is_finite(),
                "faults.straggler_factor must be ≥ 1"
            );
        }
        if f.degraded_ports > 0 {
            anyhow::ensure!(
                f.port_factor >= 1.0 && f.port_factor.is_finite(),
                "faults.port_factor must be ≥ 1"
            );
        }
        let tl = &self.telemetry;
        anyhow::ensure!(
            tl.slo_target > 0.0 && tl.slo_target < 1.0,
            "telemetry.slo_target must be in (0, 1)"
        );
        anyhow::ensure!(
            tl.burn_threshold > 0.0 && tl.burn_threshold.is_finite(),
            "telemetry.burn_threshold must be > 0"
        );
        anyhow::ensure!(tl.burn_windows >= 1, "telemetry.burn_windows must be ≥ 1");
        anyhow::ensure!(
            tl.power_budget_w >= 0.0 && tl.power_budget_w.is_finite(),
            "telemetry.power_budget_w must be ≥ 0 (0 = inherit the controller budget)"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&tl.availability_floor),
            "telemetry.availability_floor must be in [0, 1]"
        );
        let adm = &self.admission;
        let policy = ShedPolicy::parse(&adm.policy)?;
        anyhow::ensure!(
            adm.deadline_ms >= 0.0 && adm.deadline_ms.is_finite(),
            "admission.deadline_ms must be ≥ 0 (0 = inherit slo_ms)"
        );
        anyhow::ensure!(
            adm.tenant_rate_img_per_sec >= 0.0 && adm.tenant_rate_img_per_sec.is_finite(),
            "admission.tenant_rate_img_per_sec must be ≥ 0 (0 = no rate gate)"
        );
        if adm.tenant_rate_img_per_sec > 0.0 {
            anyhow::ensure!(
                adm.tenant_burst >= 1.0 && adm.tenant_burst.is_finite(),
                "admission.tenant_burst must be ≥ 1 when the rate gate is on"
            );
        }
        if policy == ShedPolicy::TailDrop {
            anyhow::ensure!(
                adm.queue_cap >= 1,
                "admission.policy \"tail-drop\" needs a queue_cap ≥ 1"
            );
        }
        if policy == ShedPolicy::DeadlineDrop {
            anyhow::ensure!(
                adm.deadline_ms > 0.0 || self.slo_ms > 0.0,
                "admission.policy \"deadline-drop\" needs a deadline_ms or a scenario slo_ms"
            );
        }
        anyhow::ensure!(
            (1..=64).contains(&self.batch.max_size),
            "batch.max_size must be in 1..=64 (the DES prices batches up to 64)"
        );
        if !self.batch.is_off() {
            anyhow::ensure!(
                self.batch.max_wait_ms > 0.0 && self.batch.max_wait_ms.is_finite(),
                "batch.max_wait_ms must be finite and > 0 when batching is on"
            );
        }
        if !adm.is_off() || !self.batch.is_off() {
            anyhow::ensure!(
                self.engine == Engine::Des,
                "the serving front end (admission/batch) needs the des engine \
                 (the analytic model has no request timeline to gate)"
            );
            anyhow::ensure!(
                self.tenants.len() == 1 && self.boards.len() == 1,
                "the serving front end drives a single workload on one board family \
                 (serve tenants come from the request trace, not the tenants array)"
            );
        }
        Ok(())
    }

    /// Resolve a tenant's explicit [`StageSpec`] list (if any) into a
    /// validated [`ExecutionPlan`] for `g` over `n` nodes. A typo'd
    /// segment label or replica id comes back as a reported error.
    pub fn explicit_plan(
        tenant: &TenantEntry,
        g: &Graph,
        n: usize,
    ) -> anyhow::Result<Option<ExecutionPlan>> {
        let Some(stages) = &tenant.plan else { return Ok(None) };
        let plan = ExecutionPlan {
            strategy: tenant.strategy,
            n_nodes: n,
            model: g.model.clone(),
            segment_order: g.segment_order(),
            stages: stages
                .iter()
                .map(|s| StagePlan {
                    segments: s.segments.clone(),
                    replicas: s.replicas.clone(),
                    split: s.split,
                })
                .collect(),
        };
        plan.validate_for(g)
            .map_err(|e| anyhow::anyhow!("explicit plan for '{}': {e}", tenant.model))?;
        Ok(Some(plan))
    }

    // ---- JSON ----------------------------------------------------------

    /// Parse a spec from its JSON document (shorthand accepted — see the
    /// module docs). Unknown keys are errors: they are usually typo'd
    /// experiment parameters.
    pub fn from_json(doc: &Json) -> anyhow::Result<Self> {
        check_keys(
            doc,
            "scenario",
            &[
                "name", "engine", "seed", "tenants", "boards", "arrival", "controller",
                "faults", "telemetry", "admission", "batch", "slo_ms", "horizon_ms",
                "sweep", "model", "strategy", "images", "input_hw", "plan", "family",
                "nodes",
            ],
        )?;
        // a sweep is a *grid over* specs, not a spec field: parsing one
        // cell out of it here would silently drop the other cells
        anyhow::ensure!(
            doc.get("sweep").is_none(),
            "this scenario declares a `sweep` grid — expand it with \
             `Sweep::from_doc` (the CLI `run` does this automatically)"
        );
        let name = match doc.get("name") {
            Some(v) => v.as_str()?.to_string(),
            None => "scenario".to_string(),
        };
        let engine = match doc.get("engine") {
            Some(v) => Engine::parse(v.as_str()?)?,
            None => Engine::Analytic,
        };
        let seed = match doc.get("seed") {
            Some(v) => v.as_u64()?,
            None => 7,
        };

        let tenants = match doc.get("tenants") {
            Some(list) => {
                // with a tenants array, every per-tenant shorthand key
                // must move inside it — a top-level one would be
                // silently ignored otherwise
                for key in ["model", "strategy", "images", "input_hw", "plan"] {
                    anyhow::ensure!(
                        doc.get(key).is_none(),
                        "top-level `{key}` conflicts with the `tenants` array — \
                         set it per tenant instead"
                    );
                }
                list.as_arr()?
                    .iter()
                    .map(|t| {
                        check_keys(
                            t,
                            "tenant",
                            &["model", "strategy", "images", "input_hw", "plan"],
                        )?;
                        Self::tenant_from_json(t)
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?
            }
            None => vec![Self::tenant_from_json(doc)?],
        };

        let boards = match doc.get("boards") {
            Some(list) => {
                anyhow::ensure!(
                    doc.get("family").is_none() && doc.get("nodes").is_none(),
                    "give either a `boards` array or the top-level \
                     `family`/`nodes` shorthand, not both"
                );
                list.as_arr()?
                    .iter()
                    .map(|b| {
                        check_keys(b, "board group", &["family", "n"])?;
                        Ok(BoardGroup {
                            family: BoardFamily::parse(b.get_str("family")?)?,
                            n: b.req("n")?.as_usize()?,
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?
            }
            None => vec![BoardGroup {
                family: match doc.get("family") {
                    Some(v) => BoardFamily::parse(v.as_str()?)?,
                    None => BoardFamily::Zynq7000,
                },
                n: match doc.get("nodes") {
                    Some(v) => v.as_usize()?,
                    None => 4,
                },
            }],
        };

        let arrival = match doc.get("arrival") {
            Some(a) => {
                check_keys(a, "arrival", &["kind", "rate", "burst_mult", "path", "time_scale"])?;
                ArrivalSpec {
                    kind: match a.get("kind") {
                        Some(v) => v.as_str()?.to_string(),
                        None => "poisson".to_string(),
                    },
                    rate: match a.get("rate") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    burst_mult: match a.get("burst_mult") {
                        Some(v) => v.as_f64()?,
                        None => 4.0,
                    },
                    path: match a.get("path") {
                        Some(v) => v.as_str()?.to_string(),
                        None => String::new(),
                    },
                    time_scale: match a.get("time_scale") {
                        Some(v) => v.as_f64()?,
                        None => 1.0,
                    },
                }
            }
            None => ArrivalSpec::default(),
        };
        let controller = match doc.get("controller") {
            Some(c) => {
                check_keys(c, "controller", &["enabled", "power_budget_w", "reconfig_tier"])?;
                ControllerSpec {
                    enabled: match c.get("enabled") {
                        Some(v) => v.as_bool()?,
                        None => true,
                    },
                    power_budget_w: match c.get("power_budget_w") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    reconfig_tier: match c.get("reconfig_tier") {
                        Some(v) => ReconfigTier::parse(v.as_str()?)?,
                        None => ReconfigTier::Full,
                    },
                }
            }
            None => ControllerSpec::default(),
        };
        let faults = match doc.get("faults") {
            Some(f) => {
                check_keys(
                    f,
                    "faults",
                    &[
                        "crash_mean_up_ms", "crash_mean_down_ms", "crashes", "stragglers",
                        "straggler_factor", "degraded_ports", "port_factor",
                    ],
                )?;
                let crashes = match f.get("crashes") {
                    Some(list) => list
                        .as_arr()?
                        .iter()
                        .map(|c| {
                            check_keys(c, "crash", &["node", "at_ms", "down_ms"])?;
                            Ok(CrashSpec {
                                node: c.req("node")?.as_usize()?,
                                at_ms: c.req("at_ms")?.as_f64()?,
                                down_ms: c.req("down_ms")?.as_f64()?,
                            })
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?,
                    None => Vec::new(),
                };
                FaultsSpec {
                    crash_mean_up_ms: match f.get("crash_mean_up_ms") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    crash_mean_down_ms: match f.get("crash_mean_down_ms") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    crashes,
                    stragglers: match f.get("stragglers") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                    straggler_factor: match f.get("straggler_factor") {
                        Some(v) => v.as_f64()?,
                        None => 1.0,
                    },
                    degraded_ports: match f.get("degraded_ports") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                    port_factor: match f.get("port_factor") {
                        Some(v) => v.as_f64()?,
                        None => 1.0,
                    },
                }
            }
            None => FaultsSpec::default(),
        };
        let telemetry = match doc.get("telemetry") {
            Some(t) => {
                check_keys(
                    t,
                    "telemetry",
                    &[
                        "metrics", "slo_target", "burn_threshold", "burn_windows",
                        "power_budget_w", "availability_floor",
                    ],
                )?;
                TelemetrySpec {
                    metrics: match t.get("metrics") {
                        Some(v) => v.as_bool()?,
                        None => false,
                    },
                    slo_target: match t.get("slo_target") {
                        Some(v) => v.as_f64()?,
                        None => 0.99,
                    },
                    burn_threshold: match t.get("burn_threshold") {
                        Some(v) => v.as_f64()?,
                        None => 2.0,
                    },
                    burn_windows: match t.get("burn_windows") {
                        Some(v) => v.as_usize()?,
                        None => 10,
                    },
                    power_budget_w: match t.get("power_budget_w") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    availability_floor: match t.get("availability_floor") {
                        Some(v) => v.as_f64()?,
                        None => 0.999,
                    },
                }
            }
            None => TelemetrySpec::default(),
        };
        let admission = match doc.get("admission") {
            Some(a) => {
                check_keys(
                    a,
                    "admission",
                    &[
                        "policy", "queue_cap", "deadline_ms", "tenant_rate_img_per_sec",
                        "tenant_burst",
                    ],
                )?;
                AdmissionSpec {
                    policy: match a.get("policy") {
                        Some(v) => v.as_str()?.to_string(),
                        None => "none".to_string(),
                    },
                    queue_cap: match a.get("queue_cap") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                    deadline_ms: match a.get("deadline_ms") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    tenant_rate_img_per_sec: match a.get("tenant_rate_img_per_sec") {
                        Some(v) => v.as_f64()?,
                        None => 0.0,
                    },
                    tenant_burst: match a.get("tenant_burst") {
                        Some(v) => v.as_f64()?,
                        None => 16.0,
                    },
                }
            }
            None => AdmissionSpec::default(),
        };
        let batch = match doc.get("batch") {
            Some(b) => {
                check_keys(b, "batch", &["max_size", "max_wait_ms"])?;
                BatchSpec {
                    max_size: match b.get("max_size") {
                        Some(v) => v.as_usize()?,
                        None => 1,
                    },
                    max_wait_ms: match b.get("max_wait_ms") {
                        Some(v) => v.as_f64()?,
                        None => 1.0,
                    },
                }
            }
            None => BatchSpec::default(),
        };
        let slo_ms = match doc.get("slo_ms") {
            Some(v) => v.as_f64()?,
            None => 0.0,
        };
        let horizon_ms = match doc.get("horizon_ms") {
            Some(v) => v.as_f64()?,
            None => 20_000.0,
        };

        let spec = ScenarioSpec {
            name,
            engine,
            seed,
            tenants,
            boards,
            arrival,
            controller,
            faults,
            telemetry,
            admission,
            batch,
            slo_ms,
            horizon_ms,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn tenant_from_json(t: &Json) -> anyhow::Result<TenantEntry> {
        let model = t
            .get("model")
            .ok_or_else(|| anyhow::anyhow!("tenant is missing `model`"))?
            .as_str()?
            .to_string();
        let strategy = match t.get("strategy") {
            Some(v) => Strategy::parse(v.as_str()?)?,
            None => Strategy::Fused,
        };
        let images = match t.get("images") {
            Some(v) => v.as_usize()?,
            None => 64,
        };
        let input_hw = match t.get("input_hw") {
            Some(v) => v.as_u64()?,
            None => 0,
        };
        let plan = match t.get("plan") {
            Some(stages) => Some(
                stages
                    .as_arr()?
                    .iter()
                    .map(|s| {
                        check_keys(s, "plan stage", &["segments", "replicas", "split"])?;
                        let segments = s
                            .req("segments")?
                            .as_arr()?
                            .iter()
                            .map(|x| Ok(x.as_str()?.to_string()))
                            .collect::<anyhow::Result<Vec<_>>>()?;
                        let replicas = s
                            .req("replicas")?
                            .as_arr()?
                            .iter()
                            .map(|x| Ok(x.as_usize()?))
                            .collect::<anyhow::Result<Vec<_>>>()?;
                        let split = match s.get_str("split")? {
                            "dp" | "data-parallel" => SplitMode::DataParallel,
                            "spatial" => SplitMode::Spatial,
                            other => anyhow::bail!(
                                "unknown split '{other}' (dp|spatial)"
                            ),
                        };
                        Ok(StageSpec { segments, replicas, split })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
            None => None,
        };
        Ok(TenantEntry { model, input_hw, strategy, images, plan })
    }

    /// Canonical (long-form) JSON emit. Lossless:
    /// `ScenarioSpec::from_json(&spec.to_json()) == spec`.
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                let mut fields = vec![
                    ("model", json::str_(&t.model)),
                    ("input_hw", json::int(t.input_hw as i64)),
                    ("strategy", json::str_(t.strategy.as_str())),
                    ("images", json::int(t.images as i64)),
                ];
                if let Some(stages) = &t.plan {
                    fields.push((
                        "plan",
                        Json::Arr(
                            stages
                                .iter()
                                .map(|s| {
                                    json::obj(vec![
                                        (
                                            "segments",
                                            Json::Arr(
                                                s.segments
                                                    .iter()
                                                    .map(|x| json::str_(x))
                                                    .collect(),
                                            ),
                                        ),
                                        (
                                            "replicas",
                                            Json::Arr(
                                                s.replicas
                                                    .iter()
                                                    .map(|&r| json::int(r as i64))
                                                    .collect(),
                                            ),
                                        ),
                                        (
                                            "split",
                                            json::str_(match s.split {
                                                SplitMode::DataParallel => "dp",
                                                SplitMode::Spatial => "spatial",
                                            }),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                json::obj(fields)
            })
            .collect();
        let boards: Vec<Json> = self
            .boards
            .iter()
            .map(|b| {
                json::obj(vec![
                    ("family", json::str_(b.family.as_str())),
                    ("n", json::int(b.n as i64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("name", json::str_(&self.name)),
            ("engine", json::str_(self.engine.as_str())),
            ("seed", json::int(self.seed as i64)),
            ("tenants", Json::Arr(tenants)),
            ("boards", Json::Arr(boards)),
            (
                "arrival",
                json::obj(vec![
                    ("kind", json::str_(&self.arrival.kind)),
                    ("rate", json::num(self.arrival.rate)),
                    ("burst_mult", json::num(self.arrival.burst_mult)),
                    ("path", json::str_(&self.arrival.path)),
                    ("time_scale", json::num(self.arrival.time_scale)),
                ]),
            ),
            (
                "controller",
                json::obj(vec![
                    ("enabled", Json::Bool(self.controller.enabled)),
                    ("power_budget_w", json::num(self.controller.power_budget_w)),
                    ("reconfig_tier", json::str_(self.controller.reconfig_tier.as_str())),
                ]),
            ),
            (
                "faults",
                json::obj(vec![
                    ("crash_mean_up_ms", json::num(self.faults.crash_mean_up_ms)),
                    ("crash_mean_down_ms", json::num(self.faults.crash_mean_down_ms)),
                    (
                        "crashes",
                        Json::Arr(
                            self.faults
                                .crashes
                                .iter()
                                .map(|c| {
                                    json::obj(vec![
                                        ("node", json::int(c.node as i64)),
                                        ("at_ms", json::num(c.at_ms)),
                                        ("down_ms", json::num(c.down_ms)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("stragglers", json::int(self.faults.stragglers as i64)),
                    ("straggler_factor", json::num(self.faults.straggler_factor)),
                    ("degraded_ports", json::int(self.faults.degraded_ports as i64)),
                    ("port_factor", json::num(self.faults.port_factor)),
                ]),
            ),
            (
                "telemetry",
                json::obj(vec![
                    ("metrics", Json::Bool(self.telemetry.metrics)),
                    ("slo_target", json::num(self.telemetry.slo_target)),
                    ("burn_threshold", json::num(self.telemetry.burn_threshold)),
                    ("burn_windows", json::int(self.telemetry.burn_windows as i64)),
                    ("power_budget_w", json::num(self.telemetry.power_budget_w)),
                    ("availability_floor", json::num(self.telemetry.availability_floor)),
                ]),
            ),
            (
                "admission",
                json::obj(vec![
                    ("policy", json::str_(&self.admission.policy)),
                    ("queue_cap", json::int(self.admission.queue_cap as i64)),
                    ("deadline_ms", json::num(self.admission.deadline_ms)),
                    (
                        "tenant_rate_img_per_sec",
                        json::num(self.admission.tenant_rate_img_per_sec),
                    ),
                    ("tenant_burst", json::num(self.admission.tenant_burst)),
                ]),
            ),
            (
                "batch",
                json::obj(vec![
                    ("max_size", json::int(self.batch.max_size as i64)),
                    ("max_wait_ms", json::num(self.batch.max_wait_ms)),
                ]),
            ),
            ("slo_ms", json::num(self.slo_ms)),
            ("horizon_ms", json::num(self.horizon_ms)),
        ])
    }

    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(text)?)
    }
}

/// Reject unknown object keys — in an experiment spec they are almost
/// always typo'd parameters that would otherwise silently fall back to
/// defaults.
fn check_keys(obj: &Json, what: &str, known: &[&str]) -> anyhow::Result<()> {
    for (k, _) in obj.as_obj()? {
        anyhow::ensure!(
            known.contains(&k.as_str()),
            "unknown {what} key '{k}' (known: {})",
            known.join(", ")
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shorthand_and_canonical_forms_agree() {
        let short = ScenarioSpec::parse(
            r#"{"model": "lenet5", "strategy": "pipeline", "nodes": 3}"#,
        )
        .unwrap();
        let long = ScenarioSpec::parse(
            r#"{
              "tenants": [{"model": "lenet5", "strategy": "pipeline", "images": 64, "input_hw": 0}],
              "boards": [{"family": "zynq7000", "n": 3}]
            }"#,
        )
        .unwrap();
        assert_eq!(short.tenants, long.tenants);
        assert_eq!(short.boards, long.boards);
        assert_eq!(short.engine, Engine::Analytic);
        assert_eq!(short.seed, 7);
    }

    #[test]
    fn canonical_json_roundtrips_losslessly() {
        let mut spec = ScenarioSpec::single(
            "resnet18",
            Strategy::Eco,
            BoardFamily::UltraScalePlus,
            5,
        );
        spec.engine = Engine::Des;
        spec.arrival = ArrivalSpec {
            kind: "burst".into(),
            rate: 120.5,
            burst_mult: 3.0,
            ..ArrivalSpec::default()
        };
        spec.admission = AdmissionSpec {
            policy: "tail-drop".into(),
            queue_cap: 24,
            deadline_ms: 80.0,
            tenant_rate_img_per_sec: 55.0,
            tenant_burst: 8.0,
        };
        spec.batch = BatchSpec { max_size: 8, max_wait_ms: 2.5 };
        spec.controller = ControllerSpec {
            enabled: true,
            power_budget_w: 30.0,
            reconfig_tier: ReconfigTier::Partial,
        };
        spec.faults = FaultsSpec {
            crash_mean_up_ms: 4_000.0,
            crash_mean_down_ms: 400.0,
            crashes: vec![CrashSpec { node: 1, at_ms: 500.0, down_ms: 250.0 }],
            stragglers: 1,
            straggler_factor: 3.0,
            degraded_ports: 1,
            port_factor: 8.0,
        };
        spec.telemetry = TelemetrySpec {
            metrics: true,
            slo_target: 0.995,
            burn_threshold: 3.0,
            burn_windows: 12,
            power_budget_w: 25.0,
            availability_floor: 0.75,
        };
        spec.slo_ms = 45.0;
        let j = spec.to_json();
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back, spec);
        // and through the pretty printer (the `run --emit-spec` path)
        let again = ScenarioSpec::parse(&json::pretty(&j)).unwrap();
        assert_eq!(again, spec);
        assert_eq!(Json::parse(&json::pretty(&j)).unwrap(), j);
    }

    #[test]
    fn explicit_plan_roundtrips_and_resolves() {
        let text = r#"{
          "model": "lenet5", "strategy": "pipeline", "nodes": 2,
          "plan": [
            {"segments": ["c1", "c2"], "replicas": [0], "split": "dp"},
            {"segments": ["c3", "head"], "replicas": [1], "split": "dp"}
          ]
        }"#;
        let spec = ScenarioSpec::parse(text).unwrap();
        let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        let g = zoo::build("lenet5", 0).unwrap();
        let plan = ScenarioSpec::explicit_plan(&spec.tenants[0], &g, 2)
            .unwrap()
            .expect("plan given");
        plan.validate_for(&g).unwrap();
        assert_eq!(plan.stages.len(), 2);
    }

    #[test]
    fn typod_segment_label_reports_instead_of_panicking() {
        let text = r#"{
          "model": "lenet5", "nodes": 1,
          "plan": [{"segments": ["c1", "c2", "c3", "heda"], "replicas": [0], "split": "dp"}]
        }"#;
        let spec = ScenarioSpec::parse(text).unwrap();
        let g = zoo::build("lenet5", 0).unwrap();
        let e = ScenarioSpec::explicit_plan(&spec.tenants[0], &g, 1)
            .unwrap_err()
            .to_string();
        assert!(e.contains("heda") || e.contains("head"), "{e}");
    }

    #[test]
    fn rejects_bad_specs() {
        // unknown key (typo'd parameter)
        assert!(ScenarioSpec::parse(r#"{"model": "mlp", "hozizon_ms": 5}"#).is_err());
        // unknown model
        assert!(ScenarioSpec::parse(r#"{"model": "vgg"}"#).is_err());
        // both shorthand and array forms
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "tenants": [{"model": "mlp"}]}"#
        )
        .is_err());
        // a top-level per-tenant key next to a tenants array would be
        // silently ignored — reject it instead
        let e = ScenarioSpec::parse(
            r#"{"tenants": [{"model": "mlp"}, {"model": "lenet5"}], "images": 128}"#
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("images"), "{e}");
        // a sweep doc must go through Sweep::from_doc, not be silently
        // collapsed to one cell
        let e = ScenarioSpec::parse(r#"{"model": "mlp", "sweep": {"nodes": [1, 2]}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("sweep"), "{e}");
        // multi-tenant over heterogeneous boards
        assert!(ScenarioSpec::parse(
            r#"{"tenants": [{"model": "mlp"}, {"model": "lenet5"}],
                "boards": [{"family": "zynq", "n": 2}, {"family": "zu+", "n": 2}]}"#
        )
        .is_err());
        // power budget without the controller
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des",
                "controller": {"enabled": false, "power_budget_w": 10}}"#
        )
        .is_err());
        // faults on the analytic engine (no timeline to crash on)
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "faults": {"stragglers": 1, "straggler_factor": 2.0}}"#
        )
        .is_err());
        // scripted crash out of node range
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "nodes": 2,
                "faults": {"crashes": [{"node": 5, "at_ms": 100, "down_ms": 50}]}}"#
        )
        .is_err());
        // random crashes need a positive mean outage
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des",
                "faults": {"crash_mean_up_ms": 1000, "crash_mean_down_ms": 0}}"#
        )
        .is_err());
        // straggler multiplier below 1 would be a speedup, not a fault
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des",
                "faults": {"stragglers": 1, "straggler_factor": 0.5}}"#
        )
        .is_err());
        // unknown reconfig tier
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "controller": {"reconfig_tier": "quantum"}}"#
        )
        .is_err());
        // burst without a multiplier > 1
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "arrival": {"kind": "burst", "burst_mult": 1.0}}"#
        )
        .is_err());
        // degenerate horizon
        assert!(ScenarioSpec::parse(r#"{"model": "mlp", "horizon_ms": 0}"#).is_err());
        // multi-tenant analytic pins its percentile pass: a custom
        // arrival would be silently ignored, so it is rejected …
        assert!(ScenarioSpec::parse(
            r#"{"tenants": [{"model": "mlp"}, {"model": "lenet5"}],
                "arrival": {"kind": "diurnal"}}"#
        )
        .is_err());
        // … while the same arrival is fine on the DES engine
        assert!(ScenarioSpec::parse(
            r#"{"tenants": [{"model": "mlp"}, {"model": "lenet5"}],
                "engine": "des", "arrival": {"kind": "diurnal"}}"#
        )
        .is_ok());
    }

    #[test]
    fn defaults_are_documented_values() {
        let s = ScenarioSpec::parse(r#"{"model": "mlp"}"#).unwrap();
        assert_eq!(s.engine, Engine::Analytic);
        assert_eq!(s.seed, 7);
        assert_eq!(s.tenants[0].strategy, Strategy::Fused);
        assert_eq!(s.tenants[0].images, 64);
        assert_eq!(s.boards, vec![BoardGroup { family: BoardFamily::Zynq7000, n: 4 }]);
        assert_eq!(s.arrival.kind, "poisson");
        assert_eq!(s.horizon_ms, 20_000.0);
        assert!(s.controller.enabled && s.controller.power_budget_w == 0.0);
        assert_eq!(s.controller.reconfig_tier, ReconfigTier::Full);
        assert!(s.faults.is_off(), "faults must default to fully off");
        assert_eq!(s.faults, FaultsSpec::default());
    }

    #[test]
    fn faults_block_parses_and_resolves_to_config() {
        let spec = ScenarioSpec::parse(
            r#"{
              "model": "lenet5", "engine": "des", "nodes": 3,
              "controller": {"enabled": true, "reconfig_tier": "partial"},
              "faults": {
                "crash_mean_up_ms": 5000, "crash_mean_down_ms": 500,
                "crashes": [{"node": 2, "at_ms": 1000, "down_ms": 300}],
                "stragglers": 1, "straggler_factor": 2.5,
                "degraded_ports": 1, "port_factor": 4.0
              }
            }"#,
        )
        .unwrap();
        assert!(!spec.faults.is_off());
        assert_eq!(spec.controller.reconfig_tier, ReconfigTier::Partial);
        let cfg = spec.faults.to_config(ReconfigCost::zynq7020());
        cfg.validate(3).unwrap();
        assert_eq!(cfg.scripted.len(), 1);
        assert_eq!(cfg.scripted[0].node, 2);
        assert_eq!(cfg.stragglers, 1);
        assert_eq!(cfg.reflash, ReconfigCost::zynq7020());
        // an empty faults object is the off default — same spec as no block
        let with_empty = ScenarioSpec::parse(
            r#"{"model": "lenet5", "engine": "des", "nodes": 3, "faults": {}}"#,
        )
        .unwrap();
        let without = ScenarioSpec::parse(
            r#"{"model": "lenet5", "engine": "des", "nodes": 3}"#,
        )
        .unwrap();
        assert_eq!(with_empty, without);
        assert_eq!(json::pretty(&with_empty.to_json()), json::pretty(&without.to_json()));
    }

    #[test]
    fn telemetry_block_parses_and_resolves_to_config() {
        let spec = ScenarioSpec::parse(
            r#"{
              "model": "lenet5", "engine": "des", "nodes": 2, "slo_ms": 40,
              "controller": {"enabled": true, "power_budget_w": 18},
              "telemetry": {"metrics": true, "burn_windows": 6, "availability_floor": 0.5}
            }"#,
        )
        .unwrap();
        assert!(!spec.telemetry.is_off());
        let cfg = spec.telemetry.to_metrics_config(spec.slo_ms, spec.controller.power_budget_w);
        assert!(cfg.enabled);
        assert_eq!(cfg.slo_ms, 40.0);
        assert_eq!(cfg.rules.slo_ms, 40.0);
        assert_eq!(cfg.rules.burn_windows, 6);
        assert_eq!(cfg.rules.availability_floor, 0.5);
        // overdraw budget inherited from the controller when unset …
        assert_eq!(cfg.rules.power_budget_w, 18.0);
        // … and overridden by an explicit telemetry budget
        let mut own = spec.clone();
        own.telemetry.power_budget_w = 9.0;
        let cfg2 = own.telemetry.to_metrics_config(own.slo_ms, own.controller.power_budget_w);
        assert_eq!(cfg2.rules.power_budget_w, 9.0);
        // off block resolves to the zero-cost off config
        assert_eq!(
            TelemetrySpec::default().to_metrics_config(40.0, 18.0),
            MetricsConfig::off()
        );

        // an empty telemetry object is the off default — same spec (and
        // same canonical JSON) as no block at all
        let with_empty = ScenarioSpec::parse(
            r#"{"model": "lenet5", "engine": "des", "nodes": 2, "telemetry": {}}"#,
        )
        .unwrap();
        let without =
            ScenarioSpec::parse(r#"{"model": "lenet5", "engine": "des", "nodes": 2}"#).unwrap();
        assert_eq!(with_empty, without);
        assert_eq!(json::pretty(&with_empty.to_json()), json::pretty(&without.to_json()));

        // bad thresholds are rejected, not silently clamped
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "telemetry": {"metrics": true, "slo_target": 1.5}}"#
        )
        .is_err());
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "telemetry": {"metrics": true, "burn_windows": 0}}"#
        )
        .is_err());
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "telemetry": {"metrics": true, "availability_floor": 2.0}}"#
        )
        .is_err());
        // typo'd knob inside the block
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "telemetry": {"metricz": true}}"#
        )
        .is_err());
    }

    #[test]
    fn serve_blocks_parse_and_resolve_to_configs() {
        let spec = ScenarioSpec::parse(
            r#"{
              "model": "lenet5", "engine": "des", "nodes": 2, "slo_ms": 40,
              "admission": {"policy": "deadline-drop", "tenant_rate_img_per_sec": 30},
              "batch": {"max_size": 8, "max_wait_ms": 2.0}
            }"#,
        )
        .unwrap();
        assert!(!spec.admission.is_off());
        assert!(!spec.batch.is_off());
        // deadline-drop with no explicit deadline inherits the SLO
        let adm = spec.admission.to_config(spec.slo_ms).unwrap().expect("gate on");
        assert_eq!(adm.policy, ShedPolicy::DeadlineDrop);
        assert_eq!(adm.deadline_ns, ms_to_ns(40.0));
        assert_eq!(adm.tenant_rate, 30.0);
        assert_eq!(adm.tenant_burst, 16.0);
        let b = spec.batch.to_config().expect("former on");
        assert_eq!(b.max_size, 8);
        assert_eq!(b.max_wait_ms, 2.0);
        // off blocks resolve to the zero-cost None
        assert!(AdmissionSpec::default().to_config(40.0).unwrap().is_none());
        assert!(BatchSpec::default().to_config().is_none());

        // empty admission/batch objects are the off defaults — same spec
        // (and same canonical JSON) as no block at all
        let with_empty = ScenarioSpec::parse(
            r#"{"model": "lenet5", "engine": "des", "nodes": 2,
                "admission": {}, "batch": {}}"#,
        )
        .unwrap();
        let without =
            ScenarioSpec::parse(r#"{"model": "lenet5", "engine": "des", "nodes": 2}"#).unwrap();
        assert_eq!(with_empty, without);
        assert_eq!(json::pretty(&with_empty.to_json()), json::pretty(&without.to_json()));
    }

    #[test]
    fn rejects_bad_serve_specs() {
        // unknown shed policy
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "admission": {"policy": "coin-flip"}}"#
        )
        .is_err());
        // tail-drop without a cap is a no-op gate — reject, don't ignore
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "admission": {"policy": "tail-drop"}}"#
        )
        .is_err());
        // deadline-drop with neither a deadline nor an SLO
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "admission": {"policy": "deadline-drop"}}"#
        )
        .is_err());
        // the serving front end needs the des engine
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "batch": {"max_size": 4}}"#
        )
        .is_err());
        // batches above the priced range
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "batch": {"max_size": 128}}"#
        )
        .is_err());
        // trace replay needs a path …
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "arrival": {"kind": "trace"}}"#
        )
        .is_err());
        // … and the des engine
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "arrival": {"kind": "trace", "path": "t.jsonl"}}"#
        )
        .is_err());
        // a path on a non-trace arrival would be silently ignored
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des",
                "arrival": {"kind": "poisson", "path": "t.jsonl"}}"#
        )
        .is_err());
        // degenerate time compression
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des",
                "arrival": {"kind": "trace", "path": "t.jsonl", "time_scale": 0}}"#
        )
        .is_err());
        // a trace arrival itself parses fine (path existence is checked
        // at session resolve time, not spec parse time)
        assert!(ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des",
                "arrival": {"kind": "trace", "path": "t.jsonl", "time_scale": 2.0}}"#
        )
        .is_ok());
    }
}
