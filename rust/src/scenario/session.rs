//! [`Session`] — resolve a [`ScenarioSpec`] and run it (DESIGN.md §12).
//!
//! The session is the one place a spec turns into live objects: zoo
//! graphs, validated [`crate::sched::ExecutionPlan`]s (strategy
//! constructors, the eco selector, or the spec's explicit stages),
//! homogeneous sub-clusters per board group, calibrated cost models and
//! the chosen engine. Supported shapes:
//!
//! | tenants | board groups | engine   | behavior                                  |
//! |---------|--------------|----------|-------------------------------------------|
//! | 1       | 1            | analytic | steady state + seeded loaded-DES percentiles (the legacy `simulate` cell) |
//! | 1       | 1            | des      | full DES + optional controller (the legacy `load` run) |
//! | n       | 1            | analytic | demand-proportional node split, per-tenant rows (the legacy `multi` grid) |
//! | n       | 1            | des      | node split, then one DES per tenant sub-cluster (e.g. multi-tenant eco under diurnal load) |
//! | 1       | m            | either   | one row per family group; an explicit arrival rate and a power budget are each split across groups by plan-capacity share (e.g. burst + power budget over a mixed zynq/US+ inventory) |
//!
//! `VTA_BENCH_FAST=1` (or [`Session::fast`]) clamps horizons to 2.5 s
//! and streams to 16 images so CI can smoke-run every example scenario.

use super::report::{EventRow, Report, ReportRow, ServeRow};
use super::spec::{ArrivalSpec, BoardGroup, Engine, ScenarioSpec, TenantEntry};
use crate::config::{
    BoardFamily, BoardProfile, Calibration, ClusterConfig, ReconfigCost,
};
use crate::coordinator::{allocate_nodes, simulate_tenants, TenantRequest};
use crate::graph::{zoo, Graph};
use crate::power::eco_plan_batched;
use crate::runtime::artifacts_dir;
use crate::sched::{
    build_plan_priced, plan_options, survivor_options, ControllerConfig, ExecutionPlan,
    OnlineController, PlanOption, Strategy,
};
use crate::serve::RequestTrace;
use crate::sim::{run_des, simulate, ArrivalProcess, CostModel, DesConfig, SimConfig};
use crate::telemetry::{RunMetrics, RunTelemetry, TelemetryConfig};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::units::ns_to_ms;
use std::collections::HashMap;

/// Memoized per-family cost models, shared across the cells of a sweep
/// (autotuned GEMM schedules are expensive to rebuild and identical for
/// every cell of one family).
pub struct CostCache {
    calib: Calibration,
    map: HashMap<&'static str, CostModel>,
}

impl CostCache {
    pub fn new(calib: Calibration) -> Self {
        CostCache { calib, map: HashMap::new() }
    }

    /// The calibration every cached model was built with.
    pub fn calib(&self) -> &Calibration {
        &self.calib
    }

    /// The cost model for a family's Table-I board + VTA config.
    pub fn get(&mut self, family: BoardFamily) -> &mut CostModel {
        let calib = &self.calib;
        self.map.entry(family.as_str()).or_insert_with(|| {
            let board = BoardProfile::for_family(family);
            let vta = board.default_vta();
            CostModel::new(vta, board, calib.clone())
        })
    }
}

/// Builder façade: `Session::new(spec)?.run()?` is a whole experiment.
pub struct Session {
    spec: ScenarioSpec,
    /// `None` until [`Session::with_calibration`]; [`Session::run`] then
    /// loads the fitted file lazily (no disk read when a calibration is
    /// supplied, as every sweep cell does).
    calib: Option<Calibration>,
    fast: bool,
    /// Tracing config threaded into every DES this session runs
    /// (DESIGN.md §13). Off by default, so reports are byte-identical to
    /// the pre-telemetry output unless [`Session::with_telemetry`] asks.
    telemetry: TelemetryConfig,
    /// When set, every DES cell records its admitted arrivals
    /// (`run --capture-trace`); harvest with [`Session::take_captured`].
    capture: bool,
    /// Admitted `(t_ms, tenant)` pairs accumulated across the DES cells
    /// of one run — interior-mutable because [`Session::run`] borrows
    /// the session immutably.
    captured: std::cell::RefCell<Vec<(f64, String)>>,
}

impl Session {
    /// Validate the spec. The calibration is resolved at [`Session::run`]
    /// time: whatever [`Session::with_calibration`] supplied, else
    /// `artifacts/calibration.json`, else defaults.
    pub fn new(spec: ScenarioSpec) -> anyhow::Result<Self> {
        spec.validate()?;
        let fast = std::env::var("VTA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Ok(Session {
            spec,
            calib: None,
            fast,
            telemetry: TelemetryConfig::off(),
            capture: false,
            captured: std::cell::RefCell::new(Vec::new()),
        })
    }

    pub fn with_calibration(mut self, calib: Calibration) -> Self {
        self.calib = Some(calib);
        self
    }

    /// Enable span tracing + telemetry collection for every run of this
    /// session (the `--trace` flag). Not supported by the multi-tenant
    /// *analytic* shape, whose loaded DES lives inside
    /// [`simulate_tenants`]; those rows simply carry no bundle.
    pub fn with_telemetry(mut self, telemetry: TelemetryConfig) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// Override fast mode (defaults to the `VTA_BENCH_FAST` env var).
    pub fn fast(mut self, fast: bool) -> Self {
        self.fast = fast;
        self
    }

    /// Record every DES cell's admitted arrivals as `(t_ms, tenant)`
    /// pairs — the `run --capture-trace` path. Analytic cells are not
    /// captured (their DES is a synthetic loaded-percentile probe, not
    /// the measured run). Harvest with [`Session::take_captured`].
    pub fn with_capture(mut self, capture: bool) -> Self {
        self.capture = capture;
        self
    }

    /// Drain the admitted arrivals captured by the last [`Session::run`]
    /// (empty unless [`Session::with_capture`] was enabled). The pairs
    /// are replayable trace input for
    /// [`crate::serve::captured_to_jsonl`].
    pub fn take_captured(&self) -> Vec<(f64, String)> {
        self.captured.take()
    }

    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    /// Run the scenario and return the unified [`Report`].
    pub fn run(&self) -> anyhow::Result<Report> {
        let calib = self
            .calib
            .clone()
            .unwrap_or_else(|| Calibration::load_or_default(&artifacts_dir()));
        let mut cache = CostCache::new(calib);
        self.run_cached(&mut cache)
    }

    /// [`Session::run`] against a shared [`CostCache`] (what
    /// [`crate::scenario::Sweep`] threads through its cells).
    pub fn run_cached(&self, cache: &mut CostCache) -> anyhow::Result<Report> {
        let spec = self.effective_spec();
        let mut report = Report::new(&spec.name, spec.engine.as_str(), spec.seed);
        match (spec.boards.len(), spec.tenants.len()) {
            (1, 1) => {
                let label = spec.tenants[0].model.clone();
                self.run_one(
                    &spec,
                    spec.boards[0],
                    &spec.tenants[0],
                    spec.seed,
                    None,
                    &label,
                    true,
                    &mut report,
                    cache,
                )?
            }
            (_, 1) => self.run_hetero(&spec, &mut report, cache)?,
            (1, _) => match spec.engine {
                Engine::Analytic => self.run_multi_analytic(&spec, &mut report, cache)?,
                Engine::Des => self.run_multi_des(&spec, &mut report, cache)?,
            },
            _ => unreachable!("rejected by ScenarioSpec::validate"),
        }
        report.finalize();
        Ok(report)
    }

    /// The spec with fast-mode clamps applied (identity when not fast).
    fn effective_spec(&self) -> ScenarioSpec {
        let mut s = self.spec.clone();
        if self.fast {
            s.horizon_ms = s.horizon_ms.min(2500.0);
            for t in &mut s.tenants {
                t.images = t.images.min(16);
            }
        }
        s
    }

    // ---- shapes --------------------------------------------------------

    /// One (tenant × board group) run on the spec's engine.
    #[allow(clippy::too_many_arguments)]
    fn run_one(
        &self,
        spec: &ScenarioSpec,
        group: BoardGroup,
        tenant: &TenantEntry,
        seed: u64,
        rate_override: Option<f64>,
        label: &str,
        keep_timeline: bool,
        report: &mut Report,
        cache: &mut CostCache,
    ) -> anyhow::Result<()> {
        match spec.engine {
            Engine::Analytic => {
                let (row, telemetry, metrics) =
                    self.analytic_cell(spec, group, tenant, seed, rate_override, label, cache)?;
                if let Some(t) = telemetry {
                    report.telemetry.push(stamp(t, &row.label, spec.engine));
                }
                if let Some(m) = metrics {
                    report.metrics.push(m);
                }
                report.rows.push(row);
            }
            Engine::Des => {
                let (row, events, timeline, telemetry, metrics, serve) =
                    self.des_cell(spec, group, tenant, seed, rate_override, label, cache)?;
                if let Some(t) = telemetry {
                    report.telemetry.push(stamp(t, &row.label, spec.engine));
                }
                if let Some(m) = metrics {
                    report.metrics.push(m);
                }
                report.serve.extend(serve);
                report.rows.push(row);
                report.events.extend(events);
                if keep_timeline {
                    report.timeline = timeline;
                }
            }
        }
        Ok(())
    }

    /// One tenant over several family groups: a row per group. An
    /// explicit arrival rate *and* a power budget both describe the
    /// whole inventory, so each is split across groups proportionally to
    /// the group's plan capacity (a 25 W cap over zynq×6 + US+×2 caps
    /// the combined draw at 25 W, not 25 W per group).
    fn run_hetero(
        &self,
        spec: &ScenarioSpec,
        report: &mut Report,
        cache: &mut CostCache,
    ) -> anyhow::Result<()> {
        let tenant = &spec.tenants[0];
        let mut seed_rng = Rng::new(spec.seed);
        let seeds: Vec<u64> = spec.boards.iter().map(|_| seed_rng.next_u64()).collect();
        // capacity shares, needed to split an explicit rate or a budget
        let split_budget = spec.engine == Engine::Des
            && spec.controller.enabled
            && spec.controller.power_budget_w > 0.0;
        let shares: Option<Vec<f64>> = if spec.arrival.rate > 0.0 || split_budget {
            let caps = spec
                .boards
                .iter()
                .map(|&b| self.group_capacity(spec, b, tenant, cache))
                .collect::<anyhow::Result<Vec<f64>>>()?;
            let total: f64 = caps.iter().sum();
            Some(caps.iter().map(|c| c / total).collect())
        } else {
            None
        };
        for (i, &group) in spec.boards.iter().enumerate() {
            let label = format!("{}x{}", group.n, group.family);
            let rate = (spec.arrival.rate > 0.0)
                .then(|| spec.arrival.rate * shares.as_ref().expect("shares computed")[i]);
            let mut group_spec = spec.clone();
            if split_budget {
                group_spec.controller.power_budget_w *=
                    shares.as_ref().expect("shares computed")[i];
            }
            self.run_one(
                &group_spec, group, tenant, seeds[i], rate, &label, false, report, cache,
            )?;
        }
        Ok(())
    }

    /// The legacy `multi` shape: demand-proportional allocation, then
    /// the analytic simulator + a seeded 70 %-load DES per tenant —
    /// delegated to [`simulate_tenants`] so the two paths cannot drift.
    fn run_multi_analytic(
        &self,
        spec: &ScenarioSpec,
        report: &mut Report,
        cache: &mut CostCache,
    ) -> anyhow::Result<()> {
        let group = spec.boards[0];
        let vta = BoardProfile::for_family(group.family).default_vta();
        let requests: Vec<TenantRequest> = spec
            .tenants
            .iter()
            .map(|t| TenantRequest {
                model: t.model.clone(),
                input_hw: t.input_hw,
                strategy: t.strategy,
                images: t.images,
            })
            .collect();
        let out = simulate_tenants(
            group.family,
            vta,
            cache.calib().clone(),
            group.n,
            &requests,
            spec.seed,
        )?;
        for (i, t) in out.iter().enumerate() {
            let attainment = slo_attainment(&t.loaded.latency_ms, spec.slo_ms);
            let mut row = ReportRow {
                label: tenant_label(&spec.tenants, i),
                engine: Engine::Analytic.as_str().to_string(),
                model: t.model.clone(),
                family: group.family.to_string(),
                nodes: t.nodes,
                strategy: t.plan.strategy.to_string(),
                ms_per_image: t.sim.ms_per_image,
                img_per_sec: t.report.throughput_img_per_sec,
                latency_mean_ms: t.sim.latency_ms.mean(),
                p50_ms: 0.0,
                p95_ms: 0.0,
                p99_ms: 0.0,
                cluster_avg_w: t.sim.power.cluster_avg_w,
                j_per_image: t.sim.power.j_per_image,
                edp_j_s: t.sim.power.edp_j_s,
                offered: t.loaded.offered,
                completed: t.loaded.completed,
                network_bytes: t.sim.network_bytes,
                reconfigs: 0,
                downtime_ms: 0.0,
                events_processed: t.loaded.events_processed,
                events_per_sec: t.loaded.events_per_sec,
                node_util: t.sim.node_utilization.clone(),
                node_watts: t.sim.power.node_watts.clone(),
                dominated: false,
                meets_slo: spec.slo_ms == 0.0
                    || t.sim.latency_ms.mean() <= spec.slo_ms,
                availability: 1.0,
                slo_attainment: attainment,
                recovery_p50_ms: f64::NAN,
                recovery_p99_ms: f64::NAN,
                stalled_windows: 0,
                shed_rate: 0.0,
                deadline_miss_rate: f64::NAN,
                batch_mean: 1.0,
                goodput_img_per_sec: goodput(t.report.throughput_img_per_sec, attainment),
            };
            row.set_percentiles(&t.loaded.latency_ms);
            report.rows.push(row);
        }
        Ok(())
    }

    /// Multi-tenant dynamic load: the same demand-proportional node
    /// split as the analytic path, then one full DES (arrival process,
    /// controller, energy meter) per tenant sub-cluster. Like the
    /// heterogeneous path, a power budget describes the *whole* cluster
    /// and is split across the tenant sub-clusters by capacity share.
    fn run_multi_des(
        &self,
        spec: &ScenarioSpec,
        report: &mut Report,
        cache: &mut CostCache,
    ) -> anyhow::Result<()> {
        let group = spec.boards[0];
        let graphs = spec
            .tenants
            .iter()
            .map(|t| zoo::build(&t.model, t.input_hw))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let cost = cache.get(group.family);
        let mut demands = Vec::with_capacity(spec.tenants.len());
        for (t, g) in spec.tenants.iter().zip(&graphs) {
            demands.push(cost.graph_time_ns(g)? as f64 * t.images.max(1) as f64);
        }
        let alloc = allocate_nodes(group.n, &demands)?;
        let split_budget =
            spec.controller.enabled && spec.controller.power_budget_w > 0.0;
        let shares: Option<Vec<f64>> = if split_budget {
            let caps = spec
                .tenants
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let sub = BoardGroup { family: group.family, n: alloc[i] };
                    self.group_capacity(spec, sub, t, cache)
                })
                .collect::<anyhow::Result<Vec<f64>>>()?;
            let total: f64 = caps.iter().sum();
            Some(caps.iter().map(|c| c / total).collect())
        } else {
            None
        };
        let mut seed_rng = Rng::new(spec.seed);
        for (i, tenant) in spec.tenants.iter().enumerate() {
            let sub = BoardGroup { family: group.family, n: alloc[i] };
            let label = tenant_label(&spec.tenants, i);
            let seed = seed_rng.next_u64();
            let mut tenant_spec = spec.clone();
            if let Some(sh) = &shares {
                tenant_spec.controller.power_budget_w *= sh[i];
            }
            self.run_one(
                &tenant_spec, sub, tenant, seed, None, &label, false, report, cache,
            )?;
        }
        Ok(())
    }

    // ---- cells ---------------------------------------------------------

    /// Steady-state capacity of the tenant's plan on one group (used to
    /// split an explicit arrival rate across heterogeneous groups; the
    /// memoized cost cache makes the repeat pricing in the real run
    /// cheap).
    fn group_capacity(
        &self,
        spec: &ScenarioSpec,
        group: BoardGroup,
        tenant: &TenantEntry,
        cache: &mut CostCache,
    ) -> anyhow::Result<f64> {
        let g = zoo::build(&tenant.model, tenant.input_hw)?;
        let cluster = cluster_for(group)?;
        let cost = cache.get(group.family);
        let (plan, _) = resolve_plan(spec, tenant, &g, &cluster, cost)?;
        let sim = simulate(&plan, &cluster, cost, &g, &SimConfig { images: 16 })?;
        Ok(1e3 / sim.ms_per_image)
    }

    /// Analytic engine, one cell: steady-state + unloaded latency from
    /// [`simulate`], loaded percentiles from a seeded DES at the
    /// configured arrival (auto rate: 70 % of capacity, 55 % for burst)
    /// — byte-for-byte the numbers the pre-scenario `simulate`
    /// subcommand printed for the same seed.
    #[allow(clippy::too_many_arguments)]
    fn analytic_cell(
        &self,
        spec: &ScenarioSpec,
        group: BoardGroup,
        tenant: &TenantEntry,
        seed: u64,
        rate_override: Option<f64>,
        label: &str,
        cache: &mut CostCache,
    ) -> anyhow::Result<(ReportRow, Option<RunTelemetry>, Option<RunMetrics>)> {
        let g = zoo::build(&tenant.model, tenant.input_hw)?;
        let cluster = cluster_for(group)?;
        let cost = cache.get(group.family);
        let (plan, eco) = resolve_plan(spec, tenant, &g, &cluster, cost)?;
        let strategy = plan.strategy.to_string();
        let sim = simulate(&plan, &cluster, cost, &g, &SimConfig { images: tenant.images })?;

        let capacity = 1e3 / sim.ms_per_image;
        let option = PlanOption {
            plan,
            node_map: None,
            capacity_img_per_sec: capacity,
            latency_ms: sim.latency_ms.mean(),
            avg_power_w: sim.power.cluster_avg_w,
            j_per_image: sim.power.j_per_image,
        };
        let rate = rate_override
            .unwrap_or_else(|| effective_rate(&spec.arrival, capacity));
        let arrival = ArrivalProcess::parse(&spec.arrival.kind, rate, spec.arrival.burst_mult)?;
        let mut cfg = DesConfig::new(arrival, (tenant.images.max(64) as f64 / rate) * 1e3, seed);
        cfg.telemetry = self.telemetry;
        cfg.metrics =
            spec.telemetry.to_metrics_config(spec.slo_ms, spec.controller.power_budget_w);
        let mut des = run_des(&[option], 0, &cluster, cost, &g, &cfg, None)?;

        let meets_slo = match &eco {
            Some((_, meets)) => *meets,
            None => spec.slo_ms == 0.0 || sim.latency_ms.mean() <= spec.slo_ms,
        };
        let attainment = slo_attainment(&des.latency_ms, spec.slo_ms);
        let mut row = ReportRow {
            label: pick_label(label, &eco),
            engine: Engine::Analytic.as_str().to_string(),
            model: tenant.model.clone(),
            family: group.family.to_string(),
            nodes: group.n,
            strategy,
            ms_per_image: sim.ms_per_image,
            img_per_sec: capacity,
            latency_mean_ms: sim.latency_ms.mean(),
            p50_ms: 0.0,
            p95_ms: 0.0,
            p99_ms: 0.0,
            cluster_avg_w: sim.power.cluster_avg_w,
            j_per_image: sim.power.j_per_image,
            edp_j_s: sim.power.edp_j_s,
            offered: des.offered,
            completed: des.completed,
            network_bytes: sim.network_bytes,
            reconfigs: 0,
            downtime_ms: 0.0,
            events_processed: des.events_processed,
            events_per_sec: des.events_per_sec,
            node_util: sim.node_utilization.clone(),
            node_watts: sim.power.node_watts.clone(),
            dominated: false,
            meets_slo,
            availability: 1.0,
            slo_attainment: attainment,
            recovery_p50_ms: f64::NAN,
            recovery_p99_ms: f64::NAN,
            stalled_windows: 0,
            shed_rate: 0.0,
            deadline_miss_rate: f64::NAN,
            batch_mean: 1.0,
            goodput_img_per_sec: goodput(capacity, attainment),
        };
        row.set_percentiles(&des.latency_ms);
        // the loaded-percentile DES carries the windowed series; the
        // steady-state figures the engine is actually about ride along as
        // synthetic gauges so the bundle is self-contained
        let metrics = des.metrics.take().map(|mut m| {
            m.push_gauge("vta_steady_ms_per_image", 0.0, sim.ms_per_image);
            m.push_gauge("vta_steady_img_per_sec", 0.0, capacity);
            m.push_gauge("vta_steady_cluster_w", 0.0, sim.power.cluster_avg_w);
            stamp_metrics(m, &row.label, Engine::Analytic)
        });
        Ok((row, des.telemetry.take(), metrics))
    }

    /// DES engine, one cell: the four §II-C candidates (plus the eco
    /// pick or the spec's explicit plan as a fifth option when that is
    /// the initial strategy), optional online controller with the spec's
    /// power budget, full energy metering.
    #[allow(clippy::too_many_arguments)]
    fn des_cell(
        &self,
        spec: &ScenarioSpec,
        group: BoardGroup,
        tenant: &TenantEntry,
        seed: u64,
        rate_override: Option<f64>,
        label: &str,
        cache: &mut CostCache,
    ) -> anyhow::Result<(
        ReportRow,
        Vec<EventRow>,
        Vec<(f64, usize)>,
        Option<RunTelemetry>,
        Option<RunMetrics>,
        Vec<ServeRow>,
    )> {
        let g = zoo::build(&tenant.model, tenant.input_hw)?;
        let cluster = cluster_for(group)?;
        let cost = cache.get(group.family);
        let mut options = plan_options(&g, &cluster, cost, &Strategy::all())?;

        let mut eco = None;
        let initial = if tenant.plan.is_some()
            || tenant.strategy == Strategy::Eco
            || tenant.strategy == Strategy::Search
        {
            // the fifth candidate: the explicit plan, the eco pick or
            // the searched plan, priced like every other option
            let (plan, eco_info) = resolve_plan(spec, tenant, &g, &cluster, cost)?;
            eco = eco_info;
            let sim = simulate(&plan, &cluster, cost, &g, &SimConfig { images: 16 })?;
            options.push(PlanOption {
                capacity_img_per_sec: 1e3 / sim.ms_per_image,
                latency_ms: sim.latency_ms.mean(),
                avg_power_w: sim.power.cluster_avg_w,
                j_per_image: sim.power.j_per_image,
                plan,
                node_map: None,
            });
            options.len() - 1
        } else {
            options
                .iter()
                .position(|o| o.plan.strategy == tenant.strategy)
                .expect("all base strategies are candidates")
        };
        let strategy = options[initial].plan.strategy.to_string();
        let cap0 = options[initial].capacity_img_per_sec;

        // with faults + controller, give the controller somewhere to run
        // to: the best surviving-node candidate per possible casualty
        // (DESIGN.md §14) — appended after `initial` so indices hold
        if !spec.faults.is_off() && spec.controller.enabled && group.n >= 2 {
            for dead in 0..group.n {
                let sopts = survivor_options(&g, &cluster, cost, &Strategy::all(), dead)?;
                if let Some(best) = sopts.into_iter().max_by(|a, b| {
                    a.capacity_img_per_sec.total_cmp(&b.capacity_img_per_sec)
                }) {
                    options.push(best);
                }
            }
        }

        // trace replays carry their own timestamps and tenant routing;
        // every other arrival kind goes through the rate vocabulary
        let mut serve_tenants: Vec<String> = Vec::new();
        let arrival = if spec.arrival.kind.eq_ignore_ascii_case("trace") {
            let trace = RequestTrace::load(&spec.arrival.path, spec.arrival.time_scale)?;
            serve_tenants = trace.tenant_names.clone();
            trace.to_process()
        } else {
            let rate = rate_override.unwrap_or_else(|| effective_rate(&spec.arrival, cap0));
            ArrivalProcess::parse(&spec.arrival.kind, rate, spec.arrival.burst_mult)?
        };
        let mut cfg = DesConfig::new(arrival, spec.horizon_ms, seed);
        cfg.telemetry = self.telemetry;
        cfg.metrics =
            spec.telemetry.to_metrics_config(spec.slo_ms, spec.controller.power_budget_w);
        cfg.serve.admission = spec.admission.to_config(spec.slo_ms)?;
        cfg.serve.batch = spec.batch.to_config();
        cfg.serve.tenants = serve_tenants;
        cfg.capture = self.capture;
        let deadline_active =
            cfg.serve.admission.as_ref().is_some_and(|a| a.deadline_ns > 0);
        if !spec.faults.is_off() {
            // the rejoin re-flash is always a full-tier cost: a crash
            // loses the PL image regardless of the controller's tier
            cfg.faults = spec.faults.to_config(ReconfigCost::for_family(group.family));
        }
        let mut controller = if spec.controller.enabled {
            let budget = spec.controller.power_budget_w;
            Some(OnlineController::new(
                ControllerConfig {
                    power_budget_w: (budget > 0.0).then_some(budget),
                    ..Default::default()
                },
                ReconfigCost::for_family_tier(group.family, spec.controller.reconfig_tier),
            )?)
        } else {
            None
        };
        let mut r = run_des(&options, initial, &cluster, cost, &g, &cfg, controller.as_mut())?;
        if self.capture {
            self.captured.borrow_mut().append(&mut r.captured);
        }

        let p99 = r.latency_ms.p99();
        let attainment = slo_attainment(&r.latency_ms, spec.slo_ms);
        let mut row = ReportRow {
            label: pick_label(label, &eco),
            engine: Engine::Des.as_str().to_string(),
            model: tenant.model.clone(),
            family: group.family.to_string(),
            nodes: group.n,
            strategy,
            ms_per_image: 1e3 / cap0,
            img_per_sec: r.throughput_img_per_sec,
            latency_mean_ms: r.latency_ms.mean(),
            p50_ms: 0.0,
            p95_ms: 0.0,
            p99_ms: 0.0,
            cluster_avg_w: r.power.avg_cluster_w,
            j_per_image: r.power.j_per_image,
            edp_j_s: r.power.edp_j_s,
            offered: r.offered,
            completed: r.completed,
            network_bytes: r.network_bytes,
            reconfigs: r.reconfigs.len(),
            downtime_ms: r.downtime_ms,
            events_processed: r.events_processed,
            events_per_sec: r.events_per_sec,
            node_util: r.node_utilization.clone(),
            node_watts: r.power.node_avg_w.clone(),
            dominated: false,
            meets_slo: spec.slo_ms == 0.0 || (p99.is_finite() && p99 <= spec.slo_ms),
            availability: r.availability,
            slo_attainment: attainment,
            recovery_p50_ms: r.recovery_ms.p50(),
            recovery_p99_ms: r.recovery_ms.p99(),
            stalled_windows: r.stalled_windows,
            shed_rate: if r.offered > 0 { r.shed as f64 / r.offered as f64 } else { 0.0 },
            deadline_miss_rate: if deadline_active && r.completed > 0 {
                r.deadline_missed as f64 / r.completed as f64
            } else {
                f64::NAN
            },
            batch_mean: if r.batches_dispatched > 0 {
                r.batch_members as f64 / r.batches_dispatched as f64
            } else {
                f64::NAN
            },
            goodput_img_per_sec: goodput(r.throughput_img_per_sec, attainment),
        };
        row.set_percentiles(&r.latency_ms);
        let mut events: Vec<EventRow> = r
            .reconfigs
            .iter()
            .map(|e| EventRow {
                label: row.label.clone(),
                at_ms: e.at_ms,
                from_strategy: e.from_strategy.to_string(),
                to_strategy: e.to_strategy.to_string(),
                downtime_ms: e.downtime_ms,
                reason: e.reason.clone(),
            })
            .collect();
        // crash/rejoin outages ride the same event stream, tagged by
        // their reason so downstream diffing can filter them out
        events.extend(r.faults.iter().map(|o| {
            let outage_ms = ns_to_ms(o.end_ns - o.start_ns);
            EventRow {
                label: row.label.clone(),
                at_ms: ns_to_ms(o.start_ns),
                from_strategy: row.strategy.clone(),
                to_strategy: row.strategy.clone(),
                downtime_ms: outage_ms,
                reason: format!("node {} crash ({outage_ms:.1} ms outage + re-flash)", o.node),
            }
        }));
        // alert firings share the event timeline, tagged `from: "alert"`
        // so downstream diffing can filter them like crash outages; the
        // same firing is also stamped into the controller audit log
        // inside the bundle (DESIGN.md §15)
        events.extend(r.alerts.iter().map(|a| EventRow {
            label: row.label.clone(),
            at_ms: a.at_ms,
            from_strategy: "alert".to_string(),
            to_strategy: a.rule.clone(),
            downtime_ms: 0.0,
            reason: a.message.clone(),
        }));
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        let telemetry = r.telemetry.take();
        let metrics =
            r.metrics.take().map(|m| stamp_metrics(m, &row.label, Engine::Des));
        let serve = r
            .serve
            .take()
            .map(|s| {
                s.tenants
                    .iter()
                    .map(|t| ServeRow {
                        label: row.label.clone(),
                        tenant: t.name.clone(),
                        offered: t.offered,
                        admitted: t.admitted,
                        shed_queue: t.shed_queue,
                        shed_deadline: t.shed_deadline,
                        shed_rate_limit: t.shed_rate_limit,
                        p50_ms: t.latency_ms.p50(),
                        p99_ms: t.latency_ms.p99(),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok((row, events, r.queue_timeline, telemetry, metrics, serve))
    }
}

/// Stamp a run's telemetry bundle with its report-row identity.
fn stamp(mut t: RunTelemetry, label: &str, engine: Engine) -> RunTelemetry {
    t.label = label.to_string();
    t.engine = engine.as_str().to_string();
    t
}

/// Stamp a run's metric bundle with its report-row identity.
fn stamp_metrics(mut m: RunMetrics, label: &str, engine: Engine) -> RunMetrics {
    m.label = label.to_string();
    m.engine = engine.as_str().to_string();
    m
}

/// Build and sanity-check one group's homogeneous sub-cluster.
fn cluster_for(group: BoardGroup) -> anyhow::Result<ClusterConfig> {
    let vta = BoardProfile::for_family(group.family).default_vta();
    let cluster = ClusterConfig::homogeneous(group.family, group.n).with_vta(vta);
    cluster.validate()?;
    Ok(cluster)
}

/// SLO attainment of a completed-latency summary: the fraction of
/// completions at or under the SLO, NaN (emitted as JSON `null`) when no
/// SLO is set or nothing completed — an outage must read as "unmeasured",
/// never as a silent perfect score (DESIGN.md §14).
fn slo_attainment(latency: &Summary, slo_ms: f64) -> f64 {
    if slo_ms <= 0.0 {
        return f64::NAN;
    }
    latency.fraction_at_or_below(slo_ms).unwrap_or(f64::NAN)
}

/// SLO-qualified throughput (DESIGN.md §16): throughput discounted by
/// the fraction of completions that met the SLO — plain throughput when
/// no SLO is set (attainment NaN).
fn goodput(img_per_sec: f64, slo_attainment: f64) -> f64 {
    if slo_attainment.is_finite() {
        img_per_sec * slo_attainment
    } else {
        img_per_sec
    }
}

/// Auto arrival rate from plan capacity: 70 % load, or 55 % for burst so
/// the MMPP high phase overloads the plan (the legacy `load` defaults).
fn effective_rate(arrival: &ArrivalSpec, capacity: f64) -> f64 {
    if arrival.rate > 0.0 {
        arrival.rate
    } else if arrival.kind.eq_ignore_ascii_case("burst")
        || arrival.kind.eq_ignore_ascii_case("mmpp")
    {
        0.55 * capacity
    } else {
        0.7 * capacity
    }
}

/// Resolve a tenant's plan: explicit stages win, then the eco selector
/// or the plan-search engine (each returning a provenance string +
/// SLO verdict), then the §II-C constructor priced by the shared
/// segment-cost table. Both selectors price at the spec's batch size so
/// a batching scenario's plan choice reflects the batching knee
/// (DESIGN.md §16/§17).
fn resolve_plan(
    spec: &ScenarioSpec,
    tenant: &TenantEntry,
    g: &Graph,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
) -> anyhow::Result<(ExecutionPlan, Option<(String, bool)>)> {
    if let Some(plan) = ScenarioSpec::explicit_plan(tenant, g, cluster.num_nodes())? {
        return Ok((plan, None));
    }
    let batch = spec.batch.max_size.max(1) as u64;
    if tenant.strategy == Strategy::Eco {
        let slo = (spec.slo_ms > 0.0).then_some(spec.slo_ms);
        let choice = eco_plan_batched(g, cluster, cost, slo, batch)?;
        let via = format!("eco→{}", choice.base);
        return Ok((choice.plan, Some((via, choice.meets_slo))));
    }
    if tenant.strategy == Strategy::Search {
        let budget = spec.controller.power_budget_w;
        let cfg = crate::search::SearchConfig {
            objective: crate::search::Objective::Latency,
            slo_ms: (spec.slo_ms > 0.0).then_some(spec.slo_ms),
            power_budget_w: (spec.controller.enabled && budget > 0.0).then_some(budget),
            batch,
            // scenario plans must cover the whole inventory: `simulate`
            // and the DES both want plan.n_nodes == cluster nodes
            rightsize: false,
            ..Default::default()
        };
        let out = crate::search::search_plan(g, cluster, cost, &cfg)?;
        let via = format!("search→{}", out.via);
        return Ok((out.plan, Some((via, out.meets_slo))));
    }
    let table = cost.seg_cost_table(g)?;
    let plan = build_plan_priced(tenant.strategy, g, cluster.num_nodes(), &table)?;
    Ok((plan, None))
}

/// Tag eco/search rows with the provenance of the selected plan
/// (`eco→pipeline`, `search→dp`, …).
fn pick_label(label: &str, pick: &Option<(String, bool)>) -> String {
    match pick {
        Some((via, _)) => format!("{label} ({via})"),
        None => label.to_string(),
    }
}

/// Row label for tenant `i`: the model name, `#i`-suffixed only when the
/// same model appears more than once.
fn tenant_label(tenants: &[TenantEntry], i: usize) -> String {
    let model = &tenants[i].model;
    if tenants.iter().filter(|t| &t.model == model).count() > 1 {
        format!("{model}#{i}")
    } else {
        model.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::ControllerSpec;

    fn session(text: &str) -> Session {
        Session::new(ScenarioSpec::parse(text).unwrap())
            .unwrap()
            .with_calibration(Calibration::default())
            .fast(false)
    }

    #[test]
    fn analytic_single_matches_direct_simulation() {
        let s = session(
            r#"{"model": "lenet5", "strategy": "pipeline", "nodes": 2, "images": 24, "seed": 9}"#,
        );
        let rep = s.run().unwrap();
        assert_eq!(rep.rows.len(), 1);
        let row = &rep.rows[0];
        assert_eq!(row.engine, "analytic");
        assert_eq!(row.strategy, "pipeline");
        // reference: the same pipeline cell priced directly
        let g = zoo::build("lenet5", 0).unwrap();
        let cluster = ClusterConfig::homogeneous(BoardFamily::Zynq7000, 2);
        let mut cost = CostModel::new(
            cluster.vta.clone(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        let table = cost.seg_cost_table(&g).unwrap();
        let plan = build_plan_priced(Strategy::Pipeline, &g, 2, &table).unwrap();
        let sim = simulate(&plan, &cluster, &mut cost, &g, &SimConfig { images: 24 }).unwrap();
        assert_eq!(row.ms_per_image, sim.ms_per_image);
        assert_eq!(row.j_per_image, sim.power.j_per_image);
        assert_eq!(row.network_bytes, sim.network_bytes);
    }

    #[test]
    fn des_single_runs_controller_and_is_deterministic() {
        let text = r#"{
          "model": "lenet5", "strategy": "ai", "nodes": 3, "engine": "des",
          "arrival": {"kind": "burst", "burst_mult": 4}, "horizon_ms": 4000, "seed": 7
        }"#;
        let a = session(text).run().unwrap();
        let b = session(text).run().unwrap();
        assert_eq!(a.rows.len(), 1);
        assert_eq!(a.rows[0].engine, "des");
        assert_eq!(a.rows[0].offered, b.rows[0].offered);
        assert_eq!(a.rows[0].p99_ms, b.rows[0].p99_ms);
        assert_eq!(a.events.len(), b.events.len());
        assert!(!a.timeline.is_empty(), "single DES run keeps its timeline");
        assert!(a.rows[0].completed > 0);
    }

    #[test]
    fn multi_tenant_analytic_rows_cover_the_budget() {
        let s = session(
            r#"{
              "tenants": [
                {"model": "resnet18", "strategy": "pipeline", "images": 16},
                {"model": "lenet5", "strategy": "sg", "images": 16},
                {"model": "mlp", "strategy": "fused", "images": 16}
              ],
              "nodes": 12, "seed": 7
            }"#,
        );
        let rep = s.run().unwrap();
        assert_eq!(rep.rows.len(), 3);
        assert_eq!(rep.rows.iter().map(|r| r.nodes).sum::<usize>(), 12);
        assert_eq!(rep.rows[1].label, "lenet5");
        for r in &rep.rows {
            assert!(r.img_per_sec > 0.0);
            assert!(r.p99_ms >= r.p50_ms);
            assert!(r.cluster_avg_w > 0.0 && r.j_per_image > 0.0);
        }
    }

    #[test]
    fn hetero_groups_produce_one_row_per_family() {
        let s = session(
            r#"{
              "model": "lenet5", "strategy": "sg", "engine": "des",
              "boards": [{"family": "zynq", "n": 2}, {"family": "zu+", "n": 2}],
              "horizon_ms": 3000, "seed": 5
            }"#,
        );
        let rep = s.run().unwrap();
        assert_eq!(rep.rows.len(), 2);
        assert_eq!(rep.rows[0].family, "zynq7000");
        assert_eq!(rep.rows[1].family, "ultrascale+");
        assert!(rep.rows[0].label.starts_with("2xzynq7000"));
        assert!(rep.timeline.is_empty(), "multi-row runs drop the timeline");
        for r in &rep.rows {
            assert!(r.completed > 0, "{}: nothing completed", r.label);
        }
    }

    #[test]
    fn eco_rows_name_their_base_strategy() {
        let s = session(
            r#"{"model": "lenet5", "strategy": "eco", "nodes": 2, "images": 16}"#,
        );
        let rep = s.run().unwrap();
        assert_eq!(rep.rows[0].strategy, "eco");
        assert!(rep.rows[0].label.contains("eco→"), "{}", rep.rows[0].label);
        assert!(rep.rows[0].meets_slo);
    }

    #[test]
    fn explicit_plan_becomes_the_initial_des_option() {
        let s = session(
            r#"{
              "model": "lenet5", "strategy": "pipeline", "nodes": 2, "engine": "des",
              "horizon_ms": 2000,
              "plan": [
                {"segments": ["c1", "c2"], "replicas": [0], "split": "dp"},
                {"segments": ["c3", "head"], "replicas": [1], "split": "dp"}
              ],
              "controller": {"enabled": false}
            }"#,
        );
        let rep = s.run().unwrap();
        assert_eq!(rep.rows[0].strategy, "pipeline");
        assert!(rep.rows[0].completed > 0);
        assert!(rep.events.is_empty(), "controller disabled");
    }

    #[test]
    fn fast_mode_clamps_horizon_and_images() {
        let spec = ScenarioSpec::parse(
            r#"{"model": "mlp", "engine": "des", "horizon_ms": 60000}"#,
        )
        .unwrap();
        let s = Session::new(spec)
            .unwrap()
            .with_calibration(Calibration::default())
            .fast(true);
        let eff = s.effective_spec();
        assert_eq!(eff.horizon_ms, 2500.0);
        assert_eq!(eff.tenants[0].images, 16);
    }

    #[test]
    fn chaos_spec_fills_the_new_columns_and_logs_the_crash() {
        let text = r#"{
          "model": "lenet5", "strategy": "pipeline", "nodes": 2, "engine": "des",
          "horizon_ms": 4000, "seed": 13, "slo_ms": 50,
          "controller": {"enabled": false},
          "faults": {"crashes": [{"node": 1, "at_ms": 1000, "down_ms": 500}]}
        }"#;
        let rep = session(text).run().unwrap();
        let row = &rep.rows[0];
        assert!(row.availability < 1.0 && row.availability > 0.5, "{}", row.availability);
        assert!(row.recovery_p50_ms.is_finite() && row.recovery_p50_ms > 500.0);
        assert!(
            row.slo_attainment.is_finite()
                && row.slo_attainment >= 0.0
                && row.slo_attainment <= 1.0
        );
        let crash_events: Vec<_> =
            rep.events.iter().filter(|e| e.reason.contains("crash")).collect();
        assert_eq!(crash_events.len(), 1);
        assert!((crash_events[0].at_ms - 1000.0).abs() < 1e-6);
        // same seed ⇒ byte-identical report
        let again = session(text).run().unwrap();
        assert_eq!(
            crate::util::json::pretty(&rep.to_json()),
            crate::util::json::pretty(&again.to_json())
        );
    }

    #[test]
    fn fault_free_faults_block_is_byte_identical_to_no_block() {
        let with = r#"{
          "model": "lenet5", "strategy": "ai", "nodes": 2, "engine": "des",
          "horizon_ms": 3000, "seed": 7, "faults": {}
        }"#;
        let without = r#"{
          "model": "lenet5", "strategy": "ai", "nodes": 2, "engine": "des",
          "horizon_ms": 3000, "seed": 7
        }"#;
        let a = session(with).run().unwrap();
        let b = session(without).run().unwrap();
        assert_eq!(
            crate::util::json::pretty(&a.to_json()),
            crate::util::json::pretty(&b.to_json())
        );
    }

    #[test]
    fn power_budget_flows_into_the_controller() {
        // structural check: a capped DES spec runs and keeps schema
        let spec = ScenarioSpec {
            controller: ControllerSpec {
                enabled: true,
                power_budget_w: 9.0,
                ..Default::default()
            },
            ..ScenarioSpec::parse(
                r#"{"model": "mlp", "engine": "des", "nodes": 2,
                    "arrival": {"kind": "burst", "burst_mult": 4},
                    "horizon_ms": 3000}"#,
            )
            .unwrap()
        };
        let rep = Session::new(spec)
            .unwrap()
            .with_calibration(Calibration::default())
            .fast(false)
            .run()
            .unwrap();
        assert_eq!(rep.rows.len(), 1);
        assert!(rep.rows[0].completed > 0);
    }

    #[test]
    fn metrics_knob_attaches_a_stamped_bundle_per_engine() {
        let des = session(
            r#"{
              "model": "lenet5", "strategy": "pipeline", "nodes": 2, "engine": "des",
              "horizon_ms": 3000, "seed": 7, "slo_ms": 40,
              "telemetry": {"metrics": true}
            }"#,
        )
        .run()
        .unwrap();
        assert_eq!(des.metrics.len(), 1);
        let m = &des.metrics[0];
        assert_eq!(m.label, des.rows[0].label);
        assert_eq!(m.engine, "des");
        assert!(m.series("vta_arrivals_total").is_some());
        assert!(m.series("vta_request_latency_ns").is_some());
        // the JSON grows exactly the trailing `metrics` key
        let top: Vec<String> = des
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        let mut want: Vec<String> =
            Report::TOP_KEYS.iter().map(|s| s.to_string()).collect();
        want.push("metrics".to_string());
        assert_eq!(top, want);

        let analytic = session(
            r#"{
              "model": "lenet5", "strategy": "pipeline", "nodes": 2,
              "images": 16, "seed": 7, "telemetry": {"metrics": true}
            }"#,
        )
        .run()
        .unwrap();
        assert_eq!(analytic.metrics.len(), 1);
        let m = &analytic.metrics[0];
        assert_eq!(m.engine, "analytic");
        assert!(m.series("vta_steady_ms_per_image").is_some());
        assert!(m.series("vta_steady_img_per_sec").is_some());
    }

    #[test]
    fn serve_off_blocks_are_byte_identical_to_no_blocks() {
        // absent blocks ≡ empty blocks ≡ batching at max_size 1: the
        // §16 zero-cost contract at report level
        let without = r#"{
          "model": "lenet5", "strategy": "ai", "nodes": 2, "engine": "des",
          "horizon_ms": 3000, "seed": 7
        }"#;
        let empty = r#"{
          "model": "lenet5", "strategy": "ai", "nodes": 2, "engine": "des",
          "horizon_ms": 3000, "seed": 7, "admission": {}, "batch": {}
        }"#;
        let batch_one = r#"{
          "model": "lenet5", "strategy": "ai", "nodes": 2, "engine": "des",
          "horizon_ms": 3000, "seed": 7, "batch": {"max_size": 1, "max_wait_ms": 9.0}
        }"#;
        let a = crate::util::json::pretty(&session(without).run().unwrap().to_json());
        let b = crate::util::json::pretty(&session(empty).run().unwrap().to_json());
        let c = crate::util::json::pretty(&session(batch_one).run().unwrap().to_json());
        assert_eq!(a, b, "empty serve blocks perturbed the report");
        assert_eq!(a, c, "max_size=1 batching perturbed the report");
        // the off row carries the documented serve defaults
        let rep = session(without).run().unwrap();
        assert_eq!(rep.rows[0].shed_rate, 0.0);
        assert!(rep.rows[0].deadline_miss_rate.is_nan());
        assert_eq!(rep.rows[0].batch_mean, 1.0);
        assert_eq!(
            rep.rows[0].goodput_img_per_sec, rep.rows[0].img_per_sec,
            "no SLO ⇒ goodput is plain throughput"
        );
        assert!(rep.serve.is_empty());
    }

    #[test]
    fn trace_arrival_replays_the_log_and_fills_serve_rows() {
        let dir = std::env::temp_dir().join(format!("vta-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("session_replay.jsonl");
        let mut lines = String::new();
        for i in 0..30u64 {
            let tenant = if i % 3 == 0 { "beta" } else { "alpha" };
            lines.push_str(&format!(
                "{{\"t_ms\": {}, \"tenant\": \"{tenant}\"}}\n",
                i * 40
            ));
        }
        std::fs::write(&path, lines).unwrap();
        let text = format!(
            r#"{{
              "model": "lenet5", "strategy": "pipeline", "nodes": 2, "engine": "des",
              "horizon_ms": 4000, "seed": 3, "controller": {{"enabled": false}},
              "arrival": {{"kind": "trace", "path": {:?}, "time_scale": 1.0}}
            }}"#,
            path.to_str().unwrap()
        );
        let rep = session(&text).run().unwrap();
        std::fs::remove_file(&path).ok();
        let row = &rep.rows[0];
        assert_eq!(row.offered, 30, "every trace request fits the horizon");
        assert_eq!(row.shed_rate, 0.0, "no gate, nothing shed");
        // two tenants in the log ⇒ per-tenant serve rows, name-sorted
        assert_eq!(rep.serve.len(), 2);
        assert_eq!(rep.serve[0].tenant, "alpha");
        assert_eq!(rep.serve[1].tenant, "beta");
        assert_eq!(rep.serve[0].offered, 20);
        assert_eq!(rep.serve[1].offered, 10);
        assert_eq!(rep.serve[0].admitted, 20);
        // the trailing `serve` key appears exactly once
        let top: Vec<String> = rep
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        let mut want: Vec<String> =
            Report::TOP_KEYS.iter().map(|s| s.to_string()).collect();
        want.push("serve".to_string());
        assert_eq!(top, want);
        // replays are seed-independent: a different seed, same report rows
        let text2 = text.replace("\"seed\": 3", "\"seed\": 44");
        std::fs::write(&path, {
            let mut l = String::new();
            for i in 0..30u64 {
                let tenant = if i % 3 == 0 { "beta" } else { "alpha" };
                l.push_str(&format!("{{\"t_ms\": {}, \"tenant\": \"{tenant}\"}}\n", i * 40));
            }
            l
        })
        .unwrap();
        let rep2 = session(&text2).run().unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(rep2.rows[0].completed, row.completed);
        assert_eq!(rep2.rows[0].p99_ms, row.p99_ms);
    }

    #[test]
    fn search_rows_name_their_provenance_and_never_lose_to_their_base() {
        let s = session(
            r#"{"model": "lenet5", "strategy": "search", "nodes": 4, "images": 16, "seed": 3}"#,
        );
        let rep = s.run().unwrap();
        let row = &rep.rows[0];
        assert_eq!(row.strategy, "search");
        assert!(row.label.contains("search→"), "{}", row.label);
        assert!(row.meets_slo, "no SLO set: the searched plan trivially meets it");
        // dominance at the report level: the same cell under every
        // heuristic strategy is no faster
        for base in ["sg", "pipeline", "ai", "fused"] {
            let text = format!(
                r#"{{"model": "lenet5", "strategy": "{base}", "nodes": 4, "images": 16, "seed": 3}}"#
            );
            let b = session(&text).run().unwrap();
            assert!(
                row.latency_mean_ms <= b.rows[0].latency_mean_ms * 1.0001,
                "{base} beat search: {} vs {} ms",
                b.rows[0].latency_mean_ms,
                row.latency_mean_ms
            );
        }
    }

    #[test]
    fn search_strategy_drives_the_des_engine() {
        let text = r#"{
          "model": "lenet5", "strategy": "search", "nodes": 3, "engine": "des",
          "horizon_ms": 3000, "seed": 7, "controller": {"enabled": false}
        }"#;
        let a = session(text).run().unwrap();
        assert_eq!(a.rows[0].strategy, "search");
        assert!(a.rows[0].completed > 0);
        let b = session(text).run().unwrap();
        assert_eq!(a.rows[0].p99_ms, b.rows[0].p99_ms, "searched DES runs stay seeded");
    }

    #[test]
    fn captured_trace_replays_to_the_same_admitted_counts() {
        let text = r#"{
          "model": "lenet5", "strategy": "pipeline", "nodes": 2, "engine": "des",
          "horizon_ms": 3000, "seed": 21, "controller": {"enabled": false}
        }"#;
        let s = session(text).with_capture(true);
        let rep = s.run().unwrap();
        let captured = s.take_captured();
        assert_eq!(
            captured.len() as u64,
            rep.rows[0].offered,
            "no admission gate: every offered request was admitted and captured"
        );
        assert!(s.take_captured().is_empty(), "take_captured drains");
        // round trip: replay the capture as an `arrival: trace` source
        let jsonl = crate::serve::captured_to_jsonl(&captured).unwrap();
        let dir = std::env::temp_dir().join(format!("vta-capture-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("capture_replay.jsonl");
        std::fs::write(&path, jsonl).unwrap();
        let replay_text = format!(
            r#"{{
              "model": "lenet5", "strategy": "pipeline", "nodes": 2, "engine": "des",
              "horizon_ms": 3000, "seed": 99, "controller": {{"enabled": false}},
              "arrival": {{"kind": "trace", "path": {:?}, "time_scale": 1.0}}
            }}"#,
            path.to_str().unwrap()
        );
        let replay = session(&replay_text).run().unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(
            replay.rows[0].offered,
            rep.rows[0].offered,
            "replaying the capture must reproduce the offered count"
        );
        assert_eq!(replay.rows[0].shed_rate, 0.0);
    }

    #[test]
    fn metrics_off_report_is_byte_identical_to_pre_metrics_output() {
        let with = r#"{
          "model": "mlp", "engine": "des", "nodes": 2,
          "horizon_ms": 2000, "seed": 11, "telemetry": {}
        }"#;
        let without = r#"{
          "model": "mlp", "engine": "des", "nodes": 2,
          "horizon_ms": 2000, "seed": 11
        }"#;
        let a = session(with).run().unwrap();
        let b = session(without).run().unwrap();
        assert_eq!(
            crate::util::json::pretty(&a.to_json()),
            crate::util::json::pretty(&b.to_json())
        );
    }
}
