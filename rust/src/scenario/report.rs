//! The unified [`Report`] every scenario run returns (DESIGN.md §12).
//!
//! One schema subsumes what used to be four ad-hoc result shapes:
//! [`crate::sim::SimResult`] (steady state), [`crate::sim::DesResult`]
//! (dynamic load), the per-tenant serving rows of `multi`, and the
//! Pareto frontier rows of `power`. A report is a list of [`ReportRow`]s
//! — one per (tenant × board group × sweep cell) — plus the
//! reconfiguration [`EventRow`]s and the queue-depth timeline of
//! single-run DES scenarios. **Every row always carries every key**, so
//! the emitted JSON schema is identical across engines (the CI scenario
//! suite snapshot-checks it); fields an engine cannot measure are filled
//! with their documented analytic/DES counterpart, never dropped.
//!
//! Dominance is computed over the rows of the *finished* report
//! ((cluster watts, ms/image) weak dominance, same geometry as
//! [`crate::power::pareto`]), which is what makes a sweep report double
//! as a Pareto frontier.

use crate::telemetry::{RunMetrics, RunTelemetry};
use crate::util::json::{self, Json};
use crate::util::stats::Summary;

/// One run result. See the field docs for the analytic/DES meaning of
/// each metric; [`ReportRow::ROW_KEYS`] is the schema contract.
#[derive(Debug, Clone)]
pub struct ReportRow {
    /// Row tag: the tenant name, the board group, or the sweep-cell tag.
    pub label: String,
    /// Engine that produced this row (`analytic` | `des`).
    pub engine: String,
    pub model: String,
    pub family: String,
    pub nodes: usize,
    /// Strategy of the (initial) plan; `eco` rows keep the tag and name
    /// the selected base strategy in `label`.
    pub strategy: String,
    /// Steady-state time per image of the plan, ms (analytic in both
    /// engines — the DES measures throughput instead).
    pub ms_per_image: f64,
    /// Analytic: plan capacity (1000 / ms_per_image). DES: measured
    /// completed / horizon.
    pub img_per_sec: f64,
    /// Analytic: unloaded single-image latency. DES: mean measured
    /// end-to-end latency.
    pub latency_mean_ms: f64,
    /// Loaded-latency percentiles (both engines run a seeded DES; the
    /// analytic engine's runs at the configured arrival against the
    /// plan's capacity). Non-finite when nothing completed.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Average cluster draw, W (steady-state for analytic, integrated
    /// over the horizon for DES).
    pub cluster_avg_w: f64,
    /// Energy per inference, J (same split as `cluster_avg_w`).
    pub j_per_image: f64,
    /// Energy-delay product, J·s.
    pub edp_j_s: f64,
    /// Images offered / completed by the loaded run (analytic: its
    /// percentile pass; DES: the measured run).
    pub offered: u64,
    pub completed: u64,
    pub network_bytes: u64,
    /// Plan switches executed (always 0 for analytic rows).
    pub reconfigs: usize,
    pub downtime_ms: f64,
    /// Simulator events processed by the (loaded) DES run behind this
    /// row, and the same divided by the simulated horizon — the engine's
    /// own speed gauge, not a cluster metric.
    pub events_processed: u64,
    pub events_per_sec: f64,
    /// Busy fraction per node, in node order.
    pub node_util: Vec<f64>,
    /// Average draw per node, W.
    pub node_watts: Vec<f64>,
    /// Another row of this report is ≤ on (watts, ms/image) and < on
    /// one — filled by [`Report::finalize`].
    pub dominated: bool,
    /// With `slo_ms > 0`: unloaded latency (analytic) / p99 (DES) under
    /// the SLO. Always true when no SLO is set.
    pub meets_slo: bool,
    /// Fraction of node-time the cluster was up over the horizon
    /// (DESIGN.md §14). `1.0` for analytic rows and fault-free DES runs.
    pub availability: f64,
    /// Fraction of completed requests whose end-to-end latency met the
    /// SLO. NaN (JSON `null`) when no SLO is set or nothing completed;
    /// `1.0` trivially when `slo_ms == 0` is treated as "no SLO".
    pub slo_attainment: f64,
    /// Recovery-time percentiles across node rejoins (outage + re-flash),
    /// ms. NaN (JSON `null`) when no rejoin happened in the horizon.
    pub recovery_p50_ms: f64,
    pub recovery_p99_ms: f64,
    /// Control windows that completed zero requests while work was in
    /// flight — the explicit outage signal (never silently zero stats).
    pub stalled_windows: u64,
    /// Fraction of offered requests the admission gate turned away
    /// (DESIGN.md §16). `0.0` when no gate is configured.
    pub shed_rate: f64,
    /// Fraction of completed requests that missed the admission
    /// deadline. NaN (JSON `null`) unless a gate with a deadline ran
    /// and something completed.
    pub deadline_miss_rate: f64,
    /// Mean realized batch size (requests per dispatch). `1.0` exactly
    /// when batching is off; NaN when nothing dispatched.
    pub batch_mean: f64,
    /// SLO-qualified throughput, img/s: `img_per_sec × slo_attainment`
    /// when an SLO is set, plain `img_per_sec` otherwise — the number
    /// admission control exists to protect.
    pub goodput_img_per_sec: f64,
}

impl ReportRow {
    /// The row schema, in emit order — the contract the scenario CI
    /// suite snapshot-checks.
    pub const ROW_KEYS: [&'static str; 35] = [
        "label",
        "engine",
        "model",
        "family",
        "nodes",
        "strategy",
        "ms_per_image",
        "img_per_sec",
        "latency_mean_ms",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "cluster_avg_w",
        "j_per_image",
        "edp_j_s",
        "offered",
        "completed",
        "network_bytes",
        "reconfigs",
        "downtime_ms",
        "events_processed",
        "events_per_sec",
        "node_util",
        "node_watts",
        "dominated",
        "meets_slo",
        "availability",
        "slo_attainment",
        "recovery_p50_ms",
        "recovery_p99_ms",
        "stalled_windows",
        "shed_rate",
        "deadline_miss_rate",
        "batch_mean",
        "goodput_img_per_sec",
    ];

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::str_(&self.label)),
            ("engine", json::str_(&self.engine)),
            ("model", json::str_(&self.model)),
            ("family", json::str_(&self.family)),
            ("nodes", json::int(self.nodes as i64)),
            ("strategy", json::str_(&self.strategy)),
            ("ms_per_image", fnum(self.ms_per_image)),
            ("img_per_sec", fnum(self.img_per_sec)),
            ("latency_mean_ms", fnum(self.latency_mean_ms)),
            ("p50_ms", fnum(self.p50_ms)),
            ("p95_ms", fnum(self.p95_ms)),
            ("p99_ms", fnum(self.p99_ms)),
            ("cluster_avg_w", fnum(self.cluster_avg_w)),
            ("j_per_image", fnum(self.j_per_image)),
            ("edp_j_s", fnum(self.edp_j_s)),
            ("offered", json::int(self.offered as i64)),
            ("completed", json::int(self.completed as i64)),
            ("network_bytes", json::int(self.network_bytes as i64)),
            ("reconfigs", json::int(self.reconfigs as i64)),
            ("downtime_ms", fnum(self.downtime_ms)),
            ("events_processed", json::int(self.events_processed as i64)),
            ("events_per_sec", fnum(self.events_per_sec)),
            (
                "node_util",
                Json::Arr(self.node_util.iter().map(|&u| fnum(u)).collect()),
            ),
            (
                "node_watts",
                Json::Arr(self.node_watts.iter().map(|&w| fnum(w)).collect()),
            ),
            ("dominated", Json::Bool(self.dominated)),
            ("meets_slo", Json::Bool(self.meets_slo)),
            ("availability", fnum(self.availability)),
            ("slo_attainment", fnum(self.slo_attainment)),
            ("recovery_p50_ms", fnum(self.recovery_p50_ms)),
            ("recovery_p99_ms", fnum(self.recovery_p99_ms)),
            ("stalled_windows", json::int(self.stalled_windows as i64)),
            ("shed_rate", fnum(self.shed_rate)),
            ("deadline_miss_rate", fnum(self.deadline_miss_rate)),
            ("batch_mean", fnum(self.batch_mean)),
            ("goodput_img_per_sec", fnum(self.goodput_img_per_sec)),
        ])
    }

    /// Fill the loaded-percentile fields from a latency summary.
    pub fn set_percentiles(&mut self, s: &Summary) {
        self.p50_ms = s.p50();
        self.p95_ms = s.p95();
        self.p99_ms = s.p99();
    }
}

/// Per-tenant admission/latency accounting from a run with the serving
/// front end on (DESIGN.md §16) — one row per (run × tenant), tagged
/// with the report row it belongs to.
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// Label of the report row whose run produced this tenant line.
    pub label: String,
    pub tenant: String,
    pub offered: u64,
    pub admitted: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub shed_rate_limit: u64,
    /// Completed-request latency percentiles for this tenant, ms. NaN
    /// (JSON `null`) when none of its requests completed.
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl ServeRow {
    pub const SERVE_KEYS: [&'static str; 9] = [
        "label",
        "tenant",
        "offered",
        "admitted",
        "shed_queue",
        "shed_deadline",
        "shed_rate_limit",
        "p50_ms",
        "p99_ms",
    ];

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::str_(&self.label)),
            ("tenant", json::str_(&self.tenant)),
            ("offered", json::int(self.offered as i64)),
            ("admitted", json::int(self.admitted as i64)),
            ("shed_queue", json::int(self.shed_queue as i64)),
            ("shed_deadline", json::int(self.shed_deadline as i64)),
            ("shed_rate_limit", json::int(self.shed_rate_limit as i64)),
            ("p50_ms", fnum(self.p50_ms)),
            ("p99_ms", fnum(self.p99_ms)),
        ])
    }
}

/// One executed reconfiguration, tagged with the row it happened in.
#[derive(Debug, Clone)]
pub struct EventRow {
    /// Label of the row whose run switched plans.
    pub label: String,
    pub at_ms: f64,
    pub from_strategy: String,
    pub to_strategy: String,
    pub downtime_ms: f64,
    pub reason: String,
}

impl EventRow {
    pub const EVENT_KEYS: [&'static str; 6] =
        ["label", "at_ms", "from", "to", "downtime_ms", "reason"];

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::str_(&self.label)),
            ("at_ms", fnum(self.at_ms)),
            ("from", json::str_(&self.from_strategy)),
            ("to", json::str_(&self.to_strategy)),
            ("downtime_ms", fnum(self.downtime_ms)),
            ("reason", json::str_(&self.reason)),
        ])
    }
}

/// The unified result of [`crate::scenario::Session::run`] /
/// [`crate::scenario::Sweep::run`].
#[derive(Debug, Clone)]
pub struct Report {
    pub scenario: String,
    /// `analytic` | `des` | `mixed` (a sweep whose axis flips the engine).
    pub engine: String,
    pub seed: u64,
    pub rows: Vec<ReportRow>,
    pub events: Vec<EventRow>,
    /// (t_ms, images in flight) — populated only by single-row DES runs
    /// (always present in the JSON, possibly empty).
    pub timeline: Vec<(f64, usize)>,
    /// Per-run telemetry bundles (DESIGN.md §13), one per traced run.
    /// Empty unless the session ran with tracing enabled, and emitted as
    /// an *extra* trailing `telemetry` key only when non-empty — so an
    /// untraced report's JSON (and [`Report::TOP_KEYS`]) is byte-for-byte
    /// what it was before telemetry existed.
    pub telemetry: Vec<RunTelemetry>,
    /// Per-run windowed metric bundles (DESIGN.md §15), one per run with
    /// the `telemetry.metrics` knob on. Same zero-cost-off contract as
    /// `telemetry`: emitted as an extra trailing `metrics` key only when
    /// non-empty.
    pub metrics: Vec<RunMetrics>,
    /// Per-tenant admission rows (DESIGN.md §16), one per (run ×
    /// tenant) of runs with the serving front end on. Same
    /// zero-cost-off contract: emitted as an extra trailing `serve` key
    /// only when non-empty.
    pub serve: Vec<ServeRow>,
}

impl Report {
    /// The top-level schema, in emit order. Traced reports append one
    /// extra `telemetry` key after these.
    pub const TOP_KEYS: [&'static str; 6] =
        ["scenario", "engine", "seed", "rows", "events", "timeline"];

    pub fn new(scenario: &str, engine: &str, seed: u64) -> Self {
        Report {
            scenario: scenario.to_string(),
            engine: engine.to_string(),
            seed,
            rows: Vec::new(),
            events: Vec::new(),
            timeline: Vec::new(),
            telemetry: Vec::new(),
            metrics: Vec::new(),
            serve: Vec::new(),
        }
    }

    /// Fold another report's rows/events into this one (sweep merging),
    /// prefixing row labels with the cell tag when non-empty.
    pub fn absorb(&mut self, tag: &str, mut other: Report) {
        if self.engine != other.engine {
            self.engine = "mixed".to_string();
        }
        for row in &mut other.rows {
            if !tag.is_empty() {
                row.label = if row.label.is_empty() {
                    tag.to_string()
                } else {
                    format!("{tag}/{}", row.label)
                };
            }
        }
        for ev in &mut other.events {
            if !tag.is_empty() {
                ev.label = if ev.label.is_empty() {
                    tag.to_string()
                } else {
                    format!("{tag}/{}", ev.label)
                };
            }
        }
        for t in &mut other.telemetry {
            if !tag.is_empty() {
                t.label = if t.label.is_empty() {
                    tag.to_string()
                } else {
                    format!("{tag}/{}", t.label)
                };
            }
        }
        for m in &mut other.metrics {
            if !tag.is_empty() {
                m.label = if m.label.is_empty() {
                    tag.to_string()
                } else {
                    format!("{tag}/{}", m.label)
                };
            }
        }
        for s in &mut other.serve {
            if !tag.is_empty() {
                s.label = if s.label.is_empty() {
                    tag.to_string()
                } else {
                    format!("{tag}/{}", s.label)
                };
            }
        }
        self.rows.append(&mut other.rows);
        self.events.append(&mut other.events);
        self.telemetry.append(&mut other.telemetry);
        self.metrics.append(&mut other.metrics);
        self.serve.append(&mut other.serve);
        // a merged report is multi-run: the per-run timeline is dropped
        self.timeline.clear();
    }

    /// Compute the cross-row `dominated` tags: (watts, ms/image) weak
    /// dominance with one strict axis — the same geometry as
    /// [`crate::power::pareto::mark_dominated`].
    pub fn finalize(&mut self) {
        let snapshot: Vec<(f64, f64)> = self
            .rows
            .iter()
            .map(|r| (r.cluster_avg_w, r.ms_per_image))
            .collect();
        for (i, r) in self.rows.iter_mut().enumerate() {
            r.dominated = snapshot.iter().enumerate().any(|(j, &(w, ms))| {
                j != i
                    && w <= r.cluster_avg_w
                    && ms <= r.ms_per_image
                    && (w < r.cluster_avg_w || ms < r.ms_per_image)
            });
        }
    }

    /// The non-dominated rows, watts-sorted with exact duplicates
    /// collapsed — the latency-vs-watts frontier of this report.
    pub fn frontier(&self) -> Vec<&ReportRow> {
        let mut f: Vec<&ReportRow> = self.rows.iter().filter(|r| !r.dominated).collect();
        f.sort_by(|a, b| a.cluster_avg_w.partial_cmp(&b.cluster_avg_w).unwrap());
        f.dedup_by(|a, b| {
            a.cluster_avg_w == b.cluster_avg_w && a.ms_per_image == b.ms_per_image
        });
        f
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("scenario", json::str_(&self.scenario)),
            ("engine", json::str_(&self.engine)),
            ("seed", json::int(self.seed as i64)),
            ("rows", Json::Arr(self.rows.iter().map(|r| r.to_json()).collect())),
            ("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect())),
            (
                "timeline",
                Json::Arr(
                    self.timeline
                        .iter()
                        .map(|&(t, d)| Json::Arr(vec![fnum(t), json::int(d as i64)]))
                        .collect(),
                ),
            ),
        ];
        if !self.telemetry.is_empty() {
            fields.push((
                "telemetry",
                Json::Arr(self.telemetry.iter().map(|t| t.to_json()).collect()),
            ));
        }
        if !self.metrics.is_empty() {
            fields.push((
                "metrics",
                Json::Arr(self.metrics.iter().map(|m| m.to_json()).collect()),
            ));
        }
        if !self.serve.is_empty() {
            fields.push((
                "serve",
                Json::Arr(self.serve.iter().map(|s| s.to_json()).collect()),
            ));
        }
        json::obj(fields)
    }
}

/// Finite-guarded number emit: a NaN percentile (empty latency summary)
/// or infinite ratio becomes JSON `null` instead of invalid output.
fn fnum(v: f64) -> Json {
    if v.is_finite() {
        json::num(v)
    } else {
        Json::Null
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(label: &str, w: f64, ms: f64) -> ReportRow {
        ReportRow {
            label: label.into(),
            engine: "analytic".into(),
            model: "mlp".into(),
            family: "zynq7000".into(),
            nodes: 2,
            strategy: "pipeline".into(),
            ms_per_image: ms,
            img_per_sec: 1e3 / ms,
            latency_mean_ms: ms * 1.5,
            p50_ms: ms * 1.4,
            p95_ms: ms * 1.9,
            p99_ms: ms * 2.0,
            cluster_avg_w: w,
            j_per_image: w * ms / 1e3,
            edp_j_s: w * ms * ms / 1e6,
            offered: 100,
            completed: 100,
            network_bytes: 4096,
            reconfigs: 0,
            downtime_ms: 0.0,
            events_processed: 400,
            events_per_sec: 50.0,
            node_util: vec![0.8, 0.7],
            node_watts: vec![3.1, 3.0],
            dominated: false,
            meets_slo: true,
            availability: 1.0,
            slo_attainment: f64::NAN,
            recovery_p50_ms: f64::NAN,
            recovery_p99_ms: f64::NAN,
            stalled_windows: 0,
            shed_rate: 0.0,
            deadline_miss_rate: f64::NAN,
            batch_mean: 1.0,
            goodput_img_per_sec: 1e3 / ms,
        }
    }

    #[test]
    fn json_keys_match_the_schema_contract_for_both_engines() {
        let mut rep = Report::new("t", "analytic", 7);
        rep.rows.push(row("a", 10.0, 5.0));
        let mut des_row = row("b", 12.0, 4.0);
        des_row.engine = "des".into();
        des_row.reconfigs = 2;
        rep.rows.push(des_row);
        rep.events.push(EventRow {
            label: "b".into(),
            at_ms: 100.0,
            from_strategy: "pipeline".into(),
            to_strategy: "fused".into(),
            downtime_ms: 52.0,
            reason: "overload".into(),
        });
        rep.timeline.push((100.0, 3));
        let j = rep.to_json();
        let top: Vec<&str> =
            j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(top, Report::TOP_KEYS);
        for r in j.get("rows").unwrap().as_arr().unwrap() {
            let keys: Vec<&str> =
                r.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, ReportRow::ROW_KEYS, "row schema drifted");
        }
        for e in j.get("events").unwrap().as_arr().unwrap() {
            let keys: Vec<&str> =
                e.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, EventRow::EVENT_KEYS);
        }
        // the emitted text is valid JSON and round-trips
        let text = crate::util::json::pretty(&j);
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn nan_percentiles_emit_null_not_invalid_json() {
        let mut rep = Report::new("t", "des", 1);
        let mut r = row("empty", 10.0, 5.0);
        r.p50_ms = f64::NAN;
        r.p99_ms = f64::INFINITY;
        rep.rows.push(r);
        let text = crate::util::json::pretty(&rep.to_json());
        let back = Json::parse(&text).unwrap();
        let row0 = &back.get("rows").unwrap().as_arr().unwrap()[0];
        assert_eq!(row0.get("p50_ms"), Some(&Json::Null));
        assert_eq!(row0.get("p99_ms"), Some(&Json::Null));
        // unmeasured chaos columns are explicit nulls, not fake zeros
        assert_eq!(row0.get("slo_attainment"), Some(&Json::Null));
        assert_eq!(row0.get("recovery_p50_ms"), Some(&Json::Null));
        assert_eq!(row0.get("recovery_p99_ms"), Some(&Json::Null));
        assert_eq!(row0.get_f64("availability").unwrap(), 1.0);
        assert_eq!(row0.get_i64("stalled_windows").unwrap(), 0);
    }

    #[test]
    fn finalize_marks_dominated_and_frontier_is_monotone() {
        let mut rep = Report::new("sweep", "analytic", 7);
        rep.rows.push(row("cheap-slow", 10.0, 8.0));
        rep.rows.push(row("bad", 12.0, 9.0)); // worse on both axes
        rep.rows.push(row("fast-hot", 20.0, 2.0));
        rep.finalize();
        assert!(!rep.rows[0].dominated);
        assert!(rep.rows[1].dominated);
        assert!(!rep.rows[2].dominated);
        let f = rep.frontier();
        assert_eq!(f.len(), 2);
        assert!(f[0].cluster_avg_w < f[1].cluster_avg_w);
        assert!(f[0].ms_per_image > f[1].ms_per_image);
    }

    #[test]
    fn telemetry_key_appears_only_when_bundles_exist() {
        let mut rep = Report::new("t", "des", 1);
        rep.rows.push(row("a", 10.0, 5.0));
        let top: Vec<String> = rep
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        assert_eq!(top, Report::TOP_KEYS, "untraced report grew a key");

        rep.telemetry.push(RunTelemetry {
            label: "a".into(),
            engine: "des".into(),
            ..Default::default()
        });
        let top: Vec<String> = rep
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        let mut want: Vec<String> =
            Report::TOP_KEYS.iter().map(|s| s.to_string()).collect();
        want.push("telemetry".to_string());
        assert_eq!(top, want);

        // absorb prefixes bundle labels like row labels
        let mut base = Report::new("sweep", "des", 1);
        base.absorb("n=4", rep);
        assert_eq!(base.telemetry[0].label, "n=4/a");
    }

    #[test]
    fn metrics_key_appears_only_when_bundles_exist() {
        let mut rep = Report::new("t", "des", 1);
        rep.rows.push(row("a", 10.0, 5.0));
        let top: Vec<String> = rep
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        assert_eq!(top, Report::TOP_KEYS, "metrics-off report grew a key");

        rep.metrics.push(RunMetrics {
            label: "a".into(),
            engine: "des".into(),
            ..Default::default()
        });
        let top: Vec<String> = rep
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        let mut want: Vec<String> =
            Report::TOP_KEYS.iter().map(|s| s.to_string()).collect();
        want.push("metrics".to_string());
        assert_eq!(top, want);
        // emitted text stays valid JSON
        let text = crate::util::json::pretty(&rep.to_json());
        assert_eq!(Json::parse(&text).unwrap(), rep.to_json());

        // absorb prefixes metric-bundle labels like row labels
        let mut base = Report::new("sweep", "des", 1);
        base.absorb("n=4", rep);
        assert_eq!(base.metrics[0].label, "n=4/a");
    }

    #[test]
    fn serve_key_appears_only_when_tenant_rows_exist() {
        let mut rep = Report::new("t", "des", 1);
        rep.rows.push(row("a", 10.0, 5.0));
        let top: Vec<String> = rep
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.clone())
            .collect();
        assert_eq!(top, Report::TOP_KEYS, "serve-off report grew a key");

        rep.serve.push(ServeRow {
            label: "a".into(),
            tenant: "alpha".into(),
            offered: 100,
            admitted: 90,
            shed_queue: 10,
            shed_deadline: 0,
            shed_rate_limit: 0,
            p50_ms: 4.0,
            p99_ms: f64::NAN,
        });
        let j = rep.to_json();
        let top: Vec<String> =
            j.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        let mut want: Vec<String> =
            Report::TOP_KEYS.iter().map(|s| s.to_string()).collect();
        want.push("serve".to_string());
        assert_eq!(top, want);
        let srow = &j.get("serve").unwrap().as_arr().unwrap()[0];
        let keys: Vec<&str> =
            srow.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ServeRow::SERVE_KEYS);
        // NaN percentiles stay valid JSON
        assert_eq!(srow.get("p99_ms"), Some(&Json::Null));
        let text = crate::util::json::pretty(&j);
        assert_eq!(Json::parse(&text).unwrap(), j);

        // absorb prefixes serve labels like row labels
        let mut base = Report::new("sweep", "des", 1);
        base.absorb("n=4", rep);
        assert_eq!(base.serve[0].label, "n=4/a");
    }

    #[test]
    fn absorb_tags_rows_and_mixes_engines() {
        let mut base = Report::new("sweep", "analytic", 7);
        let mut cell = Report::new("cell", "des", 7);
        cell.rows.push(row("", 10.0, 5.0));
        cell.timeline.push((1.0, 1));
        base.absorb("n=4", cell);
        assert_eq!(base.engine, "mixed");
        assert_eq!(base.rows[0].label, "n=4");
        assert!(base.timeline.is_empty(), "merged reports drop the timeline");
    }
}
