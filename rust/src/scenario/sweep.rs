//! [`Sweep`] — grid expansion over any [`ScenarioSpec`] axis
//! (DESIGN.md §12, EXPERIMENTS.md §E12).
//!
//! A scenario file opts in with a top-level `"sweep"` object mapping
//! **spec paths** to value lists:
//!
//! ```json
//! { "model": "resnet18", "nodes": 4,
//!   "sweep": { "nodes": [4, 8, 12], "strategy": ["pipeline", "eco"] } }
//! ```
//!
//! Paths are dotted and may index arrays (`arrival.kind`,
//! `tenants.0.strategy`, `boards.1.n`); they address the spec's JSON
//! document *as written*, so shorthand specs sweep with shorthand paths.
//! Expansion is the cartesian product in declaration order; every cell
//! is re-parsed and re-validated as a full [`ScenarioSpec`], run through
//! one [`Session`] sharing a [`CostCache`], and merged into a single
//! tagged [`Report`] whose cross-row dominance tags make it a
//! latency-vs-watts frontier for free.
//!
//! The same path/value machinery backs `vtacluster run --set key=value`
//! overrides.

use super::report::Report;
use super::session::{CostCache, Session};
use super::spec::ScenarioSpec;
use crate::config::Calibration;
use crate::util::json::Json;

/// Hard cap on grid size — a typo'd axis must not fork a million runs.
const MAX_CELLS: usize = 1024;

/// An expanded-on-demand scenario grid.
#[derive(Debug, Clone)]
pub struct Sweep {
    /// The spec document (shorthand allowed) without its `"sweep"` key.
    base: Json,
    /// (path, values) axes in declaration order.
    axes: Vec<(String, Vec<Json>)>,
}

impl Sweep {
    /// Extract the sweep from a scenario document, if it declares one.
    pub fn from_doc(doc: &Json) -> anyhow::Result<Option<Sweep>> {
        let Some(sweep) = doc.get("sweep") else { return Ok(None) };
        let axes: Vec<(String, Vec<Json>)> = sweep
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_arr()?.to_vec())))
            .collect::<anyhow::Result<_>>()?;
        let base = Json::Obj(
            doc.as_obj()?
                .iter()
                .filter(|(k, _)| k != "sweep")
                .cloned()
                .collect(),
        );
        Sweep::new(base, axes).map(Some)
    }

    /// Build a sweep programmatically (the CLI `power` frontier does).
    pub fn new(base: Json, axes: Vec<(String, Vec<Json>)>) -> anyhow::Result<Sweep> {
        anyhow::ensure!(!axes.is_empty(), "sweep declares no axes");
        let mut cells = 1usize;
        for (path, values) in &axes {
            anyhow::ensure!(!values.is_empty(), "sweep axis '{path}' has no values");
            cells = cells.saturating_mul(values.len());
        }
        anyhow::ensure!(
            cells <= MAX_CELLS,
            "sweep expands to {cells} cells (cap: {MAX_CELLS})"
        );
        Ok(Sweep { base, axes })
    }

    /// Expand the grid: every cell as `(tag, spec)`, tag =
    /// `"axis=value,..."` in declaration order.
    pub fn cells(&self) -> anyhow::Result<Vec<(String, ScenarioSpec)>> {
        let mut docs = vec![(String::new(), self.base.clone())];
        for (path, values) in &self.axes {
            let short = path.rsplit('.').next().unwrap_or(path);
            let mut next = Vec::with_capacity(docs.len() * values.len());
            for (tag, doc) in &docs {
                for v in values {
                    let mut cell = doc.clone();
                    set_path(&mut cell, path, v.clone())?;
                    let t = if tag.is_empty() {
                        format!("{short}={}", tag_value(v))
                    } else {
                        format!("{tag},{short}={}", tag_value(v))
                    };
                    next.push((t, cell));
                }
            }
            docs = next;
        }
        docs.into_iter()
            .map(|(tag, doc)| {
                let spec = ScenarioSpec::from_json(&doc)
                    .map_err(|e| anyhow::anyhow!("sweep cell [{tag}]: {e}"))?;
                Ok((tag, spec))
            })
            .collect()
    }

    /// Run every cell and merge the tagged rows into one finalized
    /// [`Report`] (cost models shared across cells per family).
    pub fn run(&self, calib: &Calibration) -> anyhow::Result<Report> {
        let cells = self.cells()?;
        let first = &cells[0].1;
        let mut report =
            Report::new(&first.name, first.engine.as_str(), first.seed);
        let mut cache = CostCache::new(calib.clone());
        for (tag, spec) in cells {
            let cell_report = Session::new(spec)?
                .with_calibration(calib.clone())
                .run_cached(&mut cache)
                .map_err(|e| anyhow::anyhow!("sweep cell [{tag}]: {e}"))?;
            report.absorb(&tag, cell_report);
        }
        report.finalize();
        Ok(report)
    }
}

/// Set `path` (dotted keys, numeric array indices) in a JSON document,
/// creating intermediate objects for missing keys. Used by sweep axes
/// and `--set` overrides.
pub fn set_path(doc: &mut Json, path: &str, value: Json) -> anyhow::Result<()> {
    anyhow::ensure!(!path.is_empty(), "empty override path");
    let parts: Vec<&str> = path.split('.').collect();
    let mut cur = doc;
    let mut value = Some(value);
    for (i, part) in parts.iter().enumerate() {
        let last = i + 1 == parts.len();
        if let Ok(idx) = part.parse::<usize>() {
            let arr = match cur {
                Json::Arr(a) => a,
                other => anyhow::bail!(
                    "path '{path}': '{part}' indexes a {}",
                    other.type_name()
                ),
            };
            anyhow::ensure!(
                idx < arr.len(),
                "path '{path}': index {idx} out of range (len {})",
                arr.len()
            );
            if last {
                arr[idx] = value.take().expect("value used once");
                return Ok(());
            }
            cur = &mut arr[idx];
        } else {
            let obj = match cur {
                Json::Obj(o) => o,
                other => anyhow::bail!(
                    "path '{path}': '{part}' keys into a {}",
                    other.type_name()
                ),
            };
            let pos = match obj.iter().position(|(k, _)| k == *part) {
                Some(p) => p,
                None => {
                    let filler =
                        if last { Json::Null } else { Json::Obj(Vec::new()) };
                    obj.push((part.to_string(), filler));
                    obj.len() - 1
                }
            };
            if last {
                obj[pos].1 = value.take().expect("value used once");
                return Ok(());
            }
            cur = &mut obj[pos].1;
        }
    }
    unreachable!("loop returns on the last path part")
}

/// Parse one `--set key=value` override. The value is JSON when it
/// parses as JSON (`8`, `true`, `[1,2]`), a bare string otherwise
/// (`eco`, `burst`).
pub fn parse_override(s: &str) -> anyhow::Result<(String, Json)> {
    let (k, v) = s
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("--set wants key=value, got '{s}'"))?;
    anyhow::ensure!(!k.trim().is_empty(), "--set '{s}': empty key");
    let v = v.trim();
    let value = Json::parse(v).unwrap_or_else(|_| Json::Str(v.to_string()));
    Ok((k.trim().to_string(), value))
}

/// Apply a list of `key=value` overrides to a scenario document.
pub fn apply_overrides(doc: &mut Json, sets: &[String]) -> anyhow::Result<()> {
    for s in sets {
        let (path, value) = parse_override(s)?;
        set_path(doc, &path, value)?;
    }
    Ok(())
}

/// Human tag for one axis value (strings unquoted, the rest compact).
fn tag_value(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string_compact(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn set_path_handles_keys_indices_and_creation() {
        let mut doc = Json::parse(
            r#"{"nodes": 4, "arrival": {"kind": "poisson"},
                "tenants": [{"model": "mlp"}, {"model": "lenet5"}]}"#,
        )
        .unwrap();
        set_path(&mut doc, "nodes", json::int(8)).unwrap();
        set_path(&mut doc, "arrival.kind", json::str_("burst")).unwrap();
        set_path(&mut doc, "tenants.1.strategy", json::str_("eco")).unwrap();
        set_path(&mut doc, "controller.power_budget_w", json::num(12.5)).unwrap();
        assert_eq!(doc.get("nodes").unwrap().as_i64().unwrap(), 8);
        assert_eq!(doc.get("arrival").unwrap().get_str("kind").unwrap(), "burst");
        let t1 = &doc.get("tenants").unwrap().as_arr().unwrap()[1];
        assert_eq!(t1.get_str("strategy").unwrap(), "eco");
        assert_eq!(
            doc.get("controller").unwrap().get_f64("power_budget_w").unwrap(),
            12.5
        );
        // errors name the path
        let e = set_path(&mut doc, "tenants.7.model", json::str_("x"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("tenants.7.model"), "{e}");
        assert!(set_path(&mut doc, "nodes.3", json::int(1)).is_err());
    }

    #[test]
    fn overrides_parse_json_or_fall_back_to_strings() {
        let (k, v) = parse_override("nodes=8").unwrap();
        assert_eq!((k.as_str(), v), ("nodes", json::int(8)));
        let (_, v) = parse_override("strategy=eco").unwrap();
        assert_eq!(v, json::str_("eco"));
        let (_, v) = parse_override("controller.enabled=true").unwrap();
        assert_eq!(v, Json::Bool(true));
        assert!(parse_override("no-equals-sign").is_err());
        assert!(parse_override("=5").is_err());
    }

    #[test]
    fn grid_expansion_is_cartesian_in_declaration_order() {
        let doc = Json::parse(
            r#"{"model": "mlp", "nodes": 2,
                "sweep": {"nodes": [2, 3], "strategy": ["sg", "pipeline"]}}"#,
        )
        .unwrap();
        let sweep = Sweep::from_doc(&doc).unwrap().expect("sweep declared");
        let cells = sweep.cells().unwrap();
        assert_eq!(cells.len(), 4);
        let tags: Vec<&str> = cells.iter().map(|(t, _)| t.as_str()).collect();
        assert_eq!(
            tags,
            ["nodes=2,strategy=sg", "nodes=2,strategy=pipeline",
             "nodes=3,strategy=sg", "nodes=3,strategy=pipeline"]
        );
        assert_eq!(cells[3].1.boards[0].n, 3);
        assert_eq!(cells[3].1.tenants[0].strategy.as_str(), "pipeline");
        // no sweep key → None
        let plain = Json::parse(r#"{"model": "mlp"}"#).unwrap();
        assert!(Sweep::from_doc(&plain).unwrap().is_none());
    }

    #[test]
    fn sweep_runs_cells_into_one_tagged_dominance_marked_report() {
        let doc = Json::parse(
            r#"{"name": "mini-frontier", "model": "mlp", "images": 8,
                "sweep": {"nodes": [1, 2], "strategy": ["sg", "pipeline"]}}"#,
        )
        .unwrap();
        let sweep = Sweep::from_doc(&doc).unwrap().unwrap();
        let rep = sweep.run(&crate::config::Calibration::default()).unwrap();
        assert_eq!(rep.scenario, "mini-frontier");
        assert_eq!(rep.rows.len(), 4);
        assert!(rep.rows[0].label.starts_with("nodes=1,strategy=sg"));
        // a 4-cell grid over one model must have a monotone frontier
        let front = rep.frontier();
        assert!(!front.is_empty() && front.len() <= 4);
        for w in front.windows(2) {
            assert!(w[1].cluster_avg_w > w[0].cluster_avg_w);
            assert!(w[1].ms_per_image < w[0].ms_per_image);
        }
        // more boards must appear somewhere on the watt axis above fewer
        assert!(rep.rows.iter().any(|r| r.nodes == 2 && !r.dominated));
    }

    #[test]
    fn oversized_grids_are_rejected() {
        let axes = vec![(
            "nodes".to_string(),
            (0..2000i64).map(json::int).collect::<Vec<_>>(),
        )];
        assert!(Sweep::new(Json::Obj(vec![]), axes).is_err());
        assert!(Sweep::new(Json::Obj(vec![]), vec![]).is_err());
    }
}
