//! The searchable partition space (DESIGN.md §17).
//!
//! A candidate schedule is a sequence of stages, each a contiguous run
//! of atomic segments on `r` dedicated replica nodes in one of the two
//! split modes — exactly the shape [`crate::sched::ExecutionPlan`]
//! validates. [`SearchSpace`] turns the memoized cost model into an
//! O(1)-per-query oracle over that space: per-split prefix sums of the
//! (optionally batch-amortized) per-image segment times, so the DP and
//! beam engines score a stage span without touching the cost model
//! again.
//!
//! Spatial splits are priced on a **ladder** — every split up to 8 plus
//! the powers of two up to 64 — so building the table for a 256-board
//! fleet costs the same handful of segment evaluations as a 12-board
//! stack. Data-parallel replication is pure arithmetic (`t₁ / r`) and is
//! therefore unrestricted. At paper scale (`n ≤ 8`) the ladder is the
//! complete split set, which is what makes the DP-vs-exhaustive pin in
//! [`crate::search::dp`] meaningful.

use crate::graph::partition::atomic_segments;
use crate::graph::Graph;
use crate::sched::{ExecutionPlan, SplitMode, StagePlan, Strategy};
use crate::sim::CostModel;

/// Analytic objective proxy the DP/beam engines optimize. Both are
/// admissible lower bounds of the metered simulator's metric (compute
/// only — the simulator adds wire time and port contention on top),
/// which is what makes them safe pruning bounds in the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proxy {
    /// Steady-state bottleneck demand (ns/image); stages combine by max.
    Throughput,
    /// Unloaded single-image wall time (ns); stages combine by sum.
    Latency,
}

impl Proxy {
    /// Fold one stage score into an accumulated plan score.
    pub fn combine(&self, acc: f64, stage: f64) -> f64 {
        match self {
            Proxy::Throughput => acc.max(stage),
            Proxy::Latency => acc + stage,
        }
    }

    /// Score of the empty plan (the fold's identity).
    pub fn identity(&self) -> f64 {
        0.0
    }
}

/// One searched stage: atoms `[a, b)` on `r` fresh replica nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Choice {
    pub a: usize,
    pub b: usize,
    pub r: usize,
    pub spatial: bool,
}

/// Prefix-sum oracle over the contiguous-partition space of one graph.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Atomic segment labels in graph order.
    pub labels: Vec<String>,
    /// `Graph::model` captured for plan assembly.
    pub model: String,
    /// Full segment order captured for plan assembly/validation.
    pub segment_order: Vec<String>,
    /// Priced spatial-split ladder, ascending, always starting at 1.
    ladder: Vec<usize>,
    /// `prefix[i][k]` = Σ per-image time (ns) of atoms `[0, k)` at
    /// spatial split `ladder[i]`.
    prefix: Vec<Vec<f64>>,
    /// Per-launch PS driver overhead, charged once per stage (ns).
    pub overhead_ns: f64,
    /// Node budget the space was built for.
    pub n_nodes: usize,
    /// Batch size the per-image times are amortized over (1 = unbatched).
    pub batch: u64,
}

/// Splits worth pricing for an `n`-node budget: the complete 1..=8 set
/// plus powers of two up to `min(n, 64)`.
fn ladder_for(n: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (1..=n.min(8)).collect();
    for p in [16usize, 32, 64] {
        if p <= n {
            out.push(p);
        }
    }
    out
}

impl SearchSpace {
    /// Price the space for `g` over an `n_nodes` budget, amortizing
    /// segment times over `batch` images per launch (`1` = the classic
    /// unbatched table).
    pub fn build(
        g: &Graph,
        cost: &mut CostModel,
        n_nodes: usize,
        batch: u64,
    ) -> anyhow::Result<SearchSpace> {
        anyhow::ensure!(n_nodes >= 1, "search space needs at least one node");
        anyhow::ensure!(batch >= 1, "batch must be ≥ 1");
        let atoms = atomic_segments(g);
        anyhow::ensure!(!atoms.is_empty(), "graph has no segments");
        let labels: Vec<String> =
            atoms.iter().map(|s| s.labels.first().expect("atom has a label").clone()).collect();
        let ladder = ladder_for(n_nodes);
        let mut prefix = Vec::with_capacity(ladder.len());
        for &r in &ladder {
            let mut p = vec![0.0; labels.len() + 1];
            for (k, label) in labels.iter().enumerate() {
                let t = cost.segment_time_batched_ns(g, label, r as u64, batch)?;
                p[k + 1] = p[k] + t as f64 / batch as f64;
            }
            prefix.push(p);
        }
        Ok(SearchSpace {
            labels,
            model: g.model.clone(),
            segment_order: g.segment_order(),
            ladder,
            prefix,
            overhead_ns: cost.driver_overhead_ns() as f64,
            n_nodes,
            batch,
        })
    }

    pub fn n_atoms(&self) -> usize {
        self.labels.len()
    }

    /// The priced spatial-split ladder (ascending).
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }

    fn ladder_idx(&self, r: usize) -> Option<usize> {
        self.ladder.binary_search(&r).ok()
    }

    /// Analytic score (ns) of running atoms `[a, b)` as one stage on `r`
    /// replicas. `None` when the cell is outside the priced space
    /// (spatial split off the ladder or `r < 2`).
    ///
    /// Mirrors the simulator's stage model: a spatial stage takes the
    /// split-`r` wall time on every replica (so it helps latency *and*
    /// throughput); a data-parallel stage takes the full single-split
    /// time per image but spreads images over `r` replicas (so it helps
    /// throughput only). The per-launch driver overhead is charged once
    /// per stage.
    pub fn stage_score(
        &self,
        a: usize,
        b: usize,
        r: usize,
        spatial: bool,
        proxy: Proxy,
    ) -> Option<f64> {
        debug_assert!(a < b && b <= self.n_atoms() && r >= 1);
        if spatial {
            if r < 2 {
                return None;
            }
            let i = self.ladder_idx(r)?;
            Some(self.prefix[i][b] - self.prefix[i][a] + self.overhead_ns)
        } else {
            let t = self.prefix[0][b] - self.prefix[0][a] + self.overhead_ns;
            Some(match proxy {
                Proxy::Throughput => t / r as f64,
                Proxy::Latency => t,
            })
        }
    }

    /// Score a complete stage sequence under `proxy`. `None` if any
    /// choice is outside the priced space.
    pub fn score(&self, choices: &[Choice], proxy: Proxy) -> Option<f64> {
        let mut acc = proxy.identity();
        for c in choices {
            acc = proxy.combine(acc, self.stage_score(c.a, c.b, c.r, c.spatial, proxy)?);
        }
        Some(acc)
    }

    /// Optimistic lower bound (ns) on covering atoms `[a, n_atoms)` with
    /// `nodes_left` fresh nodes — the beam's admissible pruning bound.
    /// Throughput: perfect work-spreading of the remaining single-split
    /// time. Latency: every remaining atom at the deepest priced split,
    /// one stage launch.
    pub fn remaining_bound(&self, a: usize, nodes_left: usize, proxy: Proxy) -> f64 {
        let n = self.n_atoms();
        if a >= n || nodes_left == 0 {
            return 0.0;
        }
        match proxy {
            Proxy::Throughput => {
                let t1 = self.prefix[0][n] - self.prefix[0][a];
                (t1 + self.overhead_ns) / nodes_left as f64
            }
            Proxy::Latency => {
                let best = self
                    .prefix
                    .iter()
                    .map(|p| p[n] - p[a])
                    .fold(f64::INFINITY, f64::min);
                best + self.overhead_ns
            }
        }
    }

    /// Materialize a stage sequence into a validated-shape
    /// [`ExecutionPlan`] over `n_nodes` (tagged [`Strategy::Search`]).
    /// Replica node ids are dealt sequentially, so stages are disjoint
    /// by construction and a sequence whose replica counts sum to
    /// `n_nodes` uses every node.
    pub fn assemble_plan(&self, choices: &[Choice], n_nodes: usize) -> ExecutionPlan {
        let mut next = 0usize;
        let stages: Vec<StagePlan> = choices
            .iter()
            .map(|c| {
                let replicas: Vec<usize> = (next..next + c.r).collect();
                next += c.r;
                StagePlan {
                    segments: self.labels[c.a..c.b].to_vec(),
                    replicas,
                    split: if c.spatial { SplitMode::Spatial } else { SplitMode::DataParallel },
                }
            })
            .collect();
        ExecutionPlan {
            strategy: Strategy::Search,
            n_nodes,
            model: self.model.clone(),
            segment_order: self.segment_order.clone(),
            stages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardProfile, Calibration, VtaConfig};
    use crate::graph::zoo;

    fn space(model: &str, n: usize, batch: u64) -> SearchSpace {
        let g = zoo::build(model, 0).unwrap();
        let mut cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        SearchSpace::build(&g, &mut cost, n, batch).unwrap()
    }

    #[test]
    fn ladder_is_complete_at_paper_scale_and_sparse_at_fleet_scale() {
        assert_eq!(space("lenet5", 4, 1).ladder(), &[1, 2, 3, 4]);
        assert_eq!(space("lenet5", 8, 1).ladder(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        let fleet = space("lenet5", 256, 1);
        assert_eq!(fleet.ladder(), &[1, 2, 3, 4, 5, 6, 7, 8, 16, 32, 64]);
    }

    #[test]
    fn stage_scores_match_the_simulator_shape() {
        let sp = space("resnet18", 4, 1);
        let a = sp.n_atoms();
        // DP throughput spreads work; DP latency does not
        let t1 = sp.stage_score(0, a, 1, false, Proxy::Latency).unwrap();
        let t4 = sp.stage_score(0, a, 4, false, Proxy::Throughput).unwrap();
        assert!((t4 - t1 / 4.0).abs() < 1e-6);
        assert_eq!(sp.stage_score(0, a, 4, false, Proxy::Latency), Some(t1));
        // spatial helps both, but sublinearly
        let s4 = sp.stage_score(0, a, 4, true, Proxy::Latency).unwrap();
        assert!(s4 < t1 && s4 > t1 / 4.0, "{s4} vs {t1}");
        assert_eq!(sp.stage_score(0, a, 4, true, Proxy::Throughput), Some(s4));
        // off-ladder spatial cells are unpriced
        assert!(sp.stage_score(0, a, 1, true, Proxy::Latency).is_none());
        let fleet = space("lenet5", 256, 1);
        assert!(fleet.stage_score(0, 1, 13, true, Proxy::Latency).is_none());
        assert!(fleet.stage_score(0, 1, 13, false, Proxy::Throughput).is_some());
    }

    #[test]
    fn assembled_plans_validate() {
        let sp = space("resnet18", 4, 1);
        let a = sp.n_atoms();
        let plan = sp.assemble_plan(
            &[
                Choice { a: 0, b: 2, r: 1, spatial: false },
                Choice { a: 2, b: a, r: 3, spatial: true },
            ],
            4,
        );
        assert_eq!(plan.strategy, Strategy::Search);
        plan.validate().unwrap();
    }

    #[test]
    fn batched_space_is_cheaper_per_image() {
        let s1 = space("resnet18", 2, 1);
        let s8 = space("resnet18", 2, 8);
        let a = s1.n_atoms();
        let t1 = s1.stage_score(0, a, 1, false, Proxy::Latency).unwrap();
        let t8 = s8.stage_score(0, a, 1, false, Proxy::Latency).unwrap();
        assert!(t8 < t1, "batch-8 per-image not cheaper: {t8} vs {t1}");
    }

    #[test]
    fn remaining_bounds_are_admissible() {
        let sp = space("resnet18", 4, 1);
        let a = sp.n_atoms();
        for proxy in [Proxy::Throughput, Proxy::Latency] {
            let bound = sp.remaining_bound(0, 4, proxy);
            // any real single-stage assignment scores at least the bound
            for (r, spatial) in [(1, false), (4, false), (2, true), (4, true)] {
                if let Some(s) = sp.stage_score(0, a, r, spatial, proxy) {
                    assert!(s >= bound - 1e-9, "{proxy:?} r={r} spatial={spatial}: {s} < {bound}");
                }
            }
        }
    }
}
