//! Width-bounded beam search over the partition space (DESIGN.md §17),
//! for when the joint space explodes: fleets of hundreds of boards, or
//! the outer product with candidate VTA bitstream configurations.
//!
//! States are partial schedules (atoms covered, nodes committed); each
//! round appends one stage to every frontier state, keeps completed
//! schedules aside, and cuts the frontier back to the `width` states
//! with the best `score ⊕ remaining_bound` — the admissible compute-only
//! bound from [`SearchSpace::remaining_bound`], so the cut prefers
//! states that can still win, not states that merely look cheap so far.
//!
//! [`beam_over_configs`] runs one beam per candidate VTA configuration
//! on its own OS thread (`std::thread::scope` — the crate deliberately
//! has no dependency on a thread-pool crate), each with its own cost
//! model, and returns the best (configuration, schedule) pair.

use super::space::{Choice, Proxy, SearchSpace};
use crate::config::{BoardProfile, Calibration, VtaConfig};
use crate::graph::Graph;
use crate::sched::ExecutionPlan;
use crate::sim::CostModel;

/// Default frontier width when the caller passes `width == 0`.
pub const DEFAULT_WIDTH: usize = 8;

/// A beam-searched schedule plus the search's own accounting.
#[derive(Debug, Clone)]
pub struct BeamOutcome {
    /// The winning stage sequence.
    pub choices: Vec<Choice>,
    /// The materialized plan ([`crate::sched::Strategy::Search`]).
    pub plan: ExecutionPlan,
    /// Its proxy score, ns (per image).
    pub score_ns: f64,
    /// States expanded across all rounds.
    pub explored: usize,
    /// Successor states cut by the beam width.
    pub pruned: usize,
}

#[derive(Clone)]
struct State {
    /// Atoms covered so far.
    a: usize,
    /// Nodes committed so far.
    m: usize,
    /// Accumulated proxy score of the committed stages.
    score: f64,
    choices: Vec<Choice>,
}

/// Beam-search a schedule of the space's graph over `n` nodes. With
/// `width == 0` the [`DEFAULT_WIDTH`] is used. Always returns a
/// complete schedule: the closing move (one data-parallel stage over
/// all remaining atoms and nodes) is generated from every state, and
/// completed schedules are collected *before* the width cut.
pub fn beam_plan(
    space: &SearchSpace,
    n: usize,
    proxy: Proxy,
    width: usize,
) -> anyhow::Result<BeamOutcome> {
    anyhow::ensure!(n >= 1, "beam_plan needs at least one node");
    anyhow::ensure!(
        n <= space.n_nodes,
        "beam over {n} nodes but the space was priced for {}",
        space.n_nodes
    );
    let width = if width == 0 { DEFAULT_WIDTH } else { width };
    let a_total = space.n_atoms();
    let mut frontier =
        vec![State { a: 0, m: 0, score: proxy.identity(), choices: Vec::new() }];
    let mut done: Option<State> = None;
    let mut explored = 0usize;
    let mut pruned = 0usize;

    while !frontier.is_empty() {
        let mut successors: Vec<State> = Vec::new();
        for st in &frontier {
            explored += 1;
            for b in st.a + 1..=a_total {
                // a non-final stage must leave ≥ 1 node for the rest;
                // the final stage must consume the budget exactly
                let r_max = if b == a_total { n - st.m } else { n.saturating_sub(st.m + 1) };
                for r in 1..=r_max {
                    if b == a_total && r != n - st.m {
                        continue;
                    }
                    for spatial in [false, true] {
                        let Some(s) = space.stage_score(st.a, b, r, spatial, proxy) else {
                            continue;
                        };
                        let mut choices = st.choices.clone();
                        choices.push(Choice { a: st.a, b, r, spatial });
                        let next = State {
                            a: b,
                            m: st.m + r,
                            score: proxy.combine(st.score, s),
                            choices,
                        };
                        if b == a_total {
                            let better =
                                done.as_ref().map(|d| next.score < d.score).unwrap_or(true);
                            if better {
                                done = Some(next);
                            }
                        } else {
                            successors.push(next);
                        }
                    }
                }
            }
        }
        successors.sort_by(|x, y| {
            let bx = proxy.combine(x.score, space.remaining_bound(x.a, n - x.m, proxy));
            let by = proxy.combine(y.score, space.remaining_bound(y.a, n - y.m, proxy));
            bx.partial_cmp(&by).expect("finite beam scores")
        });
        if successors.len() > width {
            pruned += successors.len() - width;
            successors.truncate(width);
        }
        frontier = successors;
    }

    let best = done.expect("the all-remaining-atoms closing stage always completes");
    let plan = space.assemble_plan(&best.choices, n);
    plan.validate()?;
    Ok(BeamOutcome { choices: best.choices, plan, score_ns: best.score, explored, pruned })
}

/// Beam-search the outer product of the partition space with candidate
/// VTA configurations — one OS thread per configuration, each with its
/// own cost model and priced space. Configurations that do not fit the
/// board's fabric are skipped; returns the index of the winning
/// configuration and its schedule.
#[allow(clippy::too_many_arguments)]
pub fn beam_over_configs(
    g: &Graph,
    board: &BoardProfile,
    configs: &[VtaConfig],
    calib: &Calibration,
    n: usize,
    proxy: Proxy,
    width: usize,
    batch: u64,
) -> anyhow::Result<(usize, BeamOutcome)> {
    anyhow::ensure!(!configs.is_empty(), "no candidate VTA configurations");
    let results: Vec<Option<anyhow::Result<BeamOutcome>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = configs
            .iter()
            .map(|cfg| {
                scope.spawn(move || {
                    if board.vta_fits(cfg).is_err() {
                        return None;
                    }
                    let mut cost =
                        CostModel::new(cfg.clone(), board.clone(), calib.clone());
                    Some(
                        SearchSpace::build(g, &mut cost, n, batch)
                            .and_then(|sp| beam_plan(&sp, n, proxy, width)),
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("beam thread panicked")).collect()
    });
    let mut best: Option<(usize, BeamOutcome)> = None;
    for (i, res) in results.into_iter().enumerate() {
        let Some(res) = res else { continue };
        let out = res?;
        let better = best.as_ref().map(|(_, b)| out.score_ns < b.score_ns).unwrap_or(true);
        if better {
            best = Some((i, out));
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!("no candidate VTA configuration fits board '{}'", board.name)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardProfile, Calibration, VtaConfig};
    use crate::graph::zoo;
    use crate::search::dp::dp_plan;

    fn space(model: &str, n: usize) -> (Graph, SearchSpace) {
        let g = zoo::build(model, 0).unwrap();
        let mut cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        let sp = SearchSpace::build(&g, &mut cost, n, 1).unwrap();
        (g, sp)
    }

    #[test]
    fn beam_plans_validate_and_track_the_dp_optimum() {
        for n in [2usize, 8] {
            let (g, sp) = space("resnet18", n);
            for proxy in [Proxy::Throughput, Proxy::Latency] {
                let beam = beam_plan(&sp, n, proxy, 0).unwrap();
                beam.plan.validate_for(&g).unwrap();
                let dp = dp_plan(&sp, n, proxy).unwrap();
                assert!(
                    beam.score_ns >= dp.score_ns - 1e-9,
                    "beam {} beat the exact DP {} — the DP is not optimal?",
                    beam.score_ns,
                    dp.score_ns
                );
                assert!(
                    beam.score_ns <= dp.score_ns * 1.5,
                    "beam {} far off the DP optimum {}",
                    beam.score_ns,
                    dp.score_ns
                );
            }
        }
    }

    #[test]
    fn wider_beams_never_score_worse() {
        let (_, sp) = space("resnet18", 8);
        let narrow = beam_plan(&sp, 8, Proxy::Throughput, 1).unwrap();
        let wide = beam_plan(&sp, 8, Proxy::Throughput, 64).unwrap();
        assert!(wide.score_ns <= narrow.score_ns + 1e-9);
        assert!(wide.explored >= narrow.explored);
        assert!(narrow.pruned > 0, "width 1 should be cutting successors");
    }

    #[test]
    fn beam_over_configs_picks_the_faster_clock() {
        let g = zoo::build("resnet18", 0).unwrap();
        let board = BoardProfile::zynq7020();
        let configs =
            [VtaConfig::table1_at_clock(50_000_000), VtaConfig::table1_zynq7000()];
        let (idx, out) = beam_over_configs(
            &g,
            &board,
            &configs,
            &Calibration::default(),
            4,
            Proxy::Latency,
            0,
            1,
        )
        .unwrap();
        assert_eq!(idx, 1, "100 MHz Table-I config should beat 50 MHz");
        out.plan.validate_for(&g).unwrap();
    }

    #[test]
    fn unfittable_configs_are_skipped() {
        let g = zoo::build("lenet5", 0).unwrap();
        let board = BoardProfile::zynq7020();
        // big_config needs US+ fabric — alone it is an error, alongside a
        // fitting config it is skipped
        let only_big = [VtaConfig::big_config_200mhz()];
        assert!(beam_over_configs(
            &g,
            &board,
            &only_big,
            &Calibration::default(),
            2,
            Proxy::Latency,
            0,
            1
        )
        .is_err());
        let mixed = [VtaConfig::big_config_200mhz(), VtaConfig::table1_zynq7000()];
        let (idx, _) = beam_over_configs(
            &g,
            &board,
            &mixed,
            &Calibration::default(),
            2,
            Proxy::Latency,
            0,
            1
        )
        .unwrap();
        assert_eq!(idx, 1);
    }
}
