//! Exact segment-boundary DP over the partition space (DESIGN.md §17).
//!
//! State `dp[b][m]` = best proxy score covering the first `b` atomic
//! segments with **exactly** `m` nodes; the transition appends a final
//! stage of atoms `[a, b)` on `r` fresh replicas in either split mode.
//! Dealing nodes as disjoint sequential ranges makes the all-nodes-used
//! plan invariant hold by construction, and `dp[A][n]` is always
//! reachable (a single data-parallel stage over all `n` nodes is a legal
//! schedule for any `n ≥ 1`).
//!
//! Complexity: `O(A² · n² · 2)` transitions over `O(A · n)` states with
//! O(1) stage scoring from the [`SearchSpace`] prefix sums — ~13 M
//! float ops for ResNet-18 (A = 10) on a 256-board fleet, well inside
//! the engine's replanning budget. Within the priced space (the spatial
//! ladder — complete at `n ≤ 8`) the result is **optimal**, which the
//! brute-force equivalence test below pins.

use super::space::{Choice, Proxy, SearchSpace};
use crate::sched::ExecutionPlan;

/// An optimal (within the priced space) searched schedule.
#[derive(Debug, Clone)]
pub struct DpOutcome {
    /// The winning stage sequence (atom spans, replica counts, modes).
    pub choices: Vec<Choice>,
    /// The materialized plan ([`crate::sched::Strategy::Search`]).
    pub plan: ExecutionPlan,
    /// Its proxy score, ns (per image).
    pub score_ns: f64,
    /// Transitions evaluated (the engine's explored counter).
    pub explored: usize,
}

/// Solve the partition DP for `n` nodes under `proxy`. `n` may be below
/// the space's build budget (the engine's right-sizing sweep reuses one
/// priced space for every sub-cluster size).
pub fn dp_plan(space: &SearchSpace, n: usize, proxy: Proxy) -> anyhow::Result<DpOutcome> {
    anyhow::ensure!(n >= 1, "dp_plan needs at least one node");
    anyhow::ensure!(
        n <= space.n_nodes,
        "dp over {n} nodes but the space was priced for {}",
        space.n_nodes
    );
    let a_total = space.n_atoms();
    let width = n + 1;
    let idx = |b: usize, m: usize| b * width + m;
    let inf = f64::INFINITY;
    let mut dp = vec![inf; (a_total + 1) * width];
    // parent[(b, m)] = (a, r, spatial) of the stage that got us here
    let mut parent: Vec<Option<(usize, usize, bool)>> = vec![None; (a_total + 1) * width];
    dp[idx(0, 0)] = proxy.identity();
    let mut explored = 0usize;

    for b in 1..=a_total {
        for m in 1..=n {
            let mut best = inf;
            let mut best_parent = None;
            for a in 0..b {
                for r in 1..=m {
                    let prev = dp[idx(a, m - r)];
                    if !prev.is_finite() {
                        continue;
                    }
                    for spatial in [false, true] {
                        let Some(s) = space.stage_score(a, b, r, spatial, proxy) else {
                            continue;
                        };
                        explored += 1;
                        let cand = proxy.combine(prev, s);
                        if cand < best {
                            best = cand;
                            best_parent = Some((a, r, spatial));
                        }
                    }
                }
            }
            dp[idx(b, m)] = best;
            parent[idx(b, m)] = best_parent;
        }
    }

    let score_ns = dp[idx(a_total, n)];
    anyhow::ensure!(
        score_ns.is_finite(),
        "partition DP found no schedule for {a_total} atoms on {n} nodes"
    );
    // walk the parent chain back from (A, n)
    let mut choices = Vec::new();
    let (mut b, mut m) = (a_total, n);
    while b > 0 {
        let (a, r, spatial) =
            parent[idx(b, m)].expect("finite dp state has a parent");
        choices.push(Choice { a, b, r, spatial });
        b = a;
        m -= r;
    }
    choices.reverse();
    let plan = space.assemble_plan(&choices, n);
    plan.validate()?;
    Ok(DpOutcome { choices, plan, score_ns, explored })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardProfile, Calibration, VtaConfig};
    use crate::graph::zoo;
    use crate::sim::CostModel;

    fn space(model: &str, n: usize) -> SearchSpace {
        let g = zoo::build(model, 0).unwrap();
        let mut cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        SearchSpace::build(&g, &mut cost, n, 1).unwrap()
    }

    /// Exhaustive reference: every (boundary, replica-count, mode)
    /// sequence in the priced space.
    fn brute_force(space: &SearchSpace, a: usize, nodes_left: usize, acc: f64, proxy: Proxy) -> f64 {
        if a == space.n_atoms() {
            return if nodes_left == 0 { acc } else { f64::INFINITY };
        }
        if nodes_left == 0 {
            return f64::INFINITY;
        }
        let mut best = f64::INFINITY;
        for b in a + 1..=space.n_atoms() {
            for r in 1..=nodes_left {
                for spatial in [false, true] {
                    if let Some(s) = space.stage_score(a, b, r, spatial, proxy) {
                        let down =
                            brute_force(space, b, nodes_left - r, proxy.combine(acc, s), proxy);
                        best = best.min(down);
                    }
                }
            }
        }
        best
    }

    #[test]
    fn dp_matches_brute_force_on_small_graphs() {
        for model in ["lenet5", "mlp"] {
            for n in [1usize, 2, 3, 4] {
                let sp = space(model, n);
                for proxy in [Proxy::Throughput, Proxy::Latency] {
                    let dp = dp_plan(&sp, n, proxy).unwrap();
                    let brute = brute_force(&sp, 0, n, proxy.identity(), proxy);
                    assert!(
                        (dp.score_ns - brute).abs() <= 1e-6 * brute.max(1.0),
                        "{model} n={n} {proxy:?}: dp {} != brute {brute}",
                        dp.score_ns
                    );
                    // and the reconstructed plan re-scores to the DP value
                    let rescored = sp.score(&dp.choices, proxy).unwrap();
                    assert!((rescored - dp.score_ns).abs() <= 1e-9 * brute.max(1.0));
                }
            }
        }
    }

    #[test]
    fn dp_plans_validate_and_use_every_node() {
        let g = zoo::build("resnet18", 0).unwrap();
        let sp = space("resnet18", 8);
        for n in [1usize, 3, 8] {
            for proxy in [Proxy::Throughput, Proxy::Latency] {
                let out = dp_plan(&sp, n, proxy).unwrap();
                assert_eq!(out.plan.n_nodes, n);
                out.plan.validate_for(&g).unwrap();
                assert!(out.explored > 0);
            }
        }
    }

    #[test]
    fn latency_dp_prefers_spatial_splits() {
        // with nodes to spare, cutting latency requires Spatial stages —
        // DataParallel replication never lowers single-image latency
        let sp = space("resnet18", 4);
        let out = dp_plan(&sp, 4, Proxy::Latency).unwrap();
        assert!(
            out.choices.iter().any(|c| c.spatial),
            "latency-optimal 4-node plan uses no spatial stage: {:?}",
            out.choices
        );
        // and it beats the single-node schedule
        let solo = dp_plan(&sp, 1, Proxy::Latency).unwrap();
        assert!(out.score_ns < solo.score_ns);
    }

    #[test]
    fn dp_rejects_oversized_budget() {
        let sp = space("lenet5", 2);
        assert!(dp_plan(&sp, 3, Proxy::Latency).is_err());
        assert!(dp_plan(&sp, 0, Proxy::Latency).is_err());
    }
}
