//! The plan-search engine (DESIGN.md §17): objective and constraint
//! plumbing around the DP/beam searchers, plus the generic
//! bound-and-price loop ([`prune_min`]) the GEMM autotuner is an adapter
//! over.
//!
//! [`search_plan`] assembles a candidate set — the four §II-C heuristics
//! (pricing them is what makes the dominance guarantee checkable), the
//! exact partition DP under both analytic proxies, a beam pass at fleet
//! scale, and (optionally) right-sized DP plans over power-of-two
//! sub-clusters — then prices candidates with the metered analytic
//! simulator, skipping any candidate whose admissible compute-only
//! bound already cannot beat the incumbent. Constraints follow
//! [`crate::power::eco_plan`]'s contract: infeasible candidates are
//! filtered, and if *nothing* meets the SLO/power budget the
//! lowest-latency candidate is returned flagged
//! [`SearchOutcome::meets_slo`] ` = false`.

use super::beam::beam_plan;
use super::dp::dp_plan;
use super::space::{Choice, Proxy, SearchSpace};
use crate::config::ClusterConfig;
use crate::graph::Graph;
use crate::sched::{build_plan_priced, ExecutionPlan, Strategy};
use crate::sim::{simulate, CostModel, SimConfig};

/// What the searched plan should minimize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Unloaded single-image latency (the E1 dominance metric).
    Latency,
    /// Steady-state ms/image at saturation (serving capacity).
    Throughput,
    /// Energy per inference (Eco's metric).
    JPerImage,
}

impl Objective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
            Objective::JPerImage => "j-per-image",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "latency" | "lat" => Ok(Objective::Latency),
            "throughput" | "capacity" | "ms" => Ok(Objective::Throughput),
            "j-per-image" | "j" | "energy" | "joules" => Ok(Objective::JPerImage),
            other => anyhow::bail!(
                "unknown search objective '{other}' (latency|throughput|j-per-image)"
            ),
        }
    }

    /// The analytic proxy that generates candidates for this objective.
    /// J/image has no compute-only proxy (it needs the power model), so
    /// it searches under the throughput proxy — at near-constant watts,
    /// energy per image tracks ms/image.
    pub fn proxy(&self) -> Proxy {
        match self {
            Objective::Latency => Proxy::Latency,
            Objective::Throughput | Objective::JPerImage => Proxy::Throughput,
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Knobs of one [`search_plan`] call.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchConfig {
    pub objective: Objective,
    /// Unloaded-latency SLO, ms (`None` = unconstrained).
    pub slo_ms: Option<f64>,
    /// Cluster power budget, W (`None` = uncapped).
    pub power_budget_w: Option<f64>,
    /// Batch size plans are priced at (`1` = unbatched; the scenario
    /// layer threads `batch.max_size` through here).
    pub batch: u64,
    /// Beam frontier width; `0` = the beam's default. The beam pass only
    /// runs at fleet scale (`n ≥ 16`) or when a width is forced here.
    pub beam_width: usize,
    /// Also search power-of-two sub-clusters (`m < n`) and return a
    /// [`SearchOutcome::node_map`] onto the first `m` physical nodes.
    /// Off for scenario flows (plans there must use the whole
    /// inventory); the J/image CLI and bench paths turn it on.
    pub rightsize: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            objective: Objective::Latency,
            slo_ms: None,
            power_budget_w: None,
            batch: 1,
            beam_width: 0,
            rightsize: false,
        }
    }
}

/// Accounting of one bound-and-price pass (also the beam's internal
/// counters, merged in by [`search_plan`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Candidates considered.
    pub candidates: usize,
    /// Candidates (or search states) actually priced/expanded.
    pub explored: usize,
    /// Candidates (or search states) skipped by an admissible bound or
    /// a beam cut.
    pub pruned: usize,
}

/// What [`search_plan`] picked and why.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// The winning plan, `strategy` re-tagged [`Strategy::Search`].
    pub plan: ExecutionPlan,
    /// Which candidate family won: a §II-C heuristic name, `"dp"`,
    /// `"beam"`, or `"dp@m"` for a right-sized plan over `m` nodes.
    pub via: String,
    /// Nodes the plan actually occupies (`< n` only when right-sized).
    pub nodes_used: usize,
    /// Physical node ids for a right-sized plan's logical nodes;
    /// `None` when the plan spans the whole cluster.
    pub node_map: Option<Vec<usize>>,
    /// Steady-state ms/image at saturation.
    pub ms_per_image: f64,
    /// Unloaded single-image latency, ms.
    pub latency_ms: f64,
    /// Steady-state cluster draw, W (the right-sized sub-cluster's draw
    /// when `node_map` is set — the surplus boards are powered off).
    pub cluster_w: f64,
    pub j_per_image: f64,
    /// False when no candidate met the SLO/power constraints and the
    /// lowest-latency candidate was returned as the least-bad fallback.
    pub meets_slo: bool,
    pub stats: PruneStats,
}

/// Generic bound-and-price argmin (DESIGN.md §17).
///
/// Walks `cands` in order; `bound` returns an **admissible lower bound**
/// on a candidate's score (cheap, no side effects), `price` the exact
/// score plus its payload (expensive), or `None` for an infeasible
/// candidate. A candidate whose bound cannot beat the current best is
/// skipped without pricing; improvement is strict (`<`), so ties keep
/// the earliest candidate. Returns the winner (if any candidate was
/// feasible) and the pass accounting.
pub fn prune_min<T, V>(
    cands: impl IntoIterator<Item = T>,
    mut bound: impl FnMut(&T) -> f64,
    mut price: impl FnMut(&T) -> anyhow::Result<Option<(V, f64)>>,
) -> anyhow::Result<(Option<(T, V, f64)>, PruneStats)> {
    let mut best: Option<(T, V, f64)> = None;
    let mut stats = PruneStats::default();
    for c in cands {
        stats.candidates += 1;
        if let Some((_, _, incumbent)) = &best {
            if bound(&c) >= *incumbent {
                stats.pruned += 1;
                continue;
            }
        }
        stats.explored += 1;
        if let Some((v, score)) = price(&c)? {
            let better = best.as_ref().map(|(_, _, s)| score < *s).unwrap_or(true);
            if better {
                best = Some((c, v, score));
            }
        }
    }
    Ok((best, stats))
}

/// One plan candidate awaiting pricing.
struct Cand {
    plan: ExecutionPlan,
    via: String,
    /// Admissible lower bound on the objective, ms (0 = never prune —
    /// used for the heuristics, which must be priced for the dominance
    /// guarantee, and for J/image, which has no compute-only bound).
    bound_ms: f64,
    /// Right-sized candidates carry their truncated cluster and the
    /// physical ids their logical nodes map onto.
    sub: Option<(ClusterConfig, Vec<usize>)>,
}

/// Simulator metrics of one priced candidate.
#[derive(Debug, Clone, Copy)]
struct Priced {
    ms_per_image: f64,
    latency_ms: f64,
    cluster_w: f64,
    j_per_image: f64,
}

fn objective_bound_ms(space: &SearchSpace, choices: &[Choice], objective: Objective) -> f64 {
    match objective {
        Objective::Latency => {
            space.score(choices, Proxy::Latency).map(|ns| ns / 1e6).unwrap_or(0.0)
        }
        Objective::Throughput => {
            space.score(choices, Proxy::Throughput).map(|ns| ns / 1e6).unwrap_or(0.0)
        }
        // J/image needs the power model; no admissible compute-only bound
        Objective::JPerImage => 0.0,
    }
}

/// Search the partition space of `g` over `cluster` and return the best
/// plan under `cfg`'s objective and constraints. The four §II-C
/// heuristics are always in the candidate set and priced by the same
/// metered simulator, so the outcome never loses to the best heuristic
/// on the chosen objective — the E1 dominance guarantee.
pub fn search_plan(
    g: &Graph,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
    cfg: &SearchConfig,
) -> anyhow::Result<SearchOutcome> {
    if let Some(slo) = cfg.slo_ms {
        anyhow::ensure!(slo.is_finite() && slo > 0.0, "latency SLO must be > 0");
    }
    if let Some(b) = cfg.power_budget_w {
        anyhow::ensure!(b.is_finite() && b > 0.0, "power budget must be > 0");
    }
    anyhow::ensure!(cfg.batch >= 1, "batch must be ≥ 1");
    let n = cluster.num_nodes();
    let space = SearchSpace::build(g, cost, n, cfg.batch)?;
    let seg_costs = cost.seg_cost_table_batched(g, cfg.batch)?;
    let proxy = cfg.objective.proxy();

    let mut search_stats = PruneStats::default();
    let mut cands: Vec<Cand> = Vec::new();
    // 1) the §II-C heuristics — never pruned, always priced
    for s in Strategy::all() {
        cands.push(Cand {
            plan: build_plan_priced(s, g, n, &seg_costs)?,
            via: s.as_str().to_string(),
            bound_ms: 0.0,
            sub: None,
        });
    }
    // 2) the exact DP at the full budget, under both proxies (a latency
    // optimum and a throughput optimum are different plans)
    for p in [Proxy::Latency, Proxy::Throughput] {
        let dpo = dp_plan(&space, n, p)?;
        search_stats.explored += dpo.explored;
        cands.push(Cand {
            bound_ms: objective_bound_ms(&space, &dpo.choices, cfg.objective),
            plan: dpo.plan,
            via: "dp".to_string(),
            sub: None,
        });
    }
    // 3) a beam pass at fleet scale (or when a width is forced)
    if n >= 16 || cfg.beam_width > 0 {
        let b = beam_plan(&space, n, proxy, cfg.beam_width)?;
        search_stats.explored += b.explored;
        search_stats.pruned += b.pruned;
        cands.push(Cand {
            bound_ms: objective_bound_ms(&space, &b.choices, cfg.objective),
            plan: b.plan,
            via: "beam".to_string(),
            sub: None,
        });
    }
    // 4) right-sized DP plans over power-of-two sub-clusters
    if cfg.rightsize {
        let mut m = 1usize;
        while m < n {
            let dpo = dp_plan(&space, m, proxy)?;
            search_stats.explored += dpo.explored;
            let mut sub = cluster.clone();
            sub.boards.truncate(m);
            sub.name = format!("{}-rightsized-x{m}", cluster.name);
            cands.push(Cand {
                bound_ms: objective_bound_ms(&space, &dpo.choices, cfg.objective),
                plan: dpo.plan,
                via: format!("dp@{m}"),
                sub: Some((sub, (0..m).collect())),
            });
            m *= 2;
        }
    }

    let price = |c: &Cand,
                 cost: &mut CostModel,
                 constrained: bool|
     -> anyhow::Result<Option<(Priced, f64)>> {
        let clu = c.sub.as_ref().map(|(s, _)| s).unwrap_or(cluster);
        let sim = simulate(&c.plan, clu, cost, g, &SimConfig { images: 16 })?;
        let p = Priced {
            ms_per_image: sim.ms_per_image,
            latency_ms: sim.latency_ms.mean(),
            cluster_w: sim.power.cluster_avg_w,
            j_per_image: sim.power.j_per_image,
        };
        let feasible = !constrained
            || (cfg.slo_ms.map(|s| p.latency_ms <= s).unwrap_or(true)
                && cfg.power_budget_w.map(|b| p.cluster_w <= b).unwrap_or(true));
        let score = if constrained {
            match cfg.objective {
                Objective::Latency => p.latency_ms,
                Objective::Throughput => p.ms_per_image,
                Objective::JPerImage => p.j_per_image,
            }
        } else {
            // the fallback pass optimizes latency, mirroring eco_plan
            p.latency_ms
        };
        Ok(feasible.then_some((p, score)))
    };

    let (winner, pass) = prune_min(
        0..cands.len(),
        |&i| cands[i].bound_ms,
        |&i| price(&cands[i], cost, true),
    )?;
    search_stats.candidates = pass.candidates;
    search_stats.explored += pass.explored;
    search_stats.pruned += pass.pruned;
    let (i, priced, meets) = match winner {
        Some((i, p, _)) => (i, p, true),
        None => {
            // nothing feasible: lowest-latency fallback, flagged (the
            // bounds are latency-admissible only for the latency
            // objective, so the fallback pass prices everything)
            let (fb, fb_pass) = prune_min(
                0..cands.len(),
                |_| 0.0,
                |&i| price(&cands[i], cost, false),
            )?;
            search_stats.explored += fb_pass.explored;
            let (i, p, _) = fb.expect("the unconstrained pass always has candidates");
            (i, p, false)
        }
    };

    let c = &cands[i];
    let mut plan = c.plan.clone();
    plan.strategy = Strategy::Search;
    plan.validate_for(g)?;
    Ok(SearchOutcome {
        nodes_used: plan.n_nodes,
        plan,
        via: c.via.clone(),
        node_map: c.sub.as_ref().map(|(_, m)| m.clone()),
        ms_per_image: priced.ms_per_image,
        latency_ms: priced.latency_ms,
        cluster_w: priced.cluster_w,
        j_per_image: priced.j_per_image,
        meets_slo: meets,
        stats: search_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardFamily, BoardProfile, Calibration, VtaConfig};
    use crate::graph::zoo;
    use crate::power::eco_plan;

    fn setup(n: usize) -> (Graph, ClusterConfig, CostModel) {
        let g = zoo::build("resnet18", 0).unwrap();
        let cluster = ClusterConfig::homogeneous(BoardFamily::Zynq7000, n);
        let cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        (g, cluster, cost)
    }

    #[test]
    fn search_never_loses_to_the_best_heuristic() {
        let (g, cluster, mut cost) = setup(4);
        let out = search_plan(&g, &cluster, &mut cost, &SearchConfig::default()).unwrap();
        assert_eq!(out.plan.strategy, Strategy::Search);
        assert!(out.meets_slo);
        assert!(out.node_map.is_none());
        let seg_costs = cost.seg_cost_table(&g).unwrap();
        for s in Strategy::all() {
            let plan = build_plan_priced(s, &g, 4, &seg_costs).unwrap();
            let sim =
                simulate(&plan, &cluster, &mut cost, &g, &SimConfig { images: 16 }).unwrap();
            assert!(
                out.latency_ms <= sim.latency_ms.mean() * 1.0001,
                "{s}: {} ms beats search's {} ms",
                sim.latency_ms.mean(),
                out.latency_ms
            );
        }
    }

    #[test]
    fn search_never_loses_to_eco_on_j_per_image() {
        for n in [2usize, 4] {
            let (g, cluster, mut cost) = setup(n);
            let cfg = SearchConfig {
                objective: Objective::JPerImage,
                rightsize: true,
                ..Default::default()
            };
            let out = search_plan(&g, &cluster, &mut cost, &cfg).unwrap();
            let eco = eco_plan(&g, &cluster, &mut cost, None).unwrap();
            assert!(
                out.j_per_image <= eco.j_per_image * 1.0001,
                "n={n}: eco {} J beats search's {} J",
                eco.j_per_image,
                out.j_per_image
            );
        }
    }

    #[test]
    fn impossible_slo_flags_the_fallback() {
        let (g, cluster, mut cost) = setup(4);
        let free = search_plan(&g, &cluster, &mut cost, &SearchConfig::default()).unwrap();
        let cfg = SearchConfig { slo_ms: Some(1e-3), ..Default::default() };
        let strict = search_plan(&g, &cluster, &mut cost, &cfg).unwrap();
        assert!(!strict.meets_slo);
        // the fallback optimizes latency, so it matches the free optimum
        assert!(strict.latency_ms <= free.latency_ms * 1.0001);
    }

    #[test]
    fn tiny_power_budget_flags_the_fallback() {
        let (g, cluster, mut cost) = setup(4);
        let cfg = SearchConfig { power_budget_w: Some(0.001), ..Default::default() };
        let out = search_plan(&g, &cluster, &mut cost, &cfg).unwrap();
        assert!(!out.meets_slo);
    }

    #[test]
    fn rejects_bad_knobs() {
        let (g, cluster, mut cost) = setup(2);
        for cfg in [
            SearchConfig { slo_ms: Some(0.0), ..Default::default() },
            SearchConfig { slo_ms: Some(f64::NAN), ..Default::default() },
            SearchConfig { power_budget_w: Some(-1.0), ..Default::default() },
            SearchConfig { batch: 0, ..Default::default() },
        ] {
            assert!(search_plan(&g, &cluster, &mut cost, &cfg).is_err(), "{cfg:?}");
        }
    }

    #[test]
    fn prune_min_skips_dominated_candidates_and_keeps_the_min() {
        // scores are the values themselves; bounds are half the value —
        // admissible, and tight enough to prune the tail
        let vals = [7.0, 3.0, 9.0, 2.0, 8.0];
        let (best, stats) = prune_min(
            vals.iter().copied(),
            |v| v / 2.0,
            |v| Ok(Some(((), *v))),
        )
        .unwrap();
        let (v, _, score) = best.unwrap();
        assert_eq!(v, 2.0);
        assert_eq!(score, 2.0);
        assert_eq!(stats.candidates, 5);
        // 7 explored; 3 explored; 9 pruned (4.5 ≥ 3); 2 explored; 8 pruned
        assert_eq!(stats.explored, 3);
        assert_eq!(stats.pruned, 2);
        // infeasible candidates never become the incumbent
        let (none, _) =
            prune_min(vals.iter().copied(), |_| 0.0, |_| Ok(None::<((), f64)>)).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn objective_parse_roundtrip() {
        for o in [Objective::Latency, Objective::Throughput, Objective::JPerImage] {
            assert_eq!(Objective::parse(o.as_str()).unwrap(), o);
        }
        assert_eq!(Objective::parse("energy").unwrap(), Objective::JPerImage);
        assert!(Objective::parse("bogus").is_err());
    }
}
