//! Plan search (DESIGN.md §17): DP/beam search over the whole
//! contiguous-partition space, surfaced as the sixth scheduling
//! strategy, [`crate::sched::Strategy::Search`].
//!
//! The paper's pitch — "arrange the computation graph in a pipeline
//! structure and manually allocate greater resources to the most
//! computationally intensive layers" — is a manual search. This module
//! automates it: [`space`] turns the memoized cost model into O(1)
//! prefix-sum oracles over stage spans × replica counts × split modes,
//! [`dp`] solves the partition exactly, [`beam`] handles the joint
//! space with VTA configurations at fleet scale, and [`engine`] prices
//! the candidates (always including the four §II-C heuristics — the
//! dominance guarantee) with the metered simulator under latency,
//! throughput, or J/image objectives with SLO and power-budget
//! constraints.

pub mod beam;
pub mod dp;
pub mod engine;
pub mod space;

pub use beam::{beam_over_configs, beam_plan, BeamOutcome, DEFAULT_WIDTH};
pub use dp::{dp_plan, DpOutcome};
pub use engine::{
    prune_min, search_plan, Objective, PruneStats, SearchConfig, SearchOutcome,
};
pub use space::{Choice, Proxy, SearchSpace};
