//! The workload registry (model zoo): name → graph builder.
//!
//! The paper's distinguishing claim is that the cluster "can
//! simultaneously execute diverse Neural Network models" — so the unit
//! of evaluation is a *zoo*, not one network. Every model registered
//! here exposes the same contract (see DESIGN.md §2):
//!
//! * a typed [`Graph`] with exact per-segment MAC/byte accounting, so
//!   [`crate::sim::cost::CostModel`] prices it without model-specific
//!   code;
//! * contiguous segment labels, so all four §II-C scheduling strategies
//!   and the partitioner work on it unchanged;
//! * a registry (`model`) name used for plan validation, coordinator
//!   routing, and AOT-artifact naming (`<model>_<tag>seg_<segment>`).
//!
//! Adding a model is: write a builder, append a [`ModelSpec`] to
//! [`MODELS`] — everything downstream (CLI `simulate`/`multi`, the
//! experiment runners, the multi-tenant coordinator) picks it up by
//! name. See EXPERIMENTS.md §Zoo for the walkthrough.

use super::graph::Graph;
use super::ops::Op;
use super::resnet::{build_resnet18, shift_for_k};
use super::tensor::TensorDesc;

/// One registered workload.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    /// Registry name (`Graph::model`, artifact prefix, CLI `--model`).
    pub name: &'static str,
    /// One-line description for `vtacluster info`.
    pub description: &'static str,
    /// Input size used when the caller passes `input_hw == 0`.
    pub default_hw: u64,
    /// Graph builder; takes the square input size.
    pub build: fn(u64) -> anyhow::Result<Graph>,
}

/// The registry, in presentation order.
pub static MODELS: [ModelSpec; 4] = [
    ModelSpec {
        name: "resnet18",
        description: "int8 ResNet-18 — the paper's evaluation workload (10 segments)",
        default_hw: 224,
        build: build_resnet18,
    },
    ModelSpec {
        name: "lenet5",
        description: "int8 LeNet-5-class small CNN (4 segments)",
        default_hw: 32,
        build: build_lenet5,
    },
    ModelSpec {
        name: "mlp",
        description: "int8 3-hidden-layer perceptron on flattened pixels (4 segments)",
        default_hw: 32,
        build: build_mlp,
    },
    ModelSpec {
        name: "mobilenet-lite",
        description: "int8 stride-2 conv stack, MobileNet-shaped compute (5 segments)",
        default_hw: 96,
        build: build_mobilenet_lite,
    },
];

/// All registered model names, in registry order.
pub fn names() -> Vec<&'static str> {
    MODELS.iter().map(|m| m.name).collect()
}

/// Look a model up by name.
pub fn lookup(name: &str) -> anyhow::Result<&'static ModelSpec> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (registered: {})", names().join(", ")))
}

/// Build a registered model. `input_hw == 0` selects the model's
/// default input size.
pub fn build(name: &str, input_hw: u64) -> anyhow::Result<Graph> {
    let spec = lookup(name)?;
    let hw = if input_hw == 0 { spec.default_hw } else { input_hw };
    (spec.build)(hw)
}

/// `conv → relu → requantize` with the python-convention shift for the
/// conv's accumulation depth (derived from the input node's channel
/// count) — the quantization idiom every zoo CNN shares with the
/// exported ResNet.
fn conv_block(
    g: &mut Graph,
    prefix: &str,
    segment: &str,
    input: super::graph::NodeId,
    oc: u64,
    k: u64,
    stride: u64,
    pad: u64,
) -> anyhow::Result<super::graph::NodeId> {
    let cin = g.node(input).out.shape.c();
    let c = g.add(
        &format!("{prefix}.conv"),
        Op::Conv2d { oc, kh: k, kw: k, stride, pad },
        &[input],
        segment,
    )?;
    let r = g.add(&format!("{prefix}.relu"), Op::Relu, &[c], segment)?;
    g.add(
        &format!("{prefix}.rq"),
        Op::Requantize { shift: shift_for_k(k * k * cin) },
        &[r],
        segment,
    )
}

/// LeNet-5-class CNN: three 5×5 conv stages with 2×2 max-pooling, then a
/// two-layer classifier head. Segments: `c1`, `c2`, `c3`, `head`.
///
/// `input_hw` must be ≥ 28 and a multiple of 4 so every pooled feature
/// map stays integral and the 5×5 `c3` kernel fits.
pub fn build_lenet5(input_hw: u64) -> anyhow::Result<Graph> {
    anyhow::ensure!(
        input_hw >= 28 && input_hw % 4 == 0,
        "lenet5 input_hw must be ≥ 28 and a multiple of 4"
    );
    let mut g = Graph::new_model("lenet5", &format!("lenet5-{input_hw}"));

    let x = g.add(
        "input",
        Op::Input { desc: TensorDesc::i8(&[1, input_hw, input_hw, 3]) },
        &[],
        "c1",
    )?;
    let c1 = conv_block(&mut g, "c1", "c1", x, 6, 5, 1, 2)?;
    let p1 = g.add("c1.pool", Op::MaxPool { k: 2, stride: 2, pad: 0 }, &[c1], "c1")?;

    let c2 = conv_block(&mut g, "c2", "c2", p1, 16, 5, 1, 0)?;
    let p2 = g.add("c2.pool", Op::MaxPool { k: 2, stride: 2, pad: 0 }, &[c2], "c2")?;

    let c3 = conv_block(&mut g, "c3", "c3", p2, 120, 5, 1, 0)?;

    let gap = g.add("head.gap", Op::GlobalAvgPool, &[c3], "head")?;
    let q = g.add("head.rq", Op::Requantize { shift: 0 }, &[gap], "head")?;
    let f1 = g.add("head.fc1", Op::Dense { units: 84 }, &[q], "head")?;
    let r = g.add("head.relu", Op::Relu, &[f1], "head")?;
    let q2 = g.add(
        "head.rq2",
        Op::Requantize { shift: shift_for_k(120) },
        &[r],
        "head",
    )?;
    g.add("head.fc2", Op::Dense { units: 10 }, &[q2], "head")?;

    g.validate()?;
    Ok(g)
}

/// Three-hidden-layer perceptron over flattened int8 pixels. Segments:
/// `fc1`, `fc2`, `fc3`, `head`. The graph input is rank-2
/// `(1, hw·hw·3)` — the zoo is not conv-only, and the scheduling layers
/// must not assume NHWC activations.
pub fn build_mlp(input_hw: u64) -> anyhow::Result<Graph> {
    anyhow::ensure!(input_hw >= 8, "mlp input_hw must be ≥ 8");
    let features = input_hw * input_hw * 3;
    let mut g = Graph::new_model("mlp", &format!("mlp-{input_hw}"));

    let mut cur = g.add(
        "input",
        Op::Input { desc: TensorDesc::i8(&[1, features]) },
        &[],
        "fc1",
    )?;
    let mut k = features;
    for (seg, units) in [("fc1", 512u64), ("fc2", 512), ("fc3", 256)] {
        let d = g.add(&format!("{seg}.dense"), Op::Dense { units }, &[cur], seg)?;
        let r = g.add(&format!("{seg}.relu"), Op::Relu, &[d], seg)?;
        cur = g.add(
            &format!("{seg}.rq"),
            Op::Requantize { shift: shift_for_k(k) },
            &[r],
            seg,
        )?;
        k = units;
    }
    g.add("head.fc", Op::Dense { units: 10 }, &[cur], "head")?;

    g.validate()?;
    Ok(g)
}

/// MobileNet-shaped stride-2 conv stack: a stem and three downsampling
/// blocks (each a 3×3 same-resolution conv followed by a 3×3 stride-2
/// conv), then GAP + classifier. Segments: `stem`, `b1`, `b2`, `b3`,
/// `head`. `input_hw` must be a multiple of 32.
pub fn build_mobilenet_lite(input_hw: u64) -> anyhow::Result<Graph> {
    anyhow::ensure!(
        input_hw >= 32 && input_hw % 32 == 0,
        "mobilenet-lite input_hw must be a multiple of 32"
    );
    let mut g = Graph::new_model("mobilenet-lite", &format!("mobilenet-lite-{input_hw}"));

    let x = g.add(
        "input",
        Op::Input { desc: TensorDesc::i8(&[1, input_hw, input_hw, 3]) },
        &[],
        "stem",
    )?;
    let mut cur = conv_block(&mut g, "stem", "stem", x, 32, 3, 2, 1)?;

    let mut cin = 32u64;
    for (seg, cout) in [("b1", 64u64), ("b2", 128), ("b3", 256)] {
        let a = conv_block(&mut g, &format!("{seg}.a"), seg, cur, cin, 3, 1, 1)?;
        cur = conv_block(&mut g, &format!("{seg}.b"), seg, a, cout, 3, 2, 1)?;
        cin = cout;
    }

    let gap = g.add("head.gap", Op::GlobalAvgPool, &[cur], "head")?;
    let q = g.add("head.rq", Op::Requantize { shift: 0 }, &[gap], "head")?;
    g.add("head.fc", Op::Dense { units: 1000 }, &[q], "head")?;

    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_model_builds_and_validates_at_default_hw() {
        for spec in &MODELS {
            let g = build(spec.name, 0).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            g.validate().unwrap();
            assert_eq!(g.model, spec.name);
            assert!(g.total_macs() > 0, "{} has zero MACs", spec.name);
            assert!(g.segment_order().len() >= 4, "{} too few segments", spec.name);
        }
    }

    #[test]
    fn segment_macs_cover_totals_for_all_models() {
        for spec in &MODELS {
            let g = build(spec.name, 0).unwrap();
            let per_seg = g.segment_macs();
            assert_eq!(per_seg.len(), g.segment_order().len());
            let sum: u64 = per_seg.iter().map(|(_, m)| m).sum();
            assert_eq!(sum, g.total_macs(), "{}", spec.name);
        }
    }

    #[test]
    fn registry_lookup() {
        assert_eq!(lookup("resnet18").unwrap().default_hw, 224);
        let e = lookup("vgg16").unwrap_err().to_string();
        assert!(e.contains("unknown model"), "{e}");
        assert!(e.contains("lenet5"), "error lists the registry: {e}");
        assert_eq!(names().len(), MODELS.len());
    }

    #[test]
    fn lenet_shapes() {
        let g = build_lenet5(32).unwrap();
        assert_eq!(g.segment_order(), vec!["c1", "c2", "c3", "head"]);
        // c3 output is 2×2×120 at hw=32 (32 → pool 16 → conv 12 → pool 6 → conv 2)
        assert_eq!(g.by_name("c3.rq").unwrap().out.shape.0, vec![1, 2, 2, 120]);
        let out = g.node(g.output().unwrap());
        assert_eq!(out.out.shape.0, vec![1, 10]);
        assert!(build_lenet5(16).is_err());
        assert!(build_lenet5(30).is_err());
    }

    #[test]
    fn mlp_is_rank2_end_to_end() {
        let g = build_mlp(32).unwrap();
        assert_eq!(g.segment_order(), vec!["fc1", "fc2", "fc3", "head"]);
        assert_eq!(g.input_desc().unwrap().shape.0, vec![1, 32 * 32 * 3]);
        // dense-only model: all work is GEMM, none ALU-free
        assert_eq!(g.total_macs(), 3072 * 512 + 512 * 512 + 512 * 256 + 256 * 10);
        assert!(build_mlp(4).is_err());
    }

    #[test]
    fn mobilenet_lite_downsamples_to_hw_over_16() {
        let g = build_mobilenet_lite(96).unwrap();
        assert_eq!(g.segment_order(), vec!["stem", "b1", "b2", "b3", "head"]);
        assert_eq!(g.by_name("b3.b.rq").unwrap().out.shape.0, vec![1, 6, 6, 256]);
        assert!(build_mobilenet_lite(48).is_err());
    }

    #[test]
    fn models_are_distinct_workloads() {
        let macs: Vec<u64> =
            MODELS.iter().map(|s| build(s.name, 0).unwrap().total_macs()).collect();
        for i in 0..macs.len() {
            for j in (i + 1)..macs.len() {
                assert_ne!(macs[i], macs[j], "models {i} and {j} identical in MACs");
            }
        }
    }
}
