//! Neural-network computation-graph IR and the workload registry.
//!
//! The workload side of the paper: a typed DAG of quantized operators
//! with exact MAC/byte cost accounting, a model zoo ([`zoo`]) whose
//! entries all satisfy the same contract (the ResNet-18 builder matches
//! the python model bit-for-bit in structure, cross-checked against
//! `artifacts/manifest.json`), and a partitioner producing the
//! contiguous segments the scheduling strategies distribute across FPGA
//! nodes.

pub mod graph;
pub mod ops;
pub mod partition;
pub mod resnet;
pub mod tensor;
pub mod zoo;

pub use graph::{Graph, Node, NodeId};
pub use ops::Op;
pub use partition::{partition_balanced, Segment};
pub use tensor::{DType, Shape};
pub use zoo::ModelSpec;
