//! Tensor shapes and dtypes for the graph IR.

use std::fmt;

/// Element types used on the VTA datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 8-bit activations/weights (INPUT_WIDTH / WEIGHT_WIDTH).
    I8,
    /// 32-bit accumulators (ACCUMULATOR_WIDTH).
    I32,
}

impl DType {
    pub fn bytes(&self) -> u64 {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DType::I8 => "int8",
            DType::I32 => "int32",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "int8" | "i8" => Ok(DType::I8),
            "int32" | "i32" => Ok(DType::I32),
            other => anyhow::bail!("unknown dtype '{other}'"),
        }
    }
}

/// A dense tensor shape (row-major). NHWC layout for feature maps.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<u64>);

impl Shape {
    pub fn new(dims: &[u64]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn nhwc(n: u64, h: u64, w: u64, c: u64) -> Self {
        Shape(vec![n, h, w, c])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn elems(&self) -> u64 {
        self.0.iter().product()
    }

    pub fn bytes(&self, dtype: DType) -> u64 {
        self.elems() * dtype.bytes()
    }

    pub fn dim(&self, i: usize) -> u64 {
        self.0[i]
    }

    /// NHWC accessors (panic on rank ≠ 4, which is a bug upstream).
    pub fn n(&self) -> u64 {
        assert_eq!(self.rank(), 4, "n() on rank-{} shape", self.rank());
        self.0[0]
    }
    pub fn h(&self) -> u64 {
        assert_eq!(self.rank(), 4);
        self.0[1]
    }
    pub fn w(&self) -> u64 {
        assert_eq!(self.rank(), 4);
        self.0[2]
    }
    pub fn c(&self) -> u64 {
        assert_eq!(self.rank(), 4);
        self.0[3]
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, ")")
    }
}

/// A typed tensor descriptor (shape + dtype), the edge type of the graph.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorDesc {
    pub shape: Shape,
    pub dtype: DType,
}

impl TensorDesc {
    pub fn new(shape: Shape, dtype: DType) -> Self {
        TensorDesc { shape, dtype }
    }

    pub fn i8(dims: &[u64]) -> Self {
        TensorDesc::new(Shape::new(dims), DType::I8)
    }

    pub fn i32(dims: &[u64]) -> Self {
        TensorDesc::new(Shape::new(dims), DType::I32)
    }

    pub fn bytes(&self) -> u64 {
        self.shape.bytes(self.dtype)
    }
}

impl fmt::Display for TensorDesc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.dtype.as_str(), self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_basics() {
        let s = Shape::nhwc(1, 224, 224, 3);
        assert_eq!(s.elems(), 150_528);
        assert_eq!(s.bytes(DType::I8), 150_528);
        assert_eq!(s.bytes(DType::I32), 602_112);
        assert_eq!(s.h(), 224);
        assert_eq!(s.c(), 3);
        assert_eq!(format!("{s}"), "(1,224,224,3)");
    }

    #[test]
    fn dtype_parse_roundtrip() {
        assert_eq!(DType::parse("int8").unwrap(), DType::I8);
        assert_eq!(DType::parse(DType::I32.as_str()).unwrap(), DType::I32);
        assert!(DType::parse("f32").is_err());
    }

    #[test]
    fn tensor_desc() {
        let t = TensorDesc::i32(&[1, 1000]);
        assert_eq!(t.bytes(), 4000);
        assert_eq!(format!("{t}"), "int32(1,1000)");
    }

    #[test]
    #[should_panic]
    fn nhwc_accessor_on_rank2_panics() {
        Shape::new(&[4, 5]).h();
    }
}
