//! ResNet-18 builder — the paper's evaluation workload.
//!
//! Mirrors `python/compile/model.py` exactly: same segment names, same
//! layer geometry, same requantization shifts. The integration tests
//! cross-check per-segment MAC counts against `artifacts/manifest.json`
//! so the two definitions cannot drift apart.

use super::graph::{Graph, NodeId};
use super::ops::Op;
use super::tensor::TensorDesc;

/// `(name, in_ch, out_ch, stride)` for the 8 basic blocks (== python).
pub const BASIC_BLOCKS: [(&str, u64, u64, u64); 8] = [
    ("s1b1", 64, 64, 1),
    ("s1b2", 64, 64, 1),
    ("s2b1", 64, 128, 2),
    ("s2b2", 128, 128, 1),
    ("s3b1", 128, 256, 2),
    ("s3b2", 256, 256, 1),
    ("s4b1", 256, 512, 2),
    ("s4b2", 512, 512, 1),
];

pub const SEGMENT_NAMES: [&str; 10] =
    ["stem", "s1b1", "s1b2", "s2b1", "s2b2", "s3b1", "s3b2", "s4b1", "s4b2", "head"];

pub const NUM_CLASSES: u64 = 1000;

/// Requantization shift after the residual add (== python RESIDUAL_SHIFT).
pub const RESIDUAL_SHIFT: u32 = 0;

/// Round-half-to-even, matching python's builtin `round` so the shift
/// constants are bit-identical to the exported model.
fn round_half_even(x: f64) -> i64 {
    let f = x.floor();
    let diff = x - f;
    if diff > 0.5 {
        f as i64 + 1
    } else if diff < 0.5 {
        f as i64
    } else {
        let fi = f as i64;
        if fi % 2 == 0 {
            fi
        } else {
            fi + 1
        }
    }
}

/// Requantization shift for accumulation depth K (== python shift_for_k).
pub fn shift_for_k(k: u64) -> u32 {
    let half_log = 0.5 * (k.max(1) as f64).log2();
    (6 + round_half_even(half_log).max(0)) as u32
}

/// Append one basic block to the graph; returns the output node.
fn basic_block(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    cin: u64,
    cout: u64,
    stride: u64,
) -> anyhow::Result<NodeId> {
    let k1 = 3 * 3 * cin;
    let k2 = 3 * 3 * cout;
    let c1 = g.add(
        &format!("{name}.conv1"),
        Op::Conv2d { oc: cout, kh: 3, kw: 3, stride, pad: 1 },
        &[input],
        name,
    )?;
    let r1 = g.add(&format!("{name}.relu1"), Op::Relu, &[c1], name)?;
    let q1 = g.add(
        &format!("{name}.rq1"),
        Op::Requantize { shift: shift_for_k(k1) },
        &[r1],
        name,
    )?;
    let c2 = g.add(
        &format!("{name}.conv2"),
        Op::Conv2d { oc: cout, kh: 3, kw: 3, stride: 1, pad: 1 },
        &[q1],
        name,
    )?;
    let q2 = g.add(
        &format!("{name}.rq2"),
        Op::Requantize { shift: shift_for_k(k2) },
        &[c2],
        name,
    )?;

    let identity = if stride != 1 || cin != cout {
        let cd = g.add(
            &format!("{name}.downsample"),
            Op::Conv2d { oc: cout, kh: 1, kw: 1, stride, pad: 0 },
            &[input],
            name,
        )?;
        g.add(
            &format!("{name}.rqd"),
            Op::Requantize { shift: shift_for_k(cin) },
            &[cd],
            name,
        )?
    } else {
        input
    };

    let sum = g.add(&format!("{name}.add"), Op::Add, &[q2, identity], name)?;
    let relu = g.add(&format!("{name}.relu2"), Op::Relu, &[sum], name)?;
    g.add(
        &format!("{name}.out"),
        Op::Requantize { shift: RESIDUAL_SHIFT },
        &[relu],
        name,
    )
}

/// Build ResNet-18 for a given square input size (must be a multiple of 32).
pub fn build_resnet18(input_hw: u64) -> anyhow::Result<Graph> {
    anyhow::ensure!(input_hw >= 32 && input_hw % 32 == 0, "input_hw must be a multiple of 32");
    let mut g = Graph::new_model("resnet18", &format!("resnet18-{input_hw}"));

    // --- stem
    let x = g.add(
        "input",
        Op::Input { desc: TensorDesc::i8(&[1, input_hw, input_hw, 3]) },
        &[],
        "stem",
    )?;
    let c1 = g.add(
        "stem.conv1",
        Op::Conv2d { oc: 64, kh: 7, kw: 7, stride: 2, pad: 3 },
        &[x],
        "stem",
    )?;
    let r1 = g.add("stem.relu", Op::Relu, &[c1], "stem")?;
    let q1 = g.add(
        "stem.rq",
        Op::Requantize { shift: shift_for_k(7 * 7 * 3) },
        &[r1],
        "stem",
    )?;
    let mut cur = g.add(
        "stem.maxpool",
        Op::MaxPool { k: 3, stride: 2, pad: 1 },
        &[q1],
        "stem",
    )?;

    // --- 8 basic blocks
    for (name, cin, cout, stride) in BASIC_BLOCKS {
        cur = basic_block(&mut g, name, cur, cin, cout, stride)?;
    }

    // --- head
    let gap = g.add("head.gap", Op::GlobalAvgPool, &[cur], "head")?;
    let act = g.add("head.rq", Op::Requantize { shift: 0 }, &[gap], "head")?;
    g.add("head.fc", Op::Dense { units: NUM_CLASSES }, &[act], "head")?;

    g.validate()?;
    Ok(g)
}

/// Per-segment MAC totals in segment order (for manifest cross-checks and
/// the partitioner's cost model). Thin alias of the model-agnostic
/// [`Graph::segment_macs`], kept for the existing call sites.
pub fn segment_macs(g: &Graph) -> Vec<(String, u64)> {
    g.segment_macs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates_224() {
        let g = build_resnet18(224).unwrap();
        g.validate().unwrap();
        assert_eq!(g.segment_order(), SEGMENT_NAMES.to_vec());
    }

    #[test]
    fn total_macs_matches_python_manifest() {
        // python: total_macs = 1,814,073,344 @224 (printed by aot.py)
        let g = build_resnet18(224).unwrap();
        assert_eq!(g.total_macs(), 1_814_073_344);
    }

    #[test]
    fn tiny_macs_match_python() {
        // python tiny (@32): 37.5M printed by aot.py; exact value checked
        // against the manifest in the integration tests.
        let g = build_resnet18(32).unwrap();
        let total = g.total_macs();
        assert!((37_000_000..38_000_000).contains(&total), "{total}");
    }

    #[test]
    fn segment_macs_sum_to_total() {
        let g = build_resnet18(224).unwrap();
        let per_seg = segment_macs(&g);
        assert_eq!(per_seg.len(), 10);
        let sum: u64 = per_seg.iter().map(|(_, m)| m).sum();
        assert_eq!(sum, g.total_macs());
        // stem matches the hand-computed figure from python
        assert_eq!(per_seg[0], ("stem".to_string(), 118_013_952));
    }

    #[test]
    fn weight_bytes_match_resnet18() {
        let g = build_resnet18(224).unwrap();
        let total = g.total_weight_bytes();
        assert!((10_500_000..12_000_000).contains(&total), "{total}");
    }

    #[test]
    fn shifts_match_python_convention() {
        // python: shift_for_k uses round-half-to-even via builtin round()
        assert_eq!(shift_for_k(147), 10); // stem 7·7·3
        assert_eq!(shift_for_k(576), 11); // 3·3·64
        assert_eq!(shift_for_k(1152), 11);
        assert_eq!(shift_for_k(2304), 12);
        assert_eq!(shift_for_k(4608), 12);
        assert_eq!(shift_for_k(64), 9);
        assert_eq!(shift_for_k(128), 10); // 3.5 rounds to even 4
        assert_eq!(shift_for_k(512), 10); // 4.5 rounds to even 4 (not 5!)
        assert_eq!(shift_for_k(1), 6);
    }

    #[test]
    fn round_half_even_matches_python() {
        assert_eq!(round_half_even(2.5), 2);
        assert_eq!(round_half_even(3.5), 4);
        assert_eq!(round_half_even(4.5), 4);
        assert_eq!(round_half_even(4.2), 4);
        assert_eq!(round_half_even(4.8), 5);
    }

    #[test]
    fn rejects_bad_input_size() {
        assert!(build_resnet18(100).is_err());
        assert!(build_resnet18(16).is_err());
    }

    #[test]
    fn output_is_logits() {
        let g = build_resnet18(64).unwrap();
        let out = g.node(g.output().unwrap());
        assert_eq!(out.name, "head.fc");
        assert_eq!(out.out.shape.0, vec![1, 1000]);
    }
}
