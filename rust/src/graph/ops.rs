//! Operator set of the quantized inference graphs, with exact shape
//! inference and MAC/byte cost accounting.
//!
//! The op set mirrors what TVM lowers onto VTA (and what the python L2
//! model implements): conv/dense on the GEMM core, pooling/ReLU/
//! requantize/add on the ALU.

use super::tensor::{DType, TensorDesc};

#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input placeholder.
    Input { desc: TensorDesc },
    /// 2-D convolution, NHWC × (OC,KH,KW,C) → NHWC, int8 → int32.
    Conv2d { oc: u64, kh: u64, kw: u64, stride: u64, pad: u64 },
    /// Dense (fully connected): (M,K) × (N,K) → (M,N), int8 → int32.
    Dense { units: u64 },
    /// Max-pool on int8.
    MaxPool { k: u64, stride: u64, pad: u64 },
    /// Global average pool: NHWC int8 → (N,C) int32.
    GlobalAvgPool,
    /// ReLU on the int32 accumulators (ALU MAX-imm-0).
    Relu,
    /// Requantize int32 → int8 (round-half-up shift + clip).
    Requantize { shift: u32 },
    /// Element-wise residual add (int8 + int8 → int32).
    Add,
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv2d { .. } => "conv2d",
            Op::Dense { .. } => "dense",
            Op::MaxPool { .. } => "maxpool",
            Op::GlobalAvgPool => "global_avgpool",
            Op::Relu => "relu",
            Op::Requantize { .. } => "requantize",
            Op::Add => "add",
        }
    }

    /// Number of data inputs the op consumes.
    pub fn arity(&self) -> usize {
        match self {
            Op::Input { .. } => 0,
            Op::Add => 2,
            _ => 1,
        }
    }

    /// Shape/dtype inference. `inputs` are the descriptors of the data
    /// inputs in order; errors describe the mismatch.
    pub fn infer(&self, inputs: &[TensorDesc]) -> anyhow::Result<TensorDesc> {
        anyhow::ensure!(
            inputs.len() == self.arity(),
            "{} expects {} inputs, got {}",
            self.kind(),
            self.arity(),
            inputs.len()
        );
        match self {
            Op::Input { desc } => Ok(desc.clone()),
            Op::Conv2d { oc, kh, kw, stride, pad } => {
                let x = &inputs[0];
                anyhow::ensure!(x.dtype == DType::I8, "conv2d input must be int8");
                anyhow::ensure!(x.shape.rank() == 4, "conv2d input must be NHWC");
                let (n, h, w) = (x.shape.n(), x.shape.h(), x.shape.w());
                anyhow::ensure!(
                    h + 2 * pad >= *kh && w + 2 * pad >= *kw,
                    "conv2d kernel {kh}x{kw} larger than padded input {h}x{w}+{pad}"
                );
                let oh = (h + 2 * pad - kh) / stride + 1;
                let ow = (w + 2 * pad - kw) / stride + 1;
                Ok(TensorDesc::i32(&[n, oh, ow, *oc]))
            }
            Op::Dense { units } => {
                let x = &inputs[0];
                anyhow::ensure!(x.dtype == DType::I8, "dense input must be int8");
                anyhow::ensure!(x.shape.rank() == 2, "dense input must be (M,K)");
                Ok(TensorDesc::i32(&[x.shape.dim(0), *units]))
            }
            Op::MaxPool { k, stride, pad } => {
                let x = &inputs[0];
                anyhow::ensure!(x.dtype == DType::I8, "maxpool input must be int8");
                let (n, h, w, c) = (x.shape.n(), x.shape.h(), x.shape.w(), x.shape.c());
                let oh = (h + 2 * pad - k) / stride + 1;
                let ow = (w + 2 * pad - k) / stride + 1;
                Ok(TensorDesc::i8(&[n, oh, ow, c]))
            }
            Op::GlobalAvgPool => {
                let x = &inputs[0];
                anyhow::ensure!(x.dtype == DType::I8, "global_avgpool input must be int8");
                Ok(TensorDesc::i32(&[x.shape.n(), x.shape.c()]))
            }
            Op::Relu => {
                let x = &inputs[0];
                anyhow::ensure!(x.dtype == DType::I32, "relu runs on int32 accumulators");
                Ok(x.clone())
            }
            Op::Requantize { .. } => {
                let x = &inputs[0];
                anyhow::ensure!(x.dtype == DType::I32, "requantize input must be int32");
                Ok(TensorDesc::new(x.shape.clone(), DType::I8))
            }
            Op::Add => {
                let (a, b) = (&inputs[0], &inputs[1]);
                anyhow::ensure!(a.shape == b.shape, "add shape mismatch {a} vs {b}");
                anyhow::ensure!(
                    a.dtype == DType::I8 && b.dtype == DType::I8,
                    "residual add expects int8 operands"
                );
                Ok(TensorDesc::new(a.shape.clone(), DType::I32))
            }
        }
    }

    /// Multiply-accumulate count (GEMM-core work).
    pub fn macs(&self, inputs: &[TensorDesc]) -> u64 {
        match self {
            Op::Conv2d { oc, kh, kw, .. } => {
                let out = self.infer(inputs).expect("macs on un-inferable conv");
                let c = inputs[0].shape.c();
                out.shape.n() * out.shape.h() * out.shape.w() * oc * kh * kw * c
            }
            Op::Dense { units } => {
                let x = &inputs[0];
                x.shape.dim(0) * x.shape.dim(1) * units
            }
            _ => 0,
        }
    }

    /// ALU element-operations count (element-wise work, pooling windows).
    pub fn alu_ops(&self, inputs: &[TensorDesc]) -> u64 {
        match self {
            Op::Relu | Op::Requantize { .. } => inputs[0].shape.elems(),
            Op::Add => inputs[0].shape.elems(),
            Op::MaxPool { k, .. } => {
                let out = self.infer(inputs).expect("alu_ops on un-inferable pool");
                out.shape.elems() * k * k
            }
            Op::GlobalAvgPool => inputs[0].shape.elems(),
            _ => 0,
        }
    }

    /// Weight parameter bytes (int8).
    pub fn weight_bytes(&self, inputs: &[TensorDesc]) -> u64 {
        match self {
            Op::Conv2d { oc, kh, kw, .. } => oc * kh * kw * inputs[0].shape.c(),
            Op::Dense { units } => units * inputs[0].shape.dim(1),
            _ => 0,
        }
    }

    /// The GEMM problem (M, K, N) this op lowers to, if any.
    pub fn gemm_shape(&self, inputs: &[TensorDesc]) -> Option<(u64, u64, u64)> {
        match self {
            Op::Conv2d { oc, kh, kw, .. } => {
                let out = self.infer(inputs).ok()?;
                let m = out.shape.n() * out.shape.h() * out.shape.w();
                let k = kh * kw * inputs[0].shape.c();
                Some((m, k, *oc))
            }
            Op::Dense { units } => {
                let x = &inputs[0];
                Some((x.shape.dim(0), x.shape.dim(1), *units))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tensor::Shape;

    fn i8d(dims: &[u64]) -> TensorDesc {
        TensorDesc::i8(dims)
    }

    #[test]
    fn conv_shape_and_macs() {
        let op = Op::Conv2d { oc: 64, kh: 7, kw: 7, stride: 2, pad: 3 };
        let x = i8d(&[1, 224, 224, 3]);
        let out = op.infer(std::slice::from_ref(&x)).unwrap();
        assert_eq!(out.shape, Shape::nhwc(1, 112, 112, 64));
        assert_eq!(out.dtype, DType::I32);
        // 112·112·64·7·7·3 = 118,013,952 (matches python manifest stem)
        assert_eq!(op.macs(&[x]), 118_013_952);
    }

    #[test]
    fn conv_gemm_shape_is_im2col() {
        let op = Op::Conv2d { oc: 128, kh: 3, kw: 3, stride: 1, pad: 1 };
        let x = i8d(&[1, 28, 28, 128]);
        assert_eq!(op.gemm_shape(&[x]), Some((784, 1152, 128)));
    }

    #[test]
    fn dense_infer() {
        let op = Op::Dense { units: 1000 };
        let x = i8d(&[1, 512]);
        let out = op.infer(std::slice::from_ref(&x)).unwrap();
        assert_eq!(out.shape, Shape::new(&[1, 1000]));
        assert_eq!(op.macs(&[x.clone()]), 512_000);
        assert_eq!(op.weight_bytes(&[x]), 512_000);
    }

    #[test]
    fn pool_and_elementwise() {
        let mp = Op::MaxPool { k: 3, stride: 2, pad: 1 };
        let x = i8d(&[1, 112, 112, 64]);
        let out = mp.infer(std::slice::from_ref(&x)).unwrap();
        assert_eq!(out.shape, Shape::nhwc(1, 56, 56, 64));
        assert_eq!(out.dtype, DType::I8);
        assert_eq!(mp.alu_ops(&[x]), 56 * 56 * 64 * 9);

        let relu = Op::Relu;
        let acc = TensorDesc::i32(&[1, 56, 56, 64]);
        assert_eq!(relu.infer(std::slice::from_ref(&acc)).unwrap().dtype, DType::I32);
        assert_eq!(relu.alu_ops(&[acc.clone()]), 200_704);

        let rq = Op::Requantize { shift: 11 };
        assert_eq!(rq.infer(&[acc]).unwrap().dtype, DType::I8);
    }

    #[test]
    fn add_requires_matching_int8() {
        let add = Op::Add;
        let a = i8d(&[1, 8, 8, 64]);
        let b = i8d(&[1, 8, 8, 64]);
        let out = add.infer(&[a.clone(), b]).unwrap();
        assert_eq!(out.dtype, DType::I32);
        let c = i8d(&[1, 8, 8, 32]);
        assert!(add.infer(&[a.clone(), c]).is_err());
        let d = TensorDesc::i32(&[1, 8, 8, 64]);
        assert!(add.infer(&[a, d]).is_err());
    }

    #[test]
    fn type_errors() {
        let conv = Op::Conv2d { oc: 8, kh: 3, kw: 3, stride: 1, pad: 1 };
        assert!(conv.infer(&[TensorDesc::i32(&[1, 8, 8, 3])]).is_err());
        assert!(Op::Relu.infer(&[i8d(&[1, 2])]).is_err());
        assert!(conv.infer(&[]).is_err());
    }

    #[test]
    fn kernel_larger_than_input_rejected() {
        let conv = Op::Conv2d { oc: 8, kh: 7, kw: 7, stride: 1, pad: 0 };
        assert!(conv.infer(&[i8d(&[1, 4, 4, 3])]).is_err());
    }

    #[test]
    fn global_avgpool() {
        let op = Op::GlobalAvgPool;
        let x = i8d(&[1, 7, 7, 512]);
        let out = op.infer(std::slice::from_ref(&x)).unwrap();
        assert_eq!(out.shape, Shape::new(&[1, 512]));
        assert_eq!(out.dtype, DType::I32);
    }
}
