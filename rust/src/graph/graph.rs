//! The computation DAG: typed nodes, validation, topological order and
//! whole-graph cost summaries.

use super::ops::Op;
use super::tensor::TensorDesc;
use std::collections::HashMap;

pub type NodeId = usize;

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    /// Data inputs (ids of producer nodes, in op-argument order).
    pub inputs: Vec<NodeId>,
    /// Inferred output descriptor (filled by the builder).
    pub out: TensorDesc,
    /// Segment label (stem / s1b1 / … / head) used by the partitioner.
    pub segment: String,
}

/// A validated DAG in insertion order (which is topological by
/// construction: inputs must already exist when a node is added).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Instance name, e.g. `resnet18-224` (model + input variant).
    pub name: String,
    /// Registry name of the model this graph instantiates, e.g.
    /// `resnet18` — the key under which [`crate::graph::zoo`] builders
    /// register, and the prefix of the model's AOT artifacts.
    pub model: String,
    nodes: Vec<Node>,
    by_name: HashMap<String, NodeId>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Graph { name: name.to_string(), model: name.to_string(), ..Default::default() }
    }

    /// A graph whose registry (model) name differs from its instance
    /// name — the normal case for zoo builders, where one model has
    /// several input-size variants.
    pub fn new_model(model: &str, name: &str) -> Self {
        Graph {
            name: name.to_string(),
            model: model.to_string(),
            ..Default::default()
        }
    }

    /// Add a node; infers and stores its output descriptor.
    pub fn add(
        &mut self,
        name: &str,
        op: Op,
        inputs: &[NodeId],
        segment: &str,
    ) -> anyhow::Result<NodeId> {
        anyhow::ensure!(
            !self.by_name.contains_key(name),
            "duplicate node name '{name}'"
        );
        for &i in inputs {
            anyhow::ensure!(i < self.nodes.len(), "node '{name}' references missing input {i}");
        }
        let in_descs: Vec<TensorDesc> =
            inputs.iter().map(|&i| self.nodes[i].out.clone()).collect();
        let out = op
            .infer(&in_descs)
            .map_err(|e| anyhow::anyhow!("node '{name}': {e}"))?;
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            op,
            inputs: inputs.to_vec(),
            out,
            segment: segment.to_string(),
        });
        self.by_name.insert(name.to_string(), id);
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn by_name(&self, name: &str) -> Option<&Node> {
        self.by_name.get(name).map(|&id| &self.nodes[id])
    }

    /// Input descriptors of a node.
    pub fn input_descs(&self, id: NodeId) -> Vec<TensorDesc> {
        self.nodes[id].inputs.iter().map(|&i| self.nodes[i].out.clone()).collect()
    }

    /// Ids of nodes that consume `id`'s output.
    pub fn consumers(&self, id: NodeId) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&id))
            .map(|n| n.id)
            .collect()
    }

    /// The unique sink (a validated inference graph has exactly one).
    pub fn output(&self) -> anyhow::Result<NodeId> {
        let sinks: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|n| self.consumers(n.id).is_empty())
            .map(|n| n.id)
            .collect();
        anyhow::ensure!(sinks.len() == 1, "graph has {} sinks, expected 1", sinks.len());
        Ok(sinks[0])
    }

    /// Total GEMM MACs.
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.op.macs(&self.input_descs(n.id))).sum()
    }

    /// Total ALU element ops.
    pub fn total_alu_ops(&self) -> u64 {
        self.nodes.iter().map(|n| n.op.alu_ops(&self.input_descs(n.id))).sum()
    }

    /// Total weight bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.op.weight_bytes(&self.input_descs(n.id))).sum()
    }

    /// Validate structural invariants (acyclic by construction; checks
    /// single sink, single Input node first, shape chain consistency).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.nodes.is_empty(), "empty graph");
        anyhow::ensure!(
            matches!(self.nodes[0].op, Op::Input { .. }),
            "first node must be the Input"
        );
        let extra_inputs = self
            .nodes[1..]
            .iter()
            .filter(|n| matches!(n.op, Op::Input { .. }))
            .count();
        anyhow::ensure!(extra_inputs == 0, "multiple Input nodes");
        for n in &self.nodes {
            for &i in &n.inputs {
                anyhow::ensure!(i < n.id, "node '{}' uses later node {i}", n.name);
            }
            // re-infer and compare (catches descriptor corruption)
            let descs = self.input_descs(n.id);
            let out = n.op.infer(&descs)?;
            anyhow::ensure!(
                out == n.out,
                "node '{}' stored descriptor {} != inferred {}",
                n.name,
                n.out,
                out
            );
        }
        self.output()?;
        Ok(())
    }

    /// Segment labels in first-appearance order.
    pub fn segment_order(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for n in &self.nodes {
            if out.last().map(|s| s != &n.segment).unwrap_or(true)
                && !out.contains(&n.segment)
            {
                out.push(n.segment.clone());
            }
        }
        out
    }

    /// All nodes with a given segment label.
    pub fn segment_nodes(&self, segment: &str) -> Vec<&Node> {
        self.nodes.iter().filter(|n| n.segment == segment).collect()
    }

    /// Per-segment MAC totals in segment order — the default cost oracle
    /// for the planners and the manifest cross-checks. Works for any
    /// model in the zoo, not just ResNet.
    pub fn segment_macs(&self) -> Vec<(String, u64)> {
        self.segment_order()
            .into_iter()
            .map(|seg| {
                let macs = self
                    .segment_nodes(&seg)
                    .iter()
                    .map(|n| n.op.macs(&self.input_descs(n.id)))
                    .sum();
                (seg, macs)
            })
            .collect()
    }

    /// MAC-proportional segment cost oracle — the planners' default when
    /// no calibrated cost model is in play (serving, examples, tests).
    /// Unknown labels price as 0 rather than panicking; plan validation
    /// catches any real inconsistency.
    pub fn mac_cost_oracle(&self) -> impl Fn(&str) -> f64 {
        let macs = self.segment_macs();
        move |l: &str| {
            macs.iter().find(|(x, _)| x == l).map(|(_, m)| *m as f64).unwrap_or(0.0)
        }
    }

    /// Descriptor of the graph's Input node. Serving derives the actual
    /// request shape from the artifact manifest
    /// ([`crate::coordinator::Coordinator::input_shape`]); this is the
    /// IR-side view of the same contract.
    pub fn input_desc(&self) -> anyhow::Result<&TensorDesc> {
        let first = self.nodes.first().ok_or_else(|| anyhow::anyhow!("empty graph"))?;
        anyhow::ensure!(
            matches!(first.op, Op::Input { .. }),
            "first node is not the Input"
        );
        Ok(&first.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tensor::{DType, TensorDesc};

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g
            .add("x", Op::Input { desc: TensorDesc::i8(&[1, 8, 8, 3]) }, &[], "stem")
            .unwrap();
        let c = g
            .add("conv", Op::Conv2d { oc: 4, kh: 3, kw: 3, stride: 1, pad: 1 }, &[x], "stem")
            .unwrap();
        let r = g.add("relu", Op::Relu, &[c], "stem").unwrap();
        g.add("rq", Op::Requantize { shift: 8 }, &[r], "stem").unwrap();
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny_graph();
        g.validate().unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.total_macs(), 8 * 8 * 4 * 9 * 3);
        assert_eq!(g.total_weight_bytes(), 4 * 9 * 3);
        assert_eq!(g.output().unwrap(), 3);
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut g = tiny_graph();
        let err = g
            .add("conv", Op::Relu, &[1], "stem")
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn type_mismatch_rejected_at_add() {
        let mut g = tiny_graph();
        // requantize output is int8; relu needs int32
        assert!(g.add("bad", Op::Relu, &[3], "stem").is_err());
    }

    #[test]
    fn consumers_and_lookup() {
        let g = tiny_graph();
        assert_eq!(g.consumers(1), vec![2]);
        assert_eq!(g.by_name("relu").unwrap().id, 2);
        assert!(g.by_name("nope").is_none());
    }

    #[test]
    fn residual_diamond_validates() {
        let mut g = Graph::new("diamond");
        let x = g
            .add("x", Op::Input { desc: TensorDesc::i8(&[1, 8, 8, 4]) }, &[], "b")
            .unwrap();
        let c = g
            .add("conv", Op::Conv2d { oc: 4, kh: 3, kw: 3, stride: 1, pad: 1 }, &[x], "b")
            .unwrap();
        let q = g.add("rq", Op::Requantize { shift: 8 }, &[c], "b").unwrap();
        let a = g.add("add", Op::Add, &[q, x], "b").unwrap();
        let r = g.add("relu", Op::Relu, &[a], "b").unwrap();
        g.add("rq2", Op::Requantize { shift: 0 }, &[r], "b").unwrap();
        g.validate().unwrap();
        assert_eq!(g.node(a).out.dtype, DType::I32);
        // x feeds both conv and add
        assert_eq!(g.consumers(x), vec![1, 3]);
    }

    #[test]
    fn two_sinks_fail_validation() {
        let mut g = tiny_graph();
        g.add("extra", Op::Relu, &[2], "stem").unwrap(); // second consumer of relu
        assert!(g.validate().is_err()); // rq and extra are both sinks
    }

    #[test]
    fn segment_order() {
        let g = tiny_graph();
        assert_eq!(g.segment_order(), vec!["stem"]);
    }
}
