//! Graph partitioning for the scheduling strategies.
//!
//! The unit of distribution is the **segment** (stem, 8 blocks, head —
//! the same cut points as the exported HLO artifacts). The pipeline and
//! fused strategies need the 10 segments grouped into `k ≤ 10` contiguous
//! stages with balanced cost; AI-core assignment needs the bottleneck
//! ranking. Balanced grouping is solved exactly by DP (minimise the
//! maximum stage cost — the pipeline's throughput bound).

use super::graph::Graph;

/// One distributable unit: a contiguous run of graph segments.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Segment labels in order (e.g. `["s1b1", "s1b2"]`).
    pub labels: Vec<String>,
    /// GEMM MACs in this segment group.
    pub macs: u64,
    /// ALU element ops.
    pub alu_ops: u64,
    /// Weight bytes resident on the node running this group.
    pub weight_bytes: u64,
    /// Activation bytes entering the group (network transfer size).
    pub in_bytes: u64,
    /// Activation bytes leaving the group.
    pub out_bytes: u64,
}

/// Cost/IO summary of each atomic segment, in order.
pub fn atomic_segments(g: &Graph) -> Vec<Segment> {
    let order = g.segment_order();
    let mut out = Vec::with_capacity(order.len());
    for label in &order {
        let nodes = g.segment_nodes(label);
        let macs = nodes.iter().map(|n| n.op.macs(&g.input_descs(n.id))).sum();
        let alu_ops = nodes.iter().map(|n| n.op.alu_ops(&g.input_descs(n.id))).sum();
        let weight_bytes =
            nodes.iter().map(|n| n.op.weight_bytes(&g.input_descs(n.id))).sum();
        // input bytes: the tensor crossing into this segment = output of
        // the previous segment (or the graph input for the first).
        let first = nodes.first().expect("segment with no nodes");
        let in_bytes = if first.inputs.is_empty() {
            first.out.bytes() // Input node: the image itself
        } else {
            g.node(first.inputs[0]).out.bytes()
        };
        let last = nodes.last().expect("segment with no nodes");
        let out_bytes = last.out.bytes();
        out.push(Segment {
            labels: vec![label.clone()],
            macs,
            alu_ops,
            weight_bytes,
            in_bytes,
            out_bytes,
        });
    }
    out
}

fn merge(parts: &[Segment]) -> Segment {
    assert!(!parts.is_empty());
    Segment {
        labels: parts.iter().flat_map(|p| p.labels.clone()).collect(),
        macs: parts.iter().map(|p| p.macs).sum(),
        alu_ops: parts.iter().map(|p| p.alu_ops).sum(),
        weight_bytes: parts.iter().map(|p| p.weight_bytes).sum(),
        in_bytes: parts.first().unwrap().in_bytes,
        out_bytes: parts.last().unwrap().out_bytes,
    }
}

/// Group the atomic segments into exactly `k` contiguous stages minimising
/// the maximum stage cost (classic linear-partition DP, exact).
///
/// `cost` maps a segment to its stage-time proxy (usually MACs, but the
/// schedulers pass the full node-time model).
pub fn partition_balanced<F>(g: &Graph, k: usize, cost: F) -> anyhow::Result<Vec<Segment>>
where
    F: Fn(&Segment) -> f64,
{
    let atoms = atomic_segments(g);
    let n = atoms.len();
    anyhow::ensure!(k >= 1, "k must be ≥ 1");
    anyhow::ensure!(
        k <= n,
        "cannot split {n} segments into {k} stages (max pipeline depth is {n})"
    );
    let costs: Vec<f64> = atoms.iter().map(&cost).collect();
    // prefix[i] = sum of costs[0..i]
    let mut prefix = vec![0.0; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + costs[i];
    }
    let range_cost = |a: usize, b: usize| prefix[b] - prefix[a]; // [a, b)

    // dp[j][i] = min over partitions of first i atoms into j stages of the
    // max stage cost; cut[j][i] = position of the last cut.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for i in j..=n {
            for c in (j - 1)..i {
                let v = dp[j - 1][c].max(range_cost(c, i));
                if v < dp[j][i] {
                    dp[j][i] = v;
                    cut[j][i] = c;
                }
            }
        }
    }
    // reconstruct
    let mut bounds = vec![n];
    let mut i = n;
    for j in (1..=k).rev() {
        i = cut[j][i];
        bounds.push(i);
    }
    bounds.reverse(); // [0, c1, c2, ..., n]
    let mut out = Vec::with_capacity(k);
    for w in bounds.windows(2) {
        out.push(merge(&atoms[w[0]..w[1]]));
    }
    Ok(out)
}

/// Rank atomic segments by cost, descending — the "bottleneck operators"
/// that AI-core assignment replicates first (§II-C.2).
pub fn bottleneck_ranking<F>(g: &Graph, cost: F) -> Vec<(usize, Segment)>
where
    F: Fn(&Segment) -> f64,
{
    let atoms = atomic_segments(g);
    let mut ranked: Vec<(usize, Segment)> = atoms.into_iter().enumerate().collect();
    ranked.sort_by(|a, b| cost(&b.1).partial_cmp(&cost(&a.1)).unwrap());
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::resnet::build_resnet18;

    fn g224() -> Graph {
        build_resnet18(224).unwrap()
    }

    #[test]
    fn atomic_segments_cover_graph() {
        let g = g224();
        let atoms = atomic_segments(&g);
        assert_eq!(atoms.len(), 10);
        let macs: u64 = atoms.iter().map(|s| s.macs).sum();
        assert_eq!(macs, g.total_macs());
        // IO chain: out_bytes of i == in_bytes of i+1
        for w in atoms.windows(2) {
            assert_eq!(w[0].out_bytes, w[1].in_bytes, "{:?}", w[0].labels);
        }
        // stem input is the 224×224×3 image
        assert_eq!(atoms[0].in_bytes, 224 * 224 * 3);
        // head output is the (1,1000) int32 logits
        assert_eq!(atoms[9].out_bytes, 4000);
    }

    #[test]
    fn partition_k1_is_whole_graph() {
        let g = g224();
        let parts = partition_balanced(&g, 1, |s| s.macs as f64).unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].macs, g.total_macs());
        assert_eq!(parts[0].labels.len(), 10);
    }

    #[test]
    fn partition_k10_is_atomic() {
        let g = g224();
        let parts = partition_balanced(&g, 10, |s| s.macs as f64).unwrap();
        assert_eq!(parts.len(), 10);
        assert!(parts.iter().all(|p| p.labels.len() == 1));
    }

    #[test]
    fn partition_minimises_max_stage() {
        let g = g224();
        let atoms = atomic_segments(&g);
        let total: f64 = atoms.iter().map(|s| s.macs as f64).sum();
        for k in 2..=10 {
            let parts = partition_balanced(&g, k, |s| s.macs as f64).unwrap();
            assert_eq!(parts.len(), k);
            let maxc = parts.iter().map(|p| p.macs as f64).fold(0.0, f64::max);
            // optimal max stage is ≥ total/k and ≤ total
            assert!(maxc >= total / k as f64 - 1.0);
            assert!(maxc <= total);
            // contiguity: concatenated labels == original order
            let labels: Vec<String> = parts.iter().flat_map(|p| p.labels.clone()).collect();
            let want: Vec<String> = atoms.iter().map(|a| a.labels[0].clone()).collect();
            assert_eq!(labels, want);
        }
    }

    #[test]
    fn partition_2_is_better_than_naive_split() {
        // DP must beat or match the midpoint split.
        let g = g224();
        let atoms = atomic_segments(&g);
        let parts = partition_balanced(&g, 2, |s| s.macs as f64).unwrap();
        let dp_max = parts.iter().map(|p| p.macs).max().unwrap();
        let naive_first: u64 = atoms[..5].iter().map(|s| s.macs).sum();
        let naive_second: u64 = atoms[5..].iter().map(|s| s.macs).sum();
        assert!(dp_max <= naive_first.max(naive_second));
    }

    #[test]
    fn k_too_large_errors() {
        let g = g224();
        assert!(partition_balanced(&g, 11, |s| s.macs as f64).is_err());
        assert!(partition_balanced(&g, 0, |s| s.macs as f64).is_err());
    }

    #[test]
    fn bottleneck_ranking_descending() {
        let g = g224();
        let ranked = bottleneck_ranking(&g, |s| s.macs as f64);
        assert_eq!(ranked.len(), 10);
        for w in ranked.windows(2) {
            assert!(w[0].1.macs >= w[1].1.macs);
        }
        // In ResNet-18@224 the s1 blocks are the largest MAC segments.
        assert!(ranked[0].1.macs >= 200_000_000);
    }
}
