//! The TVM-role substrate: lowering graph operators onto the VTA ISA.
//!
//! * [`tiling`]   — blocked GEMM tilings under the Table-I buffer budget
//! * [`lower`]    — tiling → instruction stream with virtual-thread
//!                  dependency tokens (double-buffered load/compute)
//! * [`autotune`] — AutoTVM-analog: enumerate tilings, price each with the
//!                  cycle model, keep the best (the paper's single-FPGA
//!                  anchor is an "optimized micro-kernel generated through
//!                  AutoTVM schedule exploration")

pub mod autotune;
pub mod lower;
pub mod tiling;

pub use autotune::{autotune_gemm, TunedGemm};
pub use lower::{lower_alu_pass, lower_gemm, GemmShape};
pub use tiling::{candidate_tilings, GemmTiling};
