//! GEMM tilings under the VTA buffer budget.
//!
//! A GEMM (M, K, N) is expressed in **block units** (Table-I BLOCK = 16):
//! `mb × kb × nb` tiles. A tiling chooses a resident chunk `(tm, tk, tn)`:
//!
//! * `tm × tn` accumulator rows stay resident across the K loop
//!   (`tm·tn ≤ acc_rows`),
//! * each K step streams `tm × tk` input rows and `tn × tk` weight tiles,
//!   **double-buffered** (×2) so loads overlap compute
//!   (`2·tm·tk ≤ inp_rows`, `2·tn·tk ≤ wgt_tiles`),
//! * the micro-op table holds the `tn × tk` inner pattern plus `tn` reset
//!   uops (`tn·tk + tn ≤ uop_capacity`).
//!
//! Reuse — the §IV big-config effect — falls out directly: input tiles
//! are re-fetched once per N-chunk and weight tiles once per M-chunk, so
//! doubling the buffers cuts DRAM traffic even at a lower clock.

use crate::config::VtaConfig;

/// A tiling in block units.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmTiling {
    pub tm: u64,
    pub tk: u64,
    pub tn: u64,
}

impl GemmTiling {
    /// Check buffer-budget feasibility for a config. The ×2 terms are the
    /// double-buffered (virtual-thread) halves; the uop table holds `tn`
    /// reset uops plus two parity copies of the `tn×tk` MAC pattern.
    pub fn feasible(&self, cfg: &VtaConfig) -> bool {
        let acc = cfg.acc_rows_resident();
        let inp = cfg.input_rows_resident();
        let wgt = cfg.weight_tiles_resident();
        let uop = cfg.uop_buffer_bits / 32;
        self.tm >= 1
            && self.tk >= 1
            && self.tn >= 1
            && self.tm * self.tn <= acc
            && 2 * self.tm * self.tk <= inp
            && 2 * self.tn * self.tk <= wgt
            && 2 * self.tn * self.tk + self.tn <= uop
            // ISA field widths (encode/decode contract)
            && self.tm <= u16::MAX as u64
            && self.tn <= 2047
            && self.tk <= 2047
    }

    /// DRAM traffic in bytes for a full (mb, kb, nb) GEMM under this
    /// tiling (closed form; the lowered program's accounting must agree).
    pub fn traffic_bytes(&self, cfg: &VtaConfig, mb: u64, kb: u64, nb: u64) -> u64 {
        let blk = cfg.block as u64;
        let m_chunks = mb.div_ceil(self.tm);
        let n_chunks = nb.div_ceil(self.tn);
        // input rows fetched once per n-chunk sweep
        let inp = n_chunks * mb * kb * blk;
        // weight tiles fetched once per m-chunk sweep
        let wgt = m_chunks * nb * kb * blk * blk;
        // outputs stored once (int8-narrowed rows)
        let out = mb * nb * blk;
        inp + wgt + out
    }
}

/// Enumerate feasible tilings (powers of two and the problem bounds).
pub fn candidate_tilings(cfg: &VtaConfig, mb: u64, kb: u64, nb: u64) -> Vec<GemmTiling> {
    let mut dims_m = pow2_upto(mb.max(1));
    let mut dims_k = pow2_upto(kb.max(1));
    let mut dims_n = pow2_upto(nb.max(1));
    // include exact bounds so small problems can be single-chunk
    push_unique(&mut dims_m, mb.max(1));
    push_unique(&mut dims_k, kb.max(1));
    push_unique(&mut dims_n, nb.max(1));
    let mut out = Vec::new();
    for &tm in &dims_m {
        for &tk in &dims_k {
            for &tn in &dims_n {
                let t = GemmTiling { tm, tk, tn };
                if t.feasible(cfg) {
                    out.push(t);
                }
            }
        }
    }
    out
}

fn pow2_upto(limit: u64) -> Vec<u64> {
    let mut v = Vec::new();
    let mut x = 1;
    while x <= limit {
        v.push(x);
        x *= 2;
    }
    v
}

fn push_unique(v: &mut Vec<u64>, x: u64) {
    if !v.contains(&x) {
        v.push(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg16() -> VtaConfig {
        VtaConfig::table1_zynq7000()
    }

    #[test]
    fn feasibility_respects_budgets() {
        let cfg = cfg16();
        // acc 256 rows, inp 256 rows, wgt 128 tiles, uop 1024
        assert!(GemmTiling { tm: 16, tk: 4, tn: 16 }.feasible(&cfg));
        assert!(!GemmTiling { tm: 32, tk: 4, tn: 16 }.feasible(&cfg)); // acc 512 > 256
        assert!(!GemmTiling { tm: 16, tk: 16, tn: 16 }.feasible(&cfg)); // inp 512 > 256
        assert!(!GemmTiling { tm: 4, tk: 32, tn: 4 }.feasible(&cfg)); // wgt 256 > 128
    }

    #[test]
    fn candidates_nonempty_and_feasible() {
        let cfg = cfg16();
        // resnet layer2 conv: M=784, K=1152, N=128 → mb=49, kb=72, nb=8
        let cands = candidate_tilings(&cfg, 49, 72, 8);
        assert!(cands.len() > 10, "{}", cands.len());
        assert!(cands.iter().all(|t| t.feasible(&cfg)));
        // the trivial tiling must be present
        assert!(cands.contains(&GemmTiling { tm: 1, tk: 1, tn: 1 }));
    }

    #[test]
    fn bigger_buffers_admit_bigger_tiles() {
        let small = cfg16();
        let big = VtaConfig::big_config_200mhz();
        // big config: acc 256Kb/32 = 8192 elems / 32 = 256 rows of 32,
        // inp 64Kb/8/32 = 256 rows, wgt 512Kb/8/1024 = 64 tiles of 32×32
        let t = GemmTiling { tm: 16, tk: 8, tn: 4 };
        assert!(t.feasible(&big));
        // same (tm,tk,tn) in block units needs 2·16·8=256 ≤ inp(256) ✓ on small
        // but wgt 2·4·8 = 64 ≤ 128 ✓ — craft one that only fits big:
        let t2 = GemmTiling { tm: 8, tk: 16, tn: 2 };
        assert!(!t2.feasible(&small) || small.input_rows_resident() >= 256);
        assert!(t2.feasible(&big));
    }

    #[test]
    fn traffic_model_reuse() {
        let cfg = cfg16();
        let (mb, kb, nb) = (49, 72, 8);
        let t_small = GemmTiling { tm: 1, tk: 1, tn: 1 };
        let t_big = GemmTiling { tm: 16, tk: 4, tn: 8 };
        let tr_small = t_small.traffic_bytes(&cfg, mb, kb, nb);
        let tr_big = t_big.traffic_bytes(&cfg, mb, kb, nb);
        assert!(
            tr_big < tr_small / 4,
            "expected ≥4× reuse: {tr_big} vs {tr_small}"
        );
    }

    #[test]
    fn traffic_floor_is_compulsory_bytes() {
        let cfg = cfg16();
        let blk = cfg.block as u64;
        // (m_rows, k_blocks, n_blocks) — single chunk: everything once
        let (mr, kb, nb) = (4, 4, 4);
        let t = GemmTiling { tm: 4, tk: 4, tn: 4 };
        assert!(t.feasible(&cfg));
        let want = mr * kb * blk // input rows × blk int8
            + nb * kb * blk * blk // weight tiles
            + mr * nb * blk; // output rows
        assert_eq!(t.traffic_bytes(&cfg, mr, kb, nb), want);
    }
}
