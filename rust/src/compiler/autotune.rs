//! AutoTVM-analog schedule search.
//!
//! §III: the single-FPGA anchor (27.34 ms) comes from "an optimized
//! micro-kernel generated through AutoTVM schedule exploration". We play
//! the same move: enumerate every feasible tiling of a GEMM, lower each
//! to a real instruction stream, price it with the cycle model, and keep
//! the fastest. Results are memoized per (config, shape) by `sim::cost`.
//!
//! The tuner is a thin adapter over the plan-search engine's generic
//! bound-and-price loop ([`crate::search::prune_min`], DESIGN.md §17):
//! the admissible bound is the max of the compute floor and the DRAM
//! traffic floor, the pricer lowers the tiling and runs the cycle model.

use super::lower::{lower_gemm, GemmShape};
use super::tiling::{candidate_tilings, GemmTiling};
use crate::search::prune_min;
use crate::vta::timing::{CycleReport, TimingModel};

/// Outcome of tuning one GEMM shape.
#[derive(Debug, Clone)]
pub struct TunedGemm {
    pub shape: GemmShape,
    pub tiling: GemmTiling,
    pub report: CycleReport,
    /// Number of schedules explored.
    pub explored: usize,
}

/// Exhaustively tune a GEMM shape against a timing model, with an
/// admissible lower-bound prune: a schedule whose analytic bound
/// (max of compute cycles and traffic cycles) already exceeds the best
/// measured makespan cannot win and is skipped without lowering.
pub fn autotune_gemm(model: &TimingModel, shape: GemmShape) -> anyhow::Result<TunedGemm> {
    let (mr, kb, nb) = shape.blocks(&model.cfg);
    let mut cands = candidate_tilings(&model.cfg, mr, kb, nb);
    anyhow::ensure!(!cands.is_empty(), "no feasible tiling for {shape:?} on {}", model.cfg.name);
    // visit large-volume (usually good) tilings first so pruning bites
    cands.sort_by_key(|t| std::cmp::Reverse(t.tm * t.tk * t.tn));

    let dram_bytes_per_cycle = model.board.dram_bw_bytes_per_sec as f64
        * model.calib.dram_efficiency
        / model.cfg.clock_hz as f64;
    let compute_floor =
        (mr * kb * nb) as f64 / model.calib.gemm_efficiency; // MAC uop cycles

    let (best, stats) = prune_min(
        cands,
        |tiling| {
            let m_p = mr.div_ceil(tiling.tm) * tiling.tm;
            let kb_p = kb.div_ceil(tiling.tk) * tiling.tk;
            let nb_p = nb.div_ceil(tiling.tn) * tiling.tn;
            let traffic = tiling.traffic_bytes(&model.cfg, m_p, kb_p, nb_p);
            compute_floor.max(traffic as f64 / dram_bytes_per_cycle)
        },
        |tiling| {
            let prog = lower_gemm("tune", shape, *tiling, &model.cfg)?;
            let report = model.price(&prog)?;
            let cycles = report.total_cycles as f64;
            Ok(Some((report, cycles)))
        },
    )?;
    let (tiling, report, _) = best.expect("a feasible tiling always prices");
    Ok(TunedGemm { shape, tiling, report, explored: stats.explored })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardProfile, Calibration, VtaConfig};

    fn model(cfg: VtaConfig) -> TimingModel {
        TimingModel::new(
            cfg,
            BoardProfile::zynq7020(),
            Calibration { driver_overhead_us: 0.0, ..Default::default() },
        )
    }

    #[test]
    fn tuned_beats_naive() {
        let m = model(VtaConfig::table1_zynq7000());
        let shape = GemmShape { m: 784, k: 1152, n: 128 };
        let tuned = autotune_gemm(&m, shape).unwrap();
        assert!(tuned.explored > 10);
        // naive (1,1,1) tiling for comparison
        let naive = lower_gemm("naive", shape, GemmTiling { tm: 1, tk: 1, tn: 1 }, &m.cfg)
            .unwrap();
        let naive_r = m.price(&naive).unwrap();
        assert!(
            tuned.report.total_cycles * 2 < naive_r.total_cycles,
            "tuned {} vs naive {}",
            tuned.report.total_cycles,
            naive_r.total_cycles
        );
    }

    #[test]
    fn big_config_reduces_traffic_per_mac() {
        // the §IV E5 mechanism: larger buffers → better reuse
        let shape = GemmShape { m: 784, k: 1152, n: 128 };
        let small = autotune_gemm(
            &model(VtaConfig::table1_at_clock(200_000_000)),
            shape,
        )
        .unwrap();
        let big = autotune_gemm(&model(VtaConfig::big_config_200mhz()), shape).unwrap();
        let t_small = small.report.dram_bytes as f64 / shape.macs() as f64;
        let t_big = big.report.dram_bytes as f64 / shape.macs() as f64;
        assert!(
            t_big < t_small,
            "big config should move fewer bytes/MAC: {t_big:.4} vs {t_small:.4}"
        );
    }

    #[test]
    fn tiny_shape_tunes() {
        let m = model(VtaConfig::table1_zynq7000());
        let tuned = autotune_gemm(&m, GemmShape { m: 1, k: 512, n: 1000 }).unwrap();
        assert!(tuned.report.total_cycles > 0);
    }
}
