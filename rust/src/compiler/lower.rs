//! Lowering: a blocked GEMM (or ALU pass) → a VTA [`Program`] with
//! virtual-thread dependency tokens.
//!
//! Mirrors how TVM lowers conv/dense for VTA: the problem is padded to
//! tile multiples; each `(mc, nc)` output chunk keeps its accumulators
//! resident while the K dimension streams through double-buffered
//! input/weight halves (two "virtual thread" contexts, even/odd). Load
//! runs two K-steps ahead of compute (depth-2 software pipeline), store
//! overlaps the next chunk — the module-overlap behaviour the timing
//! model prices.

use super::tiling::GemmTiling;
use crate::config::VtaConfig;
use crate::vta::isa::{AluOp, Insn, MemType};
use crate::vta::program::{dep, DramLayout, Program, Uop};

/// A GEMM problem in element units (im2col form for convs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub m: u64,
    pub k: u64,
    pub n: u64,
}

impl GemmShape {
    /// Problem size in VTA buffer units: `(m_rows, k_blocks, n_blocks)`.
    ///
    /// With BATCH=1 the GEMM intrinsic consumes one `(1 × block)` input
    /// row per uop-cycle, so the M dimension counts **rows directly**;
    /// only K and N are grouped into `block`-wide fragments/tiles.
    pub fn blocks(&self, cfg: &VtaConfig) -> (u64, u64, u64) {
        let b = cfg.block as u64;
        (self.m, self.k.div_ceil(b), self.n.div_ceil(b))
    }

    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }
}

/// Lower a GEMM under a tiling. The returned program's DRAM layout is
/// padded to tile multiples (`inp`: mb_p×kb_p rows, `wgt`: nb_p×kb_p
/// tiles, `out`: mb_p×nb_p rows).
pub fn lower_gemm(
    name: &str,
    shape: GemmShape,
    tiling: GemmTiling,
    cfg: &VtaConfig,
) -> anyhow::Result<Program> {
    anyhow::ensure!(tiling.feasible(cfg), "tiling {tiling:?} infeasible for {}", cfg.name);
    let (mb, kb, nb) = shape.blocks(cfg);
    let (tm, tk, tn) = (tiling.tm, tiling.tk, tiling.tn);
    let mb_p = mb.div_ceil(tm) * tm;
    let kb_p = kb.div_ceil(tk) * tk;
    let nb_p = nb.div_ceil(tn) * tn;

    let mut p = Program::new(name);
    p.dram = DramLayout {
        inp_len: (mb_p * kb_p) as usize * cfg.block as usize,
        wgt_len: (nb_p * kb_p) as usize * (cfg.block as usize).pow(2),
        acc_len: 0,
        out_len: (mb_p * nb_p) as usize * cfg.block as usize,
    };

    // ---- micro-op tables --------------------------------------------
    // reset uops: one per n', swept over m' by iter_out (dst_factor = tn)
    let reset_bgn = p.uops.len() as u16;
    for n1 in 0..tn {
        p.push_uop(Uop { dst: n1 as u16, src: 0, wgt: 0 });
    }
    let reset_end = p.uops.len() as u16;
    // MAC uops, two parity copies for the double-buffered halves
    let mut mac_ranges = [(0u16, 0u16); 2];
    for parity in 0..2u64 {
        let bgn = p.uops.len() as u16;
        let src_base = parity * tm * tk;
        let wgt_base = parity * tn * tk;
        for n1 in 0..tn {
            for k1 in 0..tk {
                p.push_uop(Uop {
                    dst: n1 as u16,
                    src: (src_base + k1) as u16,
                    wgt: (wgt_base + n1 * tk + k1) as u16,
                });
            }
        }
        mac_ranges[parity as usize] = (bgn, p.uops.len() as u16);
    }

    // ---- instruction stream -----------------------------------------
    let m_chunks = mb_p / tm;
    let n_chunks = nb_p / tn;
    let k_chunks = kb_p / tk;
    let total_chunks = m_chunks * n_chunks;
    let mut load_step: u64 = 0; // global k-step index (for pipeline depth)
    let mut chunk_idx: u64 = 0;

    for mc in 0..m_chunks {
        for nc in 0..n_chunks {
            // reset accumulators; WAR on the previous chunk's store
            p.push(Insn::Gemm {
                dep: dep(false, chunk_idx > 0, false, false),
                reset: true,
                uop_bgn: reset_bgn,
                uop_end: reset_end,
                iter_out: tm as u16,
                iter_in: 1,
                dst_factor_out: tn as u16,
                dst_factor_in: 0,
                src_factor_out: 0,
                src_factor_in: 0,
                wgt_factor_out: 0,
                wgt_factor_in: 0,
            });
            for kc in 0..k_chunks {
                let parity = (load_step % 2) as usize;
                // input rows (m', k') for this chunk
                p.push(Insn::Load {
                    // reuse a buffer half only after compute freed it
                    dep: dep(false, load_step >= 2, false, false),
                    mem: MemType::Inp,
                    sram_base: (parity as u64 * tm * tk) as u32,
                    dram_base: ((mc * tm) * kb_p + kc * tk) as u32,
                    y_size: tm as u16,
                    x_size: tk as u16,
                    x_stride: kb_p as u16,
                });
                // weight tiles (n', k')
                p.push(Insn::Load {
                    dep: dep(false, false, false, true), // data ready → compute
                    mem: MemType::Wgt,
                    sram_base: (parity as u64 * tn * tk) as u32,
                    dram_base: ((nc * tn) * kb_p + kc * tk) as u32,
                    y_size: tn as u16,
                    x_size: tk as u16,
                    x_stride: kb_p as u16,
                });
                let (mac_bgn, mac_end) = mac_ranges[parity];
                let last_k = kc + 1 == k_chunks;
                p.push(Insn::Gemm {
                    // RAW on loads; WAR-release the buffer half; signal
                    // store after the chunk's last K-step
                    dep: dep(true, false, true, last_k),
                    reset: false,
                    uop_bgn: mac_bgn,
                    uop_end: mac_end,
                    iter_out: tm as u16,
                    iter_in: 1,
                    dst_factor_out: tn as u16,
                    dst_factor_in: 0,
                    src_factor_out: tk as u16,
                    src_factor_in: 0,
                    wgt_factor_out: 0,
                    wgt_factor_in: 0,
                });
                load_step += 1;
            }
            // store the finished chunk; free the accumulators (WAR token
            // consumed by the next chunk's reset, or FINISH at the end)
            p.push(Insn::Store {
                dep: dep(true, false, true, false),
                sram_base: 0,
                dram_base: ((mc * tm) * nb_p + nc * tn) as u32,
                y_size: tm as u16,
                x_size: tn as u16,
                x_stride: nb_p as u16,
            });
            chunk_idx += 1;
        }
    }
    debug_assert_eq!(chunk_idx, total_chunks);
    // drain the two outstanding WAR tokens from the pipeline tail
    for _ in 0..load_step.min(2) {
        p.push(Insn::Load {
            dep: dep(false, true, false, false),
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 0,
            x_size: 0,
            x_stride: 0,
        });
    }
    p.push(Insn::Finish { dep: dep(false, true, false, false) });
    p.validate(cfg)?;
    Ok(p)
}

/// Lower an element-wise ALU pass over `elems` int32 accumulators:
/// load → `ops` ALU instructions → store, chunked by the accumulator
/// buffer. Used to price ReLU / requantize / residual-add / pooling.
/// `ops` holds `(op, imm)` pairs applied in sequence to every element.
pub fn lower_alu_pass(
    name: &str,
    elems: u64,
    ops: &[(AluOp, i16)],
    cfg: &VtaConfig,
) -> anyhow::Result<Program> {
    anyhow::ensure!(!ops.is_empty(), "ALU pass needs at least one op");
    let blk = cfg.block as u64;
    let rows = elems.div_ceil(blk).max(1);
    let acc_cap = cfg.acc_rows_resident();
    let chunk_rows = acc_cap.min(rows);
    let chunks = rows.div_ceil(chunk_rows);

    let mut p = Program::new(name);
    p.dram = DramLayout {
        inp_len: 0,
        wgt_len: 0,
        acc_len: (chunks * chunk_rows * blk) as usize,
        out_len: (chunks * chunk_rows * blk) as usize,
    };
    let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });

    for c in 0..chunks {
        // acc load issues on the compute queue (VTA routing); WAR on the
        // previous chunk's store
        p.push(Insn::Load {
            dep: dep(false, c > 0, false, false),
            mem: MemType::Acc,
            sram_base: 0,
            dram_base: (c * chunk_rows) as u32,
            y_size: chunk_rows as u16,
            x_size: 1,
            x_stride: 1,
        });
        for (i, (op, imm)) in ops.iter().enumerate() {
            let last = i + 1 == ops.len();
            p.push(Insn::Alu {
                dep: dep(false, false, false, last),
                op: *op,
                use_imm: true,
                imm: *imm,
                uop_bgn: u,
                uop_end: u + 1,
                iter_out: chunk_rows as u16,
                iter_in: 1,
                dst_factor_out: 1,
                dst_factor_in: 0,
                src_factor_out: 1,
                src_factor_in: 0,
            });
        }
        p.push(Insn::Store {
            dep: dep(true, false, true, false),
            sram_base: 0,
            dram_base: (c * chunk_rows) as u32,
            y_size: chunk_rows as u16,
            x_size: 1,
            x_stride: 1,
        });
    }
    p.push(Insn::Finish { dep: dep(false, true, false, false) });
    p.validate(cfg)?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardProfile, Calibration};
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;
    use crate::vta::fsim::{self, DramImage};
    use crate::vta::timing::TimingModel;

    fn cfg() -> VtaConfig {
        VtaConfig::table1_zynq7000()
    }

    /// Reference GEMM on the padded DRAM layout, with the store's int8
    /// saturation applied. Layout contract (see `GemmShape::blocks`):
    /// `inp` rows are (m, k-block) fragments, `wgt` tiles are (n-block,
    /// k-block), `out` rows are (m, n-block).
    fn ref_gemm(shape: GemmShape, tiling: GemmTiling, cfg: &VtaConfig, dram: &DramImage) -> Vec<i8> {
        let blk = cfg.block as u64;
        let (mr, kb, nb) = shape.blocks(cfg);
        let m_p = mr.div_ceil(tiling.tm) * tiling.tm;
        let kb_p = kb.div_ceil(tiling.tk) * tiling.tk;
        let nb_p = nb.div_ceil(tiling.tn) * tiling.tn;
        let (k, n) = (kb_p * blk, nb_p * blk);
        let mut out = vec![0i8; (m_p * nb_p * blk) as usize];
        for i in 0..m_p {
            for j in 0..n {
                let mut acc: i32 = 0;
                for kk in 0..k {
                    // inp row = i·kb_p + kk/blk, lane kk%blk
                    let row = i * kb_p + kk / blk;
                    let a = dram.inp[(row * blk + (kk % blk)) as usize] as i32;
                    // wgt tile = (j/blk)·kb_p + kk/blk, elem (j%blk, kk%blk)
                    let tile = (j / blk) * kb_p + kk / blk;
                    let w = dram.wgt
                        [(tile * blk * blk + (j % blk) * blk + (kk % blk)) as usize]
                        as i32;
                    acc = acc.wrapping_add(a * w);
                }
                // out row = i·nb_p + j/blk, lane j%blk
                let orow = i * nb_p + j / blk;
                out[(orow * blk + (j % blk)) as usize] = acc.clamp(-128, 127) as i8;
            }
        }
        out
    }

    fn run_case(shape: GemmShape, tiling: GemmTiling, seed: u64) -> Result<(), String> {
        let cfg = cfg();
        let prog = lower_gemm("t", shape, tiling, &cfg).map_err(|e| e.to_string())?;
        let mut rng = Rng::new(seed);
        // small values keep accumulators inside int8 so saturation is rare
        let mut dram = DramImage {
            inp: (0..prog.dram.inp_len).map(|_| rng.range_i64(-4, 5) as i8).collect(),
            wgt: (0..prog.dram.wgt_len).map(|_| rng.range_i64(-4, 5) as i8).collect(),
            acc: vec![],
            out: vec![0; prog.dram.out_len],
        };
        let want = ref_gemm(shape, tiling, &cfg, &dram);
        fsim::run(&cfg, &prog, &mut dram).map_err(|e| e.to_string())?;
        if dram.out != want {
            let idx = dram.out.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!(
                "mismatch at {idx}: got {} want {} (shape {shape:?}, tiling {tiling:?})",
                dram.out[idx], want[idx]
            ));
        }
        Ok(())
    }

    #[test]
    fn lowered_gemm_matches_reference_exact_tiles() {
        run_case(
            GemmShape { m: 64, k: 64, n: 64 },
            GemmTiling { tm: 2, tk: 2, tn: 2 },
            1,
        )
        .unwrap();
    }

    #[test]
    fn lowered_gemm_matches_reference_ragged() {
        // 33×70×25 → blocks 3×5×2, tiling 2×2×2 forces padding everywhere
        run_case(
            GemmShape { m: 33, k: 70, n: 25 },
            GemmTiling { tm: 2, tk: 2, tn: 2 },
            2,
        )
        .unwrap();
    }

    #[test]
    fn lowered_gemm_single_chunk() {
        run_case(
            GemmShape { m: 16, k: 32, n: 16 },
            GemmTiling { tm: 1, tk: 2, tn: 1 },
            3,
        )
        .unwrap();
    }

    #[test]
    fn prop_lowered_gemm_matches_reference() {
        forall("lower_gemm vs reference", 25, |rng| {
            let shape = GemmShape {
                m: rng.range(1, 80) as u64,
                k: rng.range(1, 100) as u64,
                n: rng.range(1, 64) as u64,
            };
            let cands =
                super::super::tiling::candidate_tilings(&cfg(), 6, 7, 4);
            let tiling = *rng.choice(&cands);
            run_case(shape, tiling, rng.next_u64())
        });
    }

    #[test]
    fn traffic_accounting_matches_tiling_model() {
        let cfg = cfg();
        let shape = GemmShape { m: 784, k: 1152, n: 128 };
        let tiling = GemmTiling { tm: 16, tk: 4, tn: 8 };
        let prog = lower_gemm("t", shape, tiling, &cfg).unwrap();
        let (mb, kb, nb) = shape.blocks(&cfg);
        let mb_p = mb.div_ceil(tiling.tm) * tiling.tm;
        let kb_p = kb.div_ceil(tiling.tk) * tiling.tk;
        let nb_p = nb.div_ceil(tiling.tn) * tiling.tn;
        let want = tiling.traffic_bytes(&cfg, mb_p, kb_p, nb_p);
        assert_eq!(prog.dram_traffic_bytes(&cfg), want);
    }

    #[test]
    fn gemm_cycles_match_mac_count() {
        let cfg = cfg();
        let shape = GemmShape { m: 64, k: 64, n: 64 };
        let tiling = GemmTiling { tm: 4, tk: 4, tn: 4 };
        let prog = lower_gemm("t", shape, tiling, &cfg).unwrap();
        // MAC uop-cycles = m·kb·nb (padded, all divisible here);
        // the reset pass adds m·nb more
        let (mr, kb, nb) = shape.blocks(&cfg);
        assert_eq!(prog.gemm_cycles(), mr * kb * nb + mr * nb);
        // one uop-cycle = block² MACs: total ≈ shape.macs()/block²
        assert_eq!(mr * kb * nb, shape.macs() / (cfg.block as u64).pow(2));
    }

    #[test]
    fn pipelining_overlaps_in_timing() {
        let cfg = cfg();
        let model = TimingModel::new(
            cfg.clone(),
            BoardProfile::zynq7020(),
            Calibration { driver_overhead_us: 0.0, ..Default::default() },
        );
        let shape = GemmShape { m: 256, k: 512, n: 128 };
        let tiling = GemmTiling { tm: 8, tk: 4, tn: 8 };
        let prog = lower_gemm("t", shape, tiling, &cfg).unwrap();
        let r = model.price(&prog).unwrap();
        let serial = r.load_busy + r.compute_busy + r.store_busy;
        assert!(
            (r.total_cycles as f64) < 0.8 * serial as f64,
            "overlap too weak: makespan {} vs serial {serial}",
            r.total_cycles
        );
    }

    #[test]
    fn alu_pass_validates_and_prices() {
        let cfg = cfg();
        // requantize sequence: add bias, shr, clip min/max
        let prog = lower_alu_pass(
            "rq",
            200_704,
            &[(AluOp::Add, 1024), (AluOp::Shr, 11), (AluOp::Min, 127), (AluOp::Max, -128)],
            &cfg,
        )
        .unwrap();
        assert!(prog.alu_cycles() > 0);
        let model = TimingModel::new(
            cfg,
            BoardProfile::zynq7020(),
            Calibration { driver_overhead_us: 0.0, ..Default::default() },
        );
        let r = model.price(&prog).unwrap();
        assert!(r.total_cycles > 0);
    }

    #[test]
    fn alu_pass_rejects_empty_ops() {
        assert!(lower_alu_pass("x", 100, &[], &cfg()).is_err());
    }

    #[test]
    fn infeasible_tiling_rejected() {
        let shape = GemmShape { m: 64, k: 64, n: 64 };
        let bad = GemmTiling { tm: 1000, tk: 1, tn: 1 };
        assert!(lower_gemm("t", shape, bad, &cfg()).is_err());
    }
}
