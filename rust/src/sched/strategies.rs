//! The four scheduling strategies of §II-C.
//!
//! Each constructor takes the workload graph, the cluster size, and a
//! per-segment cost oracle (`seg_cost`, typically the calibrated node
//! time from `sim::cost`) and returns a validated [`ExecutionPlan`].

use super::plan::{ExecutionPlan, SplitMode, StagePlan, Strategy};
use crate::graph::partition::{atomic_segments, partition_balanced};
use crate::graph::Graph;
use std::collections::HashMap;

/// §II-C.1 Scatter-Gather: pure data parallelism — whole images are
/// distributed across all nodes and results gathered in order.
pub fn scatter_gather(g: &Graph, n: usize) -> anyhow::Result<ExecutionPlan> {
    anyhow::ensure!(n >= 1, "need at least one node");
    let plan = ExecutionPlan {
        strategy: Strategy::ScatterGather,
        n_nodes: n,
        model: g.model.clone(),
        segment_order: g.segment_order(),
        stages: vec![StagePlan {
            segments: g.segment_order(),
            replicas: (0..n).collect(),
            split: SplitMode::DataParallel,
        }],
    };
    plan.validate()?;
    Ok(plan)
}

/// §II-C.2 AI Core Assignment: segment-granular placement that gives the
/// bottleneck operators the most compute.
///
/// * `n ≥ #segments`: every segment gets its own node; leftover nodes are
///   water-filled onto the segments with the highest per-replica cost and
///   cooperate spatially on each image (the "more consumer nodes for a
///   given task" of the paper).
/// * `n < #segments`: LPT bin-packing of segments onto nodes by cost —
///   deliberately **non-contiguous** (bottleneck first, adjacency
///   ignored), which is what distinguishes it from Pipeline Scheduling
///   and produces the paper's heavy inter-node traffic at small N.
pub fn core_assign<F>(g: &Graph, n: usize, seg_cost: F) -> anyhow::Result<ExecutionPlan>
where
    F: Fn(&str) -> f64,
{
    anyhow::ensure!(n >= 1, "need at least one node");
    if n == 1 {
        // degenerate: one node runs the whole graph as one launch (the
        // paper's N=1 row is identical across strategies)
        let mut plan = scatter_gather(g, 1)?;
        plan.strategy = Strategy::CoreAssign;
        return Ok(plan);
    }
    let atoms = atomic_segments(g);
    let k = atoms.len();
    let costs: Vec<f64> = atoms.iter().map(|a| seg_cost(&a.labels[0])).collect();

    // Replica counts per segment: start at 1, then repeatedly give the
    // current bottleneck segment another consumer node as long as the
    // packed max node load improves — "assigning more compute resources
    // to the bottleneck workload in the computational graph" (§II-C.2).
    // Light segments share nodes (LPT), which is what frees capacity.
    let mut k_s = vec![1usize; k];

    // LPT-pack slices (segment i has k_s[i] slices of cost c_i/k_s[i],
    // on distinct nodes) and return (max load, per-segment node lists).
    let pack = |k_s: &[usize]| -> Option<(f64, Vec<Vec<usize>>)> {
        let mut slices: Vec<(f64, usize)> = Vec::new(); // (cost, segment)
        for (i, &ks) in k_s.iter().enumerate() {
            for _ in 0..ks {
                slices.push((costs[i] / ks as f64, i));
            }
        }
        slices.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut load = vec![0.0f64; n];
        let mut nodes: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (c, seg) in slices {
            // least-loaded node not already hosting a slice of this segment
            let node = (0..n)
                .filter(|nd| !nodes[seg].contains(nd))
                .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())?;
            load[node] += c;
            nodes[seg].push(node);
        }
        let max = load.iter().copied().fold(0.0f64, f64::max);
        Some((max, nodes))
    };

    let (mut best_load, mut best_nodes) =
        pack(&k_s).ok_or_else(|| anyhow::anyhow!("cannot pack segments onto {n} nodes"))?;
    loop {
        // bottleneck segment = the one whose slice cost is largest
        let (bot, _) = k_s
            .iter()
            .enumerate()
            .map(|(i, &ks)| (i, costs[i] / ks as f64))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if k_s[bot] >= n {
            break; // cannot split further than the cluster
        }
        k_s[bot] += 1;
        match pack(&k_s) {
            Some((load, nodes)) if load < best_load - 1e-9 => {
                best_load = load;
                best_nodes = nodes;
            }
            _ => {
                k_s[bot] -= 1;
                break;
            }
        }
    }

    // make sure every node is used (plan invariant): give unused nodes to
    // the bottleneck segment as extra spatial replicas
    loop {
        let mut used = vec![false; n];
        for nodes in &best_nodes {
            for &nd in nodes {
                used[nd] = true;
            }
        }
        let Some(idle) = used.iter().position(|u| !u) else { break };
        let (bot, _) = k_s
            .iter()
            .enumerate()
            .filter(|(i, &ks)| !best_nodes[*i].contains(&idle) && ks < n)
            .map(|(i, &ks)| (i, costs[i] / ks as f64))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .ok_or_else(|| anyhow::anyhow!("cannot place node {idle}"))?;
        k_s[bot] += 1;
        best_nodes[bot].push(idle);
    }

    let stages: Vec<StagePlan> = atoms
        .iter()
        .enumerate()
        .map(|(i, a)| StagePlan {
            segments: a.labels.clone(),
            replicas: best_nodes[i].clone(),
            split: if best_nodes[i].len() > 1 {
                SplitMode::Spatial
            } else {
                SplitMode::DataParallel
            },
        })
        .collect();
    let plan = ExecutionPlan {
        strategy: Strategy::CoreAssign,
        n_nodes: n,
        model: g.model.clone(),
        segment_order: g.segment_order(),
        stages,
    };
    plan.validate()?;
    Ok(plan)
}

/// §II-C.3 Pipeline Scheduling: contiguous stages, one node each,
/// balanced by the cost oracle (exact DP). For `n` beyond the segment
/// count the extra nodes replicate the heaviest stages data-parallel
/// (each stage stays internally sequential, as in the paper).
pub fn pipeline<F>(g: &Graph, n: usize, seg_cost: F) -> anyhow::Result<ExecutionPlan>
where
    F: Fn(&str) -> f64,
{
    anyhow::ensure!(n >= 1, "need at least one node");
    let atoms = atomic_segments(g);
    let depth = n.min(atoms.len());
    let parts = partition_balanced(g, depth, |s| seg_cost(&s.labels[0]))?;
    let mut stages: Vec<StagePlan> = parts
        .into_iter()
        .enumerate()
        .map(|(i, p)| StagePlan {
            segments: p.labels,
            replicas: vec![i],
            split: SplitMode::DataParallel,
        })
        .collect();
    // extra nodes (n > segments): replicate bottleneck stages
    for extra in depth..n {
        let (idx, _) = stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let cost: f64 = st.segments.iter().map(|s| seg_cost(s)).sum();
                (i, cost / st.replicas.len() as f64)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        stages[idx].replicas.push(extra);
    }
    let plan = ExecutionPlan {
        strategy: Strategy::Pipeline,
        n_nodes: n,
        model: g.model.clone(),
        segment_order: g.segment_order(),
        stages,
    };
    plan.validate()?;
    Ok(plan)
}

/// §II-C.4 Fused Schedule: pipeline + core assignment. Searches every
/// pipeline depth `j ≤ n`, assigns the `n − j` leftover nodes to the
/// most loaded stages (spatially, as AI-core does), and keeps the depth
/// with the best predicted throughput `max_s cost(s)/replicas(s)`.
pub fn fused<F>(g: &Graph, n: usize, seg_cost: F) -> anyhow::Result<ExecutionPlan>
where
    F: Fn(&str) -> f64,
{
    anyhow::ensure!(n >= 1, "need at least one node");
    let atoms = atomic_segments(g);
    let max_depth = n.min(atoms.len());
    let mut best: Option<(f64, ExecutionPlan)> = None;

    for depth in 1..=max_depth {
        let parts = partition_balanced(g, depth, |s| seg_cost(&s.labels[0]))?;
        let mut stages: Vec<StagePlan> = parts
            .into_iter()
            .enumerate()
            .map(|(i, p)| StagePlan {
                segments: p.labels,
                replicas: vec![i],
                split: SplitMode::DataParallel,
            })
            .collect();
        for extra in depth..n {
            let (idx, _) = stages
                .iter()
                .enumerate()
                .map(|(i, st)| {
                    let cost: f64 = st.segments.iter().map(|s| seg_cost(s)).sum();
                    (i, cost / st.replicas.len() as f64)
                })
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            stages[idx].replicas.push(extra);
            stages[idx].split = SplitMode::Spatial;
        }
        let bottleneck = stages
            .iter()
            .map(|st| {
                let cost: f64 = st.segments.iter().map(|s| seg_cost(s)).sum();
                cost / st.replicas.len() as f64
            })
            .fold(0.0f64, f64::max);
        let plan = ExecutionPlan {
            strategy: Strategy::Fused,
            n_nodes: n,
            model: g.model.clone(),
            segment_order: g.segment_order(),
            stages,
        };
        plan.validate()?;
        if best.as_ref().map(|(b, _)| bottleneck < *b).unwrap_or(true) {
            best = Some((bottleneck, plan));
        }
    }
    Ok(best.unwrap().1)
}

/// Dispatch by strategy.
pub fn build_plan<F>(
    strategy: Strategy,
    g: &Graph,
    n: usize,
    seg_cost: F,
) -> anyhow::Result<ExecutionPlan>
where
    F: Fn(&str) -> f64,
{
    match strategy {
        Strategy::ScatterGather => scatter_gather(g, n),
        Strategy::CoreAssign => core_assign(g, n, seg_cost),
        Strategy::Pipeline => pipeline(g, n, seg_cost),
        Strategy::Fused => fused(g, n, seg_cost),
        // energy-aware selection needs the power model and the metered
        // simulator, not just a time oracle — route through power::eco
        Strategy::Eco => anyhow::bail!(
            "the eco strategy is built by power::eco_plan (it needs a \
             cluster, a cost model and an optional latency SLO)"
        ),
        // the searched strategy prices its candidates with the metered
        // simulator — route through search::search_plan
        Strategy::Search => anyhow::bail!(
            "the search strategy is built by search::search_plan (it \
             needs a cluster, a cost model and an objective/constraint \
             config, not just a time oracle)"
        ),
    }
}

/// [`build_plan`] over a precomputed `(label, cost)` table (the shape
/// [`crate::sim::CostModel::seg_cost_table`] returns), with the coverage
/// check the bare closure form cannot express: a segment of `g` missing
/// from the table is a reported error, not an `unwrap` panic inside the
/// oracle. Every CLI/scenario path prices plans through here.
pub fn build_plan_priced(
    strategy: Strategy,
    g: &Graph,
    n: usize,
    table: &[(String, f64)],
) -> anyhow::Result<ExecutionPlan> {
    let map: HashMap<&str, f64> =
        table.iter().map(|(l, c)| (l.as_str(), *c)).collect();
    let missing: Vec<String> = g
        .segment_order()
        .into_iter()
        .filter(|l| !map.contains_key(l.as_str()))
        .collect();
    anyhow::ensure!(
        missing.is_empty(),
        "cost table for model '{}' is missing segment(s) {missing:?} (has {:?})",
        g.model,
        table.iter().map(|(l, _)| l.as_str()).collect::<Vec<_>>()
    );
    // the planners only query labels from `g.segment_order()`, all of
    // which the check above guarantees are present
    build_plan(strategy, g, n, |l| map.get(l).copied().unwrap_or(f64::INFINITY))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::atomic_segments;
    use crate::graph::resnet::build_resnet18;
    use crate::util::proptest::forall;

    fn g() -> Graph {
        build_resnet18(224).unwrap()
    }

    /// MAC-proportional cost oracle for tests.
    fn mac_cost(g: &Graph) -> impl Fn(&str) -> f64 + '_ {
        move |label: &str| {
            atomic_segments(g)
                .iter()
                .find(|a| a.labels[0] == label)
                .map(|a| a.macs as f64)
                .unwrap()
        }
    }

    #[test]
    fn all_strategies_validate_across_cluster_sizes() {
        let g = g();
        let cost = mac_cost(&g);
        for n in 1..=12 {
            for s in Strategy::all() {
                let plan = build_plan(s, &g, n, &cost).unwrap();
                plan.validate().unwrap();
                assert_eq!(plan.n_nodes, n, "{s} n={n}");
            }
        }
    }

    #[test]
    fn scatter_gather_is_single_stage() {
        let g = g();
        let p = scatter_gather(&g, 8).unwrap();
        assert_eq!(p.stages.len(), 1);
        assert_eq!(p.stages[0].replicas.len(), 8);
    }

    #[test]
    fn pipeline_depth_tracks_n() {
        let g = g();
        let cost = mac_cost(&g);
        for n in 1..=10 {
            let p = pipeline(&g, n, &cost).unwrap();
            assert_eq!(p.stages.len(), n);
            assert!(p.stages.iter().all(|s| s.replicas.len() == 1));
        }
        // n=12: 10 stages + 2 replicas on bottlenecks
        let p = pipeline(&g, 12, &cost).unwrap();
        assert_eq!(p.stages.len(), 10);
        assert_eq!(p.total_assignments(), 12);
    }

    #[test]
    fn core_assign_small_n_is_noncontiguous_packing() {
        let g = g();
        let cost = mac_cost(&g);
        let p = core_assign(&g, 2, &cost).unwrap();
        assert_eq!(p.stages.len(), 10);
        // both nodes used; at least one boundary crosses nodes (the
        // non-contiguity that drives the paper's N=2 network penalty)
        let seq: Vec<Vec<usize>> =
            p.stages.iter().map(|s| s.replicas.clone()).collect();
        let crossings = seq.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(crossings >= 1, "expected inter-node boundaries, got {seq:?}");
        // per-node compute load balanced within 30% (slices counted)
        let mut load = [0.0f64; 2];
        for st in &p.stages {
            let share = cost(&st.segments[0]) / st.replicas.len() as f64;
            for &r in &st.replicas {
                load[r] += share;
            }
        }
        let ratio = load[0].max(load[1]) / load[0].min(load[1]);
        assert!(ratio < 1.3, "unbalanced packing: {load:?}");
    }

    #[test]
    fn core_assign_large_n_replicates_bottlenecks() {
        let g = g();
        let cost = mac_cost(&g);
        let p = core_assign(&g, 12, &cost).unwrap();
        assert_eq!(p.stages.len(), 10);
        assert_eq!(p.total_assignments(), 12);
        let spatial: Vec<&StagePlan> =
            p.stages.iter().filter(|s| s.split == SplitMode::Spatial).collect();
        assert_eq!(spatial.len(), 2, "two extra nodes → two spatial stages");
        // the replicated stages must be the two most expensive segments
        let mut costs: Vec<f64> = p.stages.iter().map(|s| cost(&s.segments[0])).collect();
        costs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for st in spatial {
            assert!(cost(&st.segments[0]) >= costs[2]);
        }
    }

    #[test]
    fn fused_beats_or_matches_pipeline_bottleneck() {
        let g = g();
        let cost = mac_cost(&g);
        for n in 2..=12 {
            let f = fused(&g, n, &cost).unwrap();
            let p = pipeline(&g, n, &cost).unwrap();
            let bottleneck = |plan: &ExecutionPlan| {
                plan.stages
                    .iter()
                    .map(|st| {
                        st.segments.iter().map(|s| cost(s)).sum::<f64>()
                            / st.replicas.len() as f64
                    })
                    .fold(0.0f64, f64::max)
            };
            assert!(
                bottleneck(&f) <= bottleneck(&p) * 1.0001,
                "n={n}: fused {} > pipeline {}",
                bottleneck(&f),
                bottleneck(&p)
            );
        }
    }

    #[test]
    fn priced_build_reports_missing_segments_instead_of_panicking() {
        let g = g();
        // full table → same plan as the closure form
        let table: Vec<(String, f64)> = atomic_segments(&g)
            .iter()
            .map(|a| (a.labels[0].clone(), a.macs as f64))
            .collect();
        let p = build_plan_priced(Strategy::Pipeline, &g, 4, &table).unwrap();
        let q = build_plan(Strategy::Pipeline, &g, 4, mac_cost(&g)).unwrap();
        assert_eq!(p, q);
        // a table with a typo'd label errors, naming the missing segment
        let mut bad = table.clone();
        bad[0].0 = "stemm".into();
        let e = build_plan_priced(Strategy::Pipeline, &g, 4, &bad)
            .unwrap_err()
            .to_string();
        assert!(e.contains("stem"), "{e}");
        assert!(e.contains("resnet18"), "{e}");
    }

    #[test]
    fn eco_needs_the_power_path() {
        let g = g();
        let e = build_plan(Strategy::Eco, &g, 2, |_| 1.0).unwrap_err().to_string();
        assert!(e.contains("eco_plan"), "{e}");
    }

    #[test]
    fn search_needs_the_engine_path() {
        let g = g();
        let e = build_plan(Strategy::Search, &g, 2, |_| 1.0).unwrap_err().to_string();
        assert!(e.contains("search_plan"), "{e}");
    }

    #[test]
    fn n1_plans_all_collapse_to_single_node() {
        let g = g();
        let cost = mac_cost(&g);
        for s in Strategy::all() {
            let p = build_plan(s, &g, 1, &cost).unwrap();
            assert!(p.stages.iter().all(|st| st.replicas == vec![0]), "{s}");
        }
    }

    #[test]
    fn prop_plans_valid_for_random_costs() {
        let g = g();
        forall("random-cost plans validate", 40, |rng| {
            let costs: Vec<f64> =
                (0..10).map(|_| 1.0 + rng.f64() * 100.0).collect();
            let labels = g.segment_order();
            let cost = |l: &str| {
                let i = labels.iter().position(|x| x == l).unwrap();
                costs[i]
            };
            let n = rng.range(1, 13);
            for s in Strategy::all() {
                let plan = build_plan(s, &g, n, cost).map_err(|e| e.to_string())?;
                plan.validate().map_err(|e| format!("{s} n={n}: {e}"))?;
            }
            Ok(())
        });
    }
}
