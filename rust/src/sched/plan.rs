//! Execution plans: what runs where.
//!
//! A plan is an ordered list of **stages**; each stage owns a contiguous
//! run of graph segments and a set of replica nodes. The split mode says
//! how replicas share work:
//!
//! * `DataParallel` — whole images round-robin across replicas
//!   (scatter-gather within a stage),
//! * `Spatial` — each image's activations are split row-wise across all
//!   replicas, which cooperate on every image (AI-core assignment of
//!   extra compute to one operator).

use crate::graph::resnet::SEGMENT_NAMES;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    ScatterGather,
    CoreAssign,
    Pipeline,
    Fused,
}

impl Strategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::ScatterGather => "scatter-gather",
            Strategy::CoreAssign => "ai-core-assignment",
            Strategy::Pipeline => "pipeline",
            Strategy::Fused => "fused",
        }
    }

    pub fn all() -> [Strategy; 4] {
        [Strategy::ScatterGather, Strategy::CoreAssign, Strategy::Pipeline, Strategy::Fused]
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scatter-gather" | "sg" | "scatter_gather" => Ok(Strategy::ScatterGather),
            "ai-core-assignment" | "core-assign" | "ai" | "core_assign" => {
                Ok(Strategy::CoreAssign)
            }
            "pipeline" | "pipe" => Ok(Strategy::Pipeline),
            "fused" => Ok(Strategy::Fused),
            other => anyhow::bail!("unknown strategy '{other}'"),
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitMode {
    DataParallel,
    Spatial,
}

/// One pipeline stage of a plan.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    /// Contiguous segment labels executed by this stage, in graph order.
    pub segments: Vec<String>,
    /// Nodes executing this stage (≥ 1). May overlap with other stages
    /// (AI-core assignment packs multiple segments per node at small N).
    pub replicas: Vec<usize>,
    pub split: SplitMode,
}

/// A complete schedule of the ResNet graph over the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub strategy: Strategy,
    pub n_nodes: usize,
    pub stages: Vec<StagePlan>,
}

impl ExecutionPlan {
    /// Invariants every strategy must satisfy (property-tested):
    /// 1. stages cover all 10 segments exactly once, in order;
    /// 2. every referenced node id is `< n_nodes`;
    /// 3. every node id is referenced by at least one stage (no idle
    ///    hardware — the paper always uses the whole cluster);
    /// 4. every stage has ≥ 1 replica; spatial stages have ≥ 2.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.stages.is_empty(), "plan has no stages");
        let covered: Vec<&str> = self
            .stages
            .iter()
            .flat_map(|s| s.segments.iter().map(|x| x.as_str()))
            .collect();
        let want: Vec<&str> = SEGMENT_NAMES.to_vec();
        anyhow::ensure!(
            covered == want,
            "stages cover {covered:?}, want {want:?} (contiguous, in order)"
        );
        let mut seen = vec![false; self.n_nodes];
        for (i, st) in self.stages.iter().enumerate() {
            anyhow::ensure!(!st.replicas.is_empty(), "stage {i} has no replicas");
            if st.split == SplitMode::Spatial {
                anyhow::ensure!(
                    st.replicas.len() >= 2,
                    "stage {i} is Spatial with a single replica"
                );
            }
            let mut uniq = st.replicas.clone();
            uniq.sort_unstable();
            uniq.dedup();
            anyhow::ensure!(
                uniq.len() == st.replicas.len(),
                "stage {i} lists a replica twice"
            );
            for &r in &st.replicas {
                anyhow::ensure!(r < self.n_nodes, "stage {i} references node {r} ≥ {}", self.n_nodes);
                seen[r] = true;
            }
        }
        for (n, s) in seen.iter().enumerate() {
            anyhow::ensure!(*s, "node {n} is never used by the plan");
        }
        Ok(())
    }

    /// Total replica slots (for reporting).
    pub fn total_assignments(&self) -> usize {
        self.stages.iter().map(|s| s.replicas.len()).sum()
    }

    /// Human-readable summary for logs and benches.
    pub fn describe(&self) -> String {
        let mut s = format!("{} over {} nodes:\n", self.strategy, self.n_nodes);
        for (i, st) in self.stages.iter().enumerate() {
            s.push_str(&format!(
                "  stage {i}: [{}] on nodes {:?} ({:?})\n",
                st.segments.join(","),
                st.replicas,
                st.split
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    fn whole_graph_stage(replicas: Vec<usize>) -> StagePlan {
        StagePlan {
            segments: seg(&SEGMENT_NAMES),
            replicas,
            split: SplitMode::DataParallel,
        }
    }

    #[test]
    fn valid_single_stage_plan() {
        let p = ExecutionPlan {
            strategy: Strategy::ScatterGather,
            n_nodes: 4,
            stages: vec![whole_graph_stage(vec![0, 1, 2, 3])],
        };
        p.validate().unwrap();
        assert_eq!(p.total_assignments(), 4);
    }

    #[test]
    fn rejects_gap_in_coverage() {
        let p = ExecutionPlan {
            strategy: Strategy::Pipeline,
            n_nodes: 2,
            stages: vec![
                StagePlan {
                    segments: seg(&["stem", "s1b1"]),
                    replicas: vec![0],
                    split: SplitMode::DataParallel,
                },
                StagePlan {
                    // skips s1b2
                    segments: seg(&["s2b1", "s2b2", "s3b1", "s3b2", "s4b1", "s4b2", "head"]),
                    replicas: vec![1],
                    split: SplitMode::DataParallel,
                },
            ],
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_idle_node() {
        let p = ExecutionPlan {
            strategy: Strategy::ScatterGather,
            n_nodes: 3,
            stages: vec![whole_graph_stage(vec![0, 1])],
        };
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("never used"), "{e}");
    }

    #[test]
    fn rejects_out_of_range_node() {
        let p = ExecutionPlan {
            strategy: Strategy::ScatterGather,
            n_nodes: 2,
            stages: vec![whole_graph_stage(vec![0, 2])],
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_single_replica_spatial() {
        let mut st = whole_graph_stage(vec![0]);
        st.split = SplitMode::Spatial;
        let p = ExecutionPlan { strategy: Strategy::CoreAssign, n_nodes: 1, stages: vec![st] };
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_replica() {
        let p = ExecutionPlan {
            strategy: Strategy::ScatterGather,
            n_nodes: 2,
            stages: vec![whole_graph_stage(vec![0, 0, 1])],
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::all() {
            assert_eq!(Strategy::parse(s.as_str()).unwrap(), s);
        }
        assert!(Strategy::parse("bogus").is_err());
    }
}
