//! Execution plans: what runs where.
//!
//! A plan is an ordered list of **stages**; each stage owns a contiguous
//! run of graph segments and a set of replica nodes. The split mode says
//! how replicas share work:
//!
//! * `DataParallel` — whole images round-robin across replicas
//!   (scatter-gather within a stage),
//! * `Spatial` — each image's activations are split row-wise across all
//!   replicas, which cooperate on every image (AI-core assignment of
//!   extra compute to one operator).

use crate::graph::Graph;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    ScatterGather,
    CoreAssign,
    Pipeline,
    Fused,
    /// The fifth, power-aware strategy (DESIGN.md §11): pick the
    /// schedule minimizing J/image subject to a latency SLO. Built by
    /// [`crate::power::eco_plan`] (it needs the power model and the
    /// metered simulator, not just a time oracle), so it is not part of
    /// [`Strategy::all`] — that array stays the paper's §II-C four.
    Eco,
    /// The sixth strategy (DESIGN.md §17): DP/beam search over the whole
    /// contiguous-partition space (stage boundaries × per-stage node
    /// counts × split modes) instead of a hand-picked heuristic slice.
    /// Built by [`crate::search::search_plan`] (it needs the memoized
    /// cost table, the metered simulator and the objective/constraint
    /// plumbing), so — like [`Strategy::Eco`] — it is not part of
    /// [`Strategy::all`]: the searched plan is *priced against* those
    /// four, which is what makes its dominance guarantee checkable.
    Search,
}

impl Strategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::ScatterGather => "scatter-gather",
            Strategy::CoreAssign => "ai-core-assignment",
            Strategy::Pipeline => "pipeline",
            Strategy::Fused => "fused",
            Strategy::Eco => "eco",
            Strategy::Search => "search",
        }
    }

    /// The paper's four §II-C strategies (the planner candidate set;
    /// [`Strategy::Eco`] selects *among* these, so it is excluded).
    pub fn all() -> [Strategy; 4] {
        [Strategy::ScatterGather, Strategy::CoreAssign, Strategy::Pipeline, Strategy::Fused]
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scatter-gather" | "sg" | "scatter_gather" => Ok(Strategy::ScatterGather),
            "ai-core-assignment" | "core-assign" | "ai" | "core_assign" => {
                Ok(Strategy::CoreAssign)
            }
            "pipeline" | "pipe" => Ok(Strategy::Pipeline),
            "fused" => Ok(Strategy::Fused),
            "eco" | "eco-slo" | "power" => Ok(Strategy::Eco),
            "search" | "dp-search" | "plan-search" => Ok(Strategy::Search),
            other => anyhow::bail!("unknown strategy '{other}'"),
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitMode {
    DataParallel,
    Spatial,
}

/// One pipeline stage of a plan.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    /// Contiguous segment labels executed by this stage, in graph order.
    pub segments: Vec<String>,
    /// Nodes executing this stage (≥ 1). May overlap with other stages
    /// (AI-core assignment packs multiple segments per node at small N).
    pub replicas: Vec<usize>,
    pub split: SplitMode,
}

/// A complete schedule of one model's graph over the cluster.
///
/// The plan records which model it schedules ([`ExecutionPlan::model`])
/// and the graph's full segment order at planning time — validation is
/// against *that* set, so any registered workload (see
/// [`crate::graph::zoo`]) gets the same invariants ResNet-18 always had.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub strategy: Strategy,
    pub n_nodes: usize,
    /// Registry name of the scheduled model (== `Graph::model`).
    pub model: String,
    /// The graph's segment labels in graph order, captured when the plan
    /// was built; the coverage invariant is checked against this.
    pub segment_order: Vec<String>,
    pub stages: Vec<StagePlan>,
}

impl ExecutionPlan {
    /// Invariants every strategy must satisfy (property-tested):
    /// 1. stages cover every segment of [`ExecutionPlan::segment_order`]
    ///    exactly once, in order;
    /// 2. every referenced node id is `< n_nodes`;
    /// 3. every node id is referenced by at least one stage (no idle
    ///    hardware — the paper always uses the whole cluster);
    /// 4. every stage has ≥ 1 replica; spatial stages have ≥ 2.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.stages.is_empty(), "plan has no stages");
        anyhow::ensure!(!self.segment_order.is_empty(), "plan has no segment order");
        let covered: Vec<&str> = self
            .stages
            .iter()
            .flat_map(|s| s.segments.iter().map(|x| x.as_str()))
            .collect();
        let want: Vec<&str> = self.segment_order.iter().map(String::as_str).collect();
        anyhow::ensure!(
            covered == want,
            "stages cover {covered:?}, want {want:?} (contiguous, in order)"
        );
        let mut seen = vec![false; self.n_nodes];
        for (i, st) in self.stages.iter().enumerate() {
            anyhow::ensure!(!st.replicas.is_empty(), "stage {i} has no replicas");
            if st.split == SplitMode::Spatial {
                anyhow::ensure!(
                    st.replicas.len() >= 2,
                    "stage {i} is Spatial with a single replica"
                );
            }
            let mut uniq = st.replicas.clone();
            uniq.sort_unstable();
            uniq.dedup();
            anyhow::ensure!(
                uniq.len() == st.replicas.len(),
                "stage {i} lists a replica twice"
            );
            for &r in &st.replicas {
                anyhow::ensure!(r < self.n_nodes, "stage {i} references node {r} ≥ {}", self.n_nodes);
                seen[r] = true;
            }
        }
        for (n, s) in seen.iter().enumerate() {
            anyhow::ensure!(*s, "node {n} is never used by the plan");
        }
        Ok(())
    }

    /// [`ExecutionPlan::validate`] plus the cross-check that this plan
    /// was built for `g`'s segment set — the guard the simulator and the
    /// coordinator use so a plan can never be applied to a different
    /// model's graph.
    pub fn validate_for(&self, g: &Graph) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.model == g.model,
            "plan is for model '{}', graph is '{}'",
            self.model,
            g.model
        );
        let want = g.segment_order();
        anyhow::ensure!(
            self.segment_order == want,
            "plan segment order {:?} != graph's {:?}",
            self.segment_order,
            want
        );
        self.validate()
    }

    /// Total replica slots (for reporting).
    pub fn total_assignments(&self) -> usize {
        self.stages.iter().map(|s| s.replicas.len()).sum()
    }

    /// Human-readable summary for logs and benches.
    pub fn describe(&self) -> String {
        let mut s =
            format!("{} of {} over {} nodes:\n", self.strategy, self.model, self.n_nodes);
        for (i, st) in self.stages.iter().enumerate() {
            s.push_str(&format!(
                "  stage {i}: [{}] on nodes {:?} ({:?})\n",
                st.segments.join(","),
                st.replicas,
                st.split
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Segment labels of the test model (same shape as ResNet-18's, but
    /// the plan layer no longer knows or cares about any one model).
    const SEGS: [&str; 10] =
        ["stem", "s1b1", "s1b2", "s2b1", "s2b2", "s3b1", "s3b2", "s4b1", "s4b2", "head"];

    fn seg(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    fn plan(n_nodes: usize, stages: Vec<StagePlan>) -> ExecutionPlan {
        ExecutionPlan {
            strategy: Strategy::ScatterGather,
            n_nodes,
            model: "testmodel".to_string(),
            segment_order: seg(&SEGS),
            stages,
        }
    }

    fn whole_graph_stage(replicas: Vec<usize>) -> StagePlan {
        StagePlan {
            segments: seg(&SEGS),
            replicas,
            split: SplitMode::DataParallel,
        }
    }

    #[test]
    fn valid_single_stage_plan() {
        let p = plan(4, vec![whole_graph_stage(vec![0, 1, 2, 3])]);
        p.validate().unwrap();
        assert_eq!(p.total_assignments(), 4);
    }

    #[test]
    fn rejects_gap_in_coverage() {
        let p = plan(
            2,
            vec![
                StagePlan {
                    segments: seg(&["stem", "s1b1"]),
                    replicas: vec![0],
                    split: SplitMode::DataParallel,
                },
                StagePlan {
                    // skips s1b2
                    segments: seg(&["s2b1", "s2b2", "s3b1", "s3b2", "s4b1", "s4b2", "head"]),
                    replicas: vec![1],
                    split: SplitMode::DataParallel,
                },
            ],
        );
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_idle_node() {
        let p = plan(3, vec![whole_graph_stage(vec![0, 1])]);
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("never used"), "{e}");
    }

    #[test]
    fn rejects_out_of_range_node() {
        let p = plan(2, vec![whole_graph_stage(vec![0, 2])]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_single_replica_spatial() {
        let mut st = whole_graph_stage(vec![0]);
        st.split = SplitMode::Spatial;
        let p = plan(1, vec![st]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_replica() {
        let p = plan(2, vec![whole_graph_stage(vec![0, 0, 1])]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_for_rejects_other_models_graph() {
        use crate::graph::zoo;
        let g = zoo::build("lenet5", 0).unwrap();
        // a plan built against the lenet graph validates for it …
        let p = ExecutionPlan {
            strategy: Strategy::ScatterGather,
            n_nodes: 1,
            model: g.model.clone(),
            segment_order: g.segment_order(),
            stages: vec![StagePlan {
                segments: g.segment_order(),
                replicas: vec![0],
                split: SplitMode::DataParallel,
            }],
        };
        p.validate_for(&g).unwrap();
        // … but not for a different model
        let other = zoo::build("resnet18", 32).unwrap();
        assert!(p.validate_for(&other).is_err());
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::all() {
            assert_eq!(Strategy::parse(s.as_str()).unwrap(), s);
        }
        // the fifth, power-aware strategy parses but stays out of all()
        assert_eq!(Strategy::parse("eco").unwrap(), Strategy::Eco);
        assert_eq!(Strategy::parse(Strategy::Eco.as_str()).unwrap(), Strategy::Eco);
        assert!(!Strategy::all().contains(&Strategy::Eco));
        // … and so does the sixth, searched strategy (DESIGN.md §17)
        assert_eq!(Strategy::parse("search").unwrap(), Strategy::Search);
        assert_eq!(Strategy::parse(Strategy::Search.as_str()).unwrap(), Strategy::Search);
        assert!(!Strategy::all().contains(&Strategy::Search));
        assert!(Strategy::parse("bogus").is_err());
    }
}
