//! Online reconfiguration controller (DESIGN.md §10).
//!
//! The paper's cluster is *reconfigurable*: when the offered load
//! changes, the operator can "manually allocate greater resources to
//! the most computationally intensive layers" by reprogramming the
//! boards with a different schedule. This module automates that call:
//! the controller watches the load signals the discrete-event simulator
//! ([`crate::sim::des`]) emits at every control epoch, compares the
//! active [`ExecutionPlan`] against the other pre-planned candidates,
//! and decides *when a switch is worth its downtime*.
//!
//! The decision is a drain-time break-even, not a threshold race.
//! With smoothed arrival rate λ̂ (img/s), backlog B (images), current
//! capacity μ_cur, best candidate capacity μ_best and reconfiguration
//! downtime D (s, during which λ̂·D more images arrive):
//!
//! ```text
//!   T_stay   = B / (μ_cur − λ̂)                    (∞ if λ̂ ≥ μ_cur)
//!   T_switch = D + (B + λ̂·D) / (μ_best − λ̂)      (∞ if λ̂ ≥ μ_best)
//!   switch  ⇔  T_switch < T_stay
//! ```
//!
//! plus hysteresis (a minimum dwell between switches and a minimum
//! capacity gain) so the controller cannot flap. Under sustained low
//! load it instead picks the lowest-*latency* candidate with enough
//! headroom — the paper's latency/throughput trade made continuous.
//!
//! With a power budget set ([`ControllerConfig::power_budget_w`],
//! DESIGN.md §11) the controller also watches the DES-measured cluster
//! draw: when its EMA exceeds the budget it downshifts to the candidate
//! with the lowest saturated draw, and the throughput branches never
//! activate a plan whose saturated draw exceeds the budget — watts are
//! a hard constraint, latency only a preference.

use crate::config::{ClusterConfig, ReconfigCost};
use crate::graph::Graph;
use crate::sched::{build_plan_priced, ExecutionPlan, Strategy};
use crate::sim::cluster::simulate;
use crate::sim::{CostModel, SimConfig};
use crate::telemetry::{AuditLog, AuditRecord, AuditVerdict};

/// One pre-planned candidate the controller can activate: the plan plus
/// its analytically priced steady-state capacity and unloaded latency
/// (from [`crate::sim::cluster`] — the same model the DES is
/// cross-validated against).
#[derive(Debug, Clone)]
pub struct PlanOption {
    pub plan: ExecutionPlan,
    /// Logical-replica → physical-node map for failover candidates built
    /// over a survivor subset (DESIGN.md §14). `None` = identity: the
    /// plan spans the whole cluster. When `Some(m)`, the plan's replica
    /// id `r` executes on physical node `m[r]`, so the plan invariant
    /// "every node is used" holds on the logical view while the excluded
    /// (dead) physical node idles.
    pub node_map: Option<Vec<usize>>,
    /// Steady-state service capacity, images/s (= 1000 / ms_per_image).
    pub capacity_img_per_sec: f64,
    /// Unloaded single-image latency, ms.
    pub latency_ms: f64,
    /// Steady-state cluster draw at saturation, W (from the metered
    /// analytic simulator) — what the `--power-budget` cap compares
    /// candidates by.
    pub avg_power_w: f64,
    /// Energy per inference at saturation, J.
    pub j_per_image: f64,
}

impl PlanOption {
    /// Physical node executing logical replica `r`.
    pub fn physical(&self, r: usize) -> usize {
        match &self.node_map {
            Some(m) => m[r],
            None => r,
        }
    }

    /// All physical nodes this option occupies (deduplicated).
    pub fn physical_nodes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .plan
            .stages
            .iter()
            .flat_map(|s| s.replicas.iter().map(|&r| self.physical(r)))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Does the option occupy physical node `node`?
    pub fn uses_node(&self, node: usize) -> bool {
        self.plan
            .stages
            .iter()
            .flat_map(|s| s.replicas.iter())
            .any(|&r| self.physical(r) == node)
    }

    /// True when no physical node of this option is marked down. An
    /// empty mask means "all healthy" (the fault-free DES passes that,
    /// so fault-free decisions are bit-identical to the pre-chaos code).
    pub fn healthy(&self, down: &[bool]) -> bool {
        down.is_empty() || !self.physical_nodes().iter().any(|&p| down.get(p) == Some(&true))
    }

    /// Capacity derated by the worst straggler among the option's
    /// physical nodes: a persistent k× slowdown on any replica bounds
    /// the whole plan's service rate (the straggler sits on every
    /// image's path for spatial/pipeline stages and on 1/R of them for
    /// data-parallel — the max is the conservative bound the controller
    /// plans with). Empty factors = nominal.
    pub fn effective_capacity(&self, slow: &[f64]) -> f64 {
        if slow.is_empty() {
            return self.capacity_img_per_sec;
        }
        let worst = self
            .physical_nodes()
            .iter()
            .map(|&p| slow.get(p).copied().unwrap_or(1.0))
            .fold(1.0f64, f64::max);
        self.capacity_img_per_sec / worst
    }
}

/// Build and price one candidate per strategy for `g` over `cluster`.
/// Every returned plan has passed [`ExecutionPlan::validate_for`].
pub fn plan_options(
    g: &Graph,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
    strategies: &[Strategy],
) -> anyhow::Result<Vec<PlanOption>> {
    anyhow::ensure!(!strategies.is_empty(), "no candidate strategies");
    let n = cluster.num_nodes();
    let seg_costs = cost.seg_cost_table(g)?;
    let mut out = Vec::with_capacity(strategies.len());
    for &s in strategies {
        // the searched strategy prices itself with the same metered
        // simulator (DESIGN.md §17), so its option slots straight into
        // the candidate set the controller compares
        if s == Strategy::Search {
            let scfg = crate::search::SearchConfig {
                objective: crate::search::Objective::Throughput,
                ..Default::default()
            };
            let found = crate::search::search_plan(g, cluster, cost, &scfg)?;
            out.push(PlanOption {
                plan: found.plan,
                node_map: None,
                capacity_img_per_sec: 1e3 / found.ms_per_image,
                latency_ms: found.latency_ms,
                avg_power_w: found.cluster_w,
                j_per_image: found.j_per_image,
            });
            continue;
        }
        let plan = build_plan_priced(s, g, n, &seg_costs)?;
        let sim = simulate(&plan, cluster, cost, g, &SimConfig { images: 16 })?;
        out.push(PlanOption {
            plan,
            node_map: None,
            capacity_img_per_sec: 1e3 / sim.ms_per_image,
            latency_ms: sim.latency_ms.mean(),
            avg_power_w: sim.power.cluster_avg_w,
            j_per_image: sim.power.j_per_image,
        });
    }
    Ok(out)
}

/// Failover re-planning (DESIGN.md §14): build and price candidates over
/// every node *except* `exclude`, pinned back to the surviving physical
/// ids via [`PlanOption::node_map`]. Planning and pricing run on a
/// same-shape sub-cluster of the survivors, so each candidate's capacity
/// is what the degraded cluster can actually deliver. Strategies that
/// cannot be built at the reduced node count are skipped; the result may
/// be empty (e.g. a 1-node cluster has nothing to fail over to).
pub fn survivor_options(
    g: &Graph,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
    strategies: &[Strategy],
    exclude: usize,
) -> anyhow::Result<Vec<PlanOption>> {
    let n = cluster.num_nodes();
    anyhow::ensure!(exclude < n, "excluded node {exclude} ≥ cluster size {n}");
    if n < 2 {
        return Ok(Vec::new());
    }
    let survivors: Vec<usize> = (0..n).filter(|&i| i != exclude).collect();
    let mut sub = cluster.clone();
    sub.boards.truncate(survivors.len());
    let mut out = Vec::new();
    for &s in strategies {
        let Ok(mut opts) = plan_options(g, &sub, cost, &[s]) else { continue };
        for o in &mut opts {
            o.node_map = Some(survivors.clone());
        }
        out.append(&mut opts);
    }
    Ok(out)
}

/// Check a candidate set against the graph and cluster it will serve —
/// the guard the DES runs before any option can ever be activated.
pub fn validate_options(
    options: &[PlanOption],
    g: &Graph,
    n_nodes: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(!options.is_empty(), "no plan options");
    for (i, o) in options.iter().enumerate() {
        o.plan
            .validate_for(g)
            .map_err(|e| anyhow::anyhow!("option {i} ({}): {e}", o.plan.strategy))?;
        match &o.node_map {
            None => anyhow::ensure!(
                o.plan.n_nodes == n_nodes,
                "option {i} plans {} nodes, cluster has {n_nodes}",
                o.plan.n_nodes
            ),
            Some(m) => {
                anyhow::ensure!(
                    m.len() == o.plan.n_nodes,
                    "option {i} maps {} replicas, plan has {}",
                    m.len(),
                    o.plan.n_nodes
                );
                let mut uniq = m.clone();
                uniq.sort_unstable();
                uniq.dedup();
                anyhow::ensure!(
                    uniq.len() == m.len() && m.iter().all(|&p| p < n_nodes),
                    "option {i} node map {m:?} is not an injection into 0..{n_nodes}"
                );
            }
        }
        anyhow::ensure!(
            o.capacity_img_per_sec.is_finite() && o.capacity_img_per_sec > 0.0,
            "option {i} has non-positive capacity"
        );
        anyhow::ensure!(
            o.avg_power_w.is_finite() && o.avg_power_w > 0.0,
            "option {i} has non-positive power"
        );
    }
    Ok(())
}

/// Controller policy knobs (hysteresis + thresholds). The consultation
/// cadence itself is the simulator's (`DesConfig::sample_every_ms`).
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// λ̂/μ_cur above which the upgrade path is considered.
    pub overload_util: f64,
    /// λ̂/μ_cur below which the latency-oriented downshift is considered.
    pub underload_util: f64,
    /// Backlog (expressed as ms of work at current capacity) that also
    /// triggers the upgrade path even if λ̂ looks acceptable.
    pub backlog_high_ms: f64,
    /// Downshift only when the backlog is at most this much work (ms).
    pub backlog_low_ms: f64,
    /// Required capacity gain for an upgrade (μ_best ≥ gain · μ_cur).
    pub min_capacity_gain: f64,
    /// Required latency gain for a downshift (L_best ≤ gain · L_cur).
    pub max_latency_ratio: f64,
    /// Minimum time between reconfigurations, ms (no flapping).
    pub dwell_ms: f64,
    /// EMA weight of the newest window's arrival rate, in (0, 1].
    pub rate_ema_alpha: f64,
    /// Cluster power budget, W. `Some(b)`: when the smoothed measured
    /// draw exceeds `b`, downshift to the candidate with the lowest
    /// saturated draw, and never upgrade to a plan whose saturated draw
    /// exceeds `b`. `None`: power is unconstrained (the pre-§11
    /// behavior).
    pub power_budget_w: Option<f64>,
    /// EMA weight of the newest window's measured draw, in (0, 1].
    pub power_ema_alpha: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            overload_util: 0.85,
            underload_util: 0.45,
            backlog_high_ms: 250.0,
            backlog_low_ms: 50.0,
            min_capacity_gain: 1.1,
            max_latency_ratio: 0.9,
            dwell_ms: 1000.0,
            rate_ema_alpha: 0.5,
            power_budget_w: None,
            power_ema_alpha: 0.5,
        }
    }
}

impl ControllerConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.underload_util < self.overload_util,
            "underload_util must be below overload_util"
        );
        anyhow::ensure!(self.min_capacity_gain >= 1.0, "min_capacity_gain < 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.max_latency_ratio),
            "max_latency_ratio out of range"
        );
        anyhow::ensure!(self.dwell_ms >= 0.0, "negative dwell");
        anyhow::ensure!(
            self.rate_ema_alpha > 0.0 && self.rate_ema_alpha <= 1.0,
            "rate_ema_alpha out of range"
        );
        if let Some(b) = self.power_budget_w {
            anyhow::ensure!(b.is_finite() && b > 0.0, "power budget must be > 0 W");
        }
        anyhow::ensure!(
            self.power_ema_alpha > 0.0 && self.power_ema_alpha <= 1.0,
            "power_ema_alpha out of range"
        );
        Ok(())
    }
}

/// One load sample the DES hands the controller at a control epoch.
/// Backlog + smoothed arrivals are the policy inputs; service rate is
/// taken from the candidates' analytic capacities, not measured.
#[derive(Debug, Clone)]
pub struct Observation {
    pub now_ms: f64,
    /// Width of the window the arrival count covers, ms.
    pub window_ms: f64,
    pub arrivals_in_window: u64,
    /// Images admitted but not yet completed (cluster-wide backlog).
    pub backlog: usize,
    /// Index of the currently active option.
    pub active: usize,
    /// Measured cluster draw over the window (static floor + dynamic
    /// compute share; the DES computes it from its busy timeline), W.
    pub avg_power_w_in_window: f64,
    /// Per-physical-node health at this epoch: `true` = out of service.
    /// Empty means "all healthy" — the fault-free DES passes an empty
    /// vec, keeping decisions bit-identical to the pre-chaos code. (In
    /// a real deployment this comes from heartbeats + window stats; the
    /// simulator reports its injected ground truth.)
    pub node_down: Vec<bool>,
    /// Per-physical-node persistent compute slowdown factor (1.0 =
    /// nominal). Empty means all nominal.
    pub node_slow: Vec<f64>,
}

/// A reconfiguration the controller asks the simulator to execute.
#[derive(Debug, Clone)]
pub struct Decision {
    /// Index of the option to activate.
    pub to: usize,
    /// Downtime to charge every node, ms.
    pub downtime_ms: f64,
    /// Human-readable rationale (shows up in reports).
    pub reason: String,
}

/// The reconfiguration controller. Stateful: smoothed arrival rate and
/// last-switch time live across [`OnlineController::decide`] calls.
#[derive(Debug, Clone)]
pub struct OnlineController {
    pub cfg: ControllerConfig,
    pub reconfig: ReconfigCost,
    /// Decision audit (DESIGN.md §13): every consultation — switch or
    /// hold — with the break-even numbers, when `audit.enabled`. The
    /// DES flips it on with telemetry and drains it at end of run.
    pub audit: AuditLog,
    lambda_ema: Option<f64>,
    power_ema: Option<f64>,
    last_switch_ms: f64,
    /// Set by a failover switch; cleared when the controller restores a
    /// full-width plan (or finds itself already on the best candidate).
    degraded: bool,
}

impl OnlineController {
    pub fn new(cfg: ControllerConfig, reconfig: ReconfigCost) -> anyhow::Result<Self> {
        cfg.validate()?;
        reconfig.validate()?;
        Ok(OnlineController {
            cfg,
            reconfig,
            audit: AuditLog::default(),
            lambda_ema: None,
            power_ema: None,
            last_switch_ms: f64::NEG_INFINITY,
            degraded: false,
        })
    }

    /// Is the controller currently on a failover (survivor) plan?
    pub fn is_degraded(&self) -> bool {
        self.degraded
    }

    /// The audit skeleton for one consultation; each return site fills
    /// in the verdict and any branch-specific numbers before pushing.
    fn audit_base(&self, obs: &Observation, lam: f64, p_ema: f64, mu_cur: f64) -> AuditRecord {
        AuditRecord {
            at_ms: obs.now_ms,
            active: obs.active,
            lambda_hat: lam,
            power_hat: p_ema,
            backlog: obs.backlog,
            verdict: AuditVerdict::HoldSteady,
            to: None,
            mu_cur,
            mu_best: f64::NAN,
            t_stay_s: f64::NAN,
            t_switch_s: f64::NAN,
            reason: String::new(),
        }
    }

    /// Stamp an alert-rule firing (DESIGN.md §15) into the audit log so
    /// pages and controller verdicts interleave on one timeline. Not a
    /// decision: verdict `alert`, no break-even numbers.
    pub fn audit_alert(&mut self, at_ms: f64, active: usize, backlog: usize, message: &str) {
        self.audit.push(AuditRecord {
            at_ms,
            active,
            lambda_hat: self.lambda_ema.unwrap_or(f64::NAN),
            power_hat: self.power_ema.unwrap_or(f64::NAN),
            backlog,
            verdict: AuditVerdict::Alert,
            to: None,
            mu_cur: f64::NAN,
            mu_best: f64::NAN,
            t_stay_s: f64::NAN,
            t_switch_s: f64::NAN,
            reason: message.to_string(),
        });
    }

    /// Smoothed arrival-rate estimate (img/s), if any window was seen.
    pub fn lambda_hat(&self) -> Option<f64> {
        self.lambda_ema
    }

    /// Smoothed measured cluster draw (W), if any window was seen.
    pub fn power_hat(&self) -> Option<f64> {
        self.power_ema
    }

    /// Consult the policy with a fresh observation. `None` = keep the
    /// active plan. A `Some` decision has already been charged against
    /// the dwell clock; the caller applies the downtime and the switch.
    pub fn decide(&mut self, options: &[PlanOption], obs: &Observation) -> Option<Decision> {
        let window_s = obs.window_ms / 1e3;
        let lambda_now = obs.arrivals_in_window as f64 / window_s.max(1e-9);
        let alpha = self.cfg.rate_ema_alpha;
        let lam = match self.lambda_ema {
            None => lambda_now,
            Some(prev) => (1.0 - alpha) * prev + alpha * lambda_now,
        };
        self.lambda_ema = Some(lam);
        let p_alpha = self.cfg.power_ema_alpha;
        let p_ema = match self.power_ema {
            None => obs.avg_power_w_in_window,
            Some(prev) => (1.0 - p_alpha) * prev + p_alpha * obs.avg_power_w_in_window,
        };
        self.power_ema = Some(p_ema);

        // a budgeted controller never activates a plan whose saturated
        // draw exceeds the budget, whatever the load says
        let budget = self.cfg.power_budget_w;
        let in_budget =
            move |o: &PlanOption| budget.map(|b| o.avg_power_w <= b).unwrap_or(true);
        // capacity through the straggler lens (identical to the raw
        // figure when the run is fault-free)
        let eff = |o: &PlanOption| o.effective_capacity(&obs.node_slow);

        // emergency failover (DESIGN.md §14): the active plan references
        // a dead node, so its capacity is effectively zero — every epoch
        // spent on it strands work. Overrides the dwell clock: re-plan
        // over the survivors now, or hold only if no healthy candidate
        // exists (e.g. a concurrent multi-node outage).
        if !options[obs.active].healthy(&obs.node_down) {
            let dead: Vec<usize> = obs
                .node_down
                .iter()
                .enumerate()
                .filter(|&(_, &d)| d)
                .map(|(i, _)| i)
                .collect();
            let cand = options
                .iter()
                .enumerate()
                .filter(|&(i, o)| {
                    i != obs.active && o.healthy(&obs.node_down) && in_budget(o)
                })
                .max_by(|a, b| eff(a.1).partial_cmp(&eff(b.1)).unwrap());
            let mu_cur = eff(&options[obs.active]);
            match cand {
                Some((best, opt)) => {
                    self.last_switch_ms = obs.now_ms;
                    self.degraded = true;
                    let reason = format!(
                        "failover: node(s) {dead:?} down → {} on survivors {:?} (μ {:.1})",
                        opt.plan.strategy,
                        opt.physical_nodes(),
                        eff(opt)
                    );
                    if self.audit.enabled {
                        let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                        rec.verdict = AuditVerdict::SwitchFailover;
                        rec.to = Some(best);
                        rec.mu_best = eff(opt);
                        rec.reason = reason.clone();
                        self.audit.push(rec);
                    }
                    crate::log_kv_debug!(
                        Some(obs.now_ms), "controller_switch",
                        "verdict" => "failover", "to" => best
                    );
                    return Some(Decision {
                        to: best,
                        downtime_ms: self.reconfig.downtime_ms(),
                        reason,
                    });
                }
                None => {
                    if self.audit.enabled {
                        let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                        rec.verdict = AuditVerdict::HoldNoFailover;
                        rec.reason = format!(
                            "node(s) {dead:?} down but no healthy candidate to fail over to"
                        );
                        self.audit.push(rec);
                    }
                    return None;
                }
            }
        }

        if obs.now_ms - self.last_switch_ms < self.cfg.dwell_ms {
            if self.audit.enabled {
                let mut rec = self.audit_base(
                    obs,
                    lam,
                    p_ema,
                    eff(&options[obs.active]),
                );
                rec.verdict = AuditVerdict::HoldDwell;
                rec.reason = "inside minimum dwell after last switch".into();
                self.audit.push(rec);
            }
            return None;
        }
        let cur = &options[obs.active];
        let mu_cur = eff(cur);
        let backlog_ms = obs.backlog as f64 / mu_cur * 1e3;

        // hard power cap: smoothed draw above budget → shed watts first.
        // Downshift to the lowest-saturated-draw candidate (ties broken
        // toward capacity); if the cluster is already on it, hold — the
        // throughput branches below must not upgrade past the budget.
        if let Some(budget) = self.cfg.power_budget_w {
            if p_ema > budget {
                let (best, opt) = options
                    .iter()
                    .enumerate()
                    .filter(|&(_, o)| o.healthy(&obs.node_down))
                    .min_by(|a, b| {
                        a.1.avg_power_w
                            .partial_cmp(&b.1.avg_power_w)
                            .unwrap()
                            .then(
                                b.1.capacity_img_per_sec
                                    .partial_cmp(&a.1.capacity_img_per_sec)
                                    .unwrap(),
                            )
                    })?;
                if best != obs.active && opt.avg_power_w < cur.avg_power_w {
                    self.last_switch_ms = obs.now_ms;
                    let reason = format!(
                        "power cap: drawing {p_ema:.1} W vs budget {budget:.1} W → {} \
                         ({:.1} W saturated)",
                        opt.plan.strategy, opt.avg_power_w
                    );
                    if self.audit.enabled {
                        let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                        rec.verdict = AuditVerdict::SwitchPowerCap;
                        rec.to = Some(best);
                        rec.reason = reason.clone();
                        self.audit.push(rec);
                    }
                    crate::log_kv_debug!(
                        Some(obs.now_ms), "controller_switch",
                        "verdict" => "power-cap", "to" => best, "p_ema_w" => p_ema
                    );
                    return Some(Decision {
                        to: best,
                        downtime_ms: self.reconfig.downtime_ms(),
                        reason,
                    });
                }
                if self.audit.enabled {
                    let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                    rec.verdict = AuditVerdict::HoldPowerFloor;
                    rec.reason =
                        format!("over budget {budget:.1} W but already on the cheapest draw");
                    self.audit.push(rec);
                }
                return None;
            }
        }
        // restore after rejoin (DESIGN.md §14): on a failover plan and a
        // strictly better healthy candidate exists — the full-width plan
        // becomes eligible again once its node is back. Respects dwell
        // (gated above), so a flapping node cannot make the controller
        // flap with it.
        if self.degraded {
            let cand = options
                .iter()
                .enumerate()
                .filter(|&(_, o)| o.healthy(&obs.node_down) && in_budget(o))
                .max_by(|a, b| eff(a.1).partial_cmp(&eff(b.1)).unwrap());
            if let Some((best, opt)) = cand {
                if best == obs.active {
                    // already on the best candidate — nothing to restore
                    self.degraded = false;
                } else if eff(opt) >= self.cfg.min_capacity_gain * mu_cur {
                    self.last_switch_ms = obs.now_ms;
                    self.degraded = false;
                    let reason = format!(
                        "restore: nodes back in service → {} (μ {:.1} vs degraded {:.1})",
                        opt.plan.strategy,
                        eff(opt),
                        mu_cur
                    );
                    if self.audit.enabled {
                        let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                        rec.verdict = AuditVerdict::SwitchRestore;
                        rec.to = Some(best);
                        rec.mu_best = eff(opt);
                        rec.reason = reason.clone();
                        self.audit.push(rec);
                    }
                    crate::log_kv_debug!(
                        Some(obs.now_ms), "controller_switch",
                        "verdict" => "restore", "to" => best
                    );
                    return Some(Decision {
                        to: best,
                        downtime_ms: self.reconfig.downtime_ms(),
                        reason,
                    });
                }
            }
        }

        let overloaded =
            lam > self.cfg.overload_util * mu_cur || backlog_ms > self.cfg.backlog_high_ms;
        if overloaded {
            let (best, opt) = options
                .iter()
                .enumerate()
                .filter(|&(_, o)| in_budget(o) && o.healthy(&obs.node_down))
                .max_by(|a, b| eff(a.1).partial_cmp(&eff(b.1)).unwrap())?;
            let mu_best = eff(opt);
            if best == obs.active || mu_best < self.cfg.min_capacity_gain * mu_cur {
                if self.audit.enabled {
                    let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                    rec.verdict = AuditVerdict::HoldNoGain;
                    rec.mu_best = mu_best;
                    rec.reason = "overloaded but best candidate offers no real gain".into();
                    self.audit.push(rec);
                }
                return None;
            }
            // drain-time break-even (see module docs)
            let d = self.reconfig.downtime_ms() / 1e3;
            let b = obs.backlog as f64;
            let t_stay =
                if mu_cur > lam { b / (mu_cur - lam) } else { f64::INFINITY };
            let t_switch = if mu_best > lam {
                d + (b + lam * d) / (mu_best - lam)
            } else {
                f64::INFINITY
            };
            // both saturated: the faster drain still wins in the limit
            let worth = t_switch < t_stay
                || (t_stay.is_infinite() && t_switch.is_infinite() && mu_best > mu_cur);
            if !worth {
                if self.audit.enabled {
                    let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                    rec.verdict = AuditVerdict::HoldNotWorth;
                    rec.mu_best = mu_best;
                    rec.t_stay_s = t_stay;
                    rec.t_switch_s = t_switch;
                    rec.reason = "staying drains the backlog faster than switching".into();
                    self.audit.push(rec);
                }
                return None;
            }
            self.last_switch_ms = obs.now_ms;
            let reason = format!(
                "overload: λ̂ {lam:.1} img/s vs μ {mu_cur:.1}, backlog {} → {} (μ {mu_best:.1})",
                obs.backlog, opt.plan.strategy
            );
            if self.audit.enabled {
                let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                rec.verdict = AuditVerdict::SwitchOverload;
                rec.to = Some(best);
                rec.mu_best = mu_best;
                rec.t_stay_s = t_stay;
                rec.t_switch_s = t_switch;
                rec.reason = reason.clone();
                self.audit.push(rec);
            }
            crate::log_kv_debug!(
                Some(obs.now_ms), "controller_switch",
                "verdict" => "overload", "to" => best, "lambda_hat" => lam,
                "t_stay_s" => t_stay, "t_switch_s" => t_switch
            );
            return Some(Decision {
                to: best,
                downtime_ms: self.reconfig.downtime_ms(),
                reason,
            });
        }

        // latency-oriented downshift under sustained low load
        if lam < self.cfg.underload_util * mu_cur && backlog_ms <= self.cfg.backlog_low_ms {
            // lowest-latency candidate that still has capacity headroom
            let headroom = lam / self.cfg.underload_util.max(1e-9);
            let best = options
                .iter()
                .enumerate()
                .filter(|&(_, o)| {
                    eff(o) >= headroom && in_budget(o) && o.healthy(&obs.node_down)
                })
                .min_by(|a, b| a.1.latency_ms.partial_cmp(&b.1.latency_ms).unwrap())?;
            if best.0 != obs.active
                && best.1.latency_ms <= self.cfg.max_latency_ratio * cur.latency_ms
            {
                self.last_switch_ms = obs.now_ms;
                let reason = format!(
                    "underload: λ̂ {lam:.1} img/s vs μ {mu_cur:.1} → {} (latency {:.2} ms vs {:.2})",
                    best.1.plan.strategy, best.1.latency_ms, cur.latency_ms
                );
                if self.audit.enabled {
                    let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
                    rec.verdict = AuditVerdict::SwitchUnderload;
                    rec.to = Some(best.0);
                    rec.mu_best = best.1.capacity_img_per_sec;
                    rec.reason = reason.clone();
                    self.audit.push(rec);
                }
                crate::log_kv_debug!(
                    Some(obs.now_ms), "controller_switch",
                    "verdict" => "underload", "to" => best.0, "lambda_hat" => lam
                );
                return Some(Decision {
                    to: best.0,
                    downtime_ms: self.reconfig.downtime_ms(),
                    reason,
                });
            }
        }
        if self.audit.enabled {
            let mut rec = self.audit_base(obs, lam, p_ema, mu_cur);
            rec.reason = "load inside the hysteresis band".into();
            self.audit.push(rec);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::strategies::scatter_gather;

    /// Fabricate a candidate set with controlled capacity/latency/watts
    /// (plans are real so `validate_options` also works on them).
    fn options3(specs: &[(f64, f64, f64)]) -> (Graph, Vec<PlanOption>) {
        let g = crate::graph::zoo::build("lenet5", 0).unwrap();
        let opts = specs
            .iter()
            .map(|&(cap, lat, watts)| PlanOption {
                plan: scatter_gather(&g, 1).unwrap(),
                node_map: None,
                capacity_img_per_sec: cap,
                latency_ms: lat,
                avg_power_w: watts,
                j_per_image: watts / cap,
            })
            .collect();
        (g, opts)
    }

    /// Capacity/latency specs with a common nominal draw.
    fn options(specs: &[(f64, f64)]) -> (Graph, Vec<PlanOption>) {
        let full: Vec<(f64, f64, f64)> =
            specs.iter().map(|&(cap, lat)| (cap, lat, 12.0)).collect();
        options3(&full)
    }

    fn obs(now_ms: f64, arrivals: u64, backlog: usize, active: usize) -> Observation {
        obs_w(now_ms, arrivals, backlog, active, 12.0)
    }

    fn obs_w(
        now_ms: f64,
        arrivals: u64,
        backlog: usize,
        active: usize,
        watts: f64,
    ) -> Observation {
        Observation {
            now_ms,
            window_ms: 100.0,
            arrivals_in_window: arrivals,
            backlog,
            active,
            avg_power_w_in_window: watts,
            node_down: Vec::new(),
            node_slow: Vec::new(),
        }
    }

    fn controller() -> OnlineController {
        OnlineController::new(
            ControllerConfig { rate_ema_alpha: 1.0, ..Default::default() },
            ReconfigCost::zynq7020(),
        )
        .unwrap()
    }

    #[test]
    fn overload_switches_to_highest_capacity() {
        // active 0: 50 img/s; option 1: 200 img/s. 10 arrivals / 100 ms
        // = 100 img/s offered → overloaded, backlog worth switching.
        let (_, opts) = options(&[(50.0, 5.0), (200.0, 8.0)]);
        let mut c = controller();
        let d = c.decide(&opts, &obs(100.0, 10, 40, 0)).expect("should switch");
        assert_eq!(d.to, 1);
        assert!(d.downtime_ms > 0.0);
        assert!(d.reason.contains("overload"), "{}", d.reason);
    }

    #[test]
    fn dwell_prevents_flapping() {
        let (_, opts) = options(&[(50.0, 5.0), (200.0, 8.0)]);
        let mut c = controller();
        assert!(c.decide(&opts, &obs(100.0, 10, 40, 0)).is_some());
        // immediately after, even with the same overload signal: hold
        assert!(c.decide(&opts, &obs(200.0, 10, 60, 1)).is_none());
    }

    #[test]
    fn no_switch_when_active_is_best() {
        let (_, opts) = options(&[(200.0, 8.0), (50.0, 5.0)]);
        let mut c = controller();
        assert!(c.decide(&opts, &obs(100.0, 30, 100, 0)).is_none());
    }

    #[test]
    fn no_switch_when_gain_below_threshold() {
        let (_, opts) = options(&[(100.0, 5.0), (105.0, 5.0)]);
        let mut c = controller();
        assert!(c.decide(&opts, &obs(100.0, 20, 100, 0)).is_none());
    }

    #[test]
    fn underload_downshifts_to_low_latency() {
        // active 0: fast but high latency; option 1: slower, low latency,
        // still enough headroom for 10 img/s offered.
        let (_, opts) = options(&[(500.0, 20.0), (100.0, 4.0)]);
        let mut c = controller();
        let d = c.decide(&opts, &obs(100.0, 1, 0, 0)).expect("should downshift");
        assert_eq!(d.to, 1);
        assert!(d.reason.contains("underload"), "{}", d.reason);
    }

    #[test]
    fn moderate_load_holds_steady() {
        // 60 img/s offered on a 100 img/s plan: neither over nor under.
        let (_, opts) = options(&[(100.0, 5.0), (300.0, 9.0), (80.0, 3.0)]);
        let mut c = controller();
        assert!(c.decide(&opts, &obs(100.0, 6, 2, 0)).is_none());
    }

    fn capped(budget: f64) -> OnlineController {
        OnlineController::new(
            ControllerConfig {
                rate_ema_alpha: 1.0,
                power_ema_alpha: 1.0,
                power_budget_w: Some(budget),
                ..Default::default()
            },
            ReconfigCost::zynq7020(),
        )
        .unwrap()
    }

    #[test]
    fn over_budget_downshifts_to_cheapest_plan() {
        // active 0 draws 18 W saturated; option 1 is the frugal one
        let (_, opts) = options3(&[(200.0, 5.0, 18.0), (80.0, 7.0, 11.0)]);
        let mut c = capped(14.0);
        let d = c.decide(&opts, &obs_w(100.0, 5, 0, 0, 17.5)).expect("should shed watts");
        assert_eq!(d.to, 1);
        assert!(d.reason.contains("power cap"), "{}", d.reason);
        assert!((c.power_hat().unwrap() - 17.5).abs() < 1e-9);
    }

    #[test]
    fn over_budget_on_cheapest_plan_holds() {
        let (_, opts) = options3(&[(80.0, 7.0, 11.0), (200.0, 5.0, 18.0)]);
        let mut c = capped(10.0);
        // over budget but nothing cheaper exists → hold, and crucially
        // do NOT let the overload branch grab the 18 W plan
        assert!(c.decide(&opts, &obs_w(100.0, 20, 50, 0, 11.0)).is_none());
    }

    #[test]
    fn budget_blocks_hungry_upgrade_under_overload() {
        // overloaded on 0; the highest-capacity plan (1) busts the
        // budget, so the upgrade must pick the in-budget option 2
        let (_, opts) =
            options3(&[(50.0, 5.0, 12.0), (300.0, 8.0, 20.0), (150.0, 6.0, 13.0)]);
        let mut c = capped(14.0);
        let d = c.decide(&opts, &obs_w(100.0, 10, 40, 0, 12.0)).expect("should upgrade");
        assert_eq!(d.to, 2, "picked an over-budget plan: {}", d.reason);
        // without the budget the same observation picks the 20 W plan
        let mut free = controller();
        let d = free.decide(&opts, &obs(100.0, 10, 40, 0)).unwrap();
        assert_eq!(d.to, 1);
    }

    #[test]
    fn under_budget_draw_does_not_trigger_power_branch() {
        let (_, opts) = options3(&[(200.0, 5.0, 18.0), (80.0, 7.0, 11.0)]);
        let mut c = capped(14.0);
        // drawing 12 W < 14 W budget, moderate load: hold
        assert!(c.decide(&opts, &obs_w(100.0, 10, 0, 0, 12.0)).is_none());
    }

    #[test]
    fn budget_validation() {
        let bad = ControllerConfig { power_budget_w: Some(0.0), ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ControllerConfig { power_ema_alpha: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn audit_log_records_every_consultation_when_enabled() {
        let (_, opts) = options(&[(50.0, 5.0), (200.0, 8.0)]);
        let mut c = controller();
        c.audit.enabled = true;
        let d = c.decide(&opts, &obs(100.0, 10, 40, 0)).expect("overload switch");
        assert!(c.decide(&opts, &obs(200.0, 10, 60, d.to)).is_none(), "dwell");
        assert!(c.decide(&opts, &obs(2000.0, 8, 1, d.to)).is_none(), "steady");
        let recs = c.audit.take();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].verdict, AuditVerdict::SwitchOverload);
        assert_eq!(recs[0].to, Some(1));
        assert!(
            recs[0].t_switch_s < recs[0].t_stay_s,
            "switch verdict must carry its break-even: {:?}",
            (recs[0].t_stay_s, recs[0].t_switch_s)
        );
        assert_eq!(recs[1].verdict, AuditVerdict::HoldDwell);
        assert_eq!(recs[2].verdict, AuditVerdict::HoldSteady);
        // disabled (the default): consultations leave no records
        let mut quiet = controller();
        quiet.decide(&opts, &obs(100.0, 10, 40, 0)).unwrap();
        assert!(quiet.audit.records.is_empty());
    }

    #[test]
    fn validate_options_rejects_foreign_plan() {
        let (g, opts) = options(&[(100.0, 5.0)]);
        validate_options(&opts, &g, 1).unwrap();
        let other = crate::graph::zoo::build("mlp", 0).unwrap();
        assert!(validate_options(&opts, &other, 1).is_err());
        assert!(validate_options(&opts, &g, 2).is_err());
    }

    fn obs_fault(now_ms: f64, active: usize, down: Vec<bool>) -> Observation {
        Observation { node_down: down, ..obs(now_ms, 5, 0, active) }
    }

    #[test]
    fn failover_bypasses_dwell_then_restores_after_rejoin() {
        // option 0: full-width plan on physical node 0 (200 img/s);
        // option 1: survivor plan pinned to physical node 1 (90 img/s)
        let (_, mut opts) = options(&[(200.0, 5.0), (90.0, 7.0)]);
        opts[1].node_map = Some(vec![1]);
        let mut c = controller();
        c.audit.enabled = true;

        // node 0 dies → immediate failover to the survivor plan
        let d = c
            .decide(&opts, &obs_fault(100.0, 0, vec![true, false]))
            .expect("must fail over");
        assert_eq!(d.to, 1);
        assert!(d.downtime_ms > 0.0);
        assert!(d.reason.contains("failover"), "{}", d.reason);
        assert!(c.is_degraded());

        // still down, now on the survivor plan, inside dwell: hold
        assert!(c.decide(&opts, &obs_fault(150.0, 1, vec![true, false])).is_none());
        assert!(c.is_degraded());

        // node rejoins, dwell elapsed → restore the full-width plan
        let d = c
            .decide(&opts, &obs_fault(2000.0, 1, vec![false, false]))
            .expect("must restore");
        assert_eq!(d.to, 0);
        assert!(d.reason.contains("restore"), "{}", d.reason);
        assert!(!c.is_degraded());

        let recs = c.audit.take();
        assert_eq!(recs[0].verdict, AuditVerdict::SwitchFailover);
        assert_eq!(recs[1].verdict, AuditVerdict::HoldDwell);
        assert_eq!(recs[2].verdict, AuditVerdict::SwitchRestore);
    }

    #[test]
    fn failover_holds_when_no_healthy_candidate() {
        // both options live on physical node 0 — nowhere to go
        let (_, opts) = options(&[(200.0, 5.0), (90.0, 7.0)]);
        let mut c = controller();
        assert!(c.decide(&opts, &obs_fault(100.0, 0, vec![true])).is_none());
        assert!(!c.is_degraded(), "a held failover must not mark degraded");
    }

    #[test]
    fn restore_waits_out_a_flapping_node() {
        let (_, mut opts) = options(&[(200.0, 5.0), (90.0, 7.0)]);
        opts[1].node_map = Some(vec![1]);
        let mut c = controller();
        c.decide(&opts, &obs_fault(100.0, 0, vec![true, false])).unwrap();
        // node back 50 ms later: inside dwell, restore must wait
        assert!(c.decide(&opts, &obs_fault(150.0, 1, vec![false, false])).is_none());
        assert!(c.is_degraded());
    }

    #[test]
    fn straggler_derates_effective_capacity() {
        let (_, opts) = options(&[(100.0, 5.0)]);
        let o = &opts[0]; // physical nodes = [0]
        assert_eq!(o.effective_capacity(&[]), 100.0);
        assert!((o.effective_capacity(&[2.0]) - 50.0).abs() < 1e-12);
        // a straggler elsewhere does not touch this option
        assert_eq!(o.effective_capacity(&[1.0, 3.0]), 100.0);
        assert!(o.healthy(&[]) && o.healthy(&[false, true]));
        assert!(!o.healthy(&[true]));
    }

    #[test]
    fn straggler_on_active_plan_drives_the_upgrade_branch() {
        // nominal capacities are equal; a 4× straggler on node 0 makes
        // the survivor-pinned option 1 the effectively faster plan
        let (_, mut opts) = options(&[(100.0, 5.0), (100.0, 6.0)]);
        opts[1].node_map = Some(vec![1]);
        let mut c = controller();
        let o = Observation {
            node_slow: vec![4.0, 1.0],
            ..obs(100.0, 9, 40, 0) // 90 img/s offered vs eff μ 25
        };
        let d = c.decide(&opts, &o).expect("must escape the straggler");
        assert_eq!(d.to, 1);
    }

    #[test]
    fn survivor_options_pin_plans_onto_survivors() {
        use crate::config::{BoardProfile, Calibration, VtaConfig};
        let g = crate::graph::zoo::build("lenet5", 0).unwrap();
        let cluster = crate::config::ClusterConfig::zynq_stack(3);
        let mut cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        let opts =
            survivor_options(&g, &cluster, &mut cost, &Strategy::all(), 1).unwrap();
        assert!(!opts.is_empty());
        // valid against the FULL 3-node cluster thanks to the node map
        validate_options(&opts, &g, 3).unwrap();
        for o in &opts {
            assert_eq!(o.node_map.as_deref(), Some(&[0usize, 2][..]));
            assert!(!o.uses_node(1), "survivor plan touches the dead node");
            assert!(o.healthy(&[false, true, false]));
            assert!(o.capacity_img_per_sec > 0.0);
        }
        // degenerate cases
        assert!(survivor_options(&g, &cluster, &mut cost, &Strategy::all(), 9).is_err());
        let one = crate::config::ClusterConfig::zynq_stack(1);
        assert!(survivor_options(&g, &one, &mut cost, &Strategy::all(), 0)
            .unwrap()
            .is_empty());
    }

    #[test]
    fn plan_options_prices_all_strategies() {
        use crate::config::{BoardProfile, Calibration, VtaConfig};
        let g = crate::graph::zoo::build("lenet5", 0).unwrap();
        let cluster = crate::config::ClusterConfig::zynq_stack(3);
        let mut cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        let opts = plan_options(&g, &cluster, &mut cost, &Strategy::all()).unwrap();
        assert_eq!(opts.len(), 4);
        validate_options(&opts, &g, 3).unwrap();
        // the searched strategy slots into the same candidate set and,
        // by the §17 dominance guarantee, never offers less capacity
        // than the best heuristic option
        let with_search =
            plan_options(&g, &cluster, &mut cost, &[Strategy::Search]).unwrap();
        assert_eq!(with_search.len(), 1);
        assert_eq!(with_search[0].plan.strategy, Strategy::Search);
        validate_options(&with_search, &g, 3).unwrap();
        let best_heuristic =
            opts.iter().map(|o| o.capacity_img_per_sec).fold(0.0f64, f64::max);
        assert!(
            with_search[0].capacity_img_per_sec >= best_heuristic * 0.9999,
            "search option {} img/s loses to best heuristic {} img/s",
            with_search[0].capacity_img_per_sec,
            best_heuristic
        );
        for o in &opts {
            assert!(o.capacity_img_per_sec > 0.0 && o.latency_ms > 0.0);
            // priced power: at least the 3-node idle floor, and finite
            assert!(o.avg_power_w > 3.0 * 2.0, "implausible draw {}", o.avg_power_w);
            assert!(o.j_per_image > 0.0 && o.j_per_image.is_finite());
        }
    }
}
