//! The paper's §II-C contribution: the four NN-graph scheduling
//! strategies over the FPGA cluster.
//!
//! * [`plan`]       — `ExecutionPlan`: stages × replica node sets × split
//!                    mode, with validation invariants
//! * [`strategies`] — constructors: Scatter-Gather, AI Core Assignment,
//!                    Pipeline Scheduling, Fused Schedule
//! * [`online`]     — online reconfiguration controller: watches load
//!                    signals from the DES and switches plans when the
//!                    drain-time break-even beats the reconfiguration
//!                    downtime; with a power budget it also sheds watts
//!                    (DESIGN.md §11)
//!
//! A fifth, power-aware strategy ([`Strategy::Eco`]: minimize J/image
//! under a latency SLO) lives in [`crate::power::eco`] because it needs
//! the metered simulator, not just a segment-time oracle.

pub mod online;
pub mod plan;
pub mod strategies;

pub use online::{
    plan_options, survivor_options, validate_options, ControllerConfig, Decision,
    Observation, OnlineController, PlanOption,
};
pub use plan::{ExecutionPlan, SplitMode, StagePlan, Strategy};
pub use strategies::{
    build_plan, build_plan_priced, core_assign, fused, pipeline, scatter_gather,
};
