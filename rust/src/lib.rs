//! # vta-cluster
//!
//! A reproduction of *"Reconfigurable Distributed FPGA Cluster Design for
//! Deep Learning Accelerators"* (Johnson, Fang, Perez-Vicente, Saniie —
//! IIT ECASP, 2023) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the cluster: VTA instruction-level
//!   simulator, Ethernet/MPI network model, a workload registry
//!   ([`graph::zoo`]) of int8 models sharing one IR contract, the four
//!   scheduling strategies of §II-C (scatter-gather, AI core assignment,
//!   pipeline, fused) applicable to any registered model, an analytic
//!   cluster simulator that regenerates every table/figure of the paper,
//!   a deterministic discrete-event load simulator ([`sim::des`]) with
//!   an online reconfiguration controller ([`sched::online`]) that
//!   switches plans under load and charges the modeled FPGA
//!   reconfiguration downtime, and a PJRT-backed serving coordinator
//!   with a multi-tenant layer ([`coordinator::MultiCoordinator`])
//!   running several model pipelines concurrently over a shared node
//!   budget, a power/energy subsystem ([`power`]) that meters both
//!   simulators in joules, adds an energy-minimizing scheduling
//!   strategy, and enumerates the latency-vs-watts Pareto frontier, a
//!   plan-search engine ([`search`]) — exact DP and parallel beam
//!   search over the whole contiguous-partition space, surfaced as
//!   `Strategy::Search` with latency/throughput/J-per-image objectives,
//!   SLO and power-budget constraints, and fleet-scale right-sizing —
//!   and
//!   a declarative scenario layer ([`scenario`]) — JSON
//!   [`scenario::ScenarioSpec`]s resolved by [`scenario::Session`] into
//!   unified [`scenario::Report`]s, with [`scenario::Sweep`] grids over
//!   any spec axis — that the CLI's experiment subcommands are thin
//!   adapters over, all observable through a zero-cost-when-off
//!   telemetry layer ([`telemetry`]) of per-request span traces, HDR
//!   histograms, a controller decision audit log, and a Perfetto
//!   (Chrome trace-event) exporter behind `vtacluster run --trace`,
//!   fronted by a production serving layer ([`serve`]) — per-tenant
//!   admission control with load shedding, a batch former with
//!   batch-dependent service times, and JSONL request-trace replay.
//! * **Layer 2 (python/compile, build-time)** — int8 ResNet-18 in JAX,
//!   AOT-lowered to HLO text artifacts per graph segment.
//! * **Layer 1 (python/compile/kernels, build-time)** — the VTA GEMM and
//!   ALU engines as Pallas kernels.
//!
//! Python never runs at serving time: `runtime` loads the HLO artifacts
//! through the PJRT C API (the `xla` crate behind the `pjrt` cargo
//! feature; a stub otherwise) and the coordinator serves requests
//! entirely from rust.
//!
//! See DESIGN.md for the architecture and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod graph;
pub mod net;
pub mod power;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod search;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod vta;
