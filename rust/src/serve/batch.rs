//! Batch former (DESIGN.md §16): collect admitted requests into
//! dispatch batches bounded by a max size and a max wait.
//!
//! The same former drives both serving paths — the DES (via
//! `FlushBatch` timer events keyed by a generation counter) and the
//! real PJRT coordinator (via [`chunk`], which splits a ready batch
//! into dispatch chunks). A batch computes as ONE stage launch per
//! pipeline stage: VTA amortizes instruction fetch and driver launch
//! over the batch (sub-linear compute), while activation bytes on the
//! wire stay linear in batch size.

use crate::util::units::{ms_to_ns, Nanos};

/// Batching knobs. `max_size <= 1` means batching is off — the DES
/// takes the exact per-image code path (byte-identity pinned by
/// proptest).
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Dispatch as soon as this many requests are pending.
    pub max_size: usize,
    /// Dispatch a partial batch this long after its first member
    /// arrived, so a lull cannot strand requests.
    pub max_wait_ms: f64,
}

impl BatchConfig {
    /// One chunk, no waiting — the coordinator's default, which keeps
    /// `run_batch` behaviour identical to the pre-serve code.
    pub fn unbounded() -> BatchConfig {
        BatchConfig {
            max_size: usize::MAX,
            max_wait_ms: 0.0,
        }
    }

    /// True when the former actually groups requests.
    pub fn is_active(&self) -> bool {
        self.max_size > 1
    }
}

/// One admitted request waiting in (or dispatched with) a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchMember {
    /// Admission timestamp — latency is measured from here, so time
    /// spent waiting for the batch to fill counts against the SLO.
    pub admitted_ns: Nanos,
    /// Tenant index (into the run's tenant table).
    pub tenant: usize,
}

/// What one `push` did to the former.
#[derive(Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// The batch filled to `max_size` — dispatch these now.
    Full(Vec<BatchMember>),
    /// The member opened a fresh batch: arm a flush timer at
    /// `flush_at` carrying `generation`.
    Opened { flush_at: Nanos, generation: u64 },
    /// Joined an already-open batch; its existing timer still covers it.
    Joined,
}

/// The former: at most one open batch at a time, flushed either by
/// filling up or by its timer. Generations make stale timers inert:
/// every newly opened batch bumps the counter, and [`flush`] only
/// fires when the timer's generation matches the open batch.
///
/// [`flush`]: BatchFormer::flush
#[derive(Debug)]
pub struct BatchFormer {
    max_size: usize,
    max_wait_ns: Nanos,
    pending: Vec<BatchMember>,
    generation: u64,
}

impl BatchFormer {
    pub fn new(cfg: &BatchConfig) -> BatchFormer {
        BatchFormer {
            max_size: cfg.max_size.max(1),
            max_wait_ns: ms_to_ns(cfg.max_wait_ms.max(0.0)),
            pending: Vec::new(),
            generation: 0,
        }
    }

    /// Add one member at time `now`.
    pub fn push(&mut self, member: BatchMember, now: Nanos) -> PushOutcome {
        let opened = self.pending.is_empty();
        if opened {
            self.generation += 1;
        }
        self.pending.push(member);
        if self.pending.len() >= self.max_size {
            return PushOutcome::Full(std::mem::take(&mut self.pending));
        }
        if opened {
            PushOutcome::Opened {
                flush_at: now + self.max_wait_ns,
                generation: self.generation,
            }
        } else {
            PushOutcome::Joined
        }
    }

    /// Timer callback: dispatch the open partial batch, but only if
    /// the timer belongs to it (same generation) and it still exists.
    pub fn flush(&mut self, generation: u64) -> Option<Vec<BatchMember>> {
        if generation == self.generation && !self.pending.is_empty() {
            Some(std::mem::take(&mut self.pending))
        } else {
            None
        }
    }

    /// Members waiting in the open batch.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }
}

/// Split `items` into in-order dispatch chunks of at most `max_size`
/// (0 or `usize::MAX` ⇒ one chunk). The real serving path
/// (`coordinator::service::run_batch`) and the simulated one share
/// this grouping.
pub fn chunk<T>(items: Vec<T>, max_size: usize) -> Vec<Vec<T>> {
    if items.is_empty() {
        return Vec::new();
    }
    let cap = if max_size == 0 { usize::MAX } else { max_size };
    if items.len() <= cap {
        return vec![items];
    }
    let mut out = Vec::with_capacity(items.len().div_ceil(cap));
    let mut cur: Vec<T> = Vec::with_capacity(cap);
    for it in items {
        cur.push(it);
        if cur.len() == cap {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(t: Nanos) -> BatchMember {
        BatchMember {
            admitted_ns: t,
            tenant: 0,
        }
    }

    #[test]
    fn fills_at_max_size() {
        let mut f = BatchFormer::new(&BatchConfig {
            max_size: 3,
            max_wait_ms: 1.0,
        });
        assert!(matches!(f.push(m(0), 0), PushOutcome::Opened { .. }));
        assert_eq!(f.push(m(1), 1), PushOutcome::Joined);
        match f.push(m(2), 2) {
            PushOutcome::Full(batch) => {
                assert_eq!(batch.len(), 3);
                assert_eq!(batch[0].admitted_ns, 0);
                assert_eq!(batch[2].admitted_ns, 2);
            }
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(f.pending(), 0);
    }

    #[test]
    fn timer_flushes_partial_batch_and_stale_timers_are_inert() {
        let mut f = BatchFormer::new(&BatchConfig {
            max_size: 4,
            max_wait_ms: 2.0,
        });
        let g1 = match f.push(m(0), 0) {
            PushOutcome::Opened {
                flush_at,
                generation,
            } => {
                assert_eq!(flush_at, ms_to_ns(2.0));
                generation
            }
            other => panic!("expected Opened, got {other:?}"),
        };
        let batch = f.flush(g1).expect("live timer flushes");
        assert_eq!(batch.len(), 1);
        // Re-flushing the same generation on an empty former: nothing.
        assert!(f.flush(g1).is_none());
        // New batch gets a new generation; the old timer is stale.
        let g2 = match f.push(m(5), 5) {
            PushOutcome::Opened { generation, .. } => generation,
            other => panic!("expected Opened, got {other:?}"),
        };
        assert_ne!(g1, g2);
        assert!(f.flush(g1).is_none());
        assert_eq!(f.flush(g2).expect("current timer flushes").len(), 1);
    }

    #[test]
    fn max_size_one_fills_immediately() {
        let mut f = BatchFormer::new(&BatchConfig {
            max_size: 1,
            max_wait_ms: 5.0,
        });
        assert!(matches!(f.push(m(0), 0), PushOutcome::Full(b) if b.len() == 1));
    }

    #[test]
    fn chunk_preserves_order_and_edges() {
        assert!(chunk::<u32>(vec![], 4).is_empty());
        assert_eq!(chunk(vec![1, 2, 3], 0), vec![vec![1, 2, 3]]);
        assert_eq!(chunk(vec![1, 2, 3], usize::MAX), vec![vec![1, 2, 3]]);
        assert_eq!(
            chunk(vec![1, 2, 3, 4, 5], 2),
            vec![vec![1, 2], vec![3, 4], vec![5]]
        );
        assert_eq!(chunk(vec![1, 2], 2), vec![vec![1, 2]]);
    }

    #[test]
    fn unbounded_config_is_a_single_chunk() {
        let cfg = BatchConfig::unbounded();
        assert_eq!(chunk(vec![1, 2, 3, 4], cfg.max_size), vec![vec![1, 2, 3, 4]]);
        assert!(!BatchConfig {
            max_size: 1,
            max_wait_ms: 0.0
        }
        .is_active());
    }
}
