//! Admission control (DESIGN.md §16): load-shedding policies plus
//! per-tenant token-bucket rate isolation in front of the DES.
//!
//! The gate is fully deterministic — no RNG, integer-nanosecond
//! bookkeeping — so a seeded run replays bit-identically. Shedding
//! happens at arrival time, before the request touches a queue, which
//! is what keeps a co-tenant's burst from inflating the victim
//! tenant's p99 (pinned by the isolation integration test).

use crate::util::stats::Summary;
use crate::util::units::Nanos;

/// What to do with a request the cluster cannot take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Admit everything (per-tenant rate buckets may still shed).
    None,
    /// Drop arrivals while the in-flight backlog sits at `queue_cap`.
    TailDrop,
    /// Drop arrivals whose estimated queue wait already exceeds the
    /// deadline — they would miss their SLO before computing starts.
    DeadlineDrop,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> anyhow::Result<ShedPolicy> {
        match s {
            "none" => Ok(ShedPolicy::None),
            "tail-drop" => Ok(ShedPolicy::TailDrop),
            "deadline-drop" => Ok(ShedPolicy::DeadlineDrop),
            other => anyhow::bail!(
                "unknown admission.policy '{other}' (none|tail-drop|deadline-drop)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ShedPolicy::None => "none",
            ShedPolicy::TailDrop => "tail-drop",
            ShedPolicy::DeadlineDrop => "deadline-drop",
        }
    }
}

/// Resolved admission knobs for one DES run (built by the scenario
/// layer from an `admission` spec block + the scenario SLO).
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    pub policy: ShedPolicy,
    /// Backlog bound for `tail-drop` (requests in flight; 0 = unbounded).
    pub queue_cap: usize,
    /// Deadline for `deadline-drop` and the `deadline_miss_rate`
    /// column; 0 disables both.
    pub deadline_ns: Nanos,
    /// Per-tenant token refill rate in img/s; 0 disables the buckets.
    pub tenant_rate: f64,
    /// Bucket depth in requests — the burst a tenant may front-load.
    pub tenant_burst: f64,
}

/// Why a request was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    QueueFull,
    Deadline,
    RateLimit,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue",
            ShedReason::Deadline => "deadline",
            ShedReason::RateLimit => "rate-limit",
        }
    }
}

/// Admission verdict for one offered request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    Shed(ShedReason),
}

struct Bucket {
    tokens: f64,
    last_ns: Nanos,
}

/// The admission gate itself: one token bucket per tenant plus the
/// configured shed policy, consulted once per arrival.
pub struct Admission {
    cfg: AdmissionConfig,
    buckets: Vec<Bucket>,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig, n_tenants: usize) -> Admission {
        let buckets = (0..n_tenants.max(1))
            .map(|_| Bucket {
                tokens: cfg.tenant_burst.max(1.0),
                last_ns: 0,
            })
            .collect();
        Admission { cfg, buckets }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Decide one arrival. `backlog` is the requests currently in
    /// flight; `est_wait_ns` a FIFO wait estimate (backlog × the
    /// active plan's bottleneck stage time). Tokens are only consumed
    /// on admit, so a policy-shed burst cannot starve its own tenant
    /// afterwards. Arrival times must be non-decreasing.
    pub fn offer(
        &mut self,
        tenant: usize,
        now: Nanos,
        backlog: usize,
        est_wait_ns: Nanos,
    ) -> Verdict {
        let gated = self.cfg.tenant_rate > 0.0;
        if gated {
            let b = &mut self.buckets[tenant];
            let dt_sec = now.saturating_sub(b.last_ns) as f64 / 1e9;
            b.tokens = (b.tokens + dt_sec * self.cfg.tenant_rate).min(self.cfg.tenant_burst);
            b.last_ns = now;
            if b.tokens < 1.0 {
                return Verdict::Shed(ShedReason::RateLimit);
            }
        }
        match self.cfg.policy {
            ShedPolicy::None => {}
            ShedPolicy::TailDrop => {
                if self.cfg.queue_cap > 0 && backlog >= self.cfg.queue_cap {
                    return Verdict::Shed(ShedReason::QueueFull);
                }
            }
            ShedPolicy::DeadlineDrop => {
                if self.cfg.deadline_ns > 0 && est_wait_ns > self.cfg.deadline_ns {
                    return Verdict::Shed(ShedReason::Deadline);
                }
            }
        }
        if gated {
            self.buckets[tenant].tokens -= 1.0;
        }
        Verdict::Admit
    }
}

/// Per-tenant serving outcome: admission counters plus the completed
/// latency distribution, accumulated by the DES whenever serve
/// tracking is on (admission configured or more than one tenant).
#[derive(Debug, Clone)]
pub struct TenantServeStats {
    pub name: String,
    pub offered: u64,
    pub admitted: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub shed_rate_limit: u64,
    pub latency_ms: Summary,
}

impl TenantServeStats {
    pub fn new(name: &str) -> TenantServeStats {
        TenantServeStats {
            name: name.to_string(),
            offered: 0,
            admitted: 0,
            shed_queue: 0,
            shed_deadline: 0,
            shed_rate_limit: 0,
            latency_ms: Summary::new(),
        }
    }

    pub fn shed(&self) -> u64 {
        self.shed_queue + self.shed_deadline + self.shed_rate_limit
    }

    /// Record one shed arrival under its reason.
    pub fn record_shed(&mut self, reason: ShedReason) {
        match reason {
            ShedReason::QueueFull => self.shed_queue += 1,
            ShedReason::Deadline => self.shed_deadline += 1,
            ShedReason::RateLimit => self.shed_rate_limit += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::ms_to_ns;

    fn cfg(policy: ShedPolicy) -> AdmissionConfig {
        AdmissionConfig {
            policy,
            queue_cap: 4,
            deadline_ns: ms_to_ns(10.0),
            tenant_rate: 0.0,
            tenant_burst: 16.0,
        }
    }

    #[test]
    fn policy_parse_roundtrips_and_rejects() {
        for p in [ShedPolicy::None, ShedPolicy::TailDrop, ShedPolicy::DeadlineDrop] {
            assert_eq!(ShedPolicy::parse(p.as_str()).unwrap(), p);
        }
        let err = ShedPolicy::parse("random-drop").unwrap_err().to_string();
        assert!(err.contains("tail-drop"), "{err}");
    }

    #[test]
    fn none_policy_admits_everything() {
        let mut a = Admission::new(cfg(ShedPolicy::None), 1);
        for i in 0..100 {
            assert_eq!(a.offer(0, i, 1000, u64::MAX / 2), Verdict::Admit);
        }
    }

    #[test]
    fn tail_drop_sheds_at_the_cap_and_only_there() {
        let mut a = Admission::new(cfg(ShedPolicy::TailDrop), 1);
        assert_eq!(a.offer(0, 0, 3, 0), Verdict::Admit);
        assert_eq!(a.offer(0, 1, 4, 0), Verdict::Shed(ShedReason::QueueFull));
        assert_eq!(a.offer(0, 2, 2, 0), Verdict::Admit);
    }

    #[test]
    fn deadline_drop_sheds_on_estimated_wait() {
        let mut a = Admission::new(cfg(ShedPolicy::DeadlineDrop), 1);
        assert_eq!(a.offer(0, 0, 100, ms_to_ns(9.0)), Verdict::Admit);
        assert_eq!(
            a.offer(0, 1, 100, ms_to_ns(11.0)),
            Verdict::Shed(ShedReason::Deadline)
        );
    }

    #[test]
    fn token_bucket_throttles_one_tenant_without_touching_the_other() {
        let mut a = Admission::new(
            AdmissionConfig {
                policy: ShedPolicy::None,
                queue_cap: 0,
                deadline_ns: 0,
                // 100 img/s, depth 2: a 1 ms-spaced flood refills only
                // 0.1 tokens per arrival.
                tenant_rate: 100.0,
                tenant_burst: 2.0,
            },
            2,
        );
        let mut admitted = [0u64; 2];
        for i in 0..200u64 {
            let now = ms_to_ns(i as f64); // both tenants offer every 1 ms
            for t in 0..2 {
                if a.offer(t, now, 0, 0) == Verdict::Admit {
                    admitted[t] += 1;
                }
            }
        }
        // ~burst + rate × 0.2 s ≈ 22 admits each, far below the 200 offered.
        assert!(admitted[0] > 10 && admitted[0] < 40, "{admitted:?}");
        // Buckets are per-tenant: identical offered load ⇒ identical admits.
        assert_eq!(admitted[0], admitted[1]);
    }

    #[test]
    fn bucket_refills_after_idle_gap() {
        let mut a = Admission::new(
            AdmissionConfig {
                policy: ShedPolicy::None,
                queue_cap: 0,
                deadline_ns: 0,
                tenant_rate: 10.0,
                tenant_burst: 2.0,
            },
            1,
        );
        assert_eq!(a.offer(0, 0, 0, 0), Verdict::Admit);
        assert_eq!(a.offer(0, 1, 0, 0), Verdict::Admit);
        assert_eq!(a.offer(0, 2, 0, 0), Verdict::Shed(ShedReason::RateLimit));
        // 500 ms idle at 10 img/s refills 5 tokens (clamped to depth 2).
        assert_eq!(a.offer(0, ms_to_ns(500.0), 0, 0), Verdict::Admit);
        assert_eq!(a.offer(0, ms_to_ns(500.0), 0, 0), Verdict::Admit);
        assert_eq!(
            a.offer(0, ms_to_ns(500.0), 0, 0),
            Verdict::Shed(ShedReason::RateLimit)
        );
    }

    #[test]
    fn stats_bucket_sheds_by_reason() {
        let mut s = TenantServeStats::new("a");
        s.offered = 3;
        s.admitted = 1;
        s.record_shed(ShedReason::QueueFull);
        s.record_shed(ShedReason::RateLimit);
        assert_eq!(s.shed(), 2);
        assert_eq!(s.shed_queue, 1);
        assert_eq!(s.shed_rate_limit, 1);
        assert_eq!(s.shed_deadline, 0);
    }
}
