//! Production serving front end (DESIGN.md §16).
//!
//! Three pieces sit between raw request traffic and the engines:
//!
//! * [`admission`] — a bounded admission gate with load-shedding
//!   policies (`none`, `tail-drop`, `deadline-drop`) and per-tenant
//!   token-bucket rate isolation, so one tenant's burst cannot
//!   inflate another tenant's tail latency.
//! * [`batch`] — a batch former (max size + max wait) whose batches
//!   flow through batch-dependent service times
//!   ([`crate::sim::stage_service_times_batched`]): VTA's GEMM core
//!   amortizes fetch/launch over a batch, so compute grows
//!   sub-linearly while transfer bytes stay linear.
//! * [`trace`] — an `arrival: trace` source replaying timestamped
//!   JSONL request logs (with a time-scale factor and multi-tenant
//!   routing) through [`crate::sim::run_des`].
//!
//! Like telemetry (§13), faults (§14) and metrics (§15), the whole
//! subsystem carries a zero-cost-off contract: with no `admission`/
//! `batch` block the DES takes exactly the pre-serve code path and
//! reports are byte-identical, and `batch.max_size = 1` is treated as
//! batching-off internally so it is byte-identical too (both pinned
//! by proptests).

pub mod admission;
pub mod batch;
pub mod trace;

pub use admission::{
    Admission, AdmissionConfig, ShedPolicy, ShedReason, TenantServeStats, Verdict,
};
pub use batch::{chunk, BatchConfig, BatchFormer, BatchMember, PushOutcome};
pub use trace::{captured_to_jsonl, RequestTrace};

/// Serving front-end wiring for one DES run (DESIGN.md §16).
///
/// `ServeConfig::off()` (the [`Default`]) disables everything: no
/// admission gate, no batch former, a single anonymous tenant — the
/// zero-cost-off configuration.
#[derive(Debug, Clone, Default)]
pub struct ServeConfig {
    /// Admission gate; `None` admits everything (today's behaviour).
    pub admission: Option<AdmissionConfig>,
    /// Batch former; `None` (or `max_size <= 1`) dispatches per image.
    pub batch: Option<BatchConfig>,
    /// Tenant names for request routing / per-tenant stats; empty
    /// means one anonymous tenant.
    pub tenants: Vec<String>,
}

impl ServeConfig {
    /// The do-nothing configuration (zero-cost-off).
    pub fn off() -> ServeConfig {
        ServeConfig::default()
    }

    /// True when the run needs no serve bookkeeping at all.
    pub fn is_off(&self) -> bool {
        self.admission.is_none() && self.batch.is_none() && self.tenants.len() <= 1
    }
}

/// Per-tenant serving outcome of one DES run, reported under the
/// Report's `serve` key and printed as the `vtacluster run`
/// per-tenant table.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    /// One entry per tenant, in tenant-index order.
    pub tenants: Vec<TenantServeStats>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_config_is_off() {
        assert!(ServeConfig::off().is_off());
        let one = ServeConfig {
            tenants: vec!["a".into()],
            ..ServeConfig::off()
        };
        assert!(one.is_off());
        let two = ServeConfig {
            tenants: vec!["a".into(), "b".into()],
            ..ServeConfig::off()
        };
        assert!(!two.is_off());
    }
}
