//! Trace replay (DESIGN.md §16): the `arrival: trace` source.
//!
//! A trace is a JSONL file, one request per line:
//!
//! ```text
//! {"t_ms": 12.5, "tenant": "a"}
//! {"t_ms": 13.0}                  // tenant defaults to "default"
//! ```
//!
//! Timestamps must be non-decreasing. A `time_scale` factor compresses
//! (>1) or stretches (<1) replay: wall time `t_ms / time_scale`.
//! Tenant names map to dense indices (sorted order) so the DES can
//! route each arrival through per-tenant admission buckets and report
//! per-tenant stats.

use crate::sim::ArrivalProcess;
use crate::util::json::{num, obj, str_, Json};
use crate::util::units::{ms_to_ns, ns_to_ms, Nanos};

/// Render captured `(t_ms, tenant)` admissions — the DES's
/// `capture: true` output — as replayable trace JSONL, one request per
/// line in this module's schema. Round-trips through
/// [`RequestTrace::parse`]: replaying a capture reproduces the offered
/// request count (unit-tested below; `run --capture-trace` writes this).
pub fn captured_to_jsonl(captured: &[(f64, String)]) -> anyhow::Result<String> {
    anyhow::ensure!(!captured.is_empty(), "nothing captured: no admitted requests");
    let mut out = String::new();
    let mut prev = 0.0f64;
    for (t, tenant) in captured {
        anyhow::ensure!(
            t.is_finite() && *t >= prev,
            "captured timestamps must be finite and non-decreasing (got {t} after {prev})"
        );
        anyhow::ensure!(!tenant.is_empty(), "captured tenant name must be non-empty");
        prev = *t;
        let line = obj(vec![("t_ms", num(*t)), ("tenant", str_(tenant))]);
        out.push_str(&line.to_string_compact());
        out.push('\n');
    }
    Ok(out)
}

/// A parsed, scaled request log ready to replay through the DES.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    /// Arrival times after scaling, non-decreasing.
    pub arrivals_ns: Vec<Nanos>,
    /// Tenant index per arrival, parallel to `arrivals_ns`.
    pub tenant_idx: Vec<usize>,
    /// Sorted unique tenant names; `tenant_idx` points here.
    pub tenant_names: Vec<String>,
}

impl RequestTrace {
    /// Parse JSONL text. `time_scale` > 0 divides every timestamp.
    pub fn parse(text: &str, time_scale: f64) -> anyhow::Result<RequestTrace> {
        anyhow::ensure!(
            time_scale.is_finite() && time_scale > 0.0,
            "arrival.time_scale must be finite and > 0 (got {time_scale})"
        );
        let mut raw: Vec<(f64, String)> = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let n = i + 1;
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("trace line {n}: {e}"))?;
            for (k, _) in j
                .as_obj()
                .map_err(|e| anyhow::anyhow!("trace line {n}: {e}"))?
            {
                anyhow::ensure!(
                    k == "t_ms" || k == "tenant",
                    "trace line {n}: unknown key '{k}' (t_ms|tenant)"
                );
            }
            let t = j
                .get_f64("t_ms")
                .map_err(|e| anyhow::anyhow!("trace line {n}: {e}"))?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "trace line {n}: t_ms must be finite and >= 0 (got {t})"
            );
            let tenant = match j.get("tenant") {
                Some(v) => v
                    .as_str()
                    .map_err(|e| anyhow::anyhow!("trace line {n}: tenant: {e}"))?
                    .to_string(),
                None => "default".to_string(),
            };
            anyhow::ensure!(!tenant.is_empty(), "trace line {n}: tenant must be non-empty");
            if let Some((prev, _)) = raw.last() {
                anyhow::ensure!(
                    t >= *prev,
                    "trace line {n}: t_ms {t} goes backwards (previous {prev})"
                );
            }
            raw.push((t, tenant));
        }
        anyhow::ensure!(!raw.is_empty(), "trace has no requests");
        let mut tenant_names: Vec<String> = raw.iter().map(|(_, t)| t.clone()).collect();
        tenant_names.sort();
        tenant_names.dedup();
        let arrivals_ns = raw.iter().map(|(t, _)| ms_to_ns(t / time_scale)).collect();
        let tenant_idx = raw
            .iter()
            .map(|(_, t)| tenant_names.binary_search(t).expect("name from raw"))
            .collect();
        Ok(RequestTrace {
            arrivals_ns,
            tenant_idx,
            tenant_names,
        })
    }

    /// Load a trace file. Relative paths are tried as given and then
    /// with a `../` prefix, so specs written repo-root-relative work
    /// from `rust/` too (same convention as the scenario loader).
    pub fn load(path: &str, time_scale: f64) -> anyhow::Result<RequestTrace> {
        let candidates = [
            std::path::PathBuf::from(path),
            std::path::Path::new("..").join(path),
        ];
        let found = candidates.iter().find(|p| p.is_file()).ok_or_else(|| {
            anyhow::anyhow!("trace file '{path}' not found (also tried ../{path})")
        })?;
        let text = std::fs::read_to_string(found)
            .map_err(|e| anyhow::anyhow!("{}: {e}", found.display()))?;
        Self::parse(&text, time_scale).map_err(|e| anyhow::anyhow!("{}: {e}", found.display()))
    }

    pub fn len(&self) -> usize {
        self.arrivals_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_ns.is_empty()
    }

    /// Time of the last request, ms (after scaling).
    pub fn span_ms(&self) -> f64 {
        ns_to_ms(self.arrivals_ns.last().copied().unwrap_or(0))
    }

    /// The DES arrival process replaying this trace.
    pub fn to_process(&self) -> ArrivalProcess {
        ArrivalProcess::Trace {
            arrivals_ns: self.arrivals_ns.clone(),
            tenants: self.tenant_idx.clone(),
            n_tenants: self.tenant_names.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TEXT: &str = "\
{\"t_ms\": 0.0, \"tenant\": \"b\"}\n\
{\"t_ms\": 2.0, \"tenant\": \"a\"}\n\
\n\
{\"t_ms\": 2.0}\n\
{\"t_ms\": 10.0, \"tenant\": \"a\"}\n";

    #[test]
    fn parses_scales_and_routes_tenants() {
        let tr = RequestTrace::parse(TEXT, 2.0).unwrap();
        assert_eq!(tr.len(), 4);
        // Names sorted: a, b, default.
        assert_eq!(tr.tenant_names, vec!["a", "b", "default"]);
        assert_eq!(tr.tenant_idx, vec![1, 0, 2, 0]);
        // time_scale 2 halves every timestamp.
        assert_eq!(tr.arrivals_ns, vec![0, ms_to_ns(1.0), ms_to_ns(1.0), ms_to_ns(5.0)]);
        assert_eq!(tr.span_ms(), 5.0);
        match tr.to_process() {
            ArrivalProcess::Trace {
                arrivals_ns,
                tenants,
                n_tenants,
            } => {
                assert_eq!(arrivals_ns.len(), 4);
                assert_eq!(tenants, tr.tenant_idx);
                assert_eq!(n_tenants, 3);
            }
            other => panic!("expected trace process, got {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed_traces() {
        assert!(RequestTrace::parse("", 1.0).is_err());
        assert!(RequestTrace::parse("{\"t_ms\": 1.0}", 0.0).is_err());
        assert!(RequestTrace::parse("{\"tenant\": \"a\"}", 1.0).is_err());
        assert!(RequestTrace::parse("{\"t_ms\": -1.0}", 1.0).is_err());
        assert!(RequestTrace::parse("{\"t_ms\": 1.0, \"who\": \"a\"}", 1.0).is_err());
        let back = "{\"t_ms\": 5.0}\n{\"t_ms\": 4.0}\n";
        let err = RequestTrace::parse(back, 1.0).unwrap_err().to_string();
        assert!(err.contains("goes backwards"), "{err}");
    }

    #[test]
    fn capture_round_trips_through_parse() {
        let captured = vec![
            (0.0, "default".to_string()),
            (1.5, "a".to_string()),
            (1.5, "default".to_string()),
            (9.25, "a".to_string()),
        ];
        let jsonl = captured_to_jsonl(&captured).unwrap();
        assert_eq!(jsonl.lines().count(), 4);
        let tr = RequestTrace::parse(&jsonl, 1.0).unwrap();
        assert_eq!(tr.len(), captured.len());
        assert_eq!(tr.tenant_names, vec!["a", "default"]);
        for (i, (t, tenant)) in captured.iter().enumerate() {
            assert_eq!(tr.arrivals_ns[i], ms_to_ns(*t));
            assert_eq!(&tr.tenant_names[tr.tenant_idx[i]], tenant);
        }
    }

    #[test]
    fn capture_writer_rejects_bad_input() {
        assert!(captured_to_jsonl(&[]).is_err());
        assert!(captured_to_jsonl(&[(f64::NAN, "a".to_string())]).is_err());
        assert!(
            captured_to_jsonl(&[(2.0, "a".to_string()), (1.0, "a".to_string())]).is_err()
        );
        assert!(captured_to_jsonl(&[(1.0, String::new())]).is_err());
    }

    #[test]
    fn load_reports_missing_files_with_both_candidates() {
        let err = RequestTrace::load("no/such/trace.jsonl", 1.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("also tried"), "{err}");
    }
}
