//! VTA instruction set: 128-bit instructions over four hardware modules
//! (fetch → load / compute / store) synchronised by dependency-token
//! queues (§II-B of the paper; Moreau et al. fig. 5).
//!
//! Encoding layout is our own documented packing (the Chisel RTL layout
//! is parameter-dependent); what matters for fidelity is the field set
//! and the queue semantics, both preserved exactly. Encode/decode is
//! round-trip tested by property tests.

/// Which on-chip memory a LOAD/STORE touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemType {
    /// Micro-op buffer (MICRO_OP_BUFFER_SIZE).
    Uop,
    /// Weight buffer (WEIGHT_BUFFER_SIZE), int8 block×block tiles.
    Wgt,
    /// Input buffer (INPUT_BUFFER_SIZE), int8 batch×block rows.
    Inp,
    /// Accumulator buffer (ACCUMULATOR_BUFFER_SIZE), int32 rows.
    Acc,
    /// Output path: STORE reads int8-narrowed accumulators to DRAM.
    Out,
}

impl MemType {
    pub fn code(self) -> u8 {
        match self {
            MemType::Uop => 0,
            MemType::Wgt => 1,
            MemType::Inp => 2,
            MemType::Acc => 3,
            MemType::Out => 4,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => MemType::Uop,
            1 => MemType::Wgt,
            2 => MemType::Inp,
            3 => MemType::Acc,
            4 => MemType::Out,
            _ => return None,
        })
    }
}

/// ALU micro-opcode (the VTA register-file vector unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Max,
    Min,
    /// Arithmetic shift right (requantization).
    Shr,
}

impl AluOp {
    pub fn code(self) -> u8 {
        match self {
            AluOp::Add => 0,
            AluOp::Max => 1,
            AluOp::Min => 2,
            AluOp::Shr => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        Some(match c {
            0 => AluOp::Add,
            1 => AluOp::Max,
            2 => AluOp::Min,
            3 => AluOp::Shr,
            _ => return None,
        })
    }
}

/// Dependency-queue flags: every instruction may pop a token from (wait
/// on) and/or push a token to (signal) its producer/consumer neighbour —
/// the RAW/WAR interlocks of §II-B.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DepFlags {
    pub pop_prev: bool,
    pub pop_next: bool,
    pub push_prev: bool,
    pub push_next: bool,
}

impl DepFlags {
    pub fn none() -> Self {
        Self::default()
    }

    fn bits(self) -> u128 {
        (self.pop_prev as u128)
            | (self.pop_next as u128) << 1
            | (self.push_prev as u128) << 2
            | (self.push_next as u128) << 3
    }

    fn from_bits(b: u128) -> Self {
        DepFlags {
            pop_prev: b & 1 != 0,
            pop_next: b & 2 != 0,
            push_prev: b & 4 != 0,
            push_next: b & 8 != 0,
        }
    }
}

/// A decoded VTA instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Insn {
    /// 2-D strided DMA: DRAM → SRAM (or SRAM → DRAM for `Out`).
    Load {
        dep: DepFlags,
        mem: MemType,
        /// Destination base in SRAM, in *elements* of the target buffer's
        /// granularity (uops / rows / tiles).
        sram_base: u32,
        /// Source base in DRAM, element-granular.
        dram_base: u32,
        /// Rows to transfer.
        y_size: u16,
        /// Elements per row.
        x_size: u16,
        /// DRAM stride between rows (elements).
        x_stride: u16,
    },
    Store {
        dep: DepFlags,
        sram_base: u32,
        dram_base: u32,
        y_size: u16,
        x_size: u16,
        x_stride: u16,
    },
    /// GEMM macro-instruction: run uops `[uop_bgn, uop_end)` inside a
    /// 2-level loop nest; affine index update per loop level.
    Gemm {
        dep: DepFlags,
        /// Zero the touched accumulators instead of accumulating.
        reset: bool,
        uop_bgn: u16,
        uop_end: u16,
        iter_out: u16,
        iter_in: u16,
        dst_factor_out: u16,
        dst_factor_in: u16,
        src_factor_out: u16,
        src_factor_in: u16,
        wgt_factor_out: u16,
        wgt_factor_in: u16,
    },
    /// ALU macro-instruction over accumulator rows.
    Alu {
        dep: DepFlags,
        op: AluOp,
        /// Use the immediate instead of a second accumulator operand.
        use_imm: bool,
        imm: i16,
        uop_bgn: u16,
        uop_end: u16,
        iter_out: u16,
        iter_in: u16,
        dst_factor_out: u16,
        dst_factor_in: u16,
        src_factor_out: u16,
        src_factor_in: u16,
    },
    /// End of program: compute module signals completion.
    Finish { dep: DepFlags },
}

const OP_LOAD: u128 = 0;
const OP_STORE: u128 = 1;
const OP_GEMM: u128 = 2;
const OP_FINISH: u128 = 3;
const OP_ALU: u128 = 4;

impl Insn {
    pub fn dep(&self) -> DepFlags {
        match self {
            Insn::Load { dep, .. }
            | Insn::Store { dep, .. }
            | Insn::Gemm { dep, .. }
            | Insn::Alu { dep, .. }
            | Insn::Finish { dep } => *dep,
        }
    }

    pub fn dep_mut(&mut self) -> &mut DepFlags {
        match self {
            Insn::Load { dep, .. }
            | Insn::Store { dep, .. }
            | Insn::Gemm { dep, .. }
            | Insn::Alu { dep, .. }
            | Insn::Finish { dep } => dep,
        }
    }

    /// Which module executes this instruction.
    pub fn module(&self) -> Module {
        match self {
            Insn::Load { mem, .. } => match mem {
                // uop/acc loads are issued to the compute module in VTA
                MemType::Uop | MemType::Acc => Module::Compute,
                _ => Module::Load,
            },
            Insn::Store { .. } => Module::Store,
            Insn::Gemm { .. } | Insn::Alu { .. } | Insn::Finish { .. } => Module::Compute,
        }
    }

    /// Pack to 128 bits. Layout: [0:3]=opcode, [3:7]=dep flags, then
    /// variant-specific fields (documented inline).
    pub fn encode(&self) -> u128 {
        match *self {
            Insn::Load { dep, mem, sram_base, dram_base, y_size, x_size, x_stride } => {
                OP_LOAD
                    | dep.bits() << 3
                    | (mem.code() as u128) << 7
                    | (sram_base as u128) << 10
                    | (dram_base as u128) << 42
                    | (y_size as u128) << 74
                    | (x_size as u128) << 90
                    | (x_stride as u128) << 106
            }
            Insn::Store { dep, sram_base, dram_base, y_size, x_size, x_stride } => {
                OP_STORE
                    | dep.bits() << 3
                    | (MemType::Out.code() as u128) << 7
                    | (sram_base as u128) << 10
                    | (dram_base as u128) << 42
                    | (y_size as u128) << 74
                    | (x_size as u128) << 90
                    | (x_stride as u128) << 106
            }
            Insn::Gemm {
                dep,
                reset,
                uop_bgn,
                uop_end,
                iter_out,
                iter_in,
                dst_factor_out,
                dst_factor_in,
                src_factor_out,
                src_factor_in,
                wgt_factor_out,
                wgt_factor_in,
            } => {
                OP_GEMM
                    | dep.bits() << 3
                    | (reset as u128) << 7
                    | (uop_bgn as u128) << 8
                    | (uop_end as u128) << 24
                    | (iter_out as u128) << 40
                    | (iter_in as u128) << 56
                    | (dst_factor_out as u128) << 72
                    | (dst_factor_in as u128) << 83
                    | (src_factor_out as u128) << 94
                    | (src_factor_in as u128) << 105
                    | (wgt_factor_out as u128) << 116
                    // wgt_factor_in gets the remaining bits [127 - ...]
                    | (wgt_factor_in as u128 & 0x1) << 127
            }
            Insn::Alu {
                dep,
                op,
                use_imm,
                imm,
                uop_bgn,
                uop_end,
                iter_out,
                iter_in,
                dst_factor_out,
                dst_factor_in,
                src_factor_out,
                src_factor_in,
            } => {
                OP_ALU
                    | dep.bits() << 3
                    | (op.code() as u128) << 7
                    | (use_imm as u128) << 9
                    | ((imm as u16) as u128) << 10
                    | (uop_bgn as u128) << 26
                    | (uop_end as u128) << 42
                    | (iter_out as u128) << 58
                    | (iter_in as u128) << 74
                    | (dst_factor_out as u128) << 90
                    | (dst_factor_in as u128) << 100
                    | (src_factor_out as u128) << 110
                    | ((src_factor_in as u128) & 0xFF) << 120
            }
            Insn::Finish { dep } => OP_FINISH | dep.bits() << 3,
        }
    }

    /// Decode from 128 bits; `None` on invalid opcode/fields.
    pub fn decode(bits: u128) -> Option<Insn> {
        let op = bits & 0x7;
        let dep = DepFlags::from_bits((bits >> 3) & 0xF);
        match op {
            OP_LOAD | OP_STORE => {
                let mem = MemType::from_code(((bits >> 7) & 0x7) as u8)?;
                let sram_base = ((bits >> 10) & 0xFFFF_FFFF) as u32;
                let dram_base = ((bits >> 42) & 0xFFFF_FFFF) as u32;
                let y_size = ((bits >> 74) & 0xFFFF) as u16;
                let x_size = ((bits >> 90) & 0xFFFF) as u16;
                let x_stride = ((bits >> 106) & 0xFFFF) as u16;
                if op == OP_LOAD {
                    Some(Insn::Load { dep, mem, sram_base, dram_base, y_size, x_size, x_stride })
                } else {
                    Some(Insn::Store { dep, sram_base, dram_base, y_size, x_size, x_stride })
                }
            }
            OP_GEMM => Some(Insn::Gemm {
                dep,
                reset: (bits >> 7) & 1 != 0,
                uop_bgn: ((bits >> 8) & 0xFFFF) as u16,
                uop_end: ((bits >> 24) & 0xFFFF) as u16,
                iter_out: ((bits >> 40) & 0xFFFF) as u16,
                iter_in: ((bits >> 56) & 0xFFFF) as u16,
                dst_factor_out: ((bits >> 72) & 0x7FF) as u16,
                dst_factor_in: ((bits >> 83) & 0x7FF) as u16,
                src_factor_out: ((bits >> 94) & 0x7FF) as u16,
                src_factor_in: ((bits >> 105) & 0x7FF) as u16,
                wgt_factor_out: ((bits >> 116) & 0x7FF) as u16,
                wgt_factor_in: ((bits >> 127) & 0x1) as u16,
            }),
            OP_ALU => Some(Insn::Alu {
                dep,
                op: AluOp::from_code(((bits >> 7) & 0x3) as u8)?,
                use_imm: (bits >> 9) & 1 != 0,
                imm: (((bits >> 10) & 0xFFFF) as u16) as i16,
                uop_bgn: ((bits >> 26) & 0xFFFF) as u16,
                uop_end: ((bits >> 42) & 0xFFFF) as u16,
                iter_out: ((bits >> 58) & 0xFFFF) as u16,
                iter_in: ((bits >> 74) & 0xFFFF) as u16,
                dst_factor_out: ((bits >> 90) & 0x3FF) as u16,
                dst_factor_in: ((bits >> 100) & 0x3FF) as u16,
                src_factor_out: ((bits >> 110) & 0x3FF) as u16,
                src_factor_in: ((bits >> 120) & 0xFF) as u16,
            }),
            OP_FINISH => Some(Insn::Finish { dep }),
            _ => None,
        }
    }
}

/// The four VTA hardware modules (fetch dispatches, so three execution
/// queues).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Module {
    Load,
    Compute,
    Store,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn roundtrip_simple() {
        let insns = vec![
            Insn::Load {
                dep: DepFlags { pop_next: true, ..Default::default() },
                mem: MemType::Inp,
                sram_base: 128,
                dram_base: 4096,
                y_size: 16,
                x_size: 16,
                x_stride: 224,
            },
            Insn::Gemm {
                dep: DepFlags { pop_prev: true, push_prev: true, ..Default::default() },
                reset: true,
                uop_bgn: 0,
                uop_end: 16,
                iter_out: 4,
                iter_in: 8,
                dst_factor_out: 16,
                dst_factor_in: 1,
                src_factor_out: 16,
                src_factor_in: 1,
                wgt_factor_out: 0,
                wgt_factor_in: 0,
            },
            Insn::Alu {
                dep: DepFlags::none(),
                op: AluOp::Shr,
                use_imm: true,
                imm: -11,
                uop_bgn: 2,
                uop_end: 5,
                iter_out: 10,
                iter_in: 1,
                dst_factor_out: 1,
                dst_factor_in: 0,
                src_factor_out: 1,
                src_factor_in: 0,
            },
            Insn::Store {
                dep: DepFlags { push_prev: true, ..Default::default() },
                sram_base: 0,
                dram_base: 1 << 20,
                y_size: 56,
                x_size: 64,
                x_stride: 64,
            },
            Insn::Finish { dep: DepFlags { pop_prev: true, ..Default::default() } },
        ];
        for insn in insns {
            let bits = insn.encode();
            assert_eq!(Insn::decode(bits), Some(insn));
        }
    }

    #[test]
    fn module_routing() {
        let l = Insn::Load {
            dep: DepFlags::none(),
            mem: MemType::Wgt,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        };
        assert_eq!(l.module(), Module::Load);
        // acc/uop loads go to the compute queue (as in VTA)
        let a = Insn::Load {
            dep: DepFlags::none(),
            mem: MemType::Acc,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        };
        assert_eq!(a.module(), Module::Compute);
        assert_eq!(Insn::Finish { dep: DepFlags::none() }.module(), Module::Compute);
    }

    #[test]
    fn invalid_opcode_decodes_none() {
        assert_eq!(Insn::decode(0x7), None);
        assert_eq!(Insn::decode(0x5), None);
    }

    #[test]
    fn prop_roundtrip_load_store() {
        forall("isa load/store roundtrip", 300, |rng| {
            let dep = DepFlags::from_bits(rng.below(16) as u128);
            let mem = MemType::from_code(rng.below(5) as u8).unwrap();
            let insn = Insn::Load {
                dep,
                mem,
                sram_base: rng.below(1 << 32) as u32,
                dram_base: rng.below(1 << 32) as u32,
                y_size: rng.below(1 << 16) as u16,
                x_size: rng.below(1 << 16) as u16,
                x_stride: rng.below(1 << 16) as u16,
            };
            let back = Insn::decode(insn.encode());
            crate::prop_assert_eq!(back, Some(insn));
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_gemm_alu() {
        forall("isa gemm/alu roundtrip", 300, |rng| {
            let dep = DepFlags::from_bits(rng.below(16) as u128);
            let g = Insn::Gemm {
                dep,
                reset: rng.below(2) == 1,
                uop_bgn: rng.below(1 << 16) as u16,
                uop_end: rng.below(1 << 16) as u16,
                iter_out: rng.below(1 << 16) as u16,
                iter_in: rng.below(1 << 16) as u16,
                dst_factor_out: rng.below(1 << 11) as u16,
                dst_factor_in: rng.below(1 << 11) as u16,
                src_factor_out: rng.below(1 << 11) as u16,
                src_factor_in: rng.below(1 << 11) as u16,
                wgt_factor_out: rng.below(1 << 11) as u16,
                wgt_factor_in: rng.below(2) as u16,
            };
            crate::prop_assert_eq!(Insn::decode(g.encode()), Some(g));
            let a = Insn::Alu {
                dep,
                op: AluOp::from_code(rng.below(4) as u8).unwrap(),
                use_imm: rng.below(2) == 1,
                imm: rng.range_i64(i16::MIN as i64, i16::MAX as i64 + 1) as i16,
                uop_bgn: rng.below(1 << 16) as u16,
                uop_end: rng.below(1 << 16) as u16,
                iter_out: rng.below(1 << 16) as u16,
                iter_in: rng.below(1 << 16) as u16,
                dst_factor_out: rng.below(1 << 10) as u16,
                dst_factor_in: rng.below(1 << 10) as u16,
                src_factor_out: rng.below(1 << 10) as u16,
                src_factor_in: rng.below(1 << 8) as u16,
            };
            crate::prop_assert_eq!(Insn::decode(a.encode()), Some(a));
            Ok(())
        });
    }
}
