//! A complete VTA program: instruction stream, micro-op table, and the
//! DRAM image layout it executes against.
//!
//! The compiler (`crate::compiler::lower`) produces these; `fsim` executes
//! them; `timing` prices them.

use super::isa::{DepFlags, Insn, MemType};
use crate::config::VtaConfig;

/// A GEMM/ALU micro-op: per-cycle SRAM indices (row/tile granular).
/// `dst` indexes the accumulator buffer, `src` the input buffer, `wgt`
/// the weight buffer. For ALU tensor-tensor ops `wgt` holds the second
/// accumulator operand index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Uop {
    pub dst: u16,
    pub src: u16,
    pub wgt: u16,
}

/// DRAM regions of a program image (element-granular offsets).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DramLayout {
    /// int8 input elements (row-major (M, K) for GEMM programs).
    pub inp_len: usize,
    /// int8 weight elements ((N, K) output-major).
    pub wgt_len: usize,
    /// int32 accumulator init region (optional bias).
    pub acc_len: usize,
    /// int8 output region length.
    pub out_len: usize,
}

/// A self-contained VTA program.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub name: String,
    pub insns: Vec<Insn>,
    pub uops: Vec<Uop>,
    pub dram: DramLayout,
}

impl Program {
    pub fn new(name: &str) -> Self {
        Program { name: name.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, insn: Insn) {
        self.insns.push(insn);
    }

    /// Add a uop, returning its index.
    pub fn push_uop(&mut self, u: Uop) -> u16 {
        self.uops.push(u);
        (self.uops.len() - 1) as u16
    }

    /// Static validation against a VTA configuration: every SRAM access
    /// must stay inside the configured buffer capacities and the uop
    /// ranges must exist. This is the "bitstream contract" check.
    pub fn validate(&self, cfg: &VtaConfig) -> anyhow::Result<()> {
        let inp_cap = cfg.input_rows_resident() as u64;
        let wgt_cap = cfg.weight_tiles_resident() as u64;
        let acc_cap = cfg.acc_rows_resident() as u64;
        let uop_cap = cfg.uop_buffer_bits / 32; // one uop = 32 bits in VTA
        anyhow::ensure!(
            (self.uops.len() as u64) <= uop_cap,
            "{}: {} uops exceed uop buffer ({} max)",
            self.name,
            self.uops.len(),
            uop_cap
        );
        for (i, insn) in self.insns.iter().enumerate() {
            match insn {
                Insn::Load { mem, sram_base, y_size, x_size, .. } => {
                    let end = *sram_base as u64 + (*y_size as u64) * (*x_size as u64);
                    let cap = match mem {
                        MemType::Inp => inp_cap,
                        MemType::Wgt => wgt_cap,
                        MemType::Acc => acc_cap,
                        MemType::Uop => uop_cap,
                        MemType::Out => anyhow::bail!("{}: LOAD to Out at insn {i}", self.name),
                    };
                    anyhow::ensure!(
                        end <= cap,
                        "{}: insn {i} LOAD {:?} range {end} exceeds capacity {cap}",
                        self.name,
                        mem
                    );
                }
                Insn::Store { sram_base, y_size, x_size, .. } => {
                    let end = *sram_base as u64 + (*y_size as u64) * (*x_size as u64);
                    anyhow::ensure!(
                        end <= acc_cap,
                        "{}: insn {i} STORE range {end} exceeds acc capacity {acc_cap}",
                        self.name
                    );
                }
                Insn::Gemm { uop_bgn, uop_end, iter_out, iter_in,
                             dst_factor_out, dst_factor_in,
                             src_factor_out, src_factor_in,
                             wgt_factor_out, wgt_factor_in, .. } => {
                    anyhow::ensure!(
                        uop_bgn < uop_end && (*uop_end as usize) <= self.uops.len(),
                        "{}: insn {i} GEMM uop range [{uop_bgn},{uop_end}) invalid",
                        self.name
                    );
                    anyhow::ensure!(
                        *iter_out >= 1 && *iter_in >= 1,
                        "{}: insn {i} GEMM zero iteration",
                        self.name
                    );
                    // max index reached over the loop nest must fit
                    let max_out = (*iter_out as u64 - 1) * *dst_factor_out as u64
                        + (*iter_in as u64 - 1) * *dst_factor_in as u64;
                    let max_src = (*iter_out as u64 - 1) * *src_factor_out as u64
                        + (*iter_in as u64 - 1) * *src_factor_in as u64;
                    let max_wgt = (*iter_out as u64 - 1) * *wgt_factor_out as u64
                        + (*iter_in as u64 - 1) * *wgt_factor_in as u64;
                    for u in &self.uops[*uop_bgn as usize..*uop_end as usize] {
                        anyhow::ensure!(
                            u.dst as u64 + max_out < acc_cap,
                            "{}: insn {i} GEMM dst overflow",
                            self.name
                        );
                        anyhow::ensure!(
                            u.src as u64 + max_src < inp_cap,
                            "{}: insn {i} GEMM src overflow",
                            self.name
                        );
                        anyhow::ensure!(
                            u.wgt as u64 + max_wgt < wgt_cap,
                            "{}: insn {i} GEMM wgt overflow",
                            self.name
                        );
                    }
                }
                Insn::Alu { uop_bgn, uop_end, iter_out, iter_in, .. } => {
                    anyhow::ensure!(
                        uop_bgn < uop_end && (*uop_end as usize) <= self.uops.len(),
                        "{}: insn {i} ALU uop range invalid",
                        self.name
                    );
                    anyhow::ensure!(*iter_out >= 1 && *iter_in >= 1,
                        "{}: insn {i} ALU zero iteration", self.name);
                }
                Insn::Finish { .. } => {}
            }
        }
        anyhow::ensure!(
            matches!(self.insns.last(), Some(Insn::Finish { .. })),
            "{}: program must end with FINISH",
            self.name
        );
        self.check_token_balance()?;
        Ok(())
    }

    /// Dependency tokens pushed and popped across each queue must balance,
    /// otherwise fsim/hardware deadlocks or leaks tokens.
    fn check_token_balance(&self) -> anyhow::Result<()> {
        // queues: (load→compute), (compute→load), (compute→store), (store→compute)
        let mut l2c: i64 = 0;
        let mut c2l: i64 = 0;
        let mut c2s: i64 = 0;
        let mut s2c: i64 = 0;
        use super::isa::Module;
        for insn in &self.insns {
            let d = insn.dep();
            match insn.module() {
                Module::Load => {
                    // load's "next" is compute
                    if d.push_next {
                        l2c += 1;
                    }
                    if d.pop_next {
                        c2l -= 1;
                    }
                }
                Module::Compute => {
                    // compute's prev is load, next is store
                    if d.pop_prev {
                        l2c -= 1;
                    }
                    if d.push_prev {
                        c2l += 1;
                    }
                    if d.push_next {
                        c2s += 1;
                    }
                    if d.pop_next {
                        s2c -= 1;
                    }
                }
                Module::Store => {
                    // store's prev is compute
                    if d.pop_prev {
                        c2s -= 1;
                    }
                    if d.push_prev {
                        s2c += 1;
                    }
                }
            }
        }
        anyhow::ensure!(
            l2c == 0 && c2l == 0 && c2s == 0 && s2c == 0,
            "{}: unbalanced dependency tokens (l2c={l2c}, c2l={c2l}, c2s={c2s}, s2c={s2c})",
            self.name
        );
        Ok(())
    }

    /// Total DRAM traffic in bytes (input+weight loads, acc loads ×4,
    /// output stores) — the memory-bound term of the timing model.
    pub fn dram_traffic_bytes(&self, cfg: &VtaConfig) -> u64 {
        let blk = cfg.block as u64;
        let mut bytes = 0u64;
        for insn in &self.insns {
            match insn {
                Insn::Load { mem, y_size, x_size, .. } => {
                    let elems = *y_size as u64 * *x_size as u64;
                    bytes += match mem {
                        MemType::Inp => elems * blk,       // rows of block int8
                        MemType::Wgt => elems * blk * blk, // block×block tiles
                        MemType::Acc => elems * blk * 4,   // int32 rows
                        MemType::Uop => elems * 4,         // 32-bit uops
                        MemType::Out => 0,
                    };
                }
                Insn::Store { y_size, x_size, .. } => {
                    bytes += *y_size as u64 * *x_size as u64 * blk; // int8 rows
                }
                _ => {}
            }
        }
        bytes
    }

    /// Total GEMM uop-cycles (one block-row × block×block tile MAC per
    /// cycle) — the compute-bound term.
    pub fn gemm_cycles(&self) -> u64 {
        self.insns
            .iter()
            .map(|i| match i {
                Insn::Gemm { uop_bgn, uop_end, iter_out, iter_in, .. } => {
                    (*uop_end as u64 - *uop_bgn as u64)
                        * *iter_out as u64
                        * *iter_in as u64
                }
                _ => 0,
            })
            .sum()
    }

    /// Total ALU uop-cycles.
    pub fn alu_cycles(&self) -> u64 {
        self.insns
            .iter()
            .map(|i| match i {
                Insn::Alu { uop_bgn, uop_end, iter_out, iter_in, .. } => {
                    (*uop_end as u64 - *uop_bgn as u64)
                        * *iter_out as u64
                        * *iter_in as u64
                }
                _ => 0,
            })
            .sum()
    }
}

/// Convenience for building dep flags.
pub fn dep(pop_prev: bool, pop_next: bool, push_prev: bool, push_next: bool) -> DepFlags {
    DepFlags { pop_prev, pop_next, push_prev, push_next }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VtaConfig;
    use crate::vta::isa::{AluOp, Insn};

    fn cfg() -> VtaConfig {
        VtaConfig::table1_zynq7000()
    }

    /// Minimal valid program: load 1 row + 1 tile, gemm, store.
    fn tiny_program() -> Program {
        let mut p = Program::new("tiny");
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        p.push(Insn::Load {
            dep: dep(false, false, false, true),
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Load {
            dep: dep(false, false, false, false),
            mem: MemType::Wgt,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Gemm {
            dep: dep(true, false, true, true),
            reset: true,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Store {
            dep: dep(true, false, true, false),
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        // close the loop: load pops compute's push_prev token; compute pops store's
        p.push(Insn::Load {
            dep: dep(false, true, false, false),
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 0,
            x_size: 0,
            x_stride: 0,
        });
        p.push(Insn::Finish { dep: dep(false, true, false, false) });
        p.dram = DramLayout { inp_len: 16, wgt_len: 256, acc_len: 0, out_len: 16 };
        p
    }

    #[test]
    fn tiny_program_validates() {
        tiny_program().validate(&cfg()).unwrap();
    }

    #[test]
    fn cycle_and_traffic_accounting() {
        let p = tiny_program();
        assert_eq!(p.gemm_cycles(), 1);
        assert_eq!(p.alu_cycles(), 0);
        // 1 input row (16 int8) + 1 weight tile (256 int8) + 1 out row (16)
        assert_eq!(p.dram_traffic_bytes(&cfg()), 16 + 256 + 16);
    }

    #[test]
    fn missing_finish_rejected() {
        let mut p = tiny_program();
        p.insns.pop();
        assert!(p.validate(&cfg()).unwrap_err().to_string().contains("FINISH"));
    }

    #[test]
    fn buffer_overflow_rejected() {
        let mut p = tiny_program();
        p.insns[0] = Insn::Load {
            dep: dep(false, false, false, true),
            mem: MemType::Inp,
            sram_base: 0,
            y_size: 1000,
            x_size: 1000,
            dram_base: 0,
            x_stride: 1000,
        };
        let e = p.validate(&cfg()).unwrap_err().to_string();
        assert!(e.contains("exceeds capacity"), "{e}");
    }

    #[test]
    fn unbalanced_tokens_rejected() {
        let mut p = tiny_program();
        // drop the final token-consuming load
        p.insns.remove(4);
        let e = p.validate(&cfg()).unwrap_err().to_string();
        assert!(e.contains("unbalanced"), "{e}");
    }

    #[test]
    fn bad_uop_range_rejected() {
        let mut p = tiny_program();
        if let Insn::Gemm { uop_end, .. } = &mut p.insns[2] {
            *uop_end = 99;
        }
        assert!(p.validate(&cfg()).is_err());
    }

    #[test]
    fn alu_cycles_counted() {
        let mut p = tiny_program();
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        p.insns.insert(
            3,
            Insn::Alu {
                dep: dep(false, false, false, false),
                op: AluOp::Shr,
                use_imm: true,
                imm: 8,
                uop_bgn: u,
                uop_end: u + 1,
                iter_out: 7,
                iter_in: 3,
                dst_factor_out: 0,
                dst_factor_in: 0,
                src_factor_out: 0,
                src_factor_in: 0,
            },
        );
        assert_eq!(p.alu_cycles(), 21);
        p.validate(&cfg()).unwrap();
    }
}
