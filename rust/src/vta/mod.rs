//! VTA (Versatile Tensor Accelerator) substrate.
//!
//! The paper deploys VTA (Moreau et al.) bitstreams on every node; we
//! rebuild it as an instruction-level model:
//!
//! * [`isa`]     — the 128-bit instruction set (LOAD/GEMM/ALU/STORE/FINISH)
//!                 with dependency-queue flags, encode/decode round-trip
//! * [`program`] — instruction stream + micro-op buffer + DRAM image
//! * [`fsim`]    — functional simulator: bit-exact int8/int32 execution
//!                 with RAW/WAR token semantics (validated against the
//!                 python oracle through the PJRT artifacts)
//! * [`timing`]  — cycle model: per-module service times + token-driven
//!                 overlap of load/compute/store (the virtual-thread
//!                 pipelining TVM generates), DRAM bandwidth limits
//!
//! The compiler (`crate::compiler`) lowers graph ops into [`program`]s;
//! the cluster simulator calls [`timing`] for node service times.

pub mod fsim;
pub mod isa;
pub mod program;
pub mod timing;

pub use isa::{AluOp, Insn, MemType};
pub use program::{Program, Uop};
pub use timing::{CycleReport, TimingModel};
