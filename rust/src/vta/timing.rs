//! VTA cycle/timing model.
//!
//! Prices a [`Program`] by replaying the same dependency-queue schedule as
//! `fsim`, but in the time domain: each module (load / compute / store)
//! serves its queue in order, token pops wait for the producer's
//! timestamp, and the makespan is the finish time of the last
//! instruction. This reproduces VTA's defining behaviour — **load and
//! store overlap with compute** through the RAW/WAR token pipeline, so a
//! program is memory-bound or compute-bound depending on which module's
//! busy time dominates (exactly the mechanism behind the §IV results:
//! clock scaling only helps the compute-bound share; larger buffers cut
//! DRAM traffic and help the memory-bound share).
//!
//! Calibrated constants (see `config::calibration`): GEMM pipeline
//! efficiency and effective DRAM bandwidth.

use super::isa::{Insn, MemType, Module};
use super::program::Program;
use crate::config::{BoardProfile, Calibration, VtaConfig};
use crate::util::units::{cycles_to_ns, us_to_ns, Nanos};
use std::collections::VecDeque;

/// Fixed DMA descriptor setup per LOAD/STORE instruction (cycles).
const DMA_SETUP_CYCLES: u64 = 64;
/// GEMM pipeline fill per macro-instruction (systolic array depth).
fn gemm_pipe_fill(block: u32) -> u64 {
    block as u64
}

/// Per-program cycle accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CycleReport {
    /// Makespan in cycles.
    pub total_cycles: u64,
    /// Busy cycles per module (≤ total, overlap is the point).
    pub load_busy: u64,
    pub compute_busy: u64,
    pub store_busy: u64,
    /// Raw GEMM/ALU uop cycles (pre-efficiency).
    pub gemm_cycles: u64,
    pub alu_cycles: u64,
    /// DRAM bytes moved.
    pub dram_bytes: u64,
}

impl CycleReport {
    /// Utilization of the GEMM core over the makespan.
    pub fn compute_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.gemm_cycles as f64 / self.total_cycles as f64
        }
    }

    /// True if the load module dominates (memory-bound program).
    pub fn memory_bound(&self) -> bool {
        self.load_busy > self.compute_busy
    }
}

/// The timing model for one node (board + bitstream + calibration).
#[derive(Debug, Clone)]
pub struct TimingModel {
    pub cfg: VtaConfig,
    pub board: BoardProfile,
    pub calib: Calibration,
}

impl TimingModel {
    pub fn new(cfg: VtaConfig, board: BoardProfile, calib: Calibration) -> Self {
        TimingModel { cfg, board, calib }
    }

    /// Effective DRAM bytes per PL cycle.
    fn dram_bytes_per_cycle(&self) -> f64 {
        self.board.dram_bw_bytes_per_sec as f64 * self.calib.dram_efficiency
            / self.cfg.clock_hz as f64
    }

    /// Cycle cost of one instruction on its module.
    fn insn_cycles(&self, insn: &Insn) -> u64 {
        let blk = self.cfg.block as u64;
        let dbpc = self.dram_bytes_per_cycle();
        match insn {
            Insn::Load { mem, y_size, x_size, .. } => {
                let elems = *y_size as u64 * *x_size as u64;
                let bytes = match mem {
                    MemType::Inp => elems * blk,
                    MemType::Wgt => elems * blk * blk,
                    MemType::Acc => elems * blk * 4,
                    MemType::Uop => elems * 4,
                    MemType::Out => 0,
                };
                DMA_SETUP_CYCLES + (bytes as f64 / dbpc).ceil() as u64
            }
            Insn::Store { y_size, x_size, .. } => {
                let bytes = *y_size as u64 * *x_size as u64 * blk;
                DMA_SETUP_CYCLES + (bytes as f64 / dbpc).ceil() as u64
            }
            Insn::Gemm { uop_bgn, uop_end, iter_out, iter_in, .. } => {
                let uops = (*uop_end as u64 - *uop_bgn as u64)
                    * *iter_out as u64
                    * *iter_in as u64;
                gemm_pipe_fill(self.cfg.block)
                    + (uops as f64 / self.calib.gemm_efficiency).ceil() as u64
            }
            Insn::Alu { uop_bgn, uop_end, iter_out, iter_in, .. } => {
                let uops = (*uop_end as u64 - *uop_bgn as u64)
                    * *iter_out as u64
                    * *iter_in as u64;
                // ALU reads+writes the int32 register file: 2 cycles/uop
                2 * uops
            }
            Insn::Finish { .. } => 1,
        }
    }

    /// Replay the token schedule in the time domain.
    pub fn price(&self, prog: &Program) -> anyhow::Result<CycleReport> {
        prog.validate(&self.cfg)?;
        let mut queues: [VecDeque<&Insn>; 3] =
            [VecDeque::new(), VecDeque::new(), VecDeque::new()];
        for insn in &prog.insns {
            let qi = match insn.module() {
                Module::Load => 0,
                Module::Compute => 1,
                Module::Store => 2,
            };
            queues[qi].push_back(insn);
        }
        // token queues carry the producer's finish timestamp
        let mut l2c: VecDeque<u64> = VecDeque::new();
        let mut c2l: VecDeque<u64> = VecDeque::new();
        let mut c2s: VecDeque<u64> = VecDeque::new();
        let mut s2c: VecDeque<u64> = VecDeque::new();
        let mut ready = [0u64; 3]; // module available-from time
        let mut report = CycleReport {
            gemm_cycles: prog.gemm_cycles(),
            alu_cycles: prog.alu_cycles(),
            dram_bytes: prog.dram_traffic_bytes(&self.cfg),
            ..Default::default()
        };

        loop {
            if queues.iter().all(|q| q.is_empty()) {
                break;
            }
            let mut progressed = false;
            for m in 0..3 {
                let Some(&insn) = queues[m].front() else { continue };
                let d = insn.dep();
                // determine the earliest start given tokens
                let mut start = ready[m];
                let tokens_ok = match insn.module() {
                    Module::Load => {
                        if d.pop_next {
                            match c2l.front() {
                                Some(&t) => {
                                    start = start.max(t);
                                    true
                                }
                                None => false,
                            }
                        } else {
                            true
                        }
                    }
                    Module::Compute => {
                        let a = if d.pop_prev {
                            match l2c.front() {
                                Some(&t) => {
                                    start = start.max(t);
                                    true
                                }
                                None => false,
                            }
                        } else {
                            true
                        };
                        let b = if d.pop_next {
                            match s2c.front() {
                                Some(&t) => {
                                    start = start.max(t);
                                    true
                                }
                                None => false,
                            }
                        } else {
                            true
                        };
                        a && b
                    }
                    Module::Store => {
                        if d.pop_prev {
                            match c2s.front() {
                                Some(&t) => {
                                    start = start.max(t);
                                    true
                                }
                                None => false,
                            }
                        } else {
                            true
                        }
                    }
                };
                if !tokens_ok {
                    continue;
                }
                // consume tokens
                match insn.module() {
                    Module::Load => {
                        if d.pop_next {
                            c2l.pop_front();
                        }
                    }
                    Module::Compute => {
                        if d.pop_prev {
                            l2c.pop_front();
                        }
                        if d.pop_next {
                            s2c.pop_front();
                        }
                    }
                    Module::Store => {
                        if d.pop_prev {
                            c2s.pop_front();
                        }
                    }
                }
                let cost = self.insn_cycles(insn);
                let finish = start + cost;
                ready[m] = finish;
                match insn.module() {
                    Module::Load => report.load_busy += cost,
                    Module::Compute => report.compute_busy += cost,
                    Module::Store => report.store_busy += cost,
                }
                // produce tokens
                match insn.module() {
                    Module::Load => {
                        if d.push_next {
                            l2c.push_back(finish);
                        }
                    }
                    Module::Compute => {
                        if d.push_prev {
                            c2l.push_back(finish);
                        }
                        if d.push_next {
                            c2s.push_back(finish);
                        }
                    }
                    Module::Store => {
                        if d.push_prev {
                            s2c.push_back(finish);
                        }
                    }
                }
                queues[m].pop_front();
                progressed = true;
            }
            if !progressed {
                anyhow::bail!("timing deadlock in '{}'", prog.name);
            }
        }
        report.total_cycles = ready.iter().copied().max().unwrap_or(0);
        Ok(report)
    }

    /// Wall-clock time of one program launch on this node: PL makespan at
    /// the configured clock plus the PS driver overhead.
    pub fn program_time_ns(&self, prog: &Program) -> anyhow::Result<Nanos> {
        let report = self.price(prog)?;
        Ok(self.report_time_ns(&report))
    }

    /// Convert an existing report to wall-clock ns (no re-pricing).
    pub fn report_time_ns(&self, report: &CycleReport) -> Nanos {
        cycles_to_ns(report.total_cycles, self.cfg.clock_hz)
            + us_to_ns(self.calib.driver_overhead_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::isa::Insn;
    use crate::vta::program::{dep, Program, Uop};

    fn model() -> TimingModel {
        TimingModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration { driver_overhead_us: 0.0, ..Default::default() },
        )
    }

    /// load(inp)+load(wgt) ∥ gemm chain: compute must overlap loads.
    fn overlapped_program(tiles: u16) -> Program {
        overlapped_program_iters(tiles, 64)
    }

    fn overlapped_program_iters(tiles: u16, iters: u16) -> Program {
        let mut p = Program::new("overlap");
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        for t in 0..tiles {
            p.push(Insn::Load {
                dep: dep(false, t > 0, false, false),
                mem: MemType::Inp,
                sram_base: 0,
                dram_base: 0,
                y_size: 8,
                x_size: 1,
                x_stride: 1,
            });
            p.push(Insn::Load {
                dep: dep(false, false, false, true),
                mem: MemType::Wgt,
                sram_base: 0,
                dram_base: 0,
                y_size: 4,
                x_size: 1,
                x_stride: 1,
            });
            p.push(Insn::Gemm {
                dep: dep(true, false, true, t + 1 == tiles),
                reset: t == 0,
                uop_bgn: u,
                uop_end: u + 1,
                iter_out: iters,
                iter_in: 1,
                dst_factor_out: 0,
                dst_factor_in: 0,
                src_factor_out: 0,
                src_factor_in: 0,
                wgt_factor_out: 0,
                wgt_factor_in: 0,
            });
        }
        // compute pushed `tiles` c2l tokens; loads popped tiles-1 → pop last
        p.push(Insn::Load {
            dep: dep(false, true, false, false),
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 0,
            x_size: 0,
            x_stride: 0,
        });
        p.push(Insn::Store {
            dep: dep(true, false, true, false),
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Finish { dep: dep(false, true, false, false) });
        p
    }

    #[test]
    fn overlap_reduces_makespan() {
        let m = model();
        let p = overlapped_program(8);
        let r = m.price(&p).unwrap();
        let serial = r.load_busy + r.compute_busy + r.store_busy;
        assert!(
            r.total_cycles < serial,
            "no overlap: makespan {} vs serial {}",
            r.total_cycles,
            serial
        );
        // and the makespan is at least the slowest module
        assert!(r.total_cycles >= r.load_busy.max(r.compute_busy).max(r.store_busy));
    }

    #[test]
    fn memory_vs_compute_bound_flips_with_clock() {
        // same program, huge clock → loads (clock-independent in seconds,
        // so more cycles at higher clock) dominate
        let p = overlapped_program_iters(8, 256); // compute-heavy
        let slow = model();
        let mut fast = model();
        fast.cfg.clock_hz = 1_000_000_000;
        fast.board.dram_bw_bytes_per_sec = 100_000_000; // starved DRAM
        let r_slow = slow.price(&p).unwrap();
        let r_fast = fast.price(&p).unwrap();
        assert!(!r_slow.memory_bound());
        assert!(r_fast.memory_bound());
    }

    #[test]
    fn gemm_efficiency_scales_compute() {
        let p = overlapped_program(4);
        let m1 = model();
        let mut m2 = model();
        m2.calib.gemm_efficiency = m1.calib.gemm_efficiency / 2.0;
        let r1 = m1.price(&p).unwrap();
        let r2 = m2.price(&p).unwrap();
        assert!(r2.compute_busy > (r1.compute_busy as f64 * 1.8) as u64);
    }

    #[test]
    fn time_includes_driver_overhead() {
        let mut m = model();
        m.calib.driver_overhead_us = 1000.0; // 1 ms
        let p = overlapped_program(2);
        let t = m.program_time_ns(&p).unwrap();
        assert!(t >= 1_000_000, "{t}");
    }

    #[test]
    fn report_totals_consistent() {
        let m = model();
        let p = overlapped_program(4);
        let r = m.price(&p).unwrap();
        assert_eq!(r.gemm_cycles, 4 * 64);
        assert!(r.dram_bytes > 0);
        assert!(r.compute_utilization() > 0.0 && r.compute_utilization() <= 1.0);
    }

    #[test]
    fn higher_clock_is_never_slower_in_seconds() {
        let p = overlapped_program(8);
        let m100 = model();
        let mut m300 = model();
        m300.cfg.clock_hz = 300_000_000;
        let t100 = m100.program_time_ns(&p).unwrap();
        let t300 = m300.program_time_ns(&p).unwrap();
        assert!(t300 <= t100, "300 MHz {t300} > 100 MHz {t100}");
    }
}
