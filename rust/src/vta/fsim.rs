//! VTA functional simulator: bit-exact execution of a [`Program`] with
//! real dependency-queue semantics.
//!
//! The three execution modules (load / compute / store) each consume
//! their instruction queue in order; an instruction only issues when the
//! dependency tokens it pops are available (RAW/WAR interlocks, §II-B).
//! The simulator round-robins the modules and detects deadlock — a
//! mis-compiled token pattern fails loudly here before it can produce a
//! silently-wrong timing estimate.
//!
//! Numerics are identical to `python/compile/kernels/ref.py`: int8
//! operands, int32 wrapping accumulation, arithmetic shifts, saturating
//! int8 store.

use super::isa::{AluOp, Insn, MemType, Module};
use super::program::Program;
use crate::config::VtaConfig;

/// DRAM image a program executes against. Regions are element-granular:
/// `inp` rows of `block` int8, `wgt` tiles of `block²` int8 (output-major
/// within the tile), `acc` rows of `block` int32, `out` rows of `block`
/// int8.
#[derive(Debug, Clone, Default)]
pub struct DramImage {
    pub inp: Vec<i8>,
    pub wgt: Vec<i8>,
    pub acc: Vec<i32>,
    pub out: Vec<i8>,
}

/// Execution statistics (also sanity-checked by tests).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunStats {
    pub load_insns: u64,
    pub compute_insns: u64,
    pub store_insns: u64,
    pub gemm_uops: u64,
    pub alu_uops: u64,
    /// Scheduling rounds where at least one module was token-stalled.
    pub stall_rounds: u64,
}

#[derive(Debug, thiserror::Error)]
pub enum FsimError {
    #[error("fsim deadlock in '{program}': tokens l2c={l2c} c2l={c2l} c2s={c2s} s2c={s2c}, pcs=[{pc_load},{pc_compute},{pc_store}]")]
    Deadlock {
        program: String,
        l2c: u32,
        c2l: u32,
        c2s: u32,
        s2c: u32,
        pc_load: usize,
        pc_compute: usize,
        pc_store: usize,
    },
    #[error("fsim dram out of range in '{0}': {1}")]
    DramRange(String, String),
}

struct Sram {
    inp: Vec<i8>,  // rows × block
    wgt: Vec<i8>,  // tiles × block²
    acc: Vec<i32>, // rows × block
}

/// Run `prog` against `dram`; the program must already `validate()`.
pub fn run(cfg: &VtaConfig, prog: &Program, dram: &mut DramImage) -> anyhow::Result<RunStats> {
    prog.validate(cfg)?;
    let blk = cfg.block as usize;
    let mut sram = Sram {
        inp: vec![0; cfg.input_rows_resident() as usize * blk],
        wgt: vec![0; cfg.weight_tiles_resident() as usize * blk * blk],
        acc: vec![0; cfg.acc_rows_resident() as usize * blk],
    };

    // split instructions into per-module queues, keeping program order
    let mut queues: [Vec<&Insn>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for insn in &prog.insns {
        let qi = match insn.module() {
            Module::Load => 0,
            Module::Compute => 1,
            Module::Store => 2,
        };
        queues[qi].push(insn);
    }
    let mut pc = [0usize; 3];
    // dependency-token counters (queue name = producer2consumer)
    let (mut l2c, mut c2l, mut c2s, mut s2c) = (0u32, 0u32, 0u32, 0u32);
    let mut stats = RunStats::default();

    loop {
        let done = (0..3).all(|m| pc[m] >= queues[m].len());
        if done {
            break;
        }
        let mut progressed = false;
        let mut stalled = false;
        for m in 0..3 {
            if pc[m] >= queues[m].len() {
                continue;
            }
            let insn = queues[m][pc[m]];
            let d = insn.dep();
            // can we pop the tokens this instruction needs?
            let ready = match insn.module() {
                Module::Load => !d.pop_next || c2l > 0, // load's next = compute
                Module::Compute => {
                    (!d.pop_prev || l2c > 0) && (!d.pop_next || s2c > 0)
                }
                Module::Store => !d.pop_prev || c2s > 0,
            };
            if !ready {
                stalled = true;
                continue;
            }
            // pop
            match insn.module() {
                Module::Load => {
                    if d.pop_next {
                        c2l -= 1;
                    }
                }
                Module::Compute => {
                    if d.pop_prev {
                        l2c -= 1;
                    }
                    if d.pop_next {
                        s2c -= 1;
                    }
                }
                Module::Store => {
                    if d.pop_prev {
                        c2s -= 1;
                    }
                }
            }
            execute(cfg, prog, insn, &mut sram, dram, &mut stats)?;
            // push
            match insn.module() {
                Module::Load => {
                    if d.push_next {
                        l2c += 1;
                    }
                    // push_prev from load is unused in VTA
                }
                Module::Compute => {
                    if d.push_prev {
                        c2l += 1;
                    }
                    if d.push_next {
                        c2s += 1;
                    }
                }
                Module::Store => {
                    if d.push_prev {
                        s2c += 1;
                    }
                }
            }
            match insn.module() {
                Module::Load => stats.load_insns += 1,
                Module::Compute => stats.compute_insns += 1,
                Module::Store => stats.store_insns += 1,
            }
            pc[m] += 1;
            progressed = true;
        }
        if stalled {
            stats.stall_rounds += 1;
        }
        if !progressed {
            return Err(FsimError::Deadlock {
                program: prog.name.clone(),
                l2c,
                c2l,
                c2s,
                s2c,
                pc_load: pc[0],
                pc_compute: pc[1],
                pc_store: pc[2],
            }
            .into());
        }
    }
    Ok(stats)
}

fn execute(
    cfg: &VtaConfig,
    prog: &Program,
    insn: &Insn,
    sram: &mut Sram,
    dram: &mut DramImage,
    stats: &mut RunStats,
) -> anyhow::Result<()> {
    let blk = cfg.block as usize;
    match insn {
        Insn::Load { mem, sram_base, dram_base, y_size, x_size, x_stride, .. } => {
            let (rows, cols, stride) = (*y_size as usize, *x_size as usize, *x_stride as usize);
            for r in 0..rows {
                for c in 0..cols {
                    let s_idx = *sram_base as usize + r * cols + c;
                    let d_idx = *dram_base as usize + r * stride + c;
                    match mem {
                        MemType::Inp => {
                            let (s, d) = (s_idx * blk, d_idx * blk);
                            bounds(&prog.name, d + blk, dram.inp.len(), "inp")?;
                            sram.inp[s..s + blk].copy_from_slice(&dram.inp[d..d + blk]);
                        }
                        MemType::Wgt => {
                            let t = blk * blk;
                            let (s, d) = (s_idx * t, d_idx * t);
                            bounds(&prog.name, d + t, dram.wgt.len(), "wgt")?;
                            sram.wgt[s..s + t].copy_from_slice(&dram.wgt[d..d + t]);
                        }
                        MemType::Acc => {
                            let (s, d) = (s_idx * blk, d_idx * blk);
                            bounds(&prog.name, d + blk, dram.acc.len(), "acc")?;
                            sram.acc[s..s + blk].copy_from_slice(&dram.acc[d..d + blk]);
                        }
                        MemType::Uop => { /* uops live in prog.uops */ }
                        MemType::Out => unreachable!("validated"),
                    }
                }
            }
        }
        Insn::Store { sram_base, dram_base, y_size, x_size, x_stride, .. } => {
            let (rows, cols, stride) = (*y_size as usize, *x_size as usize, *x_stride as usize);
            for r in 0..rows {
                for c in 0..cols {
                    let s_idx = (*sram_base as usize + r * cols + c) * blk;
                    let d_idx = (*dram_base as usize + r * stride + c) * blk;
                    bounds(&prog.name, d_idx + blk, dram.out.len(), "out")?;
                    for i in 0..blk {
                        // saturating int8 narrow (compiler emits explicit
                        // clips, making this a no-op in practice)
                        dram.out[d_idx + i] = sram.acc[s_idx + i].clamp(-128, 127) as i8;
                    }
                }
            }
        }
        Insn::Gemm {
            reset,
            uop_bgn,
            uop_end,
            iter_out,
            iter_in,
            dst_factor_out,
            dst_factor_in,
            src_factor_out,
            src_factor_in,
            wgt_factor_out,
            wgt_factor_in,
            ..
        } => {
            for i in 0..*iter_out as usize {
                for j in 0..*iter_in as usize {
                    for u in &prog.uops[*uop_bgn as usize..*uop_end as usize] {
                        let dst = (u.dst as usize
                            + i * *dst_factor_out as usize
                            + j * *dst_factor_in as usize)
                            * blk;
                        let src = (u.src as usize
                            + i * *src_factor_out as usize
                            + j * *src_factor_in as usize)
                            * blk;
                        let wgt = (u.wgt as usize
                            + i * *wgt_factor_out as usize
                            + j * *wgt_factor_in as usize)
                            * blk
                            * blk;
                        if *reset {
                            sram.acc[dst..dst + blk].fill(0);
                        } else {
                            for x in 0..blk {
                                let mut acc = sram.acc[dst + x];
                                for k in 0..blk {
                                    acc = acc.wrapping_add(
                                        (sram.inp[src + k] as i32)
                                            * (sram.wgt[wgt + x * blk + k] as i32),
                                    );
                                }
                                sram.acc[dst + x] = acc;
                            }
                        }
                        stats.gemm_uops += 1;
                    }
                }
            }
        }
        Insn::Alu {
            op,
            use_imm,
            imm,
            uop_bgn,
            uop_end,
            iter_out,
            iter_in,
            dst_factor_out,
            dst_factor_in,
            src_factor_out,
            src_factor_in,
            ..
        } => {
            for i in 0..*iter_out as usize {
                for j in 0..*iter_in as usize {
                    for u in &prog.uops[*uop_bgn as usize..*uop_end as usize] {
                        let dst = (u.dst as usize
                            + i * *dst_factor_out as usize
                            + j * *dst_factor_in as usize)
                            * blk;
                        let src = (u.src as usize
                            + i * *src_factor_out as usize
                            + j * *src_factor_in as usize)
                            * blk;
                        for x in 0..blk {
                            let a = sram.acc[dst + x];
                            let b = if *use_imm { *imm as i32 } else { sram.acc[src + x] };
                            sram.acc[dst + x] = match op {
                                AluOp::Add => a.wrapping_add(b),
                                AluOp::Max => a.max(b),
                                AluOp::Min => a.min(b),
                                AluOp::Shr => a >> (b & 31),
                            };
                        }
                        stats.alu_uops += 1;
                    }
                }
            }
        }
        Insn::Finish { .. } => {}
    }
    Ok(())
}

fn bounds(prog: &str, end: usize, len: usize, what: &str) -> anyhow::Result<()> {
    if end > len {
        return Err(FsimError::DramRange(
            prog.to_string(),
            format!("{what} access up to {end} exceeds region {len}"),
        )
        .into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::isa::Insn;
    use crate::vta::program::{dep, Program, Uop};

    fn cfg() -> VtaConfig {
        VtaConfig::table1_zynq7000()
    }

    /// Build a single-tile GEMM program: out = inp_row × wgt_tileᵀ.
    fn gemm1_program() -> Program {
        let mut p = Program::new("gemm1");
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        p.push(Insn::Load {
            dep: dep(false, false, false, false),
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Load {
            dep: dep(false, false, false, true),
            mem: MemType::Wgt,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        // reset then accumulate
        p.push(Insn::Gemm {
            dep: dep(true, false, false, false),
            reset: true,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Gemm {
            dep: dep(false, false, false, true),
            reset: false,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Store {
            dep: dep(true, false, true, false),
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Finish { dep: dep(false, true, false, false) });
        p
    }

    #[test]
    fn single_tile_gemm_matches_naive() {
        let cfg = cfg();
        let blk = cfg.block as usize;
        let mut rng = crate::util::rng::Rng::new(42);
        let mut dram = DramImage {
            inp: rng.i8_vec(blk),
            wgt: rng.i8_vec(blk * blk),
            acc: vec![],
            out: vec![0; blk],
        };
        let p = gemm1_program();
        let stats = run(&cfg, &p, &mut dram).unwrap();
        assert_eq!(stats.gemm_uops, 2); // reset + mac
        for x in 0..blk {
            let want: i32 = (0..blk)
                .map(|k| dram.inp[k] as i32 * dram.wgt[x * blk + k] as i32)
                .sum();
            assert_eq!(dram.out[x] as i32, want.clamp(-128, 127), "lane {x}");
        }
    }

    #[test]
    fn alu_shr_and_clip() {
        let cfg = cfg();
        let blk = cfg.block as usize;
        let mut p = Program::new("alu");
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        // acc starts at 0 after reset; ADD imm 100 → SHR 3 → 12
        p.push(Insn::Gemm {
            dep: dep(false, false, false, false),
            reset: true,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        for (op, imm) in [(AluOp::Add, 100i16), (AluOp::Shr, 3)] {
            // the SHR is the last compute op: signal the store module
            let last = op == AluOp::Shr;
            p.push(Insn::Alu {
                dep: dep(false, false, false, last),
                op,
                use_imm: true,
                imm,
                uop_bgn: u,
                uop_end: u + 1,
                iter_out: 1,
                iter_in: 1,
                dst_factor_out: 0,
                dst_factor_in: 0,
                src_factor_out: 0,
                src_factor_in: 0,
            });
        }
        p.push(Insn::Store {
            dep: dep(true, false, false, false),
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Finish { dep: dep(false, false, false, false) });
        let mut dram = DramImage { out: vec![0; blk], ..Default::default() };
        run(&cfg, &p, &mut dram).unwrap();
        assert!(dram.out.iter().all(|&v| v == 12), "{:?}", &dram.out[..4]);
    }

    #[test]
    fn negative_shr_is_arithmetic() {
        let cfg = cfg();
        let blk = cfg.block as usize;
        let mut p = Program::new("ashr");
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        p.push(Insn::Gemm {
            dep: dep(false, false, false, false),
            reset: true,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Alu {
            dep: dep(false, false, false, false),
            op: AluOp::Add,
            use_imm: true,
            imm: -100,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
        });
        p.push(Insn::Alu {
            dep: dep(false, false, false, true),
            op: AluOp::Shr,
            use_imm: true,
            imm: 3,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
        });
        p.push(Insn::Store {
            dep: dep(true, false, false, false),
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Finish { dep: dep(false, false, false, false) });
        let mut dram = DramImage { out: vec![0; blk], ..Default::default() };
        run(&cfg, &p, &mut dram).unwrap();
        // -100 >> 3 = -13 (arithmetic floor), not -12
        assert!(dram.out.iter().all(|&v| v == -13));
    }

    #[test]
    fn deadlock_detected() {
        let mut p = Program::new("deadlock");
        p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        // compute pops a token load never pushes — push/pop totals balance
        // (so static validation passes) but order guarantees a runtime
        // deadlock: compute waits on load, load waits on compute.
        p.push(Insn::Load {
            dep: dep(false, true, false, true), // pop_next first: waits for compute
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Gemm {
            dep: dep(true, false, true, false), // waits for load
            reset: true,
            uop_bgn: 0,
            uop_end: 1,
            iter_out: 1,
            iter_in: 1,
            dst_factor_out: 0,
            dst_factor_in: 0,
            src_factor_out: 0,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Finish { dep: dep(false, false, false, false) });
        let mut dram = DramImage {
            inp: vec![0; 16],
            ..Default::default()
        };
        let err = run(&cfg(), &p, &mut dram).unwrap_err().to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn dram_oob_is_error() {
        let p = gemm1_program();
        let mut dram = DramImage {
            inp: vec![0; 4], // too small: needs 16
            wgt: vec![0; 256],
            acc: vec![],
            out: vec![0; 16],
        };
        let err = run(&cfg(), &p, &mut dram).unwrap_err().to_string();
        assert!(err.contains("exceeds region"), "{err}");
    }

    #[test]
    fn loop_nest_factors_apply() {
        // 2 output rows from 2 input rows × same tile: iter_out=2,
        // dst_factor_out=1, src_factor_out=1.
        let cfg = cfg();
        let blk = cfg.block as usize;
        let mut p = Program::new("nest");
        let u = p.push_uop(Uop { dst: 0, src: 0, wgt: 0 });
        p.push(Insn::Load {
            dep: dep(false, false, false, false),
            mem: MemType::Inp,
            sram_base: 0,
            dram_base: 0,
            y_size: 2,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Load {
            dep: dep(false, false, false, true),
            mem: MemType::Wgt,
            sram_base: 0,
            dram_base: 0,
            y_size: 1,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Gemm {
            dep: dep(true, false, false, false),
            reset: true,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 2,
            iter_in: 1,
            dst_factor_out: 1,
            dst_factor_in: 0,
            src_factor_out: 1,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Gemm {
            dep: dep(false, false, false, true),
            reset: false,
            uop_bgn: u,
            uop_end: u + 1,
            iter_out: 2,
            iter_in: 1,
            dst_factor_out: 1,
            dst_factor_in: 0,
            src_factor_out: 1,
            src_factor_in: 0,
            wgt_factor_out: 0,
            wgt_factor_in: 0,
        });
        p.push(Insn::Store {
            dep: dep(true, false, true, false),
            sram_base: 0,
            dram_base: 0,
            y_size: 2,
            x_size: 1,
            x_stride: 1,
        });
        p.push(Insn::Finish { dep: dep(false, true, false, false) });

        let mut rng = crate::util::rng::Rng::new(7);
        let mut dram = DramImage {
            inp: rng.i8_vec(2 * blk),
            wgt: rng.i8_vec(blk * blk),
            acc: vec![],
            out: vec![0; 2 * blk],
        };
        run(&cfg, &p, &mut dram).unwrap();
        for r in 0..2 {
            for x in 0..blk {
                let want: i32 = (0..blk)
                    .map(|k| dram.inp[r * blk + k] as i32 * dram.wgt[x * blk + k] as i32)
                    .sum();
                assert_eq!(dram.out[r * blk + x] as i32, want.clamp(-128, 127));
            }
        }
    }
}
