//! The fifth scheduling strategy: minimize J/image subject to a latency
//! SLO (DESIGN.md §11).
//!
//! The four §II-C strategies answer "how fast can this cluster go?";
//! [`eco_plan`] answers the question the paper's power-efficiency goal
//! actually poses: *of the schedules that are fast enough, which burns
//! the fewest joules per inference?* It prices every base strategy with
//! the metered analytic simulator, keeps the candidates whose unloaded
//! latency meets the SLO, and returns the one with the lowest J/image —
//! re-tagged [`Strategy::Eco`] so reports show what selected it. With no
//! SLO every candidate qualifies and the pick is the pure energy
//! optimum; if *no* candidate meets the SLO the lowest-latency plan is
//! returned with [`EcoChoice::meets_slo`] = false so callers can warn
//! instead of silently violating their deadline.

use crate::config::ClusterConfig;
use crate::graph::Graph;
use crate::sched::{build_plan_priced, ExecutionPlan, Strategy};
use crate::sim::{simulate, CostModel, SimConfig};

/// What [`eco_plan`] picked and why.
#[derive(Debug, Clone)]
pub struct EcoChoice {
    /// The winning plan, `strategy` re-tagged to [`Strategy::Eco`].
    pub plan: ExecutionPlan,
    /// The base §II-C strategy the winning schedule came from.
    pub base: Strategy,
    pub j_per_image: f64,
    pub ms_per_image: f64,
    /// Unloaded latency the SLO was checked against, ms.
    pub latency_ms: f64,
    /// Steady-state cluster draw at saturation, W.
    pub cluster_w: f64,
    /// False when no candidate met the SLO and the lowest-latency plan
    /// was returned as the least-bad fallback.
    pub meets_slo: bool,
}

/// Build the energy-optimal plan for `g` over `cluster` under an
/// optional unloaded-latency SLO (ms).
pub fn eco_plan(
    g: &Graph,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
    slo_ms: Option<f64>,
) -> anyhow::Result<EcoChoice> {
    eco_plan_batched(g, cluster, cost, slo_ms, 1)
}

/// [`eco_plan`] with batch-aware candidate construction (DESIGN.md §17):
/// the §II-C candidates are built from the per-image cost table at
/// `batch` images per launch, so a batching scenario's eco pick reflects
/// the amortized knee instead of batch=1 segment times. `batch <= 1` is
/// bit-identical to [`eco_plan`].
pub fn eco_plan_batched(
    g: &Graph,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
    slo_ms: Option<f64>,
    batch: u64,
) -> anyhow::Result<EcoChoice> {
    if let Some(slo) = slo_ms {
        anyhow::ensure!(slo.is_finite() && slo > 0.0, "latency SLO must be > 0");
    }
    anyhow::ensure!(batch >= 1, "batch must be ≥ 1");
    let n = cluster.num_nodes();
    let seg_costs = cost.seg_cost_table_batched(g, batch)?;
    let mut candidates = Vec::with_capacity(4);
    for s in Strategy::all() {
        let plan = build_plan_priced(s, g, n, &seg_costs)?;
        let sim = simulate(&plan, cluster, cost, g, &SimConfig { images: 16 })?;
        candidates.push(EcoChoice {
            plan,
            base: s,
            j_per_image: sim.power.j_per_image,
            ms_per_image: sim.ms_per_image,
            latency_ms: sim.latency_ms.mean(),
            cluster_w: sim.power.cluster_avg_w,
            meets_slo: slo_ms.map(|slo| sim.latency_ms.mean() <= slo).unwrap_or(true),
        });
    }
    // min J/image over the SLO-feasible set; if the SLO filtered out
    // everything, fall back to the lowest-latency plan (flagged)
    let any_ok = candidates.iter().any(|x| x.meets_slo);
    let mut best = candidates
        .into_iter()
        .filter(|x| !any_ok || x.meets_slo)
        .min_by(|a, b| {
            if any_ok {
                a.j_per_image.partial_cmp(&b.j_per_image).unwrap()
            } else {
                a.latency_ms.partial_cmp(&b.latency_ms).unwrap()
            }
        })
        .expect("four candidates always exist");
    best.plan.strategy = Strategy::Eco;
    best.plan.validate_for(g)?;
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardFamily, BoardProfile, Calibration, VtaConfig};
    use crate::graph::zoo;

    fn setup(n: usize) -> (Graph, ClusterConfig, CostModel) {
        let g = zoo::build("resnet18", 0).unwrap();
        let cluster = ClusterConfig::homogeneous(BoardFamily::Zynq7000, n);
        let cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        (g, cluster, cost)
    }

    #[test]
    fn eco_is_energy_minimal_among_slo_feasible() {
        let (g, cluster, mut cost) = setup(4);
        let choice = eco_plan(&g, &cluster, &mut cost, None).unwrap();
        assert_eq!(choice.plan.strategy, Strategy::Eco);
        assert!(choice.meets_slo);
        // with no SLO the pick must not lose on J/image to any base plan
        let seg_costs = cost.seg_cost_table(&g).unwrap();
        for s in Strategy::all() {
            let plan = build_plan_priced(s, &g, 4, &seg_costs).unwrap();
            let sim =
                simulate(&plan, &cluster, &mut cost, &g, &SimConfig { images: 16 }).unwrap();
            assert!(
                choice.j_per_image <= sim.power.j_per_image * 1.0001,
                "{s}: {} J beats eco's {} J",
                sim.power.j_per_image,
                choice.j_per_image
            );
        }
    }

    #[test]
    fn tight_slo_changes_or_flags_the_pick() {
        let (g, cluster, mut cost) = setup(4);
        let free = eco_plan(&g, &cluster, &mut cost, None).unwrap();
        // an SLO nobody can meet → lowest-latency fallback, flagged
        let strict = eco_plan(&g, &cluster, &mut cost, Some(1e-3)).unwrap();
        assert!(!strict.meets_slo);
        // the fallback optimizes latency, so it cannot be slower than
        // the unconstrained energy pick
        assert!(strict.latency_ms <= free.latency_ms * 1.0001);
        // a generous SLO reproduces the unconstrained pick
        let loose = eco_plan(&g, &cluster, &mut cost, Some(1e6)).unwrap();
        assert_eq!(loose.base, free.base);
        assert!(loose.meets_slo);
    }

    #[test]
    fn rejects_bad_slo() {
        let (g, cluster, mut cost) = setup(2);
        assert!(eco_plan(&g, &cluster, &mut cost, Some(0.0)).is_err());
        assert!(eco_plan(&g, &cluster, &mut cost, Some(f64::NAN)).is_err());
        assert!(eco_plan_batched(&g, &cluster, &mut cost, None, 0).is_err());
    }

    #[test]
    fn batched_eco_matches_unbatched_at_batch_one() {
        let (g, cluster, mut cost) = setup(4);
        let plain = eco_plan(&g, &cluster, &mut cost, None).unwrap();
        let b1 = eco_plan_batched(&g, &cluster, &mut cost, None, 1).unwrap();
        assert_eq!(plain.base, b1.base);
        assert_eq!(plain.plan, b1.plan);
        assert_eq!(plain.j_per_image, b1.j_per_image);
        // a real batch still yields a valid eco pick
        let b8 = eco_plan_batched(&g, &cluster, &mut cost, None, 8).unwrap();
        b8.plan.validate_for(&g).unwrap();
    }
}
