//! Power and energy subsystem (DESIGN.md §11).
//!
//! The paper targets "the best performance regarding latency **and
//! power efficiency**"; this module supplies the second axis:
//!
//! * [`model`]  — per-board electrical model: PS/PL static floor, PL
//!               dynamic draw scaled by the active VTA config's
//!               DSP/BRAM/LUT footprint and clock, DRAM/Ethernet pJ per
//!               byte, switch-port and reconfiguration power
//! * [`meter`]  — the shared energy accounting: the analytic simulator's
//!               per-image [`PowerReport`] and the DES's time-integrated
//!               [`EnergyReport`], built from the same terms so the two
//!               pin each other (property-tested to < 5 %)
//! * [`eco`]    — the fifth scheduling strategy: minimize J/image
//!               subject to a latency SLO
//! * [`pareto`] — the latency-vs-watts frontier over (board family ×
//!               node count × strategy), behind the CLI `power`
//!               subcommand

pub mod eco;
pub mod meter;
pub mod model;
pub mod pareto;

pub use eco::{eco_plan, eco_plan_batched, EcoChoice};
pub use meter::{analytic_power, integrate_energy, EnergyReport, PowerReport};
pub use model::{PlUsage, PowerModel};
pub use pareto::{
    frontier, most_efficient, pareto_sweep, search_for_family, ParetoPoint,
};
