//! Energy metering shared by both simulators (DESIGN.md §11).
//!
//! Both simulators already agree on *time* through one demand accounting
//! (the 5 % throughput pin in `tests/proptests.rs`); this module makes
//! them agree on *energy* by construction, the same way:
//!
//! * the **analytic** meter ([`analytic_power`]) prices one steady-state
//!   image period: every node draws the idle floor for the whole period
//!   plus PL dynamic power for its busy share (`utilization × period` =
//!   the node's per-image demand), the switch powers `n + 1` ports, and
//!   DRAM/Ethernet pay per byte the steady-state model already counts;
//! * the **DES** meter ([`integrate_energy`]) integrates the identical
//!   terms over the run: idle floor × horizon, dynamic × the per-node
//!   `busy_ns` the event loop records, per-byte energy on the bytes
//!   actually delivered inside the horizon, plus the reconfiguration
//!   overdraw for every plan switch the controller executed.
//!
//! At saturation `horizon / completed` converges to the analytic image
//! period and `busy_ns / completed` to the per-image demand, so
//! DES-integrated J/image pins analytic J/image — property-tested to
//! < 5 % alongside the throughput pin.

use super::model::PowerModel;
use crate::config::vta::VtaConfig;

/// Steady-state power figures of one [`crate::sched::ExecutionPlan`]
/// (attached to every [`crate::sim::SimResult`]).
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Average electrical draw per node (idle floor + dynamic × busy
    /// share; per-byte DRAM/Ethernet energy is reported cluster-wide), W.
    pub node_watts: Vec<f64>,
    /// Average cluster draw at steady state, switch ports included, W.
    pub cluster_avg_w: f64,
    /// Worst-case draw: every node computing at once, all ports lit, W.
    pub cluster_peak_w: f64,
    /// Energy per inference, J.
    pub j_per_image: f64,
    /// Throughput per watt = `(1000 / ms_per_image) / cluster_avg_w`
    /// (equivalently `1 / j_per_image`), images/s/W.
    pub img_per_sec_per_w: f64,
    /// Energy-delay product: `j_per_image × unloaded latency (s)`, J·s.
    pub edp_j_s: f64,
}

/// Energy a DES run actually consumed (attached to every
/// [`crate::sim::DesResult`]).
#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// Total cluster energy over the horizon, J.
    pub total_j: f64,
    /// `total_j / completed`; 0 when nothing completed.
    pub j_per_image: f64,
    /// `total_j / horizon`, W.
    pub avg_cluster_w: f64,
    /// Highest control-window draw observed, W (≥ `avg_cluster_w`).
    pub peak_window_w: f64,
    /// Energy charged to reconfigurations (idle floor + config-port
    /// overdraw over the modeled downtime, every node), J.
    pub reconfig_j: f64,
    /// Energy-delay product: `j_per_image × mean latency (s)`, J·s.
    pub edp_j_s: f64,
    /// Average draw per node (idle floor + its integrated dynamic), W.
    pub node_avg_w: Vec<f64>,
}

/// DRAM bytes one inference moves: the weights streamed through the
/// accelerator once per image plus both DMA sides (into DRAM at the
/// receiver, out of DRAM at the sender) of every wire byte. Activation
/// reuse inside the PL SRAM buffers is deliberately not charged — it is
/// what the buffers are for.
pub fn dram_bytes_per_image(weight_bytes: u64, wire_bytes: f64) -> f64 {
    weight_bytes as f64 + 2.0 * wire_bytes
}

/// Price one steady-state image period of a plan. `utilization` is the
/// per-node demand share of the bottleneck (from `sim::cluster`),
/// `ms_per_image` the bottleneck period, `net_bytes_per_image` the wire
/// bytes the demand accounting counted, `weight_bytes` the model's
/// parameter footprint and `latency_ms` the unloaded latency (for EDP).
pub fn analytic_power(
    pm: &PowerModel,
    cfg: &VtaConfig,
    utilization: &[f64],
    ms_per_image: f64,
    net_bytes_per_image: f64,
    weight_bytes: u64,
    latency_ms: f64,
) -> PowerReport {
    let n = utilization.len();
    let dyn_w = pm.pl_dynamic_w(cfg);
    let period_s = ms_per_image / 1e3;
    let switch_w = (n as f64 + 1.0) * pm.switch_port_w;

    let node_watts: Vec<f64> =
        utilization.iter().map(|&u| pm.idle_w() + dyn_w * u).collect();
    let compute_j: f64 = node_watts.iter().map(|w| w * period_s).sum();
    let io_j = pm.dram_j(dram_bytes_per_image(weight_bytes, net_bytes_per_image))
        + pm.eth_j(net_bytes_per_image);
    let j_per_image = compute_j + switch_w * period_s + io_j;

    let cluster_avg_w = j_per_image / period_s;
    let cluster_peak_w = n as f64 * (pm.idle_w() + dyn_w) + switch_w;
    PowerReport {
        node_watts,
        cluster_avg_w,
        cluster_peak_w,
        j_per_image,
        img_per_sec_per_w: 1.0 / j_per_image,
        edp_j_s: j_per_image * latency_ms / 1e3,
    }
}

/// Inputs the DES hands the integrator at the end of a run.
pub struct DesEnergyInputs<'a> {
    /// Simulated horizon, ns.
    pub horizon_ns: u64,
    /// Per-node busy time (compute + blocking-MPI share), ns, already
    /// clipped at the horizon by the event loop.
    pub busy_ns: &'a [u64],
    /// Images whose logits reached the master inside the horizon.
    pub completed: u64,
    /// Wire bytes of transfers *delivered* inside the horizon (booked
    /// bytes whose arrival fell beyond it carry no energy yet).
    pub delivered_bytes: u64,
    /// Model parameter footprint (weights streamed once per image), B.
    pub weight_bytes: u64,
    /// Total reconfiguration downtime charged by the controller, ms.
    pub reconfig_downtime_ms: f64,
    /// Config-port overdraw above the idle floor, W (the idle share of a
    /// switch is already inside the static integral).
    pub reconfig_overdraw_w: f64,
    /// Per-control-window cluster draw samples, W (for the peak).
    pub window_w: &'a [f64],
    /// Mean end-to-end latency, ms (for EDP).
    pub mean_latency_ms: f64,
}

/// Integrate cluster energy over a DES run — same per-component terms
/// as [`analytic_power`], integrated instead of amortized.
pub fn integrate_energy(pm: &PowerModel, cfg: &VtaConfig, inp: &DesEnergyInputs) -> EnergyReport {
    let n = inp.busy_ns.len();
    let dyn_w = pm.pl_dynamic_w(cfg);
    let horizon_s = inp.horizon_ns as f64 / 1e9;
    let switch_w = (n as f64 + 1.0) * pm.switch_port_w;

    let node_avg_w: Vec<f64> = inp
        .busy_ns
        .iter()
        .map(|&b| pm.idle_w() + dyn_w * (b as f64 / 1e9) / horizon_s.max(1e-12))
        .collect();
    let compute_j: f64 = node_avg_w.iter().map(|w| w * horizon_s).sum();
    let io_j = pm
        .dram_j(dram_bytes_per_image(0, inp.delivered_bytes as f64))
        + pm.eth_j(inp.delivered_bytes as f64)
        + pm.dram_j(inp.weight_bytes as f64 * inp.completed as f64);
    // downtime idle draw is inside the static integral; charge only the
    // configuration-port overdraw, on every node per switch
    let reconfig_j =
        inp.reconfig_downtime_ms / 1e3 * inp.reconfig_overdraw_w * n as f64;
    let total_j = compute_j + switch_w * horizon_s + io_j + reconfig_j;

    let avg_cluster_w = total_j / horizon_s.max(1e-12);
    let j_per_image =
        if inp.completed > 0 { total_j / inp.completed as f64 } else { 0.0 };
    let peak_window_w = inp
        .window_w
        .iter()
        .copied()
        .fold(avg_cluster_w, f64::max);
    EnergyReport {
        total_j,
        j_per_image,
        avg_cluster_w,
        peak_window_w,
        reconfig_j,
        edp_j_s: j_per_image * inp.mean_latency_ms / 1e3,
        node_avg_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pm() -> PowerModel {
        PowerModel::zynq7020()
    }

    fn cfg() -> VtaConfig {
        VtaConfig::table1_zynq7000()
    }

    #[test]
    fn analytic_bounds_and_identities() {
        let util = [1.0, 0.5, 0.25];
        let r = analytic_power(&pm(), &cfg(), &util, 10.0, 200_000.0, 1_000_000, 30.0);
        // every node between idle floor and active ceiling
        for (&u, &w) in util.iter().zip(&r.node_watts) {
            assert!(w >= pm().idle_w() - 1e-9, "node {w} below idle");
            assert!(w <= pm().active_w(&cfg()) + 1e-9, "node {w} above active");
            assert!(w > pm().idle_w() || u == 0.0);
        }
        assert!(r.cluster_peak_w >= r.cluster_avg_w);
        // img/s/W is exactly the reciprocal of J/image
        assert!((r.img_per_sec_per_w * r.j_per_image - 1.0).abs() < 1e-9);
        // EDP = J/img × latency
        assert!((r.edp_j_s - r.j_per_image * 0.030).abs() < 1e-12);
    }

    #[test]
    fn idle_cluster_draws_the_floor() {
        let r = analytic_power(&pm(), &cfg(), &[0.0, 0.0], 5.0, 0.0, 0, 5.0);
        let floor = 2.0 * pm().idle_w() + 3.0 * pm().switch_port_w;
        assert!((r.cluster_avg_w - floor).abs() < 1e-9, "{}", r.cluster_avg_w);
    }

    #[test]
    fn des_integral_matches_analytic_by_construction() {
        // a synthetic perfectly-steady run: 100 images over 1 s, each
        // keeping node 0 busy 10 ms and node 1 busy 4 ms, 2 kB wire each
        let busy = [100u64 * 10_000_000, 100 * 4_000_000];
        let inp = DesEnergyInputs {
            horizon_ns: 1_000_000_000,
            busy_ns: &busy,
            completed: 100,
            delivered_bytes: 100 * 2_000,
            weight_bytes: 50_000,
            reconfig_downtime_ms: 0.0,
            reconfig_overdraw_w: 0.0,
            window_w: &[],
            mean_latency_ms: 12.0,
        };
        let des = integrate_energy(&pm(), &cfg(), &inp);
        let analytic =
            analytic_power(&pm(), &cfg(), &[1.0, 0.4], 10.0, 2_000.0, 50_000, 12.0);
        let rel = (des.j_per_image - analytic.j_per_image).abs() / analytic.j_per_image;
        assert!(rel < 1e-9, "meters drifted: {rel}");
    }

    #[test]
    fn reconfig_energy_charged_per_node() {
        let busy = [0u64, 0];
        let base = DesEnergyInputs {
            horizon_ns: 1_000_000_000,
            busy_ns: &busy,
            completed: 1,
            delivered_bytes: 0,
            weight_bytes: 0,
            reconfig_downtime_ms: 0.0,
            reconfig_overdraw_w: 0.8,
            window_w: &[],
            mean_latency_ms: 1.0,
        };
        let without = integrate_energy(&pm(), &cfg(), &base);
        let with = integrate_energy(
            &pm(),
            &cfg(),
            &DesEnergyInputs { reconfig_downtime_ms: 100.0, ..base },
        );
        let expect = 0.1 * 0.8 * 2.0;
        assert!((with.total_j - without.total_j - expect).abs() < 1e-9);
        assert!(with.reconfig_j > 0.0 && without.reconfig_j == 0.0);
    }

    #[test]
    fn peak_window_at_least_average() {
        let busy = [500_000_000u64];
        let inp = DesEnergyInputs {
            horizon_ns: 1_000_000_000,
            busy_ns: &busy,
            completed: 10,
            delivered_bytes: 0,
            weight_bytes: 0,
            reconfig_downtime_ms: 0.0,
            reconfig_overdraw_w: 0.0,
            window_w: &[3.0, 9.5, 4.0],
            mean_latency_ms: 1.0,
        };
        let r = integrate_energy(&pm(), &cfg(), &inp);
        assert!(r.peak_window_w >= r.avg_cluster_w);
        assert!(r.peak_window_w >= 9.5);
        assert_eq!(r.node_avg_w.len(), 1);
    }
}
