//! Per-board electrical power model (DESIGN.md §11).
//!
//! The paper's objective is "the best performance regarding latency
//! **and power efficiency**" on low-power edge FPGAs — so every watt the
//! cluster draws has to come from somewhere the model can name:
//!
//! * **PS static** — the processing system (ARM cores, DDR controller,
//!   peripherals) draws power whenever the board is on, load or no load.
//! * **PL static** — a configured bitstream leaks and clocks its fabric
//!   even while the VTA engine sits idle.
//! * **PL dynamic** — toggling DSP slices, BRAMs and LUT fabric while a
//!   VTA program runs; scales with the *active* [`VtaConfig`]'s resource
//!   footprint and clock, so the §IV big config costs more watts than
//!   Table I — that trade is exactly what the Pareto sweep surfaces.
//! * **DRAM / Ethernet** — energy per byte moved (weights streamed per
//!   inference, activations staged over the PS GEM).
//! * **Switch port** — each powered GbE link on the cluster switch.
//! * **Reconfiguration** — extra draw while PCAP/ICAP streams a
//!   bitstream during the downtime `config::reconfig` already charges.
//!
//! Constants are *modeled, not fitted* — anchored the same way
//! `config::calibration` anchors κ, against published board
//! measurements: a PYNQ-Z1/Zynq-7020 idles around 2.5 W and serves VTA
//! inference around 4–5 W; ZU+ MPSoC boards idle higher (~3.5 W SoC
//! share) and run a Table-I VTA around 6–7 W. Per-resource toggle
//! coefficients are XPE-magnitude figures (28 nm ≈ 0.1 W per DSP·GHz,
//! scaled ~0.6× for the 16 nm UltraScale+ fabric). Everything downstream
//! (J/image, images/s/W, energy-delay product) is *predicted* from these
//! per-component terms.

use crate::config::board::BoardFamily;
use crate::config::reconfig::ReconfigCost;
use crate::config::vta::VtaConfig;

/// PL resource footprint of one VTA configuration — the same estimate
/// [`crate::config::BoardProfile::vta_fits`] gates bitstreams with,
/// reused here so the power model and the fit check can never disagree
/// about what a config occupies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlUsage {
    /// DSP48 slices (2 int8 MACs per slice).
    pub dsp_slices: u64,
    /// BRAM footprint in kilobits (double-buffered SRAM buffers).
    pub bram_kbits: u64,
    /// LUT estimate: fixed fetch/decode/DMA fabric plus per-MAC glue.
    pub luts: u64,
}

impl PlUsage {
    /// Fixed non-GEMM fabric (fetch, load, store, ALU, AXI DMA).
    const BASE_LUTS: u64 = 15_000;
    /// Routing/control glue per GEMM MAC lane.
    const LUTS_PER_MAC: u64 = 24;

    pub fn for_config(cfg: &VtaConfig) -> Self {
        let macs = cfg.macs_per_cycle();
        PlUsage {
            dsp_slices: macs / 2,
            bram_kbits: (cfg.input_buffer_bits
                + cfg.weight_buffer_bits
                + cfg.acc_buffer_bits
                + cfg.uop_buffer_bits)
                / 1024
                * 2,
            luts: Self::BASE_LUTS + Self::LUTS_PER_MAC * macs,
        }
    }
}

/// Electrical model of one board family plus the shared switch port.
/// All wattages are board-level (PS + PL rails), not die-level.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModel {
    pub family: BoardFamily,
    /// Processing-system draw with the board idle (cores, DDR PHY, NIC), W.
    pub ps_static_w: f64,
    /// Configured-PL static draw (leakage + clock tree), W.
    pub pl_static_w: f64,
    /// Dynamic draw per active DSP slice per GHz of PL clock, W.
    pub dsp_w_per_ghz: f64,
    /// Dynamic draw per BRAM kilobit per GHz, W.
    pub bram_w_per_kbit_ghz: f64,
    /// Dynamic draw per 1000 LUTs per GHz, W.
    pub lut_w_per_klut_ghz: f64,
    /// DRAM access energy, pJ per byte moved.
    pub dram_pj_per_byte: f64,
    /// Incremental Ethernet energy per byte at an endpoint NIC, pJ
    /// (PHY/MAC static share lives in `ps_static_w`).
    pub eth_pj_per_byte: f64,
    /// Per-powered-port draw of the cluster switch, W.
    pub switch_port_w: f64,
    /// Extra draw while the configuration port streams a bitstream, W
    /// (on top of the static floor; charged over the modeled downtime).
    pub reconfig_w: f64,
}

impl PowerModel {
    /// PYNQ-Z1 / ZedBoard (Zynq-7020): ≈2.5 W idle, ≈4–5 W serving VTA
    /// inference — the published wall-meter range for these boards.
    pub fn zynq7020() -> Self {
        PowerModel {
            family: BoardFamily::Zynq7000,
            ps_static_w: 1.9,
            pl_static_w: 0.6,
            dsp_w_per_ghz: 0.10,
            bram_w_per_kbit_ghz: 0.003,
            lut_w_per_klut_ghz: 0.05,
            dram_pj_per_byte: 600.0, // DDR3-1066 ×32, incl. I/O
            eth_pj_per_byte: 2_000.0,
            switch_port_w: 0.7,
            reconfig_w: 0.8,
        }
    }

    /// Zynq UltraScale+ MPSoC: higher static floor (quad A53 + DDR4),
    /// ~0.6× toggle energy from the 16 nm fabric.
    pub fn zu_mpsoc() -> Self {
        PowerModel {
            family: BoardFamily::UltraScalePlus,
            ps_static_w: 2.6,
            pl_static_w: 0.9,
            dsp_w_per_ghz: 0.06,
            bram_w_per_kbit_ghz: 0.0018,
            lut_w_per_klut_ghz: 0.03,
            dram_pj_per_byte: 300.0, // DDR4-2400 ×64
            eth_pj_per_byte: 2_000.0,
            switch_port_w: 0.7,
            reconfig_w: 1.2,
        }
    }

    pub fn for_family(family: BoardFamily) -> Self {
        match family {
            BoardFamily::Zynq7000 => Self::zynq7020(),
            BoardFamily::UltraScalePlus => Self::zu_mpsoc(),
        }
    }

    /// Board draw with a bitstream loaded but the engine idle, W.
    pub fn idle_w(&self) -> f64 {
        self.ps_static_w + self.pl_static_w
    }

    /// PL dynamic draw while `cfg` actively computes, W.
    pub fn pl_dynamic_w(&self, cfg: &VtaConfig) -> f64 {
        let u = PlUsage::for_config(cfg);
        let ghz = cfg.clock_hz as f64 / 1e9;
        ghz * (self.dsp_w_per_ghz * u.dsp_slices as f64
            + self.bram_w_per_kbit_ghz * u.bram_kbits as f64
            + self.lut_w_per_klut_ghz * u.luts as f64 / 1e3)
    }

    /// Board draw while `cfg` actively computes (compute rails only —
    /// DRAM/Ethernet traffic is charged per byte, not folded in here), W.
    pub fn active_w(&self, cfg: &VtaConfig) -> f64 {
        self.idle_w() + self.pl_dynamic_w(cfg)
    }

    /// DRAM energy for `bytes` moved, J.
    pub fn dram_j(&self, bytes: f64) -> f64 {
        bytes * self.dram_pj_per_byte * 1e-12
    }

    /// Endpoint-NIC energy for `bytes` on the wire, J. Each byte crosses
    /// two NICs (tx + rx), so callers pass wire bytes once and this
    /// charges both ends.
    pub fn eth_j(&self, wire_bytes: f64) -> f64 {
        2.0 * wire_bytes * self.eth_pj_per_byte * 1e-12
    }

    /// Energy one node spends on a plan switch: the modeled downtime at
    /// the idle floor plus the configuration-port overdraw, J.
    pub fn reconfig_j(&self, rc: &ReconfigCost) -> f64 {
        rc.downtime_ms() / 1e3 * (self.idle_w() + self.reconfig_w)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let pos = |v: f64, what: &str| {
            anyhow::ensure!(v.is_finite() && v > 0.0, "{what} must be finite and > 0");
            Ok(())
        };
        pos(self.ps_static_w, "ps_static_w")?;
        pos(self.pl_static_w, "pl_static_w")?;
        pos(self.dsp_w_per_ghz, "dsp_w_per_ghz")?;
        pos(self.bram_w_per_kbit_ghz, "bram_w_per_kbit_ghz")?;
        pos(self.lut_w_per_klut_ghz, "lut_w_per_klut_ghz")?;
        pos(self.dram_pj_per_byte, "dram_pj_per_byte")?;
        pos(self.eth_pj_per_byte, "eth_pj_per_byte")?;
        pos(self.switch_port_w, "switch_port_w")?;
        pos(self.reconfig_w, "reconfig_w")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zynq_anchors_idle_and_active() {
        let pm = PowerModel::zynq7020();
        pm.validate().unwrap();
        // published PYNQ-Z1 wall figures: ~2.5 W idle, ~4–5 W serving
        assert!((pm.idle_w() - 2.5).abs() < 0.2, "idle {}", pm.idle_w());
        let active = pm.active_w(&VtaConfig::table1_zynq7000());
        assert!((3.5..5.5).contains(&active), "active {active}");
    }

    #[test]
    fn usplus_draws_more_but_tolerates_higher_clock() {
        let z = PowerModel::zynq7020();
        let u = PowerModel::zu_mpsoc();
        u.validate().unwrap();
        assert!(u.idle_w() > z.idle_w());
        // Table-I US+ (300 MHz) draws more than Table-I Zynq (100 MHz)…
        let au = u.active_w(&VtaConfig::table1_ultrascale());
        let az = z.active_w(&VtaConfig::table1_zynq7000());
        assert!(au > az, "US+ active {au} vs Zynq {az}");
        // …but by less than the 3× clock: per-GHz toggle energy is lower
        assert!(au < 3.0 * az);
    }

    #[test]
    fn dynamic_scales_with_clock_and_block() {
        let pm = PowerModel::zu_mpsoc();
        let d300 = pm.pl_dynamic_w(&VtaConfig::table1_ultrascale());
        let d350 = pm.pl_dynamic_w(&VtaConfig::ultrascale_350mhz());
        let dbig = pm.pl_dynamic_w(&VtaConfig::big_config_200mhz());
        assert!(d350 > d300, "350 MHz must cost more watts");
        // BLOCK=32 at 200 MHz toggles 4× the MACs at 2/3 the clock
        assert!(dbig > d300, "big config must cost more watts than Table I");
    }

    #[test]
    fn pl_usage_mirrors_fit_check() {
        let u = PlUsage::for_config(&VtaConfig::table1_zynq7000());
        assert_eq!(u.dsp_slices, 128);
        assert_eq!(u.bram_kbits, 896);
        assert!(u.luts < 53_200, "LUT estimate exceeds the 7020 fabric");
    }

    #[test]
    fn reconfig_energy_positive_and_family_ordered() {
        let z = PowerModel::zynq7020().reconfig_j(&ReconfigCost::zynq7020());
        let u = PowerModel::zu_mpsoc().reconfig_j(&ReconfigCost::zu_mpsoc());
        assert!(z > 0.0);
        // bigger bitstream, hotter board: a US+ switch costs more joules
        assert!(u > z, "US+ reconfig {u} J vs Zynq {z} J");
    }

    #[test]
    fn invalid_rejected() {
        let mut pm = PowerModel::zynq7020();
        pm.switch_port_w = 0.0;
        assert!(pm.validate().is_err());
        let mut pm = PowerModel::zynq7020();
        pm.dram_pj_per_byte = f64::NAN;
        assert!(pm.validate().is_err());
    }
}
