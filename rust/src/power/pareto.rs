//! Latency-vs-watts Pareto frontier over deployment configurations
//! (DESIGN.md §11, EXPERIMENTS.md §E11).
//!
//! "Which cluster should I build?" has two axes once power is modeled:
//! a 12-board Zynq stack and a 3-board US+ stack may hit the same
//! ms/image at very different wall draw. [`pareto_sweep`] enumerates
//! (board family × node count × §II-C strategy), prices every cell with
//! the metered analytic simulator, and marks each configuration as
//! frontier or dominated: a cell is **dominated** when some other cell
//! is at least as fast *and* draws at most as many watts, with one of
//! the two strictly better. The surviving frontier is monotone by
//! construction — sorted by watts, ms/image strictly decreases — which
//! the CLI `power` subcommand prints and the unit tests pin.

use super::eco::eco_plan;
use crate::config::{BoardFamily, BoardProfile, Calibration, ClusterConfig};
use crate::graph::zoo;
use crate::sched::{build_plan_priced, Strategy};
use crate::sim::{simulate, CostModel, SimConfig};

/// One priced deployment configuration.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub family: BoardFamily,
    pub strategy: Strategy,
    pub nodes: usize,
    pub ms_per_image: f64,
    /// Unloaded end-to-end latency, ms.
    pub latency_ms: f64,
    /// Steady-state cluster draw at saturation, W.
    pub cluster_w: f64,
    pub j_per_image: f64,
    pub img_per_sec_per_w: f64,
    /// True when another configuration is ≤ on both axes and < on one.
    pub dominated: bool,
}

/// The paper's per-family cluster-size ceilings (12 Zynq / 5 US+).
pub fn family_max_nodes(family: BoardFamily) -> usize {
    match family {
        BoardFamily::Zynq7000 => 12,
        BoardFamily::UltraScalePlus => 5,
    }
}

/// Enumerate and price every (family × n × strategy) cell for `model`.
/// `max_nodes = 0` uses each family's paper ceiling; smaller values
/// clamp the sweep (the bench's fast mode). Points come back sorted by
/// watts with `dominated` filled in.
pub fn pareto_sweep(
    model: &str,
    families: &[BoardFamily],
    max_nodes: usize,
    calib: &Calibration,
) -> anyhow::Result<Vec<ParetoPoint>> {
    anyhow::ensure!(!families.is_empty(), "no board families to sweep");
    let g = zoo::build(model, 0)?;
    let mut points = Vec::new();
    for &family in families {
        let board = BoardProfile::for_family(family);
        let vta = board.default_vta();
        let mut cost = CostModel::new(vta.clone(), board, calib.clone());
        let ceiling = family_max_nodes(family);
        let top = if max_nodes == 0 { ceiling } else { max_nodes.min(ceiling) };
        for n in 1..=top {
            let cluster = ClusterConfig::homogeneous(family, n).with_vta(vta.clone());
            let seg_costs = cost.seg_cost_table(&g)?;
            for s in Strategy::all() {
                let plan = build_plan_priced(s, &g, n, &seg_costs)?;
                let sim = simulate(&plan, &cluster, &mut cost, &g, &SimConfig { images: 16 })?;
                points.push(ParetoPoint {
                    family,
                    strategy: s,
                    nodes: n,
                    ms_per_image: sim.ms_per_image,
                    latency_ms: sim.latency_ms.mean(),
                    cluster_w: sim.power.cluster_avg_w,
                    j_per_image: sim.power.j_per_image,
                    img_per_sec_per_w: sim.power.img_per_sec_per_w,
                    dominated: false,
                });
            }
        }
    }
    mark_dominated(&mut points);
    points.sort_by(|a, b| {
        a.cluster_w
            .partial_cmp(&b.cluster_w)
            .unwrap()
            .then(a.ms_per_image.partial_cmp(&b.ms_per_image).unwrap())
    });
    Ok(points)
}

/// Fill [`ParetoPoint::dominated`]: (watts, ms/image) weak dominance
/// with at least one strict axis.
pub fn mark_dominated(points: &mut [ParetoPoint]) {
    let snapshot: Vec<(f64, f64)> =
        points.iter().map(|p| (p.cluster_w, p.ms_per_image)).collect();
    for (i, p) in points.iter_mut().enumerate() {
        p.dominated = snapshot.iter().enumerate().any(|(j, &(w, ms))| {
            j != i
                && w <= p.cluster_w
                && ms <= p.ms_per_image
                && (w < p.cluster_w || ms < p.ms_per_image)
        });
    }
}

/// The non-dominated subset, sorted by watts. Monotone: ms/image
/// strictly decreases as watts increase (ties collapse to one point —
/// dominance removed them already, bar exact duplicates).
pub fn frontier(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut f: Vec<ParetoPoint> =
        points.iter().filter(|p| !p.dominated).cloned().collect();
    f.sort_by(|a, b| a.cluster_w.partial_cmp(&b.cluster_w).unwrap());
    // exact duplicates on both axes dominate nothing and survive
    // `mark_dominated`; keep the first of each
    f.dedup_by(|a, b| a.cluster_w == b.cluster_w && a.ms_per_image == b.ms_per_image);
    f
}

/// The frontier point with the best images/s/W, if any.
pub fn most_efficient(points: &[ParetoPoint]) -> Option<&ParetoPoint> {
    points
        .iter()
        .filter(|p| !p.dominated)
        .max_by(|a, b| a.img_per_sec_per_w.partial_cmp(&b.img_per_sec_per_w).unwrap())
}

/// Energy-optimal plan for one family at a fixed cluster size under an
/// optional latency SLO — the `power --slo` path of the CLI.
pub fn eco_for_family(
    model: &str,
    family: BoardFamily,
    nodes: usize,
    slo_ms: Option<f64>,
    calib: &Calibration,
) -> anyhow::Result<super::eco::EcoChoice> {
    let g = zoo::build(model, 0)?;
    let board = BoardProfile::for_family(family);
    let vta = board.default_vta();
    let mut cost = CostModel::new(vta.clone(), board, calib.clone());
    let cluster = ClusterConfig::homogeneous(family, nodes).with_vta(vta);
    eco_plan(&g, &cluster, &mut cost, slo_ms)
}

/// Searched plan for one family at a fixed cluster size (DESIGN.md §17)
/// — the `power --slo` path's sixth-strategy counterpart. Minimizes
/// J/image with right-sizing on, so a fleet larger than the workload
/// needs comes back with a sub-cluster plan and a node map.
pub fn search_for_family(
    model: &str,
    family: BoardFamily,
    nodes: usize,
    slo_ms: Option<f64>,
    calib: &Calibration,
) -> anyhow::Result<crate::search::SearchOutcome> {
    let g = zoo::build(model, 0)?;
    let board = BoardProfile::for_family(family);
    let vta = board.default_vta();
    let mut cost = CostModel::new(vta.clone(), board, calib.clone());
    let cluster = ClusterConfig::homogeneous(family, nodes).with_vta(vta);
    let cfg = crate::search::SearchConfig {
        objective: crate::search::Objective::JPerImage,
        slo_ms,
        rightsize: true,
        ..Default::default()
    };
    crate::search::search_plan(&g, &cluster, &mut cost, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep_small() -> Vec<ParetoPoint> {
        pareto_sweep(
            "lenet5",
            &[BoardFamily::Zynq7000, BoardFamily::UltraScalePlus],
            3,
            &Calibration::default(),
        )
        .unwrap()
    }

    #[test]
    fn frontier_is_monotone_and_undominated() {
        let points = sweep_small();
        assert_eq!(points.len(), 2 * 3 * 4, "2 families × 3 sizes × 4 strategies");
        let f = frontier(&points);
        assert!(!f.is_empty());
        for w in f.windows(2) {
            assert!(w[1].cluster_w > w[0].cluster_w, "frontier not watt-sorted");
            assert!(
                w[1].ms_per_image < w[0].ms_per_image,
                "frontier not monotone: {:.2} W/{:.3} ms then {:.2} W/{:.3} ms",
                w[0].cluster_w,
                w[0].ms_per_image,
                w[1].cluster_w,
                w[1].ms_per_image
            );
        }
        // no frontier point may be dominated by any sweep point
        for p in &f {
            for q in &points {
                assert!(
                    !(q.cluster_w <= p.cluster_w
                        && q.ms_per_image <= p.ms_per_image
                        && (q.cluster_w < p.cluster_w || q.ms_per_image < p.ms_per_image)),
                    "frontier point dominated by {:?} n={}",
                    q.strategy,
                    q.nodes
                );
            }
        }
    }

    #[test]
    fn bigger_clusters_draw_more_watts() {
        let points = sweep_small();
        let w = |n: usize| {
            points
                .iter()
                .filter(|p| {
                    p.nodes == n
                        && p.family == BoardFamily::Zynq7000
                        && p.strategy == Strategy::ScatterGather
                })
                .map(|p| p.cluster_w)
                .next()
                .unwrap()
        };
        assert!(w(3) > w(2) && w(2) > w(1));
    }

    #[test]
    fn most_efficient_is_on_the_frontier() {
        let points = sweep_small();
        let best = most_efficient(&points).unwrap();
        assert!(!best.dominated);
        for p in &points {
            assert!(best.img_per_sec_per_w >= p.img_per_sec_per_w || p.dominated);
        }
    }

    #[test]
    fn mark_dominated_basic_geometry() {
        let mk = |w: f64, ms: f64| ParetoPoint {
            family: BoardFamily::Zynq7000,
            strategy: Strategy::ScatterGather,
            nodes: 1,
            ms_per_image: ms,
            latency_ms: ms,
            cluster_w: w,
            j_per_image: w * ms / 1e3,
            img_per_sec_per_w: 1e3 / (w * ms),
            dominated: false,
        };
        let mut pts = vec![mk(10.0, 5.0), mk(12.0, 6.0), mk(20.0, 2.0)];
        mark_dominated(&mut pts);
        assert!(!pts[0].dominated);
        assert!(pts[1].dominated, "strictly worse on both axes");
        assert!(!pts[2].dominated, "faster, pricier point stays");
    }

    #[test]
    fn unknown_model_and_empty_families_rejected() {
        assert!(pareto_sweep("nope", &[BoardFamily::Zynq7000], 2, &Calibration::default())
            .is_err());
        assert!(pareto_sweep("lenet5", &[], 2, &Calibration::default()).is_err());
    }

    #[test]
    fn search_for_family_never_loses_to_eco() {
        let calib = Calibration::default();
        let eco = eco_for_family("lenet5", BoardFamily::Zynq7000, 3, None, &calib).unwrap();
        let found =
            search_for_family("lenet5", BoardFamily::Zynq7000, 3, None, &calib).unwrap();
        assert!(
            found.j_per_image <= eco.j_per_image * 1.0001,
            "eco {} J beats search's {} J",
            eco.j_per_image,
            found.j_per_image
        );
        assert!(found.nodes_used >= 1 && found.nodes_used <= 3);
        if let Some(map) = &found.node_map {
            assert_eq!(map.len(), found.nodes_used);
        }
    }
}
