//! Simulation time and byte-size units.
//!
//! The discrete-event simulator uses **integer nanoseconds** so event
//! ordering is exact and runs are bit-reproducible (f64 time would make
//! event order depend on accumulated rounding).

/// Simulation time in nanoseconds.
pub type Nanos = u64;

pub const NS_PER_US: Nanos = 1_000;
pub const NS_PER_MS: Nanos = 1_000_000;
pub const NS_PER_SEC: Nanos = 1_000_000_000;

/// Convert milliseconds (f64) to integer nanoseconds, rounding.
pub fn ms_to_ns(ms: f64) -> Nanos {
    (ms * NS_PER_MS as f64).round() as Nanos
}

/// Convert microseconds (f64) to integer nanoseconds, rounding.
pub fn us_to_ns(us: f64) -> Nanos {
    (us * NS_PER_US as f64).round() as Nanos
}

/// Convert integer nanoseconds to f64 milliseconds (for reporting).
pub fn ns_to_ms(ns: Nanos) -> f64 {
    ns as f64 / NS_PER_MS as f64
}

/// Convert integer nanoseconds to f64 seconds.
pub fn ns_to_sec(ns: Nanos) -> f64 {
    ns as f64 / NS_PER_SEC as f64
}

/// Time for `bytes` at `bits_per_sec`, in integer ns (ceil — a transfer
/// can't finish early).
pub fn transfer_ns(bytes: u64, bits_per_sec: u64) -> Nanos {
    assert!(bits_per_sec > 0);
    let bits = bytes as u128 * 8;
    ((bits * NS_PER_SEC as u128).div_ceil(bits_per_sec as u128)) as Nanos
}

/// Cycles at `clock_hz` expressed in integer ns (ceil).
pub fn cycles_to_ns(cycles: u64, clock_hz: u64) -> Nanos {
    assert!(clock_hz > 0);
    ((cycles as u128 * NS_PER_SEC as u128).div_ceil(clock_hz as u128)) as Nanos
}

/// Human-readable duration.
pub fn fmt_ns(ns: Nanos) -> String {
    if ns >= NS_PER_SEC {
        format!("{:.3} s", ns as f64 / NS_PER_SEC as f64)
    } else if ns >= NS_PER_MS {
        format!("{:.3} ms", ns as f64 / NS_PER_MS as f64)
    } else if ns >= NS_PER_US {
        format!("{:.3} µs", ns as f64 / NS_PER_US as f64)
    } else {
        format!("{ns} ns")
    }
}

/// Human-readable byte count (binary units).
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let bf = b as f64;
    if bf >= KIB * KIB * KIB {
        format!("{:.2} GiB", bf / (KIB * KIB * KIB))
    } else if bf >= KIB * KIB {
        format!("{:.2} MiB", bf / (KIB * KIB))
    } else if bf >= KIB {
        format!("{:.2} KiB", bf / KIB)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(ms_to_ns(27.34), 27_340_000);
        assert!((ns_to_ms(27_340_000) - 27.34).abs() < 1e-9);
        assert_eq!(us_to_ns(1.5), 1_500);
    }

    #[test]
    fn transfer_time_1gbps() {
        // 125 MB/s → 1 KB takes 8 µs
        assert_eq!(transfer_ns(1000, 1_000_000_000), 8_000);
        // ceil: 1 byte at 1 Gb/s is 8 ns exactly
        assert_eq!(transfer_ns(1, 1_000_000_000), 8);
        // ceil rounds up on non-exact division
        assert_eq!(transfer_ns(1, 3_000_000_000), 3);
    }

    #[test]
    fn cycles_at_clock() {
        // 100 MHz → 10 ns per cycle
        assert_eq!(cycles_to_ns(1, 100_000_000), 10);
        assert_eq!(cycles_to_ns(2_734_000, 100_000_000), 27_340_000);
        // 300 MHz rounds up
        assert_eq!(cycles_to_ns(1, 300_000_000), 4);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(5), "5 ns");
        assert_eq!(fmt_ns(1_500), "1.500 µs");
        assert_eq!(fmt_ns(27_340_000), "27.340 ms");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(11_200_000), "10.68 MiB");
    }
}
