//! Measurement harness for the `cargo bench` targets (criterion is not in
//! the offline vendor set).
//!
//! Usage inside a `harness = false` bench:
//! ```no_run
//! use vta_cluster::util::bench::Bench;
//! let mut b = Bench::new("fig3_zynq7000");
//! b.iter("scatter_gather_n4", || { /* work */ });
//! b.finish();
//! ```
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean ± std and percentiles, honours `VTA_BENCH_FAST=1` for CI smoke
//! runs.

use super::stats::Summary;
use std::time::{Duration, Instant};

pub struct Bench {
    suite: String,
    target: Duration,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        let fast = std::env::var("VTA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        let target = if fast { Duration::from_millis(200) } else { Duration::from_secs(1) };
        println!("\n== bench suite: {suite} ==");
        Bench { suite: suite.to_string(), target, results: Vec::new() }
    }

    /// Measure a closure: warmup, auto-scale batch size, then sample.
    pub fn iter<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Summary {
        // warmup + calibration: find a batch size that runs ≥ ~1 ms
        let mut batch = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed();
            if dt >= Duration::from_millis(1) || batch >= 1 << 24 {
                break;
            }
            batch *= 8;
        }
        // sample until target elapsed (min 5 samples, max 200)
        let mut summary = Summary::new();
        let start = Instant::now();
        while (start.elapsed() < self.target || summary.len() < 5) && summary.len() < 200 {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let per_iter = t0.elapsed().as_secs_f64() / batch as f64;
            summary.push(per_iter * 1e9); // ns
        }
        println!(
            "  {name:40} {:>12.1} ns/iter ± {:>10.1}  (p50 {:>12.1}, n={}, batch={batch})",
            summary.mean(),
            summary.std(),
            summary.p50(),
            summary.len(),
        );
        self.results.push((name.to_string(), summary));
        &self.results.last().unwrap().1
    }

    /// Record an externally-measured sample set (e.g. simulated latencies).
    pub fn record(&mut self, name: &str, summary: Summary, unit: &str) {
        println!("  {name:40} {}", summary.display(unit));
        self.results.push((name.to_string(), summary));
    }

    /// Print a one-line table row (for paper-table benches).
    pub fn row(&mut self, text: &str) {
        println!("  {text}");
    }

    pub fn finish(self) {
        println!("== {} done: {} benchmarks ==\n", self.suite, self.results.len());
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("VTA_BENCH_FAST", "1");
        let mut b = Bench::new("self-test");
        let s = b.iter("noop-ish", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(s.mean() > 0.0);
        assert!(s.len() >= 5);
        b.finish();
    }
}
