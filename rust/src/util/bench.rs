//! Measurement harness for the `cargo bench` targets (criterion is not in
//! the offline vendor set), plus the [`BenchReport`] schema behind
//! `vtacluster bench --check` (DESIGN.md §15).
//!
//! Usage inside a `harness = false` bench:
//! ```no_run
//! use vta_cluster::util::bench::Bench;
//! let mut b = Bench::new("fig3_zynq7000");
//! b.iter("scatter_gather_n4", || { /* work */ });
//! b.finish();
//! ```
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean ± std and percentiles, honours `VTA_BENCH_FAST=1` for CI smoke
//! runs.
//!
//! [`BenchReport`] is the stable `BENCH_*.json` shape every suite in
//! [`crate::exp::bench_suites`] writes: per-entry deterministic `metrics`
//! (what the regression gate compares against a checked-in baseline with
//! a relative tolerance) and host-dependent `wall` figures (recorded for
//! trend plots, never gated).

use super::json::{self, Json};
use super::stats::Summary;
use std::path::Path;
use std::time::{Duration, Instant};

pub struct Bench {
    suite: String,
    target: Duration,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        let fast = std::env::var("VTA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        let target = if fast { Duration::from_millis(200) } else { Duration::from_secs(1) };
        println!("\n== bench suite: {suite} ==");
        Bench { suite: suite.to_string(), target, results: Vec::new() }
    }

    /// Measure a closure: warmup, auto-scale batch size, then sample.
    pub fn iter<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Summary {
        // warmup + calibration: find a batch size that runs ≥ ~1 ms
        let mut batch = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed();
            if dt >= Duration::from_millis(1) || batch >= 1 << 24 {
                break;
            }
            batch *= 8;
        }
        // sample until target elapsed (min 5 samples, max 200)
        let mut summary = Summary::new();
        let start = Instant::now();
        while (start.elapsed() < self.target || summary.len() < 5) && summary.len() < 200 {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let per_iter = t0.elapsed().as_secs_f64() / batch as f64;
            summary.push(per_iter * 1e9); // ns
        }
        println!(
            "  {name:40} {:>12.1} ns/iter ± {:>10.1}  (p50 {:>12.1}, n={}, batch={batch})",
            summary.mean(),
            summary.std(),
            summary.p50(),
            summary.len(),
        );
        self.results.push((name.to_string(), summary));
        &self.results.last().unwrap().1
    }

    /// Record an externally-measured sample set (e.g. simulated latencies).
    pub fn record(&mut self, name: &str, summary: Summary, unit: &str) {
        println!("  {name:40} {}", summary.display(unit));
        self.results.push((name.to_string(), summary));
    }

    /// Print a one-line table row (for paper-table benches).
    pub fn row(&mut self, text: &str) {
        println!("  {text}");
    }

    pub fn finish(self) {
        println!("== {} done: {} benchmarks ==\n", self.suite, self.results.len());
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---- BENCH_*.json schema + regression gate -----------------------------

/// One named measurement of a suite. `metrics` are deterministic
/// simulation outputs (seeded DES figures — gated by `bench --check`);
/// `wall` figures depend on the host and are recorded but never gated.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    pub name: String,
    pub metrics: Vec<(String, f64)>,
    pub wall: Vec<(String, f64)>,
}

impl BenchEntry {
    pub fn new(name: &str) -> Self {
        BenchEntry { name: name.to_string(), metrics: Vec::new(), wall: Vec::new() }
    }

    /// Builder-style: record a gated metric.
    pub fn metric(mut self, name: &str, value: f64) -> Self {
        self.metrics.push((name.to_string(), value));
        self
    }

    /// Builder-style: record an ungated wall-clock figure.
    pub fn wall(mut self, name: &str, value: f64) -> Self {
        self.wall.push((name.to_string(), value));
        self
    }

    fn get_metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == name).map(|&(_, v)| v)
    }

    fn to_json(&self) -> Json {
        let kv = |pairs: &[(String, f64)]| {
            Json::Obj(
                pairs
                    .iter()
                    .map(|(k, v)| {
                        (k.clone(), if v.is_finite() { json::num(*v) } else { Json::Null })
                    })
                    .collect(),
            )
        };
        json::obj(vec![
            ("name", json::str_(&self.name)),
            ("metrics", kv(&self.metrics)),
            ("wall", kv(&self.wall)),
        ])
    }

    fn from_json(doc: &Json) -> anyhow::Result<Self> {
        let kv = |field: &str| -> anyhow::Result<Vec<(String, f64)>> {
            match doc.get(field) {
                Some(obj) => obj
                    .as_obj()?
                    .iter()
                    .map(|(k, v)| {
                        let value = match v {
                            Json::Null => f64::NAN,
                            other => other.as_f64()?,
                        };
                        Ok((k.clone(), value))
                    })
                    .collect(),
                None => Ok(Vec::new()),
            }
        };
        Ok(BenchEntry {
            name: doc.get_str("name")?.to_string(),
            metrics: kv("metrics")?,
            wall: kv("wall")?,
        })
    }
}

/// A whole suite's results — the `BENCH_<suite>.json` document.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub suite: String,
    /// Measured under `VTA_BENCH_FAST=1` clamps. Fast and full runs are
    /// not comparable, so `check_against` only gates matching modes.
    pub fast: bool,
    /// `false` marks a bootstrap baseline: adopted (with a note), never
    /// gated — how a baseline first enters the tree without a local run.
    pub pinned: bool,
    pub entries: Vec<BenchEntry>,
}

impl BenchReport {
    pub fn new(suite: &str) -> Self {
        let fast = std::env::var("VTA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        BenchReport { suite: suite.to_string(), fast, pinned: true, entries: Vec::new() }
    }

    pub fn push(&mut self, entry: BenchEntry) {
        self.entries.push(entry);
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("suite", json::str_(&self.suite)),
            ("fast", Json::Bool(self.fast)),
            ("pinned", Json::Bool(self.pinned)),
            ("entries", Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())),
        ])
    }

    pub fn from_json(doc: &Json) -> anyhow::Result<Self> {
        Ok(BenchReport {
            suite: doc.get_str("suite")?.to_string(),
            fast: doc.req("fast")?.as_bool()?,
            pinned: doc.req("pinned")?.as_bool()?,
            entries: doc
                .req("entries")?
                .as_arr()?
                .iter()
                .map(BenchEntry::from_json)
                .collect::<anyhow::Result<_>>()?,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let doc = json::from_file(path)?;
        Self::from_json(&doc)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, json::pretty(&self.to_json()))
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        Ok(())
    }

    /// Compare this (fresh) report against a checked-in `baseline`.
    /// Returns `(notes, failures)`: a non-empty failure list is a CI
    /// gate trip. Gated: every finite baseline metric, with relative
    /// deviation > `tol` in *either* direction failing (a surprise
    /// speedup warrants a baseline update, not a silent drift); exact-zero
    /// baselines compare absolutely. Not gated: `wall` figures, entries
    /// new in the current run (noted), unpinned baselines (adopted), and
    /// fast/full mode mismatches (skipped with a note).
    pub fn check_against(&self, baseline: &BenchReport, tol: f64) -> (Vec<String>, Vec<String>) {
        let mut notes = Vec::new();
        let mut failures = Vec::new();
        if !baseline.pinned {
            notes.push(format!(
                "{}: baseline is unpinned (bootstrap) — adopting current results",
                self.suite
            ));
            return (notes, failures);
        }
        if self.fast != baseline.fast {
            notes.push(format!(
                "{}: fast-mode mismatch (current fast={}, baseline fast={}) — skipping gate",
                self.suite, self.fast, baseline.fast
            ));
            return (notes, failures);
        }
        for base in &baseline.entries {
            let Some(cur) = self.entries.iter().find(|e| e.name == base.name) else {
                failures.push(format!("{}/{}: entry missing from current run", self.suite, base.name));
                continue;
            };
            for (key, want) in &base.metrics {
                if !want.is_finite() {
                    continue; // an unmeasured baseline figure gates nothing
                }
                let Some(got) = cur.get_metric(key) else {
                    failures.push(format!(
                        "{}/{}/{key}: metric missing from current run",
                        self.suite, base.name
                    ));
                    continue;
                };
                if !got.is_finite() {
                    failures.push(format!(
                        "{}/{}/{key}: current value unmeasured (baseline {want:.4})",
                        self.suite, base.name
                    ));
                    continue;
                }
                let dev = if *want == 0.0 {
                    got.abs()
                } else {
                    (got - want).abs() / want.abs()
                };
                if dev > tol {
                    failures.push(format!(
                        "{}/{}/{key}: {got:.4} vs baseline {want:.4} ({:+.1}% > ±{:.0}%)",
                        self.suite,
                        base.name,
                        if *want == 0.0 { dev * 100.0 } else { (got - want) / want.abs() * 100.0 },
                        tol * 100.0,
                    ));
                }
            }
        }
        for cur in &self.entries {
            if !baseline.entries.iter().any(|b| b.name == cur.name) {
                notes.push(format!(
                    "{}/{}: new entry, not in baseline (update the baseline to gate it)",
                    self.suite, cur.name
                ));
            }
        }
        (notes, failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("VTA_BENCH_FAST", "1");
        let mut b = Bench::new("self-test");
        let s = b.iter("noop-ish", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(s.mean() > 0.0);
        assert!(s.len() >= 5);
        b.finish();
    }

    fn report() -> BenchReport {
        let mut r = BenchReport::new("des");
        r.fast = true;
        r.push(
            BenchEntry::new("poisson_steady")
                .metric("img_per_sec", 100.0)
                .metric("p99_ms", 12.0)
                .metric("reconfigs", 0.0)
                .wall("wall_ms", 350.0),
        );
        r.push(BenchEntry::new("burst").metric("img_per_sec", 80.0));
        r
    }

    #[test]
    fn bench_report_json_roundtrips_with_nan_as_null() {
        let mut r = report();
        r.entries[0].metrics.push(("recovery_p50_ms".into(), f64::NAN));
        let j = r.to_json();
        let text = json::pretty(&j);
        let back = BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.suite, "des");
        assert!(back.fast && back.pinned);
        assert_eq!(back.entries.len(), 2);
        assert_eq!(back.entries[0].get_metric("img_per_sec"), Some(100.0));
        assert!(back.entries[0].get_metric("recovery_p50_ms").unwrap().is_nan());
        assert_eq!(back.entries[0].wall, r.entries[0].wall);
    }

    #[test]
    fn check_gates_deviations_in_both_directions_but_never_wall() {
        let base = report();
        // identical → clean
        let (notes, failures) = report().check_against(&base, 0.05);
        assert!(failures.is_empty(), "{failures:?}");
        assert!(notes.is_empty(), "{notes:?}");
        // wall drift alone never gates
        let mut cur = report();
        cur.entries[0].wall[0].1 *= 10.0;
        assert!(cur.check_against(&base, 0.05).1.is_empty());
        // 2× slowdown on a gated metric fails …
        let mut cur = report();
        cur.entries[0].metrics[0].1 = 50.0;
        let (_, failures) = cur.check_against(&base, 0.05);
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("img_per_sec"), "{failures:?}");
        // … and so does a surprise 2× speedup (baselines must be updated,
        // not silently outgrown)
        let mut cur = report();
        cur.entries[0].metrics[1].1 = 24.0;
        assert_eq!(cur.check_against(&base, 0.05).1.len(), 1);
        // zero baselines compare absolutely
        let mut cur = report();
        cur.entries[0].metrics[2].1 = 3.0;
        assert_eq!(cur.check_against(&base, 0.05).1.len(), 1);
    }

    #[test]
    fn check_skips_unpinned_fast_mismatch_and_notes_new_entries() {
        // unpinned baseline: adopt, never fail
        let mut base = report();
        base.pinned = false;
        let mut cur = report();
        cur.entries[0].metrics[0].1 = 1.0;
        let (notes, failures) = cur.check_against(&base, 0.05);
        assert!(failures.is_empty());
        assert!(notes[0].contains("unpinned"), "{notes:?}");
        // fast/full mismatch: skip with a note
        let mut base = report();
        base.fast = false;
        let (notes, failures) = report().check_against(&base, 0.05);
        assert!(failures.is_empty());
        assert!(notes[0].contains("fast-mode mismatch"), "{notes:?}");
        // missing entry/metric in the current run is a failure
        let base = report();
        let mut cur = report();
        cur.entries.remove(1);
        cur.entries[0].metrics.remove(1);
        let (_, failures) = cur.check_against(&base, 0.05);
        assert_eq!(failures.len(), 2, "{failures:?}");
        // a new current-only entry is a note, not a failure
        let mut cur = report();
        cur.push(BenchEntry::new("brand-new").metric("x", 1.0));
        let (notes, failures) = cur.check_against(&base, 0.05);
        assert!(failures.is_empty());
        assert!(notes.iter().any(|n| n.contains("brand-new")), "{notes:?}");
    }
}
