//! Leveled stderr logging, configured by the `VTA_LOG` environment
//! variable (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised
static INIT: OnceLock<()> = OnceLock::new();

fn init() {
    INIT.get_or_init(|| {
        let lvl = std::env::var("VTA_LOG")
            .ok()
            .and_then(|s| Level::from_env(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

/// Current log level.
pub fn level() -> Level {
    init();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, benches).
pub fn set_level(lvl: Level) {
    init();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Core log function; prefer the macros.
pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{:5}] {module}: {msg}", lvl.as_str());
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_names() {
        assert_eq!(Level::from_env("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_env("warning"), Some(Level::Warn));
        assert_eq!(Level::from_env("nope"), None);
    }
}
