//! Leveled stderr logging, configured by the `VTA_LOG` environment
//! variable (`error|warn|info|debug|trace`, default `info`).
//!
//! Besides the free-form `log_*!` macros there is a structured
//! key=value form (DESIGN.md §13): [`log_kv`] / [`crate::log_kv_debug!`]
//! emit one event name plus sorted `key=value` pairs, with an optional
//! sim-time timestamp, so controller and DES debug output is grep- and
//! machine-parseable. With `VTA_LOG_JSON=1` each line is a single JSON
//! object instead.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised
static INIT: OnceLock<()> = OnceLock::new();

fn init() {
    INIT.get_or_init(|| {
        let lvl = std::env::var("VTA_LOG")
            .ok()
            .and_then(|s| Level::from_env(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

/// Current log level.
pub fn level() -> Level {
    init();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, benches).
pub fn set_level(lvl: Level) {
    init();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Core log function; prefer the macros.
pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{:5}] {module}: {msg}", lvl.as_str());
    }
}

static JSON_MODE: OnceLock<bool> = OnceLock::new();

/// `VTA_LOG_JSON=1` switches [`log_kv`] to one-JSON-object-per-line.
pub fn json_mode() -> bool {
    *JSON_MODE.get_or_init(|| {
        std::env::var("VTA_LOG_JSON").map(|v| v == "1" || v == "true").unwrap_or(false)
    })
}

/// Render one structured event. Pure (no env, no I/O) so the format is
/// unit-testable; [`log_kv`] feeds it the ambient JSON-mode flag.
///
/// Text mode: `[DEBUG] module @123.4ms event k=v k2="v 2"` — values
/// containing spaces, quotes or `=` are JSON-string-quoted so the line
/// splits unambiguously on spaces. JSON mode: a single-line object with
/// every value as a string.
pub fn format_kv(
    json: bool,
    lvl: Level,
    module: &str,
    t_ms: Option<f64>,
    event: &str,
    kvs: &[(&str, String)],
) -> String {
    if json {
        let mut fields = vec![
            ("level", crate::util::json::str_(lvl.as_str())),
            ("module", crate::util::json::str_(module)),
        ];
        if let Some(t) = t_ms {
            fields.push(("t_ms", crate::util::json::num(t)));
        }
        fields.push(("event", crate::util::json::str_(event)));
        for (k, v) in kvs {
            fields.push((*k, crate::util::json::str_(v)));
        }
        return crate::util::json::obj(fields).to_string_compact();
    }
    let mut line = format!("[{:5}] {module}", lvl.as_str());
    if let Some(t) = t_ms {
        line.push_str(&format!(" @{t:.3}ms"));
    }
    line.push(' ');
    line.push_str(event);
    for (k, v) in kvs {
        let needs_quoting =
            v.is_empty() || v.contains([' ', '"', '=', '\n', '\t']);
        if needs_quoting {
            line.push_str(&format!(" {k}={}", crate::util::json::str_(v).to_string_compact()));
        } else {
            line.push_str(&format!(" {k}={v}"));
        }
    }
    line
}

/// Emit one structured event to stderr (level-gated). `t_ms` is the
/// *simulated* timestamp when the caller has one — sim modules must
/// never stamp host time here.
pub fn log_kv(lvl: Level, module: &str, t_ms: Option<f64>, event: &str, kvs: &[(&str, String)]) {
    if enabled(lvl) {
        eprintln!("{}", format_kv(json_mode(), lvl, module, t_ms, event, kvs));
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

/// Structured debug event: `log_kv_debug!(Some(t_ms), "event", "k" => v, ...)`.
/// The level gate wraps the whole call so values are not even formatted
/// when debug logging is off (hot-path safe).
#[macro_export]
macro_rules! log_kv_debug {
    ($t_ms:expr, $event:expr $(, $k:expr => $v:expr)* $(,)?) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Debug) {
            $crate::util::logging::log_kv(
                $crate::util::logging::Level::Debug,
                module_path!(),
                $t_ms,
                $event,
                &[$(($k, format!("{}", $v))),*],
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_names() {
        assert_eq!(Level::from_env("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_env("warning"), Some(Level::Warn));
        assert_eq!(Level::from_env("nope"), None);
    }

    #[test]
    fn kv_text_format_is_splittable() {
        let line = format_kv(
            false,
            Level::Debug,
            "vta_cluster::sched::online",
            Some(123.4),
            "controller_switch",
            &[("to", "1".to_string()), ("reason", "power cap hit".to_string())],
        );
        assert_eq!(
            line,
            "[DEBUG] vta_cluster::sched::online @123.400ms controller_switch \
             to=1 reason=\"power cap hit\""
        );
        // no timestamp → no @ field
        let line = format_kv(false, Level::Info, "m", None, "boot", &[]);
        assert_eq!(line, "[INFO ] m boot");
    }

    #[test]
    fn kv_json_format_is_one_valid_object_per_line() {
        let line = format_kv(
            true,
            Level::Debug,
            "mod",
            Some(5.0),
            "ev",
            &[("k", "v w".to_string())],
        );
        assert!(!line.contains('\n'));
        let j = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(j.get_str("level").unwrap(), "DEBUG");
        assert_eq!(j.get_f64("t_ms").unwrap(), 5.0);
        assert_eq!(j.get_str("event").unwrap(), "ev");
        assert_eq!(j.get_str("k").unwrap(), "v w");
    }
}
