//! Deterministic pseudo-random numbers (SplitMix64 seeding + xoshiro256**).
//!
//! Every stochastic component in the simulator (workload generators,
//! property tests, jittered arrival processes) takes an explicit [`Rng`]
//! so that runs are reproducible from a single seed, which the benches
//! print alongside their results.

/// xoshiro256** — small, fast, high-quality; state seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (i64).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform in `[lo, hi)` (usize).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed with the given mean (for arrival processes).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Random int8 (full range), matching numpy's `integers(-128, 128)`.
    pub fn i8(&mut self) -> i8 {
        self.range_i64(-128, 128) as i8
    }

    /// A vector of random int8 values.
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.i8()).collect()
    }

    /// Choose a random element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let v = rng.below(10);
            assert!(v < 10);
            counts[v as usize] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "bucket count {c} too skewed");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((0.47..0.53).contains(&mean), "mean {mean}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Rng::new(3);
        let mean = 5.0;
        let sum: f64 = (0..20_000).map(|_| rng.exp(mean)).sum();
        let got = sum / 20_000.0;
        assert!((4.7..5.3).contains(&got), "exp mean {got}");
    }

    #[test]
    fn i8_covers_extremes() {
        let mut rng = Rng::new(4);
        let vals = rng.i8_vec(20_000);
        assert!(vals.iter().any(|&v| v == -128));
        assert!(vals.iter().any(|&v| v == 127));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left input unchanged");
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            let v = rng.range_i64(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }
}
