//! Summary statistics for benches, metrics and the experiment harness.

/// Streaming mean/variance (Welford) plus retained samples for percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in samples {
            s.push(v);
        }
        s
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
        let n = self.samples.len() as f64;
        let d = v - self.mean;
        self.mean += d / n;
        self.m2 += d * (v - self.mean);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1). Zero for fewer than two samples.
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on sorted samples, `q` in
    /// [0,100]. `None` when no samples were recorded — callers that can
    /// legitimately see an empty summary (e.g. a zero-completion serving
    /// run) must decide their own fallback instead of crashing.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=100.0).contains(&q));
        if self.samples.is_empty() {
            return None;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.len() == 1 {
            return Some(s[0]);
        }
        let rank = q / 100.0 * (s.len() as f64 - 1.0);
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        Some(s[lo] + (s[hi] - s[lo]) * frac)
    }

    /// Median; NaN on an empty summary (see [`Summary::percentile`]).
    pub fn p50(&self) -> f64 {
        self.percentile(50.0).unwrap_or(f64::NAN)
    }
    /// 95th percentile; NaN on an empty summary.
    pub fn p95(&self) -> f64 {
        self.percentile(95.0).unwrap_or(f64::NAN)
    }
    /// 99th percentile; NaN on an empty summary.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0).unwrap_or(f64::NAN)
    }

    /// Fraction of samples ≤ `x` — the SLO-attainment primitive. `None`
    /// when no samples were recorded: a zero-completion window (e.g. a
    /// full-cluster outage) must surface as "unmeasured", never as a
    /// silent `0.0` that reads like a real attainment figure.
    pub fn fraction_at_or_below(&self, x: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let n = self.samples.iter().filter(|&&v| v <= x).count();
        Some(n as f64 / self.samples.len() as f64)
    }

    /// `mean ± std (n=..)` single-line rendering with a unit suffix.
    pub fn display(&self, unit: &str) -> String {
        if self.is_empty() {
            return format!("no samples {unit} (n=0)");
        }
        format!(
            "{:.3} ± {:.3} {unit} (n={}, p50={:.3}, p99={:.3})",
            self.mean(),
            self.std(),
            self.len(),
            self.p50(),
            self.p99(),
        )
    }
}

/// Relative error |got - want| / |want| (used to score paper reproduction).
pub fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        got.abs()
    } else {
        (got - want).abs() / want.abs()
    }
}

/// Geometric mean (for aggregating per-row reproduction errors).
pub fn geomean(vals: &[f64]) -> f64 {
    assert!(!vals.is_empty());
    let s: f64 = vals.iter().map(|v| v.max(1e-12).ln()).sum();
    (s / vals.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known_values() {
        let s = Summary::from_samples([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_samples((1..=100).map(|v| v as f64));
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0).unwrap() - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0).unwrap() - 100.0).abs() < 1e-12);
        assert!(s.p99() > 98.0);
    }

    #[test]
    fn empty_summary_reports_cleanly() {
        let s = Summary::new();
        assert_eq!(s.percentile(99.0), None);
        assert!(s.p50().is_nan() && s.p99().is_nan());
        assert_eq!(s.mean(), 0.0);
        // display must not panic and must flag the empty sample set
        assert!(s.display("ms").contains("n=0"));
    }

    #[test]
    fn fraction_at_or_below_explicit_on_empty() {
        // the outage-window fix: an empty summary is "unmeasured", not 0
        assert_eq!(Summary::new().fraction_at_or_below(10.0), None);
        let s = Summary::from_samples([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.fraction_at_or_below(2.0), Some(0.5));
        assert_eq!(s.fraction_at_or_below(0.5), Some(0.0));
        assert_eq!(s.fraction_at_or_below(4.0), Some(1.0));
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples([3.25]);
        assert_eq!(s.mean(), 3.25);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.p50(), 3.25);
    }

    #[test]
    fn min_max() {
        let s = Summary::from_samples([3.0, -1.0, 9.0]);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn rel_err_basics() {
        assert!((rel_err(11.0, 10.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_err(2.0, 0.0), 2.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[10.0]) - 10.0).abs() < 1e-12);
    }
}
