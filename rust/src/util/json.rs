//! Minimal JSON parser/emitter.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers with exponents, booleans, null). Object key order is preserved
//! so emitted files diff cleanly. This is the interchange layer for
//! `artifacts/manifest.json` (written by python) and for experiment/
//! calibration configs (written by rust).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (the JSON data model); integer
/// accessors check round-trip exactness.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("json type error: expected {expected}, found {found}")]
    Type { expected: &'static str, found: &'static str },
    #[error("json missing key: {0}")]
    MissingKey(String),
    #[error("json number {0} is not an exact integer")]
    NotAnInteger(f64),
}

type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    pub fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type { expected: "bool", found: other.type_name() }),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type { expected: "number", found: other.type_name() }),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) {
            Ok(n as i64)
        } else {
            Err(JsonError::NotAnInteger(n))
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let v = self.as_i64()?;
        if v < 0 {
            return Err(JsonError::NotAnInteger(v as f64));
        }
        Ok(v as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type { expected: "string", found: other.type_name() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type { expected: "array", found: other.type_name() }),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type { expected: "object", found: other.type_name() }),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Convenience: `obj.get_str("name")` etc.
    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str()
    }
    pub fn get_i64(&self, key: &str) -> Result<i64> {
        self.req(key)?.as_i64()
    }
    pub fn get_u64(&self, key: &str) -> Result<u64> {
        self.req(key)?.as_u64()
    }
    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64()
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 1-space indent (matches python's `indent=1`).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    /// Pretty rendering with a caller-chosen indent width.
    pub fn to_string_indent(&self, width: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(width), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// The canonical human-facing rendering (2-space indent, trailing
/// newline) used by `vtacluster run --emit-spec` and every emitted
/// [`crate::scenario::Report`]. Guaranteed lossless:
/// `parse(pretty(x)) == x` (unit-tested below).
pub fn pretty(j: &Json) -> String {
    let mut s = j.to_string_indent(2);
    s.push('\n');
    s
}

/// Builder helpers so call sites read naturally.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn int(n: i64) -> Json {
    Json::Num(n as f64)
}
pub fn str_(s: &str) -> Json {
    Json::Str(s.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number '{text}'")))
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

/// Read and parse a JSON file.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(Json::parse(&text)?)
}

/// Sorted-key map view of an object (for canonical comparisons in tests).
pub fn to_map(j: &Json) -> Option<BTreeMap<String, Json>> {
    match j {
        Json::Obj(o) => Some(o.iter().cloned().collect()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_string_escapes() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"vta","nums":[1,2.5,-3],"flag":true,"sub":{"x":null}}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn pretty_roundtrips_exactly() {
        // the satellite contract: parse(pretty(x)) == x, for nesting,
        // escapes, numbers with exponents, and empty containers
        let src = r#"{"name":"vta \"run\"","axes":{"n":[4,8,12],"strategy":["pipeline","eco"]},"empty":[],"none":{},"rate":-3.5e2,"on":true,"off":null}"#;
        let j = Json::parse(src).unwrap();
        let p = pretty(&j);
        assert_eq!(Json::parse(&p).unwrap(), j);
        // 2-space indent, one key per line, trailing newline
        assert!(p.contains("\n  \"name\""), "{p}");
        assert!(p.ends_with("}\n"), "{p}");
        // indent width is honoured at depth 2
        assert!(p.contains("\n    \"n\""), "{p}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07a").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn integer_accessors() {
        assert_eq!(Json::parse("7").unwrap().as_i64().unwrap(), 7);
        assert!(Json::parse("7.5").unwrap().as_i64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
    }

    #[test]
    fn missing_key_error_names_key() {
        let j = Json::parse("{}").unwrap();
        let e = j.req("clock_mhz").unwrap_err();
        assert!(e.to_string().contains("clock_mhz"));
    }

    #[test]
    fn builders() {
        let j = obj(vec![("a", int(1)), ("b", arr(vec![str_("x")]))]);
        assert_eq!(j.to_string_compact(), r#"{"a":1,"b":["x"]}"#);
    }

    #[test]
    fn emits_large_ints_exactly() {
        let j = int(1_814_073_344);
        assert_eq!(j.to_string_compact(), "1814073344");
    }
}
