//! Support substrates that would normally come from crates.io.
//!
//! This image builds fully offline against a fixed vendor set (see
//! `.cargo/config.toml`), so serde/clap/criterion/proptest/rand are not
//! available. Each submodule is a small, tested, purpose-built replacement:
//!
//! * [`json`]     — JSON parse/emit (manifest + config interchange)
//! * [`rng`]      — deterministic SplitMix64/xoshiro RNG
//! * [`stats`]    — summary statistics for benches and metrics
//! * [`cli`]      — argument parsing for the `vtacluster` binary
//! * [`units`]    — simulation time (integer nanoseconds) and byte units
//! * [`bench`]    — measurement harness used by `cargo bench` targets
//! * [`proptest`] — property-based testing mini-framework
//! * [`logging`]  — leveled stderr logging controlled by `VTA_LOG`

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod units;
