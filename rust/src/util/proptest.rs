//! Property-based testing mini-framework (proptest is not vendored).
//!
//! A property is a closure taking an [`Rng`]; [`forall`] runs it across
//! many deterministic seeds and, on failure, reports the failing seed so
//! the case can be replayed exactly:
//!
//! ```no_run
//! use vta_cluster::util::proptest::forall;
//! forall("gemm roundtrip", 200, |rng| {
//!     let m = rng.range(1, 64);
//!     // ... build inputs from rng, check invariant, return Ok(()) or Err(msg)
//!     Ok(())
//! });
//! ```
//!
//! Seeds derive from `VTA_PROP_SEED` (default 0) so CI failures reproduce
//! locally by exporting the same value.

use super::rng::Rng;

/// Run `cases` random cases of a property; panic with the failing seed.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base: u64 = std::env::var("VTA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case)
            .wrapping_add(fxhash(name));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay: VTA_PROP_SEED={base}, seed={seed}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed on replay seed {seed}: {msg}");
    }
}

/// Tiny FNV-style hash to decorrelate property names.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality helper with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {}\n  left: {:?}\n  right: {:?}",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("always-true", 50, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        forall("always-false", 10, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn macros_work() {
        forall("macro-check", 20, |rng| {
            let a = rng.range(0, 100);
            prop_assert!(a < 100, "a={a} out of range");
            prop_assert_eq!(a, a);
            Ok(())
        });
    }

    #[test]
    fn cases_get_distinct_seeds() {
        let mut vals = Vec::new();
        forall("distinct", 20, |rng| {
            vals.push(rng.next_u64());
            Ok(())
        });
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len(), 20);
    }
}
