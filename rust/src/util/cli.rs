//! Tiny argument parser for the `vtacluster` binary and the examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments and auto-generated `--help`. Unknown flags are an error (they
//! are usually typos of experiment parameters).

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
    /// Repeatable `--key value` collected into a list (e.g. `--set`).
    is_multi: bool,
}

/// Declarative CLI: declare options, then parse.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<Spec>,
    positional_name: Option<(String, String)>,
}

#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    multis: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
            is_multi: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            is_multi: false,
        });
        self
    }

    /// Declare a boolean `--name`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
            is_multi: false,
        });
        self
    }

    /// Declare a repeatable `--name <value>` collected into a list
    /// (zero occurrences → empty list; e.g. `run --set a=1 --set b=2`).
    pub fn multi(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            is_multi: true,
        });
        self
    }

    /// Declare that positional arguments are accepted.
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional_name = Some((name.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [OPTIONS]", self.program, self.about, self.program);
        if let Some((name, _)) = &self.positional_name {
            s.push_str(&format!(" [{name}...]"));
        }
        s.push_str("\n\nOPTIONS:\n");
        for spec in &self.specs {
            let lhs = if spec.is_flag {
                format!("--{}", spec.name)
            } else {
                format!("--{} <v>", spec.name)
            };
            let def = match &spec.default {
                Some(d) => format!(" [default: {d}]"),
                None if spec.is_flag => String::new(),
                None if spec.is_multi => " [repeatable]".to_string(),
                None => " [required]".to_string(),
            };
            s.push_str(&format!("  {lhs:24} {}{def}\n", spec.help));
        }
        s.push_str("  --help                   print this help\n");
        if let Some((name, help)) = &self.positional_name {
            s.push_str(&format!("\nARGS:\n  {name:24} {help}\n"));
        }
        s
    }

    /// Parse a list of arguments (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> anyhow::Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut multis: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        anyhow::bail!("flag --{name} takes no value");
                    }
                    flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("option --{name} needs a value"))?,
                    };
                    if spec.is_multi {
                        multis.entry(name).or_default().push(v);
                    } else {
                        values.insert(name, v);
                    }
                }
            } else {
                if self.positional_name.is_none() {
                    anyhow::bail!("unexpected positional argument '{arg}'\n\n{}", self.usage());
                }
                positional.push(arg);
            }
        }
        // defaults + required check
        for spec in &self.specs {
            if spec.is_flag {
                flags.entry(spec.name.clone()).or_insert(false);
            } else if spec.is_multi {
                multis.entry(spec.name.clone()).or_default();
            } else if !values.contains_key(&spec.name) {
                match &spec.default {
                    Some(d) => {
                        values.insert(spec.name.clone(), d.clone());
                    }
                    None => anyhow::bail!("missing required option --{}\n\n{}", spec.name, self.usage()),
                }
            }
        }
        Ok(Args { values, flags, multis, positional })
    }

    /// Parse the process arguments.
    pub fn parse(&self) -> anyhow::Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    /// Every occurrence of a repeatable option, in command-line order.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.multis
            .get(name)
            .unwrap_or_else(|| panic!("multi option --{name} was not declared"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: invalid integer: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: invalid integer: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: invalid number: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("nodes", "4", "cluster size")
            .req("strategy", "scheduling strategy")
            .flag("verbose", "log more")
            .multi("set", "spec override")
            .positional("files", "input files")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_all_forms() {
        let a = cli()
            .parse_from(argv(&["--strategy=pipeline", "--nodes", "8", "--verbose", "f1", "f2"]))
            .unwrap();
        assert_eq!(a.get("strategy"), "pipeline");
        assert_eq!(a.get_usize("nodes").unwrap(), 8);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["f1", "f2"]);
    }

    #[test]
    fn applies_defaults() {
        let a = cli().parse_from(argv(&["--strategy", "sg"])).unwrap();
        assert_eq!(a.get("nodes"), "4");
        assert!(!a.get_flag("verbose"));
        assert!(a.get_all("set").is_empty());
    }

    #[test]
    fn multi_option_collects_in_order() {
        let a = cli()
            .parse_from(argv(&["--strategy=sg", "--set", "n=4", "--set=engine=des"]))
            .unwrap();
        assert_eq!(a.get_all("set"), ["n=4", "engine=des"]);
    }

    #[test]
    fn missing_required_is_error() {
        let e = cli().parse_from(argv(&[])).unwrap_err().to_string();
        assert!(e.contains("--strategy"), "{e}");
    }

    #[test]
    fn unknown_option_is_error() {
        let e = cli()
            .parse_from(argv(&["--strategy", "x", "--bogus", "1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--bogus"), "{e}");
    }

    #[test]
    fn flag_with_value_is_error() {
        assert!(cli().parse_from(argv(&["--strategy=x", "--verbose=1"])).is_err());
    }

    #[test]
    fn help_contains_options() {
        let u = cli().usage();
        assert!(u.contains("--nodes"));
        assert!(u.contains("[default: 4]"));
        assert!(u.contains("[required]"));
    }
}
