//! Controller decision audit log (DESIGN.md §13).
//!
//! Every [`crate::sched::OnlineController::decide`] consultation —
//! switch *or* hold — is recorded with the numbers that justified it:
//! the smoothed arrival rate and power draw, the backlog, and for the
//! overload branch the drain-time break-even figures (T_stay /
//! T_switch) the module docs of [`crate::sched::online`] derive. The
//! log answers the question a latency regression always raises first:
//! *why did (or didn't) the controller act at t?*
//!
//! The log is off by default (zero cost beyond one branch per
//! consultation); the DES enables it when telemetry is on and drains it
//! into the run's [`crate::telemetry::RunTelemetry`].

use crate::util::json::{self, Json};

/// What the controller concluded from one observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuditVerdict {
    /// Inside the minimum dwell after a switch — no evaluation ran.
    HoldDwell,
    /// Over the power budget → downshift to the cheapest candidate.
    SwitchPowerCap,
    /// Over the power budget but already on the cheapest plan.
    HoldPowerFloor,
    /// Overloaded, but the best candidate is active or below the
    /// capacity-gain threshold.
    HoldNoGain,
    /// Overloaded, but the drain-time break-even says staying is faster.
    HoldNotWorth,
    /// Overload upgrade: T_switch < T_stay.
    SwitchOverload,
    /// Underload downshift to a lower-latency candidate.
    SwitchUnderload,
    /// Emergency failover: the active plan references a dead node
    /// (DESIGN.md §14). Bypasses the dwell clock.
    SwitchFailover,
    /// All nodes back in service → leave the survivor plan for the best
    /// full-width candidate.
    SwitchRestore,
    /// Active plan references a dead node but no healthy candidate
    /// exists (e.g. a concurrent multi-node outage).
    HoldNoFailover,
    /// No branch fired — load sits in the hysteresis band.
    HoldSteady,
    /// Not a decision: an alert rule fired on this window
    /// (DESIGN.md §15) and was stamped into the log so pages and
    /// controller actions share one timeline.
    Alert,
}

impl AuditVerdict {
    pub fn as_str(self) -> &'static str {
        match self {
            AuditVerdict::HoldDwell => "hold-dwell",
            AuditVerdict::SwitchPowerCap => "switch-power-cap",
            AuditVerdict::HoldPowerFloor => "hold-power-floor",
            AuditVerdict::HoldNoGain => "hold-no-gain",
            AuditVerdict::HoldNotWorth => "hold-not-worth",
            AuditVerdict::SwitchOverload => "switch-overload",
            AuditVerdict::SwitchUnderload => "switch-underload",
            AuditVerdict::SwitchFailover => "switch-failover",
            AuditVerdict::SwitchRestore => "switch-restore",
            AuditVerdict::HoldNoFailover => "hold-no-failover",
            AuditVerdict::HoldSteady => "hold-steady",
            AuditVerdict::Alert => "alert",
        }
    }

    pub fn is_switch(self) -> bool {
        matches!(
            self,
            AuditVerdict::SwitchPowerCap
                | AuditVerdict::SwitchOverload
                | AuditVerdict::SwitchUnderload
                | AuditVerdict::SwitchFailover
                | AuditVerdict::SwitchRestore
        )
    }
}

/// One consultation, with the break-even arithmetic that decided it.
/// Fields a branch did not compute are NaN (emitted as JSON null).
#[derive(Debug, Clone)]
pub struct AuditRecord {
    pub at_ms: f64,
    /// Active option index when the observation arrived.
    pub active: usize,
    /// Smoothed arrival rate λ̂, img/s.
    pub lambda_hat: f64,
    /// Smoothed measured draw, W.
    pub power_hat: f64,
    pub backlog: usize,
    pub verdict: AuditVerdict,
    /// Target option of a switch verdict.
    pub to: Option<usize>,
    /// Capacity of the active plan μ_cur, img/s.
    pub mu_cur: f64,
    /// Capacity of the best candidate μ_best (overload branch only).
    pub mu_best: f64,
    /// Projected drain time if the cluster stays, s (overload branch).
    pub t_stay_s: f64,
    /// Projected drain time through a switch, s (overload branch).
    pub t_switch_s: f64,
    /// The human-readable rationale (same text as the executed
    /// [`crate::sim::ReconfigEvent`] for switch verdicts).
    pub reason: String,
}

impl AuditRecord {
    pub fn to_json(&self) -> Json {
        let fnum = |v: f64| if v.is_finite() { json::num(v) } else { Json::Null };
        json::obj(vec![
            ("at_ms", fnum(self.at_ms)),
            ("active", json::int(self.active as i64)),
            ("verdict", json::str_(self.verdict.as_str())),
            (
                "to",
                self.to.map(|t| json::int(t as i64)).unwrap_or(Json::Null),
            ),
            ("lambda_hat", fnum(self.lambda_hat)),
            ("power_hat", fnum(self.power_hat)),
            ("backlog", json::int(self.backlog as i64)),
            ("mu_cur", fnum(self.mu_cur)),
            ("mu_best", fnum(self.mu_best)),
            ("t_stay_s", fnum(self.t_stay_s)),
            ("t_switch_s", fnum(self.t_switch_s)),
            ("reason", json::str_(&self.reason)),
        ])
    }
}

/// The controller-side collector. Disabled it records nothing, so the
/// controller can carry it unconditionally.
#[derive(Debug, Clone, Default)]
pub struct AuditLog {
    pub enabled: bool,
    pub records: Vec<AuditRecord>,
}

impl AuditLog {
    pub fn push(&mut self, rec: AuditRecord) {
        if self.enabled {
            self.records.push(rec);
        }
    }

    /// Drain the collected records (what the DES does at end of run).
    pub fn take(&mut self) -> Vec<AuditRecord> {
        std::mem::take(&mut self.records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(verdict: AuditVerdict) -> AuditRecord {
        AuditRecord {
            at_ms: 100.0,
            active: 0,
            lambda_hat: 50.0,
            power_hat: 12.0,
            backlog: 3,
            verdict,
            to: verdict.is_switch().then_some(1),
            mu_cur: 80.0,
            mu_best: f64::NAN,
            t_stay_s: f64::NAN,
            t_switch_s: f64::NAN,
            reason: "test".into(),
        }
    }

    #[test]
    fn disabled_log_records_nothing() {
        let mut log = AuditLog::default();
        log.push(rec(AuditVerdict::HoldSteady));
        assert!(log.records.is_empty());
        log.enabled = true;
        log.push(rec(AuditVerdict::SwitchOverload));
        assert_eq!(log.records.len(), 1);
        let drained = log.take();
        assert_eq!(drained.len(), 1);
        assert!(log.records.is_empty());
    }

    #[test]
    fn json_emits_nan_as_null() {
        let j = rec(AuditVerdict::HoldSteady).to_json();
        assert_eq!(j.get("mu_best"), Some(&Json::Null));
        assert_eq!(j.get("to"), Some(&Json::Null));
        assert_eq!(j.get("verdict").unwrap().as_str().unwrap(), "hold-steady");
        // round-trips as valid JSON
        let text = json::pretty(&j);
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn verdict_names_are_stable() {
        for (v, s) in [
            (AuditVerdict::HoldDwell, "hold-dwell"),
            (AuditVerdict::SwitchPowerCap, "switch-power-cap"),
            (AuditVerdict::SwitchUnderload, "switch-underload"),
            (AuditVerdict::SwitchFailover, "switch-failover"),
            (AuditVerdict::SwitchRestore, "switch-restore"),
            (AuditVerdict::HoldNoFailover, "hold-no-failover"),
            (AuditVerdict::Alert, "alert"),
        ] {
            assert_eq!(v.as_str(), s);
        }
        assert!(AuditVerdict::SwitchOverload.is_switch());
        assert!(AuditVerdict::SwitchFailover.is_switch());
        assert!(AuditVerdict::SwitchRestore.is_switch());
        assert!(!AuditVerdict::HoldNotWorth.is_switch());
        assert!(!AuditVerdict::HoldNoFailover.is_switch());
        assert!(!AuditVerdict::Alert.is_switch());
    }
}
