//! The telemetry clock abstraction (DESIGN.md §13).
//!
//! The codebase measures time in two incommensurable domains: host
//! wall-clock (`std::time::Instant`, what the real PJRT serving
//! coordinator experiences) and simulated integer nanoseconds
//! ([`crate::util::units::Nanos`], what both simulators advance).
//! Mixing them is a bug — a DES run that reports "throughput" from host
//! elapsed time measures the *simulator's* speed, not the cluster's.
//! [`Clock`] makes the domain explicit: a metrics consumer holds one
//! clock and every reading says which kind of time it is.

use crate::util::units::Nanos;
use std::time::{Duration, Instant};

/// A span measurer in one time domain: host wall-clock or sim-time.
#[derive(Debug, Clone, Copy)]
pub enum Clock {
    /// Host time. `start` samples `Instant::now()`; [`Clock::mark`]
    /// moves the end of the span to now.
    Wall { started: Option<Instant>, latest: Option<Instant> },
    /// Simulated time. The owner advances the span explicitly with
    /// [`Clock::mark_at`]; host time never leaks in.
    Sim { started: Option<Nanos>, latest: Option<Nanos> },
}

impl Default for Clock {
    fn default() -> Self {
        Clock::wall()
    }
}

impl Clock {
    pub fn wall() -> Self {
        Clock::Wall { started: None, latest: None }
    }

    pub fn sim() -> Self {
        Clock::Sim { started: None, latest: None }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self, Clock::Sim { .. })
    }

    /// Open the span: wall clocks at `Instant::now()`, sim clocks at 0 ns.
    pub fn start(&mut self) {
        match self {
            Clock::Wall { started, .. } => *started = Some(Instant::now()),
            Clock::Sim { started, .. } => *started = Some(0),
        }
    }

    /// Open a sim span at an explicit origin (no-op start on wall clocks,
    /// which always originate at `Instant::now()`).
    pub fn start_at(&mut self, ns: Nanos) {
        match self {
            Clock::Wall { started, .. } => *started = Some(Instant::now()),
            Clock::Sim { started, .. } => *started = Some(ns),
        }
    }

    /// Extend the span to "now". On a sim clock this is a no-op — sim
    /// time only advances through [`Clock::mark_at`].
    pub fn mark(&mut self) {
        if let Clock::Wall { latest, .. } = self {
            *latest = Some(Instant::now());
        }
    }

    /// Extend the span to the given sim time. On a wall clock the
    /// nanosecond value is ignored and "now" is sampled instead, so
    /// callers generic over the domain can always pass the sim time they
    /// have.
    pub fn mark_at(&mut self, ns: Nanos) {
        match self {
            Clock::Wall { latest, .. } => *latest = Some(Instant::now()),
            Clock::Sim { latest, .. } => *latest = Some(ns),
        }
    }

    /// Span from start to the last mark; zero until both ends exist.
    pub fn elapsed(&self) -> Duration {
        match self {
            Clock::Wall { started: Some(s), latest: Some(l) } => l.duration_since(*s),
            Clock::Sim { started: Some(s), latest: Some(l) } => {
                Duration::from_nanos(l.saturating_sub(*s))
            }
            _ => Duration::ZERO,
        }
    }

    pub fn elapsed_sec(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_is_exact_and_host_free() {
        let mut c = Clock::sim();
        assert_eq!(c.elapsed(), Duration::ZERO);
        c.start();
        c.mark_at(2_500_000_000);
        assert_eq!(c.elapsed(), Duration::from_millis(2500));
        // wall-style mark must not disturb a sim span
        c.mark();
        assert_eq!(c.elapsed(), Duration::from_millis(2500));
        assert!(c.is_sim());
    }

    #[test]
    fn sim_clock_with_origin() {
        let mut c = Clock::sim();
        c.start_at(1_000_000);
        c.mark_at(4_000_000);
        assert_eq!(c.elapsed(), Duration::from_millis(3));
        // marks never go negative even if the owner rewinds
        c.mark_at(0);
        assert_eq!(c.elapsed(), Duration::ZERO);
    }

    #[test]
    fn wall_clock_advances() {
        let mut c = Clock::wall();
        assert!(!c.is_sim());
        c.start();
        std::thread::sleep(Duration::from_millis(2));
        c.mark();
        assert!(c.elapsed() >= Duration::from_millis(1));
    }

    #[test]
    fn unstarted_clocks_read_zero() {
        let mut c = Clock::wall();
        c.mark();
        assert_eq!(c.elapsed(), Duration::ZERO);
        let mut s = Clock::sim();
        s.mark_at(99);
        assert_eq!(s.elapsed(), Duration::ZERO);
    }
}
