//! Chrome trace-event exporter (DESIGN.md §13).
//!
//! Renders one or more [`RunTelemetry`] bundles as the Chrome
//! trace-event JSON format that `chrome://tracing` and Perfetto load
//! directly (`vtacluster run <spec> --trace out.json`). The mapping:
//!
//! * each run is a *process* (`pid` = run index + 1, named after the
//!   row label and engine);
//! * each cluster node is a *thread*; compute intervals are complete
//!   (`"X"`) events on the owning node's track — the per-node FIFO
//!   guarantees they never overlap;
//! * queue-wait and network hops are async (`"b"`/`"e"`) pairs keyed
//!   by request id, so Perfetto draws each request's critical path as
//!   a nestable track;
//! * executed reconfigurations are `"X"` spans and controller audit
//!   verdicts are instant (`"i"`) markers on a dedicated `controller`
//!   track;
//! * the window stream renders as counter (`"C"`) tracks — backlog,
//!   window power draw, and the controller's EMA'd arrival rate — so
//!   the metric time-series (DESIGN.md §15) plot alongside the spans
//!   in ui.perfetto.dev.
//!
//! Timestamps convert sim-time nanoseconds to the format's
//! microseconds (`ns / 1000`), so a 8 s simulated run renders as 8 s
//! of trace time regardless of how long the simulator took.

use super::RunTelemetry;
use crate::util::json::{self, Json};

const MASTER_TID: usize = 1000;
const CONTROLLER_TID: usize = 2000;
const FAULT_TID: usize = 3000;

fn us(ns: u64) -> Json {
    json::num(ns as f64 / 1e3)
}

fn meta(pid: usize, tid: Option<usize>, kind: &str, name: &str) -> Json {
    let mut fields = vec![
        ("name", json::str_(kind)),
        ("ph", json::str_("M")),
        ("pid", json::int(pid as i64)),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", json::int(tid as i64)));
    }
    fields.push(("args", json::obj(vec![("name", json::str_(name))])));
    json::obj(fields)
}

fn complete(
    pid: usize,
    tid: usize,
    name: &str,
    cat: &str,
    start_ns: u64,
    end_ns: u64,
    args: Json,
) -> Json {
    json::obj(vec![
        ("name", json::str_(name)),
        ("cat", json::str_(cat)),
        ("ph", json::str_("X")),
        ("pid", json::int(pid as i64)),
        ("tid", json::int(tid as i64)),
        ("ts", us(start_ns)),
        ("dur", json::num(end_ns.saturating_sub(start_ns) as f64 / 1e3)),
        ("args", args),
    ])
}

/// A counter (`ph` "C") sample: Perfetto plots one track per
/// (process, name), with the series value in `args`.
fn counter(pid: usize, name: &str, ts_us: Json, value: f64) -> Json {
    json::obj(vec![
        ("name", json::str_(name)),
        ("cat", json::str_("metric")),
        ("ph", json::str_("C")),
        ("pid", json::int(pid as i64)),
        ("ts", ts_us),
        ("args", json::obj(vec![(name, json::num(value))])),
    ])
}

/// An async begin/end pair (`ph` "b" then "e") keyed by request id.
fn async_pair(
    out: &mut Vec<Json>,
    pid: usize,
    tid: usize,
    name: &str,
    cat: &str,
    id: usize,
    start_ns: u64,
    end_ns: u64,
) {
    for (ph, ts) in [("b", start_ns), ("e", end_ns)] {
        out.push(json::obj(vec![
            ("name", json::str_(name)),
            ("cat", json::str_(cat)),
            ("ph", json::str_(ph)),
            ("id", json::int(id as i64)),
            ("pid", json::int(pid as i64)),
            ("tid", json::int(tid as i64)),
            ("ts", us(ts)),
        ]));
    }
}

/// Render telemetry bundles as a Chrome trace-event document.
pub fn chrome_trace(runs: &[RunTelemetry]) -> Json {
    let mut events = Vec::new();
    for (i, run) in runs.iter().enumerate() {
        let pid = i + 1;
        let pname = if run.engine.is_empty() {
            run.label.clone()
        } else {
            format!("{} ({})", run.label, run.engine)
        };
        events.push(meta(pid, None, "process_name", &pname));

        // name every node track that appears in the spans
        let mut nodes = std::collections::BTreeSet::new();
        for t in &run.traces {
            for s in &t.stages {
                if !s.is_gather() {
                    nodes.insert(s.node);
                }
                for c in &s.computes {
                    nodes.insert(c.node);
                }
            }
        }
        for &n in &nodes {
            events.push(meta(pid, Some(n + 1), "thread_name", &format!("node {n}")));
        }
        events.push(meta(pid, Some(MASTER_TID), "thread_name", "master"));
        if !run.reconfigs.is_empty() || !run.audit.is_empty() {
            events.push(meta(pid, Some(CONTROLLER_TID), "thread_name", "controller"));
        }
        if !run.faults.is_empty() {
            events.push(meta(pid, Some(FAULT_TID), "thread_name", "faults"));
        }

        for t in &run.traces {
            for s in &t.stages {
                if s.is_gather() {
                    // network-only hop back to the master
                    async_pair(
                        &mut events,
                        pid,
                        MASTER_TID,
                        "net gather",
                        "net",
                        t.img,
                        s.start_ns,
                        s.end_ns,
                    );
                    continue;
                }
                let tid = s.node + 1;
                let net_end = s.start_ns + s.net_ns;
                let queue_end = net_end + s.queue_ns;
                // zero-duration hops still emit, so every traced run
                // carries all three categories for the CI validator
                async_pair(
                    &mut events,
                    pid,
                    tid,
                    &format!("net s{}", s.si),
                    "net",
                    t.img,
                    s.start_ns,
                    net_end,
                );
                async_pair(
                    &mut events,
                    pid,
                    tid,
                    &format!("queue s{}", s.si),
                    "queue",
                    t.img,
                    net_end,
                    queue_end,
                );
                for c in &s.computes {
                    events.push(complete(
                        pid,
                        c.node + 1,
                        &format!("compute s{}", s.si),
                        "compute",
                        c.start_ns,
                        c.end_ns,
                        json::obj(vec![
                            ("img", json::int(t.img as i64)),
                            ("plan", json::int(t.plan as i64)),
                        ]),
                    ));
                }
            }
        }

        for w in &run.windows {
            let ts = json::num(w.t_ms * 1e3);
            events.push(counter(pid, "backlog", ts.clone(), w.backlog as f64));
            if w.power_w.is_finite() {
                events.push(counter(pid, "power (W)", ts, w.power_w));
            }
        }
        for a in &run.audit {
            if a.lambda_hat.is_finite() {
                events.push(counter(
                    pid,
                    "lambda_hat (img/s)",
                    json::num(a.at_ms * 1e3),
                    a.lambda_hat,
                ));
            }
        }

        for r in &run.reconfigs {
            events.push(complete(
                pid,
                CONTROLLER_TID,
                &format!("reconfig {}→{}", r.from, r.to),
                "reconfig",
                r.start_ns,
                r.end_ns,
                json::obj(vec![
                    ("from", json::int(r.from as i64)),
                    ("to", json::int(r.to as i64)),
                    ("reason", json::str_(&r.reason)),
                ]),
            ));
        }

        for f in &run.faults {
            events.push(json::obj(vec![
                ("name", json::str_(&format!("node {} {}", f.node, f.kind))),
                ("cat", json::str_("fault")),
                ("ph", json::str_("i")),
                ("s", json::str_("p")),
                ("pid", json::int(pid as i64)),
                ("tid", json::int(FAULT_TID as i64)),
                ("ts", us(f.at_ns)),
                ("args", json::obj(vec![
                    ("node", json::int(f.node as i64)),
                    ("kind", json::str_(&f.kind)),
                ])),
            ]));
        }

        for a in &run.audit {
            let fnum = |v: f64| if v.is_finite() { json::num(v) } else { Json::Null };
            events.push(json::obj(vec![
                ("name", json::str_(a.verdict.as_str())),
                ("cat", json::str_("audit")),
                ("ph", json::str_("i")),
                ("s", json::str_("p")),
                ("pid", json::int(pid as i64)),
                ("tid", json::int(CONTROLLER_TID as i64)),
                ("ts", json::num(a.at_ms * 1e3)),
                ("args", json::obj(vec![
                    ("lambda_hat", fnum(a.lambda_hat)),
                    ("power_hat", fnum(a.power_hat)),
                    ("backlog", json::int(a.backlog as i64)),
                    ("mu_cur", fnum(a.mu_cur)),
                    ("mu_best", fnum(a.mu_best)),
                    ("t_stay_s", fnum(a.t_stay_s)),
                    ("t_switch_s", fnum(a.t_switch_s)),
                    ("reason", json::str_(&a.reason)),
                ])),
            ]));
        }
    }
    json::obj(vec![
        ("displayTimeUnit", json::str_("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::audit::{AuditRecord, AuditVerdict};
    use super::super::span::{
        ComputeSpan, FaultMark, ReconfigSpan, RequestTrace, StageSpan, WindowRow,
    };
    use super::*;
    use crate::telemetry::HdrHist;

    fn bundle() -> RunTelemetry {
        RunTelemetry {
            label: "burst".into(),
            engine: "des".into(),
            sample_stride: 1,
            traces: vec![RequestTrace {
                img: 0,
                plan: 0,
                admitted_ns: 1_000,
                done_ns: Some(9_000),
                stages: vec![
                    StageSpan {
                        si: 0,
                        start_ns: 1_000,
                        end_ns: 6_000,
                        net_ns: 1_000,
                        queue_ns: 1_500,
                        compute_ns: 2_500,
                        node: 1,
                        computes: vec![
                            ComputeSpan { node: 1, start_ns: 3_500, end_ns: 6_000 },
                            ComputeSpan { node: 2, start_ns: 3_000, end_ns: 5_000 },
                        ],
                    },
                    StageSpan {
                        si: usize::MAX, // gather
                        start_ns: 6_000,
                        end_ns: 9_000,
                        net_ns: 3_000,
                        queue_ns: 0,
                        compute_ns: 0,
                        node: 0,
                        computes: vec![],
                    },
                ],
            }],
            windows: vec![WindowRow {
                t_ms: 0.005,
                events: 12,
                arrivals: 1,
                completions: 1,
                stalled: false,
                backlog: 3,
                power_w: 7.25,
                stages: vec![],
            }],
            faults: vec![FaultMark { at_ns: 4_000, node: 1, kind: "down".into() }],
            reconfigs: vec![ReconfigSpan {
                start_ns: 10_000,
                end_ns: 12_000,
                from: 0,
                to: 1,
                reason: "overload".into(),
            }],
            audit: vec![AuditRecord {
                at_ms: 0.01,
                active: 0,
                lambda_hat: 5.0,
                power_hat: 4.0,
                backlog: 2,
                verdict: AuditVerdict::SwitchOverload,
                to: Some(1),
                mu_cur: 3.0,
                mu_best: 9.0,
                t_stay_s: 1.0,
                t_switch_s: 0.5,
                reason: "overload".into(),
            }],
            queue_hist: HdrHist::new(),
            service_hist: HdrHist::new(),
            latency_hist: HdrHist::new(),
        }
    }

    fn strs<'a>(evs: &'a [Json], key: &str) -> Vec<&'a str> {
        evs.iter().filter_map(|e| e.get(key).and_then(|v| v.as_str().ok())).collect()
    }

    #[test]
    fn emits_all_phases_and_categories() {
        let doc = chrome_trace(&[bundle()]);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let phases = strs(evs, "ph");
        for ph in ["M", "X", "b", "e", "i", "C"] {
            assert!(phases.contains(&ph), "missing phase {ph}: {phases:?}");
        }
        let cats = strs(evs, "cat");
        for cat in ["compute", "queue", "net", "reconfig", "audit", "fault"] {
            assert!(cats.contains(&cat), "missing cat {cat}: {cats:?}");
        }
        // async begin/end balance
        let b = phases.iter().filter(|p| **p == "b").count();
        let e = phases.iter().filter(|p| **p == "e").count();
        assert_eq!(b, e);
        // every non-metadata event has a timestamp
        for ev in evs {
            if ev.get("ph").unwrap().as_str().unwrap() != "M" {
                assert!(ev.get("ts").is_some(), "{}", ev.to_string_compact());
            }
        }
    }

    #[test]
    fn counter_tracks_carry_the_window_metrics() {
        let doc = chrome_trace(&[bundle()]);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let counters: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "C")
            .collect();
        let names = counters
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect::<Vec<_>>();
        for name in ["backlog", "power (W)", "lambda_hat (img/s)"] {
            assert!(names.contains(&name), "missing counter {name}: {names:?}");
        }
        let backlog = counters
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "backlog")
            .unwrap();
        // 0.005 ms window close → 5 µs
        assert_eq!(backlog.get("ts").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(
            backlog.get("args").unwrap().get_f64("backlog").unwrap(),
            3.0
        );
    }

    #[test]
    fn timestamps_are_microseconds() {
        let doc = chrome_trace(&[bundle()]);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let compute: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("cat").map(|c| c.as_str().unwrap()) == Some("compute"))
            .collect();
        assert_eq!(compute.len(), 2);
        // node 1's compute: 3500 ns → 3.5 µs, dur 2500 ns → 2.5 µs
        let c1 = compute
            .iter()
            .find(|e| e.get("tid").unwrap().as_i64().unwrap() == 2)
            .unwrap();
        assert_eq!(c1.get("ts").unwrap().as_f64().unwrap(), 3.5);
        assert_eq!(c1.get("dur").unwrap().as_f64().unwrap(), 2.5);
    }

    #[test]
    fn process_and_thread_names_cover_the_tracks() {
        let doc = chrome_trace(&[bundle()]);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let meta_names: Vec<String> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert!(meta_names.contains(&"burst (des)".to_string()));
        assert!(meta_names.contains(&"node 1".to_string()));
        assert!(meta_names.contains(&"node 2".to_string()));
        assert!(meta_names.contains(&"master".to_string()));
        assert!(meta_names.contains(&"controller".to_string()));
        assert!(meta_names.contains(&"faults".to_string()));
    }

    #[test]
    fn empty_runs_produce_an_empty_but_valid_document() {
        let doc = chrome_trace(&[]);
        assert_eq!(doc.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
        assert!(Json::parse(&json::pretty(&doc)).is_ok());
    }
}
