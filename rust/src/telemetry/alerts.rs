//! Declarative per-window alert rules over the metric stream
//! (DESIGN.md §15).
//!
//! Four rules, all evaluated at every control-window close against the
//! same observation the controller sees:
//!
//! * **slo-burn-rate** — violation fraction over a sliding window of
//!   the last `burn_windows` control windows, normalized by the error
//!   budget `1 - slo_target`. A burn rate of 1.0 spends the budget
//!   exactly; firing at `burn_threshold` (default 2×) is the classic
//!   fast-burn page.
//! * **power-overdraw** — the window's average draw exceeds the power
//!   budget. The controller *caps* plans by predicted draw; this rule
//!   catches the windows where realized draw still overshoots (bursts,
//!   reconfiguration overlap).
//! * **availability-floor** — the fraction of nodes up drops below the
//!   floor (crash outages, DESIGN.md §14).
//! * **stalled-window** — a window completed nothing while work was in
//!   flight (the DES's reconfiguration/outage stall signal).
//!
//! Rules are edge-triggered: a firing is emitted when the condition
//! becomes true and re-arms only after a clean window, so a 600 ms
//! outage is one alert, not six. Firings land in three places — the
//! run's [`super::metrics::RunMetrics`] bundle, the Report event
//! timeline, and the controller audit log (verdict `alert`) — so the
//! "what fired" and the "what the controller did about it" line up on
//! one timeline.

use crate::util::json::{self, Json};
use std::collections::VecDeque;

/// Thresholds for the per-window rules. Resolved from the spec's
/// `telemetry` block; a rule whose threshold is unset (0 / NaN) is off.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertRules {
    /// Latency SLO for the burn-rate rule, ms; 0 = rule off.
    pub slo_ms: f64,
    /// Attainment target the error budget is derived from.
    pub slo_target: f64,
    /// Burn-rate multiple that fires the page.
    pub burn_threshold: f64,
    /// Sliding-window length, in control windows.
    pub burn_windows: usize,
    /// Power budget for the overdraw rule, W; 0 = rule off.
    pub power_budget_w: f64,
    /// Minimum fraction of nodes up; 0 = rule off.
    pub availability_floor: f64,
}

impl Default for AlertRules {
    fn default() -> Self {
        AlertRules {
            slo_ms: 0.0,
            slo_target: 0.99,
            burn_threshold: 2.0,
            burn_windows: 10,
            power_budget_w: 0.0,
            availability_floor: 0.999,
        }
    }
}

/// One rule firing, timestamped at the window close that tripped it.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertEvent {
    pub at_ms: f64,
    /// Rule name: `slo-burn-rate`, `power-overdraw`,
    /// `availability-floor`, or `stalled-window`.
    pub rule: String,
    /// Observed value that tripped the rule.
    pub value: f64,
    /// The threshold it crossed.
    pub threshold: f64,
    pub message: String,
}

impl AlertEvent {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("at_ms", json::num(self.at_ms)),
            ("rule", json::str_(&self.rule)),
            ("value", json::num(self.value)),
            ("threshold", json::num(self.threshold)),
            ("message", json::str_(&self.message)),
        ])
    }
}

/// What one control window looked like, from the alert engine's side.
#[derive(Debug, Clone)]
pub struct WindowObs {
    pub t_ms: f64,
    /// Requests completed in this window.
    pub completions: u64,
    /// Of those, how many finished over the SLO.
    pub slo_violations: u64,
    /// Average cluster draw over the window, W.
    pub power_w: f64,
    pub nodes_up: usize,
    pub nodes_total: usize,
    /// Zero completions with work in flight.
    pub stalled: bool,
}

/// Evaluates [`AlertRules`] against the window stream, edge-triggered
/// per rule.
#[derive(Debug, Clone)]
pub struct AlertEngine {
    rules: AlertRules,
    /// (violations, completions) per window, most recent last.
    burn: VecDeque<(u64, u64)>,
    burn_firing: bool,
    power_firing: bool,
    avail_firing: bool,
    stall_firing: bool,
}

impl AlertEngine {
    pub fn new(rules: AlertRules) -> Self {
        AlertEngine {
            rules,
            burn: VecDeque::new(),
            burn_firing: false,
            power_firing: false,
            avail_firing: false,
            stall_firing: false,
        }
    }

    /// Feed one closed window; returns the rules that fired on this
    /// window's edge (deterministic rule order).
    pub fn observe(&mut self, obs: &WindowObs) -> Vec<AlertEvent> {
        let mut fired = Vec::new();
        let r = &self.rules;

        if r.slo_ms > 0.0 {
            self.burn.push_back((obs.slo_violations, obs.completions));
            while self.burn.len() > r.burn_windows.max(1) {
                self.burn.pop_front();
            }
            let bad: u64 = self.burn.iter().map(|&(v, _)| v).sum();
            let total: u64 = self.burn.iter().map(|&(_, c)| c).sum();
            let budget = (1.0 - r.slo_target).max(1e-9);
            let burn = if total > 0 {
                (bad as f64 / total as f64) / budget
            } else {
                0.0
            };
            let hot = burn >= r.burn_threshold;
            if hot && !self.burn_firing {
                fired.push(AlertEvent {
                    at_ms: obs.t_ms,
                    rule: "slo-burn-rate".into(),
                    value: burn,
                    threshold: r.burn_threshold,
                    message: format!(
                        "slo burn rate {burn:.1}x budget ({bad}/{total} over {} ms slo in last {} windows)",
                        r.slo_ms,
                        self.burn.len()
                    ),
                });
            }
            self.burn_firing = hot;
        }

        if r.power_budget_w > 0.0 && obs.power_w.is_finite() {
            let hot = obs.power_w > r.power_budget_w;
            if hot && !self.power_firing {
                fired.push(AlertEvent {
                    at_ms: obs.t_ms,
                    rule: "power-overdraw".into(),
                    value: obs.power_w,
                    threshold: r.power_budget_w,
                    message: format!(
                        "window draw {:.1} W over budget {:.1} W",
                        obs.power_w, r.power_budget_w
                    ),
                });
            }
            self.power_firing = hot;
        }

        if r.availability_floor > 0.0 && obs.nodes_total > 0 {
            let avail = obs.nodes_up as f64 / obs.nodes_total as f64;
            let hot = avail < r.availability_floor;
            if hot && !self.avail_firing {
                fired.push(AlertEvent {
                    at_ms: obs.t_ms,
                    rule: "availability-floor".into(),
                    value: avail,
                    threshold: r.availability_floor,
                    message: format!(
                        "{}/{} nodes up, below floor {:.3}",
                        obs.nodes_up, obs.nodes_total, r.availability_floor
                    ),
                });
            }
            self.avail_firing = hot;
        }

        {
            let hot = obs.stalled;
            if hot && !self.stall_firing {
                fired.push(AlertEvent {
                    at_ms: obs.t_ms,
                    rule: "stalled-window".into(),
                    value: 1.0,
                    threshold: 1.0,
                    message: "window completed nothing with work in flight".into(),
                });
            }
            self.stall_firing = hot;
        }

        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(t_ms: f64) -> WindowObs {
        WindowObs {
            t_ms,
            completions: 10,
            slo_violations: 0,
            power_w: 5.0,
            nodes_up: 4,
            nodes_total: 4,
            stalled: false,
        }
    }

    #[test]
    fn burn_rate_fires_on_edge_and_rearms_after_recovery() {
        let rules = AlertRules { slo_ms: 50.0, burn_windows: 4, ..Default::default() };
        let mut e = AlertEngine::new(rules);
        assert!(e.observe(&obs(100.0)).is_empty());
        // 5/10 violations vs a 1% budget: burn 50x >= 2x -> fire once
        let fired = e.observe(&WindowObs { slo_violations: 5, ..obs(200.0) });
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, "slo-burn-rate");
        assert!(fired[0].value > 2.0);
        // still hot next window: edge-triggered, no re-fire
        let again = e.observe(&WindowObs { slo_violations: 5, ..obs(300.0) });
        assert!(again.is_empty());
        // clean windows push the bad ones out of the sliding budget...
        for t in [400.0, 500.0, 600.0, 700.0] {
            e.observe(&obs(t));
        }
        // ...and the rule re-arms
        let refire = e.observe(&WindowObs { slo_violations: 5, ..obs(800.0) });
        assert_eq!(refire.len(), 1);
    }

    #[test]
    fn power_and_availability_rules_need_configured_thresholds() {
        // defaults: power budget 0 = off; availability floor on
        let mut e = AlertEngine::new(AlertRules::default());
        let fired = e.observe(&WindowObs {
            power_w: 1e6,
            nodes_up: 1,
            nodes_total: 4,
            ..obs(100.0)
        });
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, "availability-floor");

        let mut e = AlertEngine::new(AlertRules {
            power_budget_w: 10.0,
            availability_floor: 0.0,
            ..Default::default()
        });
        let fired = e.observe(&WindowObs { power_w: 12.5, ..obs(100.0) });
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, "power-overdraw");
        assert_eq!(fired[0].threshold, 10.0);
    }

    #[test]
    fn stalled_window_fires_once_per_stall_run() {
        let mut e = AlertEngine::new(AlertRules::default());
        let mk = |t, stalled| WindowObs { stalled, completions: 0, ..obs(t) };
        assert_eq!(e.observe(&mk(100.0, true)).len(), 1);
        assert!(e.observe(&mk(200.0, true)).is_empty());
        assert!(e.observe(&mk(300.0, false)).is_empty());
        assert_eq!(e.observe(&mk(400.0, true)).len(), 1);
    }

    #[test]
    fn alert_json_has_stable_keys() {
        let a = AlertEvent {
            at_ms: 100.0,
            rule: "stalled-window".into(),
            value: 1.0,
            threshold: 1.0,
            message: "m".into(),
        };
        let keys: Vec<&str> = a
            .to_json()
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, ["at_ms", "rule", "value", "threshold", "message"]);
    }
}
