//! Telemetry: the observability layer for the simulators and the
//! controller (DESIGN.md §13, §15).
//!
//! Six pieces, all zero-cost when off:
//!
//! * [`span`] — per-request span tracing: every sampled request records
//!   network / queue-wait / compute spans per pipeline stage, in
//!   sim-time nanoseconds, decomposed exactly along the critical path;
//! * [`hist`] — log-linear HDR-style histograms with bounded memory and
//!   ≤ 1/256 relative error, replacing store-every-sample percentiles
//!   on the hot path;
//! * [`audit`] — the controller decision audit log: every
//!   [`crate::sched::OnlineController::decide`] consultation with the
//!   break-even numbers that justified the verdict;
//! * [`chrome`] — the Chrome trace-event / Perfetto exporter behind
//!   `vtacluster run <spec> --trace out.json`;
//! * [`metrics`] — the labeled metric registry (counters, gauges, HDR
//!   histograms) sampled per control window, exported as Prometheus
//!   text or a Report time-series section (DESIGN.md §15);
//! * [`alerts`] — declarative per-window rules (SLO burn-rate,
//!   power overdraw, availability floor, stalled windows) whose
//!   firings land in the Report timeline and the audit log.
//!
//! [`clock`] supplies the wall-vs-sim time abstraction the coordinator
//! metrics use so host elapsed time can never masquerade as simulated
//! throughput again.
//!
//! A DES run with telemetry enabled threads a [`Tracer`] through its
//! event loop and tears it down into one [`RunTelemetry`] bundle per
//! report row; the scenario [`crate::scenario::Report`] carries the
//! bundles only when they are non-empty, so untraced reports are
//! byte-identical to the pre-telemetry output.

pub mod alerts;
pub mod audit;
pub mod chrome;
pub mod clock;
pub mod hist;
pub mod metrics;
pub mod span;

pub use alerts::{AlertEngine, AlertEvent, AlertRules, WindowObs};
pub use audit::{AuditLog, AuditRecord, AuditVerdict};
pub use chrome::chrome_trace;
pub use clock::Clock;
pub use hist::HdrHist;
pub use metrics::{MetricKind, MetricsConfig, MetricsRegistry, RunMetrics, SeriesData};
pub use span::{
    ComputeSpan, FaultMark, ReconfigSpan, RequestTrace, StageSpan, StageWindow,
    TelemetryConfig, Tracer, WindowRow, MAX_TRACES,
};

use crate::util::json::{self, Json};
use crate::util::units::ns_to_ms;

/// Everything one simulator run collected: sampled request traces,
/// per-window stage metrics, reconfiguration spans, the controller
/// audit log, and the run-level histograms. Produced by
/// [`Tracer::finish`]; the scenario layer stamps `label`/`engine`.
#[derive(Debug, Clone, Default)]
pub struct RunTelemetry {
    pub label: String,
    pub engine: String,
    pub sample_stride: u64,
    pub traces: Vec<RequestTrace>,
    pub windows: Vec<WindowRow>,
    pub reconfigs: Vec<ReconfigSpan>,
    /// Fault-process transitions (node crash / rejoin, DESIGN.md §14).
    pub faults: Vec<FaultMark>,
    pub audit: Vec<AuditRecord>,
    /// Run-level queue-wait per stage execution, ns.
    pub queue_hist: HdrHist,
    /// Run-level compute (service) time per stage execution, ns.
    pub service_hist: HdrHist,
    /// Run-level end-to-end latency of sampled requests, ns.
    pub latency_hist: HdrHist,
}

fn hist_json(h: &HdrHist) -> Json {
    let p = |q: f64| h.percentile(q).map(|v| json::num(ns_to_ms(v))).unwrap_or(Json::Null);
    json::obj(vec![
        ("count", json::int(h.count() as i64)),
        ("mean_ms", json::num(ns_to_ms(h.mean() as u64))),
        ("p50_ms", p(50.0)),
        ("p99_ms", p(99.0)),
        ("max_ms", json::num(ns_to_ms(h.max()))),
    ])
}

fn stage_index_json(si: usize) -> Json {
    // the gather hop is keyed by the usize::MAX sentinel; emit -1
    if si == usize::MAX {
        json::int(-1)
    } else {
        json::int(si as i64)
    }
}

impl RunTelemetry {
    /// The report-embedded rendering: window time series, reconfig
    /// spans, audit log, and histogram summaries — but *not* the raw
    /// request spans, which only go to the Chrome trace file.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::str_(&self.label)),
            ("engine", json::str_(&self.engine)),
            ("sample_stride", json::int(self.sample_stride as i64)),
            ("traced_requests", json::int(self.traces.len() as i64)),
            ("latency", hist_json(&self.latency_hist)),
            ("queue", hist_json(&self.queue_hist)),
            ("service", hist_json(&self.service_hist)),
            (
                "windows",
                Json::Arr(
                    self.windows
                        .iter()
                        .map(|w| {
                            json::obj(vec![
                                ("t_ms", json::num(w.t_ms)),
                                ("events", json::int(w.events as i64)),
                                ("arrivals", json::int(w.arrivals as i64)),
                                ("completions", json::int(w.completions as i64)),
                                ("stalled", Json::Bool(w.stalled)),
                                ("backlog", json::int(w.backlog as i64)),
                                (
                                    "power_w",
                                    if w.power_w.is_finite() {
                                        json::num(w.power_w)
                                    } else {
                                        Json::Null
                                    },
                                ),
                                (
                                    "stages",
                                    Json::Arr(
                                        w.stages
                                            .iter()
                                            .map(|s| {
                                                json::obj(vec![
                                                    ("si", stage_index_json(s.si)),
                                                    ("count", json::int(s.count as i64)),
                                                    ("queue_p50_ms", json::num(s.queue_p50_ms)),
                                                    ("queue_p99_ms", json::num(s.queue_p99_ms)),
                                                    (
                                                        "service_p50_ms",
                                                        json::num(s.service_p50_ms),
                                                    ),
                                                    (
                                                        "service_p99_ms",
                                                        json::num(s.service_p99_ms),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "reconfig_spans",
                Json::Arr(
                    self.reconfigs
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("start_ms", json::num(ns_to_ms(r.start_ns))),
                                ("end_ms", json::num(ns_to_ms(r.end_ns))),
                                ("from", json::int(r.from as i64)),
                                ("to", json::int(r.to as i64)),
                                ("reason", json::str_(&r.reason)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "faults",
                Json::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            json::obj(vec![
                                ("at_ms", json::num(ns_to_ms(f.at_ns))),
                                ("node", json::int(f.node as i64)),
                                ("kind", json::str_(&f.kind)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("audit", Json::Arr(self.audit.iter().map(|a| a.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_summarises_without_raw_spans() {
        let mut t = Tracer::new(&TelemetryConfig::on(1.0)).unwrap();
        t.admit(0, 0, 0);
        t.stage(
            0,
            StageSpan {
                si: 0,
                start_ns: 0,
                end_ns: 3_000_000,
                net_ns: 0,
                queue_ns: 1_000_000,
                compute_ns: 2_000_000,
                node: 0,
                computes: vec![ComputeSpan { node: 0, start_ns: 1_000_000, end_ns: 3_000_000 }],
            },
        );
        t.done(0, 0, 3_000_000);
        t.window(100.0, 10, 1, 1, false, 2, 4.5);
        t.fault(2_000_000, 1, "down");
        let mut bundle = t.finish(Vec::new());
        bundle.label = "cell".into();
        bundle.engine = "des".into();
        let j = bundle.to_json();
        assert_eq!(j.get_str("label").unwrap(), "cell");
        assert_eq!(j.get_i64("traced_requests").unwrap(), 1);
        assert_eq!(j.get("latency").unwrap().get_i64("count").unwrap(), 1);
        assert!((j.get("latency").unwrap().get_f64("p50_ms").unwrap() - 3.0).abs() < 0.05);
        assert_eq!(j.get("windows").unwrap().as_arr().unwrap().len(), 1);
        let w0 = &j.get("windows").unwrap().as_arr().unwrap()[0];
        assert_eq!(w0.get("stalled"), Some(&Json::Bool(false)));
        assert_eq!(w0.get_i64("backlog").unwrap(), 2);
        assert!((w0.get_f64("power_w").unwrap() - 4.5).abs() < 1e-9);
        let faults = j.get("faults").unwrap().as_arr().unwrap();
        assert_eq!(faults.len(), 1);
        assert_eq!(faults[0].get_str("kind").unwrap(), "down");
        assert_eq!(faults[0].get_i64("node").unwrap(), 1);
        assert!(j.get("spans").is_none(), "raw spans must not bloat reports");
        // round-trips as valid JSON
        let text = json::pretty(&j);
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn gather_sentinel_emits_minus_one() {
        assert_eq!(stage_index_json(usize::MAX), json::int(-1));
        assert_eq!(stage_index_json(3), json::int(3));
    }
}
