//! Labeled metric registry: windowed time-series for the simulators
//! (DESIGN.md §15).
//!
//! Span traces ([`super::span`]) answer "what happened to request N";
//! the registry answers "what was the cluster doing at t" — the
//! continuous signals (queue depth, per-node utilization, window power
//! draw, arrival/completion rates, SLO violations, fault gauges) the
//! controller already computes internally, exposed as named series a
//! dashboard or alert rule can consume.
//!
//! Three metric kinds, all labeled (`node`, `tenant`, …):
//!
//! * **counter** — monotone cumulative total (`vta_arrivals_total`);
//! * **gauge**   — last-write-wins instantaneous value (`vta_backlog`);
//! * **histogram** — HDR-backed distribution ([`super::hist::HdrHist`],
//!   ≤ 1/256 relative error), run-level, e.g. `vta_request_latency_ns`.
//!
//! Counters and gauges are snapshotted once per control window by
//! [`MetricsRegistry::sample`], so every series is a `(t_ms, value)`
//! time-series aligned with the controller's observation epochs.
//!
//! The registry follows the same zero-cost-off contract as tracing:
//! [`MetricsRegistry::new`] returns `None` when the config is off, every
//! hook site in the DES is one `Option` null check, and a report without
//! metrics is byte-identical to the pre-metrics output (property-tested).
//!
//! Two exporters: [`RunMetrics::to_json`] (the `metrics` section of a
//! [`crate::scenario::Report`]) and [`prometheus`] (text exposition for
//! `vtacluster run <spec> --metrics out.prom`).

use super::alerts::AlertEvent;
use super::audit::AuditRecord;
use super::hist::HdrHist;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// Metric-registry switch carried by the simulator configs, resolved
/// from the spec's `telemetry.metrics` knob.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsConfig {
    pub enabled: bool,
    /// Latency SLO the violation counter and the burn-rate alert use,
    /// ms; `0` = no SLO accounting.
    pub slo_ms: f64,
    /// Declarative alert rules evaluated per window (DESIGN.md §15).
    pub rules: super::alerts::AlertRules,
}

impl MetricsConfig {
    /// The default: completely off, zero cost.
    pub fn off() -> Self {
        MetricsConfig {
            enabled: false,
            slo_ms: 0.0,
            rules: super::alerts::AlertRules::default(),
        }
    }

    /// Registry on, with the given SLO wired into the rules.
    pub fn on(slo_ms: f64) -> Self {
        MetricsConfig {
            enabled: true,
            slo_ms,
            rules: super::alerts::AlertRules { slo_ms, ..Default::default() },
        }
    }
}

impl Default for MetricsConfig {
    fn default() -> Self {
        MetricsConfig::off()
    }
}

/// What a series measures — fixed at first touch; mixing kinds under
/// one name is a programming error and panics in debug builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }

    /// Prometheus exposition type name.
    fn prom_type(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "summary",
        }
    }
}

/// One exported series: a (name × label-set) with its final value, its
/// per-window points (counter/gauge) or its HDR histogram.
#[derive(Debug, Clone)]
pub struct SeriesData {
    pub name: String,
    /// Sorted `(key, value)` label pairs.
    pub labels: Vec<(String, String)>,
    pub kind: MetricKind,
    /// Final value (cumulative for counters, last write for gauges;
    /// unused for histograms).
    pub value: f64,
    /// `(t_ms, value)` snapshots, one per control window.
    pub points: Vec<(f64, f64)>,
    /// The distribution, for `kind == Histogram`.
    pub hist: HdrHist,
}

impl SeriesData {
    pub fn to_json(&self) -> Json {
        let labels = json::obj(
            self.labels
                .iter()
                .map(|(k, v)| (k.as_str(), json::str_(v)))
                .collect(),
        );
        let mut fields = vec![
            ("name", json::str_(&self.name)),
            ("kind", json::str_(self.kind.as_str())),
            ("labels", labels),
        ];
        match self.kind {
            MetricKind::Histogram => {
                let p = |q: f64| {
                    self.hist
                        .percentile(q)
                        .map(|v| json::int(v as i64))
                        .unwrap_or(Json::Null)
                };
                fields.push(("count", json::int(self.hist.count() as i64)));
                fields.push(("mean", json::num(self.hist.mean())));
                fields.push(("p50", p(50.0)));
                fields.push(("p99", p(99.0)));
                fields.push(("max", json::int(self.hist.max() as i64)));
            }
            _ => {
                fields.push(("value", fnum(self.value)));
                fields.push((
                    "points",
                    Json::Arr(
                        self.points
                            .iter()
                            .map(|&(t, v)| Json::Arr(vec![json::num(t), fnum(v)]))
                            .collect(),
                    ),
                ));
            }
        }
        json::obj(fields)
    }
}

/// The bundle one metered run exports: every series, the alert firings,
/// and the controller audit log (so the "why" is inspectable from the
/// metrics section alone, tracing on or off). The scenario layer stamps
/// `label`/`engine` like it does for [`super::RunTelemetry`].
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub label: String,
    pub engine: String,
    pub series: Vec<SeriesData>,
    pub alerts: Vec<AlertEvent>,
    pub audit: Vec<AuditRecord>,
}

impl RunMetrics {
    /// Look a series up by name (first label-set match).
    pub fn series(&self, name: &str) -> Option<&SeriesData> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Append a single-point gauge (the analytic engine's steady-state
    /// equivalents enter the bundle through this).
    pub fn push_gauge(&mut self, name: &str, t_ms: f64, value: f64) {
        self.series.push(SeriesData {
            name: name.to_string(),
            labels: Vec::new(),
            kind: MetricKind::Gauge,
            value,
            points: vec![(t_ms, value)],
            hist: HdrHist::new(),
        });
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::str_(&self.label)),
            ("engine", json::str_(&self.engine)),
            (
                "series",
                Json::Arr(self.series.iter().map(|s| s.to_json()).collect()),
            ),
            (
                "alerts",
                Json::Arr(self.alerts.iter().map(|a| a.to_json()).collect()),
            ),
            ("audit", Json::Arr(self.audit.iter().map(|a| a.to_json()).collect())),
        ])
    }
}

/// The live collector one run threads its hooks through. `None` when
/// metrics are off — the simulator pays one null check per hook.
#[derive(Debug, Clone)]
pub struct MetricsRegistry {
    series: BTreeMap<(String, Vec<(String, String)>), (MetricKind, f64, Vec<(f64, f64)>, HdrHist)>,
}

impl MetricsRegistry {
    pub fn new(cfg: &MetricsConfig) -> Option<MetricsRegistry> {
        cfg.enabled.then(|| MetricsRegistry { series: BTreeMap::new() })
    }

    fn entry(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        kind: MetricKind,
    ) -> &mut (MetricKind, f64, Vec<(f64, f64)>, HdrHist) {
        let mut key_labels: Vec<(String, String)> = labels
            .iter()
            .map(|&(k, v)| (k.to_string(), v.to_string()))
            .collect();
        key_labels.sort();
        let e = self
            .series
            .entry((name.to_string(), key_labels))
            .or_insert_with(|| (kind, 0.0, Vec::new(), HdrHist::new()));
        debug_assert_eq!(e.0, kind, "metric '{name}' re-registered with a different kind");
        e
    }

    /// Add `delta` to a counter.
    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)], delta: f64) {
        self.entry(name, labels, MetricKind::Counter).1 += delta;
    }

    /// Set a gauge to `v` (last write before the snapshot wins).
    pub fn gauge(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.entry(name, labels, MetricKind::Gauge).1 = v;
    }

    /// Record one observation into a histogram.
    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)], v: u64) {
        self.entry(name, labels, MetricKind::Histogram).3.record(v);
    }

    /// Close a control window: snapshot every counter and gauge into its
    /// point series at `t_ms`.
    pub fn sample(&mut self, t_ms: f64) {
        for (kind, value, points, _) in self.series.values_mut() {
            if *kind != MetricKind::Histogram {
                points.push((t_ms, *value));
            }
        }
    }

    /// Tear down into the run's immutable bundle (deterministic series
    /// order: the registry key is already `(name, labels)`-sorted).
    pub fn finish(self, alerts: Vec<AlertEvent>, audit: Vec<AuditRecord>) -> RunMetrics {
        RunMetrics {
            label: String::new(),
            engine: String::new(),
            series: self
                .series
                .into_iter()
                .map(|((name, labels), (kind, value, points, hist))| SeriesData {
                    name,
                    labels,
                    kind,
                    value,
                    points,
                    hist,
                })
                .collect(),
            alerts,
            audit,
        }
    }
}

/// One-line help per well-known metric (the `# HELP` exposition line).
fn help(name: &str) -> &'static str {
    match name {
        "vta_arrivals_total" => "requests admitted, cumulative per window",
        "vta_completions_total" => "requests completed end-to-end, cumulative",
        "vta_slo_violations_total" => "completed requests over the latency SLO",
        "vta_alerts_total" => "alert-rule firings (DESIGN.md §15)",
        "vta_reconfigs_total" => "executed plan switches",
        "vta_reconfig_downtime_ms_total" => "cumulative reconfiguration downtime, ms",
        "vta_fault_outages_total" => "node crash events (DESIGN.md §14)",
        "vta_stalled_windows_total" => "zero-completion windows with work in flight",
        "vta_backlog" => "requests in flight at the window close",
        "vta_queue_depth" => "booked stage computes still pending across nodes",
        "vta_window_power_w" => "cluster draw over the closing window, W",
        "vta_node_utilization" => "per-node busy fraction over the window",
        "vta_node_down" => "1 while the node is crashed, else 0",
        "vta_lambda_hat" => "controller's EMA arrival-rate estimate, img/s",
        "vta_power_hat_w" => "controller's EMA cluster-draw estimate, W",
        "vta_request_latency_ns" => "end-to-end request latency, ns (HDR)",
        "vta_recovery_ns" => "crash-to-rejoin recovery time, ns (HDR)",
        "vta_steady_ms_per_image" => "analytic steady-state time per image, ms",
        "vta_steady_img_per_sec" => "analytic steady-state plan capacity, img/s",
        "vta_steady_cluster_w" => "analytic steady-state cluster draw, W",
        "vta_admission_offered_total" => "requests offered to the admission gate (DESIGN.md §16)",
        "vta_admission_admitted_total" => "requests the admission gate let through",
        "vta_admission_shed_total" => "requests shed, by reason and tenant",
        "vta_batch_size" => "realized batch size per dispatch (HDR)",
        _ => "vta cluster metric",
    }
}

fn prom_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

fn prom_labels(run: &str, extra: &[(String, String)], quantile: Option<f64>) -> String {
    let mut parts = Vec::with_capacity(extra.len() + 2);
    if !run.is_empty() {
        parts.push(format!("run=\"{}\"", prom_escape(run)));
    }
    for (k, v) in extra {
        parts.push(format!("{k}=\"{}\"", prom_escape(v)));
    }
    if let Some(q) = quantile {
        parts.push(format!("quantile=\"{q}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render bundles as Prometheus text exposition (final values; the
/// windowed points live in the JSON section). One `# HELP`/`# TYPE`
/// header per metric name, one sample per (bundle × label-set);
/// histograms export as summaries with p50/p99 quantiles.
pub fn prometheus(bundles: &[RunMetrics]) -> String {
    // group samples under their metric name so headers emit exactly once
    let mut names: Vec<&str> = Vec::new();
    let mut kinds: BTreeMap<&str, MetricKind> = BTreeMap::new();
    let mut lines: BTreeMap<&str, Vec<String>> = BTreeMap::new();
    for b in bundles {
        for s in &b.series {
            if !kinds.contains_key(s.name.as_str()) {
                names.push(&s.name);
                kinds.insert(&s.name, s.kind);
            }
            let out = lines.entry(&s.name).or_default();
            match s.kind {
                MetricKind::Histogram => {
                    for q in [0.5, 0.99] {
                        let v = s
                            .hist
                            .percentile(q * 100.0)
                            .map(|v| v.to_string())
                            .unwrap_or_else(|| "NaN".to_string());
                        out.push(format!(
                            "{}{} {v}",
                            s.name,
                            prom_labels(&b.label, &s.labels, Some(q))
                        ));
                    }
                    let sum = s.hist.mean() * s.hist.count() as f64;
                    out.push(format!(
                        "{}_sum{} {sum}",
                        s.name,
                        prom_labels(&b.label, &s.labels, None)
                    ));
                    out.push(format!(
                        "{}_count{} {}",
                        s.name,
                        prom_labels(&b.label, &s.labels, None),
                        s.hist.count()
                    ));
                }
                _ => {
                    if s.value.is_finite() {
                        out.push(format!(
                            "{}{} {}",
                            s.name,
                            prom_labels(&b.label, &s.labels, None),
                            s.value
                        ));
                    }
                }
            }
        }
    }
    names.sort();
    let mut text = String::new();
    for name in names {
        text.push_str(&format!("# HELP {name} {}\n", help(name)));
        text.push_str(&format!("# TYPE {name} {}\n", kinds[name].prom_type()));
        for line in &lines[name] {
            text.push_str(line);
            text.push('\n');
        }
    }
    text
}

fn fnum(v: f64) -> Json {
    if v.is_finite() {
        json::num(v)
    } else {
        Json::Null
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_none_when_off() {
        assert!(MetricsRegistry::new(&MetricsConfig::off()).is_none());
        assert!(MetricsRegistry::new(&MetricsConfig::default()).is_none());
        assert!(MetricsRegistry::new(&MetricsConfig::on(50.0)).is_some());
    }

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut m = MetricsRegistry::new(&MetricsConfig::on(0.0)).unwrap();
        m.inc("vta_arrivals_total", &[], 3.0);
        m.gauge("vta_backlog", &[], 2.0);
        m.sample(100.0);
        m.inc("vta_arrivals_total", &[], 4.0);
        m.gauge("vta_backlog", &[], 1.0);
        m.gauge("vta_backlog", &[], 5.0); // last write wins
        m.sample(200.0);
        let b = m.finish(Vec::new(), Vec::new());
        let arrivals = b.series("vta_arrivals_total").unwrap();
        assert_eq!(arrivals.kind, MetricKind::Counter);
        assert_eq!(arrivals.points, vec![(100.0, 3.0), (200.0, 7.0)]);
        assert_eq!(arrivals.value, 7.0);
        let backlog = b.series("vta_backlog").unwrap();
        assert_eq!(backlog.points, vec![(100.0, 2.0), (200.0, 5.0)]);
    }

    #[test]
    fn labels_key_distinct_series_in_sorted_order() {
        let mut m = MetricsRegistry::new(&MetricsConfig::on(0.0)).unwrap();
        m.gauge("vta_node_utilization", &[("node", "1")], 0.5);
        m.gauge("vta_node_utilization", &[("node", "0")], 0.9);
        m.sample(100.0);
        let b = m.finish(Vec::new(), Vec::new());
        let utils: Vec<&SeriesData> = b
            .series
            .iter()
            .filter(|s| s.name == "vta_node_utilization")
            .collect();
        assert_eq!(utils.len(), 2);
        // deterministic (name, labels) order: node=0 before node=1
        assert_eq!(utils[0].labels, vec![("node".to_string(), "0".to_string())]);
        assert_eq!(utils[0].value, 0.9);
        assert_eq!(utils[1].value, 0.5);
    }

    #[test]
    fn histograms_skip_the_window_snapshot() {
        let mut m = MetricsRegistry::new(&MetricsConfig::on(0.0)).unwrap();
        m.observe("vta_request_latency_ns", &[], 1_000_000);
        m.observe("vta_request_latency_ns", &[], 3_000_000);
        m.sample(100.0);
        let b = m.finish(Vec::new(), Vec::new());
        let h = b.series("vta_request_latency_ns").unwrap();
        assert_eq!(h.kind, MetricKind::Histogram);
        assert!(h.points.is_empty(), "histograms are run-level, not windowed");
        assert_eq!(h.hist.count(), 2);
        let j = h.to_json();
        assert_eq!(j.get_i64("count").unwrap(), 2);
        assert!(j.get("points").is_none());
    }

    #[test]
    fn json_round_trips_and_orders_keys() {
        let mut m = MetricsRegistry::new(&MetricsConfig::on(0.0)).unwrap();
        m.inc("vta_arrivals_total", &[], 2.0);
        m.gauge("vta_window_power_w", &[], 9.5);
        m.observe("vta_request_latency_ns", &[], 2_000_000);
        m.sample(100.0);
        let mut b = m.finish(Vec::new(), Vec::new());
        b.label = "cell".into();
        b.engine = "des".into();
        let j = b.to_json();
        let top: Vec<&str> =
            j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(top, ["label", "engine", "series", "alerts", "audit"]);
        let text = json::pretty(&j);
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn prometheus_exposition_has_headers_and_samples() {
        let mut m = MetricsRegistry::new(&MetricsConfig::on(0.0)).unwrap();
        m.inc("vta_arrivals_total", &[], 12.0);
        m.gauge("vta_node_utilization", &[("node", "0")], 0.75);
        m.observe("vta_request_latency_ns", &[], 5_000_000);
        m.sample(100.0);
        let mut b = m.finish(Vec::new(), Vec::new());
        b.label = "n=2/t0".into();
        let text = prometheus(&[b]);
        assert!(text.contains("# TYPE vta_arrivals_total counter"), "{text}");
        assert!(text.contains("# TYPE vta_node_utilization gauge"), "{text}");
        assert!(text.contains("# TYPE vta_request_latency_ns summary"), "{text}");
        assert!(text.contains("vta_arrivals_total{run=\"n=2/t0\"} 12"), "{text}");
        assert!(
            text.contains("vta_node_utilization{run=\"n=2/t0\",node=\"0\"} 0.75"),
            "{text}"
        );
        assert!(text.contains("quantile=\"0.99\""), "{text}");
        assert!(text.contains("vta_request_latency_ns_count{run=\"n=2/t0\"} 1"), "{text}");
        // exactly one header per metric name
        assert_eq!(text.matches("# TYPE vta_arrivals_total").count(), 1);
    }
}
