//! Span-based request tracing for the simulators (DESIGN.md §13).
//!
//! Every sampled request carries a [`RequestTrace`]: one [`StageSpan`]
//! per pipeline stage, decomposed along the *critical path* into
//! network / queue-wait / compute — all in sim-time nanoseconds, never
//! wall-clock. The decomposition is exact by construction: for the
//! consumer that finishes last, `net + queue + compute` equals the
//! stage's span, and stages chain gaplessly (stage *k* starts where
//! stage *k−1* ended), so the spans of a trace sum to the request's
//! end-to-end latency to the nanosecond. The property test in
//! `tests/proptests.rs` pins this.
//!
//! Sampling is a deterministic stride on the request id (`id % stride
//! == 0`), chosen over RNG thinning so (a) the main DES RNG is never
//! consumed — traced and untraced runs replay the *identical* event
//! sequence — and (b) a given request is traced at every sample rate
//! that includes it. With tracing off (or sample rate 0) no [`Tracer`]
//! exists at all and the simulator pays one `Option` null-check per
//! hook.

use super::audit::AuditRecord;
use super::hist::HdrHist;
use crate::util::units::{ns_to_ms, Nanos};
use std::collections::BTreeMap;

/// Stop storing new [`RequestTrace`]s past this many (histograms and
/// window rows keep accumulating): bounds trace memory on 10⁸-event
/// runs without touching the aggregate numbers.
pub const MAX_TRACES: usize = 50_000;

/// Telemetry switch carried by the simulator configs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetryConfig {
    pub enabled: bool,
    /// Fraction of requests to trace, in (0, 1]. 0 disables tracing
    /// entirely (nothing is collected, not even histograms).
    pub sample_rate: f64,
}

impl TelemetryConfig {
    /// The default: completely off, zero cost.
    pub fn off() -> Self {
        TelemetryConfig { enabled: false, sample_rate: 0.0 }
    }

    /// Tracing on at the given sample rate.
    pub fn on(sample_rate: f64) -> Self {
        TelemetryConfig { enabled: true, sample_rate }
    }

    /// The deterministic sampling stride: trace request `id` iff
    /// `id % stride == 0`. `None` means "collect nothing".
    pub fn stride(&self) -> Option<u64> {
        if !self.enabled || self.sample_rate <= 0.0 || !self.sample_rate.is_finite() {
            return None;
        }
        Some(((1.0 / self.sample_rate.min(1.0)).round() as u64).max(1))
    }
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig::off()
    }
}

/// One consumer's compute interval within a stage (for the Perfetto
/// per-node compute tracks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ComputeSpan {
    pub node: usize,
    pub start_ns: Nanos,
    pub end_ns: Nanos,
}

/// One pipeline stage of a traced request, decomposed along the
/// critical path (the consumer that finished last).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpan {
    /// Stage index; `usize::MAX` flags the trailing gather hop back to
    /// the master (network-only, no compute).
    pub si: usize,
    /// When the stage became runnable (= previous stage's `end_ns`).
    pub start_ns: Nanos,
    /// When the slowest consumer finished (= next stage's `start_ns`).
    pub end_ns: Nanos,
    /// Critical-path network transfer time.
    pub net_ns: Nanos,
    /// Critical-path wait for the consumer node to free up.
    pub queue_ns: Nanos,
    /// Critical-path compute time.
    pub compute_ns: Nanos,
    /// Node the critical-path consumer ran on.
    pub node: usize,
    /// Every consumer's compute interval (parallel split ⇒ several).
    pub computes: Vec<ComputeSpan>,
}

impl StageSpan {
    pub fn is_gather(&self) -> bool {
        self.si == usize::MAX
    }
}

/// The full span tree of one sampled request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestTrace {
    pub img: usize,
    /// Active plan-option index when the request was admitted.
    pub plan: usize,
    pub admitted_ns: Nanos,
    /// `None` if the horizon ended before the request completed.
    pub done_ns: Option<Nanos>,
    pub stages: Vec<StageSpan>,
}

/// Per-stage queue/service percentiles over one control window, ms.
#[derive(Debug, Clone, PartialEq)]
pub struct StageWindow {
    pub si: usize,
    /// Sampled stage executions contributing to this window.
    pub count: u64,
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    pub service_p50_ms: f64,
    pub service_p99_ms: f64,
}

/// One control-epoch snapshot of the event-loop and stage metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowRow {
    pub t_ms: f64,
    /// DES events processed during the window.
    pub events: u64,
    pub arrivals: u64,
    pub completions: u64,
    /// Zero completions while work was in flight (DESIGN.md §14): the
    /// window sat inside an outage / reconfiguration stall. Flagged
    /// explicitly so an outage reads as "stalled", never as a silent
    /// row of zeros that looks like an idle cluster.
    pub stalled: bool,
    /// Requests in flight when the window closed (the Perfetto counter
    /// track and the `vta_backlog` gauge read this).
    pub backlog: u64,
    /// Average cluster draw over the window, W (DESIGN.md §9 meter).
    pub power_w: f64,
    pub stages: Vec<StageWindow>,
}

/// An executed reconfiguration, as a span (the cluster is draining /
/// reprogramming for its duration).
#[derive(Debug, Clone, PartialEq)]
pub struct ReconfigSpan {
    pub start_ns: Nanos,
    pub end_ns: Nanos,
    pub from: usize,
    pub to: usize,
    pub reason: String,
}

/// A fault-process transition (DESIGN.md §14) — node crash or rejoin —
/// as an instant mark on the trace timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultMark {
    pub at_ns: Nanos,
    pub node: usize,
    /// `"down"` (crash) or `"up"` (rejoin after re-flash).
    pub kind: String,
}

/// The live collector one DES run threads its hooks through. Built via
/// [`Tracer::new`], which returns `None` when telemetry is off so every
/// hook site is a null check.
#[derive(Debug, Clone)]
pub struct Tracer {
    stride: u64,
    traces: BTreeMap<usize, RequestTrace>,
    /// stage index → (queue hist, service hist) for the current window.
    window_stages: BTreeMap<usize, (HdrHist, HdrHist)>,
    windows: Vec<WindowRow>,
    reconfigs: Vec<ReconfigSpan>,
    faults: Vec<FaultMark>,
    /// Run-level histograms (never reset), in nanoseconds.
    queue_hist: HdrHist,
    service_hist: HdrHist,
    latency_hist: HdrHist,
}

impl Tracer {
    pub fn new(cfg: &TelemetryConfig) -> Option<Tracer> {
        cfg.stride().map(|stride| Tracer {
            stride,
            traces: BTreeMap::new(),
            window_stages: BTreeMap::new(),
            windows: Vec::new(),
            reconfigs: Vec::new(),
            faults: Vec::new(),
            queue_hist: HdrHist::new(),
            service_hist: HdrHist::new(),
            latency_hist: HdrHist::new(),
        })
    }

    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// Is request `img` in the sample?
    pub fn wants(&self, img: usize) -> bool {
        img as u64 % self.stride == 0
    }

    /// A sampled request entered the system.
    pub fn admit(&mut self, img: usize, now: Nanos, plan: usize) {
        if self.traces.len() >= MAX_TRACES {
            return; // histograms keep running; spans stop accumulating
        }
        self.traces.insert(
            img,
            RequestTrace { img, plan, admitted_ns: now, done_ns: None, stages: Vec::new() },
        );
    }

    /// A sampled request finished a stage.
    pub fn stage(&mut self, img: usize, span: StageSpan) {
        self.queue_hist.record(span.queue_ns);
        self.service_hist.record(span.compute_ns);
        // the gather hop keys its own row under the usize::MAX sentinel
        let (q, s) = self.window_stages.entry(span.si).or_default();
        q.record(span.queue_ns);
        s.record(span.compute_ns);
        if let Some(t) = self.traces.get_mut(&img) {
            t.stages.push(span);
        }
    }

    /// A sampled request completed end-to-end.
    pub fn done(&mut self, img: usize, admitted_ns: Nanos, done_ns: Nanos) {
        self.latency_hist.record(done_ns.saturating_sub(admitted_ns));
        if let Some(t) = self.traces.get_mut(&img) {
            t.done_ns = Some(done_ns);
        }
    }

    /// Close a control window: snapshot the per-stage histograms into a
    /// [`WindowRow`] and reset them for the next epoch. `stalled` flags
    /// a zero-completion window with work still in flight (an outage).
    #[allow(clippy::too_many_arguments)]
    pub fn window(
        &mut self,
        t_ms: f64,
        events: u64,
        arrivals: u64,
        completions: u64,
        stalled: bool,
        backlog: u64,
        power_w: f64,
    ) {
        let p = |h: &HdrHist, q: f64| h.percentile(q).map(ns_to_ms).unwrap_or(0.0);
        let stages = self
            .window_stages
            .iter()
            .filter(|(_, (q, _))| !q.is_empty())
            .map(|(&si, (q, s))| StageWindow {
                si,
                count: q.count(),
                queue_p50_ms: p(q, 50.0),
                queue_p99_ms: p(q, 99.0),
                service_p50_ms: p(s, 50.0),
                service_p99_ms: p(s, 99.0),
            })
            .collect();
        for (q, s) in self.window_stages.values_mut() {
            q.reset();
            s.reset();
        }
        self.windows.push(WindowRow {
            t_ms,
            events,
            arrivals,
            completions,
            stalled,
            backlog,
            power_w,
            stages,
        });
    }

    /// A fault-process transition fired (node crash or rejoin).
    pub fn fault(&mut self, at_ns: Nanos, node: usize, kind: &str) {
        self.faults.push(FaultMark { at_ns, node, kind: kind.to_string() });
    }

    /// A reconfiguration executed (plan switch with downtime).
    pub fn reconfig(&mut self, start_ns: Nanos, end_ns: Nanos, from: usize, to: usize, reason: &str) {
        self.reconfigs.push(ReconfigSpan {
            start_ns,
            end_ns,
            from,
            to,
            reason: reason.to_string(),
        });
    }

    /// Tear down into the run's immutable telemetry bundle.
    pub fn finish(self, audit: Vec<AuditRecord>) -> super::RunTelemetry {
        super::RunTelemetry {
            label: String::new(),
            engine: String::new(),
            sample_stride: self.stride,
            traces: self.traces.into_values().collect(),
            windows: self.windows,
            reconfigs: self.reconfigs,
            faults: self.faults,
            audit,
            queue_hist: self.queue_hist,
            service_hist: self.service_hist,
            latency_hist: self.latency_hist,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stride_math() {
        assert_eq!(TelemetryConfig::off().stride(), None);
        assert_eq!(TelemetryConfig::on(0.0).stride(), None);
        assert_eq!(TelemetryConfig::on(1.0).stride(), Some(1));
        assert_eq!(TelemetryConfig::on(0.5).stride(), Some(2));
        assert_eq!(TelemetryConfig::on(0.01).stride(), Some(100));
        assert_eq!(TelemetryConfig::on(7.0).stride(), Some(1)); // clamped
        assert_eq!(TelemetryConfig { enabled: false, sample_rate: 1.0 }.stride(), None);
    }

    #[test]
    fn tracer_none_when_off() {
        assert!(Tracer::new(&TelemetryConfig::off()).is_none());
        assert!(Tracer::new(&TelemetryConfig::on(0.0)).is_none());
        assert!(Tracer::new(&TelemetryConfig::on(0.25)).is_some());
    }

    fn span(si: usize, start: Nanos, net: Nanos, queue: Nanos, comp: Nanos) -> StageSpan {
        StageSpan {
            si,
            start_ns: start,
            end_ns: start + net + queue + comp,
            net_ns: net,
            queue_ns: queue,
            compute_ns: comp,
            node: 0,
            computes: vec![ComputeSpan {
                node: 0,
                start_ns: start + net + queue,
                end_ns: start + net + queue + comp,
            }],
        }
    }

    #[test]
    fn trace_assembly_conserves_time() {
        let mut t = Tracer::new(&TelemetryConfig::on(0.5)).unwrap();
        assert!(t.wants(0) && !t.wants(1) && t.wants(2));
        t.admit(0, 100, 0);
        t.stage(0, span(0, 100, 5, 10, 85)); // ends at 200
        t.stage(0, span(1, 200, 0, 40, 60)); // ends at 300
        t.done(0, 100, 300);
        let bundle = t.finish(Vec::new());
        assert_eq!(bundle.traces.len(), 1);
        let tr = &bundle.traces[0];
        assert_eq!(tr.done_ns, Some(300));
        let total: Nanos =
            tr.stages.iter().map(|s| s.net_ns + s.queue_ns + s.compute_ns).sum();
        assert_eq!(total, 300 - 100);
        // chaining
        assert_eq!(tr.stages[0].start_ns, tr.admitted_ns);
        assert_eq!(tr.stages[1].start_ns, tr.stages[0].end_ns);
        assert_eq!(tr.stages.last().unwrap().end_ns, 300);
        // run histograms saw both stages
        assert_eq!(bundle.queue_hist.count(), 2);
        assert_eq!(bundle.latency_hist.count(), 1);
        assert_eq!(bundle.latency_hist.p50(), Some(200));
    }

    #[test]
    fn window_snapshot_resets_stage_hists() {
        let mut t = Tracer::new(&TelemetryConfig::on(1.0)).unwrap();
        t.admit(0, 0, 0);
        t.stage(0, span(0, 0, 0, 1_000_000, 2_000_000));
        t.window(100.0, 42, 3, 1, false, 2, 6.5);
        assert_eq!(t.windows.len(), 1);
        let w = &t.windows[0];
        assert_eq!((w.events, w.arrivals, w.completions), (42, 3, 1));
        assert!(!w.stalled);
        assert_eq!(w.stages.len(), 1);
        assert_eq!(w.stages[0].count, 1);
        assert!((w.stages[0].queue_p50_ms - 1.0).abs() / 1.0 < 0.01);
        assert!((w.stages[0].service_p50_ms - 2.0).abs() / 2.0 < 0.01);
        assert_eq!(w.backlog, 2);
        assert!((w.power_w - 6.5).abs() < 1e-9);
        // next window is empty: stage hists were reset
        t.window(200.0, 0, 0, 0, true, 1, 0.0);
        assert!(t.windows[1].stages.is_empty());
        assert!(t.windows[1].stalled, "outage window must carry its flag");
        // run-level hist unaffected by the reset
        assert_eq!(t.queue_hist.count(), 1);
    }

    #[test]
    fn fault_marks_flow_into_the_bundle() {
        let mut t = Tracer::new(&TelemetryConfig::on(1.0)).unwrap();
        t.fault(5_000_000, 2, "down");
        t.fault(9_000_000, 2, "up");
        let bundle = t.finish(Vec::new());
        assert_eq!(bundle.faults.len(), 2);
        assert_eq!(bundle.faults[0], FaultMark { at_ns: 5_000_000, node: 2, kind: "down".into() });
        assert_eq!(bundle.faults[1].kind, "up");
    }

    #[test]
    fn trace_cap_keeps_histograms_running() {
        let mut t = Tracer::new(&TelemetryConfig::on(1.0)).unwrap();
        // simulate a tiny cap by filling the map directly
        for i in 0..10 {
            t.admit(i, i as Nanos, 0);
        }
        assert_eq!(t.traces.len(), 10);
        // spans for untracked imgs still feed the histograms
        t.stage(999, span(0, 0, 1, 2, 3));
        assert_eq!(t.queue_hist.count(), 1);
        let bundle = t.finish(Vec::new());
        assert_eq!(bundle.traces.len(), 10);
        assert!(bundle.traces.iter().all(|tr| tr.stages.is_empty()));
    }
}
