//! Log-linear HDR-style histogram (DESIGN.md §13).
//!
//! [`crate::util::stats::Summary`] keeps every sample, which is exact
//! but unbounded — a 10⁸-event DES run must not retain 10⁸ floats just
//! to answer "p99". [`HdrHist`] buckets non-negative integer values
//! (the telemetry layer feeds it sim-time nanoseconds) on a log-linear
//! grid: values below 2⁷ land in exact unit buckets, and every octave
//! above is split into 2⁷ equal sub-buckets, so the bucket width is
//! always ≤ value/2⁷ and the midpoint a percentile reports is within
//! **1/256 ≈ 0.4 % relative error** of the true sample — the ≤ 1 %
//! bound the property test in `tests/proptests.rs` pins against
//! `Summary` on random workloads.
//!
//! Buckets are stored sparsely (ordered map keyed by bucket index), so
//! memory is bounded by the number of *distinct* buckets ever touched
//! (≤ 7 424 for the full u64 range, typically a few dozen), not by the
//! sample count. Histograms merge losslessly — window histograms fold
//! into run histograms bucket by bucket.

use std::collections::BTreeMap;

/// Sub-bucket resolution: 2^SUB_BITS linear sub-buckets per octave.
const SUB_BITS: u32 = 7;
const SUB_COUNT: u64 = 1 << SUB_BITS; // 128

/// A mergeable log-linear histogram over `u64` values with ≤ 1/256
/// relative error on reported percentiles.
#[derive(Debug, Clone, Default)]
pub struct HdrHist {
    /// bucket index → sample count (sparse, ordered for percentile walks).
    counts: BTreeMap<u32, u64>,
    count: u64,
    min: u64,
    max: u64,
    sum: u128,
}

/// Bucket index of a value: exact below `SUB_COUNT`, log-linear above.
fn index_of(v: u64) -> u32 {
    if v < SUB_COUNT {
        return v as u32;
    }
    let exp = 63 - v.leading_zeros(); // ≥ SUB_BITS
    let sub = (v >> (exp - SUB_BITS)) - SUB_COUNT; // in [0, SUB_COUNT)
    SUB_COUNT as u32 + (exp - SUB_BITS) * SUB_COUNT as u32 + sub as u32
}

/// Midpoint of a bucket — the value a percentile in that bucket reports.
fn midpoint_of(index: u32) -> u64 {
    if index < SUB_COUNT as u32 {
        return index as u64;
    }
    let octave = (index - SUB_COUNT as u32) / SUB_COUNT as u32;
    let sub = ((index - SUB_COUNT as u32) % SUB_COUNT as u32) as u64;
    let lo = (SUB_COUNT + sub) << octave;
    let width = 1u64 << octave;
    lo + width / 2
}

impl HdrHist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` occurrences of `v` (what [`HdrHist::merge`] uses).
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        *self.counts.entry(index_of(v)).or_insert(0) += n;
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += n;
        self.sum += v as u128 * n as u128;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest recorded value (exact, not bucketed). 0 when empty.
    pub fn min(&self) -> u64 {
        if self.is_empty() {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value (exact, not bucketed). 0 when empty.
    pub fn max(&self) -> u64 {
        if self.is_empty() {
            0
        } else {
            self.max
        }
    }

    /// Exact mean of the recorded values (the sum is kept exactly).
    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The value at quantile `q` ∈ [0, 100]: the midpoint of the bucket
    /// holding the ⌈q/100 · n⌉-th smallest sample, clamped into the
    /// recorded [min, max] so the bound also holds at the extremes.
    /// `None` when no samples were recorded.
    pub fn percentile(&self, q: f64) -> Option<u64> {
        assert!((0.0..=100.0).contains(&q));
        if self.is_empty() {
            return None;
        }
        let target = ((q / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (&idx, &c) in &self.counts {
            seen += c;
            if seen >= target {
                return Some(midpoint_of(idx).clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    pub fn p50(&self) -> Option<u64> {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Option<u64> {
        self.percentile(99.0)
    }

    /// Fold another histogram in, bucket by bucket (lossless: both sides
    /// share the fixed bucket grid).
    pub fn merge(&mut self, other: &HdrHist) {
        if other.is_empty() {
            return;
        }
        for (&idx, &c) in &other.counts {
            *self.counts.entry(idx).or_insert(0) += c;
        }
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Drop every sample but keep the allocation — what the per-window
    /// stage histograms do at each control epoch.
    pub fn reset(&mut self) {
        self.counts.clear();
        self.count = 0;
        self.min = 0;
        self.max = 0;
        self.sum = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = HdrHist::new();
        for v in 0..SUB_COUNT {
            h.record(v);
        }
        for q in [0.0, 25.0, 50.0, 99.0, 100.0] {
            let p = h.percentile(q).unwrap();
            assert!(p < SUB_COUNT, "p{q} = {p}");
        }
        assert_eq!(h.percentile(50.0), Some(63)); // 64th smallest of 0..=127
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUB_COUNT - 1);
    }

    #[test]
    fn bucket_relative_error_bound() {
        // every value maps to a bucket whose midpoint is within 1/256
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            for &x in &[v, v + v / 3, v.saturating_mul(2) - 1] {
                let mid = midpoint_of(index_of(x));
                let err = (mid as f64 - x as f64).abs() / x as f64;
                assert!(err <= 1.0 / 256.0 + 1e-12, "v={x} mid={mid} err={err}");
            }
            v *= 2;
        }
    }

    #[test]
    fn percentiles_track_known_distribution() {
        let mut h = HdrHist::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000);
        }
        let p50 = h.percentile(50.0).unwrap() as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.01, "{p50}");
        let p99 = h.percentile(99.0).unwrap() as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.01, "{p99}");
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.min(), 1000);
        assert_eq!(h.max(), 10_000_000);
    }

    #[test]
    fn merge_equals_single_histogram() {
        let mut a = HdrHist::new();
        let mut b = HdrHist::new();
        let mut whole = HdrHist::new();
        for v in 0..5000u64 {
            let x = v * v + 17;
            if v % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            whole.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for q in [1.0, 50.0, 95.0, 99.9] {
            assert_eq!(a.percentile(q), whole.percentile(q), "q={q}");
        }
    }

    #[test]
    fn empty_and_reset() {
        let mut h = HdrHist::new();
        assert_eq!(h.percentile(50.0), None);
        assert_eq!(h.mean(), 0.0);
        h.record(42);
        assert_eq!(h.percentile(50.0), Some(42));
        h.reset();
        assert!(h.is_empty());
        assert_eq!(h.percentile(99.0), None);
        // reuse after reset behaves like new
        h.record(7);
        assert_eq!(h.percentile(100.0), Some(7));
    }

    #[test]
    fn extreme_percentiles_clamp_to_min_max() {
        let mut h = HdrHist::new();
        h.record(1_000_003);
        h.record(2_000_007);
        assert_eq!(h.percentile(0.0), Some(1_000_003));
        assert_eq!(h.percentile(100.0), Some(2_000_007));
    }
}
