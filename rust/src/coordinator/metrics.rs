//! Serving metrics: latency distribution and throughput.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

#[derive(Debug, Default)]
pub struct Metrics {
    latencies_ms: Summary,
    completed: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn record(&mut self, latency: Duration) {
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
        self.completed += 1;
        self.finished = Some(Instant::now());
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn latency_ms(&self) -> &Summary {
        &self.latencies_ms
    }

    /// Wall-clock span from start() to the last completion.
    pub fn elapsed(&self) -> Duration {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => f.duration_since(s),
            _ => Duration::ZERO,
        }
    }

    /// Images per second over the measured span.
    pub fn throughput(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.completed as f64 / secs
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        if self.completed == 0 {
            return "no completions".to_string();
        }
        format!(
            "{} images | {:.2} img/s | latency {}",
            self.completed,
            self.throughput(),
            self.latencies_ms.display("ms"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.start();
        for i in 0..10 {
            m.record(Duration::from_millis(10 + i));
        }
        assert_eq!(m.completed(), 10);
        assert!(m.latency_ms().mean() > 9.0);
        assert!(m.throughput() > 0.0);
        assert!(m.report().contains("10 images"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.report(), "no completions");
    }
}
