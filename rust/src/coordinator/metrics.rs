//! Serving metrics: latency distribution and throughput.
//!
//! Throughput needs a time base, and the codebase has two: host
//! wall-clock for the real PJRT serving path, and simulated integer
//! nanoseconds for the DES. The old implementation hard-coded
//! `Instant::now()`, so a simulator feeding it would have divided
//! simulated completions by *host* elapsed time — measuring how fast
//! the simulator runs, not how fast the cluster serves. The span is
//! now kept by a [`crate::telemetry::Clock`] (DESIGN.md §13): wall
//! metrics behave exactly as before, and [`Metrics::sim`] +
//! [`Metrics::record_at_ms`] give the DES the same accounting in
//! sim-time.

use crate::telemetry::Clock;
use crate::util::stats::Summary;
use crate::util::units::Nanos;
use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    latencies_ms: Summary,
    completed: u64,
    clock: Clock,
}

impl Metrics {
    /// Wall-clock metrics (the real serving coordinator).
    pub fn new() -> Self {
        Self::default()
    }

    /// Sim-time metrics: the span only advances through
    /// [`Metrics::record_at_ms`], never from the host clock.
    pub fn sim() -> Self {
        Metrics { latencies_ms: Summary::new(), completed: 0, clock: Clock::sim() }
    }

    pub fn start(&mut self) {
        self.clock.start();
    }

    /// Record a completion on a wall clock ("it finished just now").
    pub fn record(&mut self, latency: Duration) {
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
        self.completed += 1;
        self.clock.mark();
    }

    /// Record a completion at an explicit sim time.
    pub fn record_at_ms(&mut self, latency_ms: f64, now_ns: Nanos) {
        self.latencies_ms.push(latency_ms);
        self.completed += 1;
        self.clock.mark_at(now_ns);
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn latency_ms(&self) -> &Summary {
        &self.latencies_ms
    }

    /// Hand the latency distribution to a caller that outlives the run.
    pub fn into_latency(self) -> Summary {
        self.latencies_ms
    }

    /// Span from start() to the last completion, in the metrics' own
    /// time domain (wall or sim).
    pub fn elapsed(&self) -> Duration {
        self.clock.elapsed()
    }

    /// Images per second over the measured span.
    pub fn throughput(&self) -> f64 {
        let secs = self.clock.elapsed_sec();
        if secs > 0.0 {
            self.completed as f64 / secs
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        if self.completed == 0 {
            return "no completions".to_string();
        }
        format!(
            "{} images | {:.2} img/s | latency {}",
            self.completed,
            self.throughput(),
            self.latencies_ms.display("ms"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.start();
        for i in 0..10 {
            m.record(Duration::from_millis(10 + i));
        }
        assert_eq!(m.completed(), 10);
        assert!(m.latency_ms().mean() > 9.0);
        assert!(m.throughput() > 0.0);
        assert!(m.report().contains("10 images"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.report(), "no completions");
    }

    #[test]
    fn sim_metrics_use_sim_time_not_host_time() {
        let mut m = Metrics::sim();
        m.start();
        // 100 completions "spread over" 2 simulated seconds — the host
        // executes this loop in microseconds
        for i in 1..=100u64 {
            m.record_at_ms(5.0, i * 20_000_000);
        }
        assert_eq!(m.completed(), 100);
        assert_eq!(m.elapsed(), Duration::from_secs(2));
        assert!((m.throughput() - 50.0).abs() < 1e-9, "{}", m.throughput());
        assert_eq!(m.into_latency().mean(), 5.0);
    }

    #[test]
    fn wall_record_on_sim_clock_does_not_advance_it() {
        let mut m = Metrics::sim();
        m.start();
        m.record(Duration::from_millis(3));
        // the sample is kept but sim time never moved
        assert_eq!(m.completed(), 1);
        assert_eq!(m.elapsed(), Duration::ZERO);
        assert_eq!(m.throughput(), 0.0);
    }
}
