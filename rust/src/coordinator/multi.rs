//! Multi-tenant serving: several independent model pipelines over one
//! shared node budget (DESIGN.md §7).
//!
//! The paper's cluster "can simultaneously execute diverse Neural
//! Network models". This module is that claim made concrete, twice:
//!
//! * [`MultiCoordinator`] — *real* serving: one [`Coordinator`] pipeline
//!   per tenant, each with its own [`ExecutionPlan`] and worker threads,
//!   running concurrently in one process. `submit(tenant, image)` routes
//!   by tenant name; [`MultiCoordinator::run_batches`] drives all
//!   tenants' batches at once and returns a merged per-tenant
//!   [`ServingReport`].
//! * [`simulate_tenants`] — the analytic counterpart for models whose
//!   AOT artifacts are not exported: the shared budget is split across
//!   tenants (proportional to their single-node service demand), each
//!   tenant's strategy plans its sub-cluster, and the calibrated
//!   simulator prices every pipeline. This is what `vtacluster multi`
//!   runs by default.

use super::service::{Coordinator, ServingReport};
use crate::config::{BoardFamily, BoardProfile, Calibration, ClusterConfig, VtaConfig};
use crate::graph::zoo;
use crate::runtime::TensorData;
use crate::sched::online::PlanOption;
use crate::sched::{build_plan_priced, ExecutionPlan, Strategy};
use crate::serve::BatchConfig;
use crate::sim::{
    run_des, simulate, ArrivalProcess, CostModel, DesConfig, DesResult, SimConfig, SimResult,
};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::path::PathBuf;

/// One tenant of a real serving deployment.
pub struct TenantSpec {
    /// Routing key; unique per tenant (two tenants may serve the same
    /// model under different names and plans).
    pub name: String,
    /// The tenant's schedule; `plan.model` selects the AOT artifacts.
    pub plan: ExecutionPlan,
    /// Exported input variant (32 tiny / 224 paper).
    pub input_hw: u64,
}

/// Several concurrently running serving pipelines sharing one process
/// and one node budget.
pub struct MultiCoordinator {
    tenants: Vec<(String, Coordinator)>,
}

impl MultiCoordinator {
    /// Start every tenant's pipeline. Fails if tenant names collide, the
    /// summed plan sizes exceed `node_budget`, or any model's artifacts
    /// are missing at `dir`.
    pub fn start(
        dir: PathBuf,
        specs: Vec<TenantSpec>,
        node_budget: usize,
        fast: bool,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!specs.is_empty(), "no tenants");
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        anyhow::ensure!(names.len() == specs.len(), "duplicate tenant names");
        let used: usize = specs.iter().map(|s| s.plan.n_nodes).sum();
        anyhow::ensure!(
            used <= node_budget,
            "tenants need {used} nodes, budget is {node_budget}"
        );
        let mut tenants = Vec::with_capacity(specs.len());
        for spec in specs {
            let coord = Coordinator::start_variant(dir.clone(), &spec.plan, spec.input_hw, fast)
                .map_err(|e| anyhow::anyhow!("tenant '{}': {e}", spec.name))?;
            tenants.push((spec.name, coord));
        }
        Ok(MultiCoordinator { tenants })
    }

    /// Tenant names, in start order.
    pub fn tenants(&self) -> Vec<&str> {
        self.tenants.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Route one image to a tenant's pipeline; returns the request id
    /// (ids are per-tenant).
    pub fn submit(&self, tenant: &str, image: TensorData) -> anyhow::Result<u64> {
        self.coordinator(tenant)?.submit(image)
    }

    /// The underlying pipeline of one tenant.
    pub fn coordinator(&self, tenant: &str) -> anyhow::Result<&Coordinator> {
        self.tenants
            .iter()
            .find(|(n, _)| n == tenant)
            .map(|(_, c)| c)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown tenant '{tenant}' (serving: {})",
                    self.tenants().join(", ")
                )
            })
    }

    /// Serve every tenant's batch concurrently (one driver thread per
    /// tenant, pipelines already run their own workers). Returns, per
    /// tenant in start order, the ordered outputs and a
    /// [`ServingReport`] whose `model` field is the tenant name.
    /// Dispatches each tenant's whole batch as one wave; see
    /// [`MultiCoordinator::run_batches_chunked`] to cap in-flight work.
    pub fn run_batches(
        &mut self,
        batches: Vec<(String, Vec<TensorData>)>,
    ) -> anyhow::Result<Vec<(String, Vec<TensorData>, ServingReport)>> {
        self.run_batches_chunked(batches, BatchConfig::unbounded())
    }

    /// [`MultiCoordinator::run_batches`] through the serve-layer chunker
    /// (DESIGN.md §16): every tenant's driver keeps at most
    /// `cfg.max_size` of its images in flight at once.
    pub fn run_batches_chunked(
        &mut self,
        batches: Vec<(String, Vec<TensorData>)>,
        cfg: BatchConfig,
    ) -> anyhow::Result<Vec<(String, Vec<TensorData>, ServingReport)>> {
        let mut pending: HashMap<String, Vec<TensorData>> = HashMap::new();
        for (name, batch) in batches {
            anyhow::ensure!(
                self.tenants.iter().any(|(n, _)| n == &name),
                "unknown tenant '{name}'"
            );
            anyhow::ensure!(
                pending.insert(name.clone(), batch).is_none(),
                "two batches for tenant '{name}'"
            );
        }
        let mut out = Vec::new();
        std::thread::scope(|scope| -> anyhow::Result<()> {
            let mut handles = Vec::new();
            for (name, coord) in self.tenants.iter_mut() {
                let Some(batch) = pending.remove(name.as_str()) else { continue };
                let tenant = name.clone();
                handles.push(scope.spawn(move || {
                    let (outs, mut report) = coord.run_batch_chunked(batch, cfg)?;
                    report.model = tenant.clone();
                    Ok::<_, anyhow::Error>((tenant, outs, report))
                }));
            }
            for h in handles {
                let r = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("tenant driver thread panicked"))??;
                out.push(r);
            }
            Ok(())
        })?;
        Ok(out)
    }

    /// Stop every tenant pipeline (also runs on drop of the inner
    /// coordinators).
    pub fn shutdown(&mut self) {
        for (_, coord) in self.tenants.iter_mut() {
            coord.shutdown();
        }
    }
}

/// Split `budget` nodes across tenants proportionally to `demands`
/// (largest-remainder), guaranteeing every tenant ≥ 1 node.
pub fn allocate_nodes(budget: usize, demands: &[f64]) -> anyhow::Result<Vec<usize>> {
    let k = demands.len();
    anyhow::ensure!(k >= 1, "no tenants to allocate to");
    anyhow::ensure!(budget >= k, "budget {budget} < {k} tenants (need ≥ 1 node each)");
    anyhow::ensure!(
        demands.iter().all(|d| d.is_finite() && *d >= 0.0),
        "demands must be finite and non-negative"
    );
    let total: f64 = demands.iter().sum();
    // degenerate demand → equal split
    let share = |d: f64| if total > 0.0 { d / total } else { 1.0 / k as f64 };
    // one guaranteed node each, remainder proportional
    let spare = (budget - k) as f64;
    let mut alloc: Vec<usize> = demands.iter().map(|&d| 1 + (share(d) * spare) as usize).collect();
    let mut rem: Vec<(f64, usize)> = demands
        .iter()
        .enumerate()
        .map(|(i, &d)| (share(d) * spare - (share(d) * spare).floor(), i))
        .collect();
    rem.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let mut left = budget - alloc.iter().sum::<usize>();
    for &(_, i) in rem.iter().cycle().take(left.min(k * budget)) {
        if left == 0 {
            break;
        }
        alloc[i] += 1;
        left -= 1;
    }
    debug_assert_eq!(alloc.iter().sum::<usize>(), budget);
    Ok(alloc)
}

/// One tenant of an analytic multi-tenant run.
#[derive(Debug, Clone)]
pub struct TenantRequest {
    /// Registry name of the workload (see [`crate::graph::zoo`]).
    pub model: String,
    /// Input size (`0` → the model's default).
    pub input_hw: u64,
    /// The tenant's scheduling strategy.
    pub strategy: Strategy,
    /// Images in the tenant's stream.
    pub images: usize,
}

/// Result of one tenant of [`simulate_tenants`].
#[derive(Debug, Clone)]
pub struct TenantSim {
    pub model: String,
    /// Nodes of the shared budget this tenant received.
    pub nodes: usize,
    pub plan: ExecutionPlan,
    pub sim: SimResult,
    /// Loaded behavior: a seeded discrete-event run of this tenant's
    /// pipeline under Poisson arrivals at 70 % of the plan's capacity —
    /// where the report's latency percentiles come from.
    pub loaded: DesResult,
    /// The simulator's verdict in serving-report form (throughput from
    /// the steady-state per-image time, latencies from the loaded DES,
    /// wall from the makespan).
    pub report: ServingReport,
}

/// Plan and price a multi-tenant deployment analytically: the node
/// budget is split proportionally to each tenant's single-node service
/// demand (`graph_time × images`), each tenant's strategy schedules its
/// share, every pipeline is priced by the calibrated simulator, and a
/// seeded discrete-event run ([`crate::sim::des`]) measures each
/// tenant's latency distribution under Poisson load at 70 % of its
/// capacity. `seed` makes the stochastic path reproducible — the CLI
/// prints it with the report. Models need no AOT artifacts — any zoo
/// entry works.
pub fn simulate_tenants(
    family: BoardFamily,
    vta: VtaConfig,
    calib: Calibration,
    node_budget: usize,
    requests: &[TenantRequest],
    seed: u64,
) -> anyhow::Result<Vec<TenantSim>> {
    anyhow::ensure!(!requests.is_empty(), "no tenants requested");
    let mut cost = CostModel::new(vta.clone(), BoardProfile::for_family(family), calib);
    let graphs = requests
        .iter()
        .map(|r| zoo::build(&r.model, r.input_hw))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let mut demands = Vec::with_capacity(requests.len());
    for (req, g) in requests.iter().zip(&graphs) {
        demands.push(cost.graph_time_ns(g)? as f64 * req.images.max(1) as f64);
    }
    let alloc = allocate_nodes(node_budget, &demands)?;

    // independent per-tenant seeds derived from the run seed
    let mut seed_rng = Rng::new(seed);
    let mut out = Vec::with_capacity(requests.len());
    for ((req, g), &n) in requests.iter().zip(&graphs).zip(&alloc) {
        let cluster = ClusterConfig::homogeneous(family, n).with_vta(vta.clone());
        let plan = if req.strategy == Strategy::Eco {
            // power-aware tenant: minimize J/image on its sub-cluster
            crate::power::eco_plan(g, &cluster, &mut cost, None)?.plan
        } else if req.strategy == Strategy::Search {
            // searched tenant: DP/beam over its sub-cluster's partition
            // space (DESIGN.md §17), latency objective, unconstrained
            crate::search::search_plan(
                g,
                &cluster,
                &mut cost,
                &crate::search::SearchConfig::default(),
            )?
            .plan
        } else {
            let seg_costs = cost.seg_cost_table(g)?;
            build_plan_priced(req.strategy, g, n, &seg_costs)?
        };
        let sim = simulate(&plan, &cluster, &mut cost, g, &SimConfig { images: req.images })?;

        // loaded latency: drive the pipeline with a seeded Poisson
        // stream at 70 % of its steady-state capacity
        let capacity = 1e3 / sim.ms_per_image;
        let option = PlanOption {
            plan: plan.clone(),
            capacity_img_per_sec: capacity,
            latency_ms: sim.latency_ms.mean(),
            avg_power_w: sim.power.cluster_avg_w,
            j_per_image: sim.power.j_per_image,
            node_map: None,
        };
        let rate = 0.7 * capacity;
        let target_images = req.images.max(32) as f64;
        let des_cfg = DesConfig::new(
            ArrivalProcess::Poisson { rate_per_sec: rate },
            target_images / rate * 1e3,
            seed_rng.next_u64(),
        );
        let loaded = run_des(&[option], 0, &cluster, &mut cost, g, &des_cfg, None)?;
        let (mean_ms, p99_ms) = if loaded.completed > 0 {
            (
                loaded.latency_ms.mean(),
                loaded.latency_ms.percentile(99.0).unwrap_or(0.0),
            )
        } else {
            // degenerate horizon: fall back to the unloaded figure
            (sim.latency_ms.mean(), sim.latency_ms.percentile(99.0).unwrap_or(0.0))
        };
        let report = ServingReport {
            model: req.model.clone(),
            images: req.images as u64,
            throughput_img_per_sec: capacity,
            mean_latency_ms: mean_ms,
            p99_latency_ms: p99_ms,
            wall_ms: sim.makespan_ms,
        };
        out.push(TenantSim { model: req.model.clone(), nodes: n, plan, sim, loaded, report });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_covers_budget_with_min_one() {
        let a = allocate_nodes(12, &[3.0, 1.0, 0.0]).unwrap();
        assert_eq!(a.iter().sum::<usize>(), 12);
        assert!(a.iter().all(|&n| n >= 1));
        assert!(a[0] > a[1], "heavier tenant got fewer nodes: {a:?}");
        // degenerate: all-zero demand → near-equal split
        let e = allocate_nodes(9, &[0.0, 0.0, 0.0]).unwrap();
        assert_eq!(e.iter().sum::<usize>(), 9);
        assert!(e.iter().all(|&n| n == 3), "{e:?}");
        // too-small budget errors
        assert!(allocate_nodes(2, &[1.0, 1.0, 1.0]).is_err());
    }

    #[test]
    fn analytic_multi_tenant_runs_three_models() {
        let reqs = [
            TenantRequest {
                model: "resnet18".into(),
                input_hw: 224,
                strategy: Strategy::Pipeline,
                images: 16,
            },
            TenantRequest {
                model: "lenet5".into(),
                input_hw: 0,
                strategy: Strategy::ScatterGather,
                images: 16,
            },
            TenantRequest {
                model: "mlp".into(),
                input_hw: 0,
                strategy: Strategy::Fused,
                images: 16,
            },
        ];
        let out = simulate_tenants(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            Calibration::default(),
            12,
            &reqs,
            7,
        )
        .unwrap();
        assert_eq!(out.len(), 3);
        let used: usize = out.iter().map(|t| t.nodes).sum();
        assert_eq!(used, 12, "budget not fully used");
        for t in &out {
            t.plan.validate().unwrap();
            assert_eq!(t.plan.n_nodes, t.nodes);
            assert!(t.report.throughput_img_per_sec > 0.0, "{}", t.model);
            assert!(t.sim.ms_per_image.is_finite());
            // §11: every tenant's report carries its watts and J/image
            assert!(t.sim.power.cluster_avg_w > 0.0, "{}: no watts", t.model);
            assert!(t.sim.power.j_per_image > 0.0, "{}: no J/image", t.model);
            assert_eq!(t.sim.power.node_watts.len(), t.nodes);
        }
        // resnet dominates the demand → gets the most nodes
        assert!(out[0].nodes > out[1].nodes, "{:?}", out.iter().map(|t| t.nodes).collect::<Vec<_>>());
        // per-model routing: reports carry their model names
        assert_eq!(out[1].report.model, "lenet5");
        // loaded DES ran and produced the report's latency percentiles
        for t in &out {
            assert!(t.loaded.completed > 0, "{}: empty loaded run", t.model);
            assert!(
                t.report.p99_latency_ms >= t.report.mean_latency_ms * 0.99,
                "{}: p99 {} below mean {}",
                t.model,
                t.report.p99_latency_ms,
                t.report.mean_latency_ms
            );
        }
    }

    #[test]
    fn simulate_tenants_is_seed_reproducible() {
        let reqs = [TenantRequest {
            model: "lenet5".into(),
            input_hw: 0,
            strategy: Strategy::ScatterGather,
            images: 24,
        }];
        let run = |seed| {
            simulate_tenants(
                BoardFamily::Zynq7000,
                VtaConfig::table1_zynq7000(),
                Calibration::default(),
                2,
                &reqs,
                seed,
            )
            .unwrap()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a[0].report.p99_latency_ms, b[0].report.p99_latency_ms);
        assert_eq!(a[0].loaded.completed, b[0].loaded.completed);
        let c = run(8);
        assert!(
            a[0].loaded.offered != c[0].loaded.offered
                || a[0].report.p99_latency_ms != c[0].report.p99_latency_ms,
            "seed change did not alter the loaded run"
        );
    }

    #[test]
    fn eco_tenant_supported() {
        let reqs = [
            TenantRequest {
                model: "lenet5".into(),
                input_hw: 0,
                strategy: Strategy::Eco,
                images: 8,
            },
            TenantRequest {
                model: "mlp".into(),
                input_hw: 0,
                strategy: Strategy::Fused,
                images: 8,
            },
        ];
        let out = simulate_tenants(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            Calibration::default(),
            4,
            &reqs,
            3,
        )
        .unwrap();
        assert_eq!(out[0].plan.strategy, Strategy::Eco);
        out[0].plan.validate().unwrap();
        assert!(out[0].sim.power.j_per_image > 0.0);
    }

    #[test]
    fn search_tenant_supported() {
        let reqs = [
            TenantRequest {
                model: "lenet5".into(),
                input_hw: 0,
                strategy: Strategy::Search,
                images: 8,
            },
            TenantRequest {
                model: "mlp".into(),
                input_hw: 0,
                strategy: Strategy::Pipeline,
                images: 8,
            },
        ];
        let out = simulate_tenants(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            Calibration::default(),
            4,
            &reqs,
            3,
        )
        .unwrap();
        assert_eq!(out[0].plan.strategy, Strategy::Search);
        out[0].plan.validate().unwrap();
        assert!(out[0].sim.ms_per_image > 0.0);
    }

    #[test]
    fn unknown_model_rejected() {
        let reqs = [TenantRequest {
            model: "vgg".into(),
            input_hw: 0,
            strategy: Strategy::Pipeline,
            images: 4,
        }];
        assert!(simulate_tenants(
            BoardFamily::Zynq7000,
            VtaConfig::table1_zynq7000(),
            Calibration::default(),
            4,
            &reqs,
            7,
        )
        .is_err());
    }
}
