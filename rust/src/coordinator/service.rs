//! Worker topology and request flow.
//!
//! ```text
//!   submit() ──► stage-0 replicas ──► stage-1 replicas ──► … ──► collector
//!                 (round-robin)         (round-robin)
//! ```
//!
//! Every replica is a thread with a private PJRT [`Engine`] that compiles
//! its stage's segment artifacts once at startup. Channels carry whole
//! activations (the Ethernet role); the collector thread stamps
//! completion times. Only `DataParallel` plans are servable on the real
//! artifacts — `Spatial` stages split single-image work across nodes,
//! which needs resharded weights the exporter doesn't produce (the
//! timing simulator covers those; see DESIGN.md §5).

use super::metrics::Metrics;
use crate::runtime::{Engine, Manifest, TensorData};
use crate::sched::{ExecutionPlan, SplitMode};
use crate::serve::{chunk, BatchConfig};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

struct Job {
    id: u64,
    tensor: TensorData,
    submitted: Instant,
}

enum StageMsg {
    Work(Job),
    Shutdown,
}

struct Completion {
    id: u64,
    logits: TensorData,
    submitted: Instant,
}

/// A running serving pipeline for one model.
pub struct Coordinator {
    model: String,
    entry: Vec<Sender<StageMsg>>, // stage-0 replica channels
    all_senders: Vec<Sender<StageMsg>>, // for shutdown
    results: Receiver<Completion>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    rr: AtomicU64,
    input_shape: Vec<usize>,
}

/// Summary of a served batch (one per model in multi-tenant runs).
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Model (or tenant) this report belongs to.
    pub model: String,
    pub images: u64,
    pub throughput_img_per_sec: f64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub wall_ms: f64,
}

impl Coordinator {
    /// Build the topology for a plan over the artifacts at `dir`.
    /// `input_hw` selects the exported variant (224 paper / 32 tiny).
    /// Serves the pallas-variant artifacts (correctness reference); use
    /// [`Coordinator::start_fast`] for the serving-optimized variant.
    pub fn start(dir: PathBuf, plan: &ExecutionPlan, input_hw: u64) -> anyhow::Result<Self> {
        Self::start_variant(dir, plan, input_hw, false)
    }

    /// Like [`Coordinator::start`] but with the `fast_` (ref-impl) HLO
    /// artifacts — identical numerics, no interpret-mode overhead.
    pub fn start_fast(dir: PathBuf, plan: &ExecutionPlan, input_hw: u64) -> anyhow::Result<Self> {
        Self::start_variant(dir, plan, input_hw, true)
    }

    /// [`Coordinator::start`] with an explicit variant choice — the
    /// entry point [`crate::coordinator::MultiCoordinator`] uses.
    pub fn start_variant(
        dir: PathBuf,
        plan: &ExecutionPlan,
        input_hw: u64,
        fast: bool,
    ) -> anyhow::Result<Self> {
        plan.validate()?;
        anyhow::ensure!(
            plan.stages.iter().all(|s| s.split == SplitMode::DataParallel),
            "only DataParallel plans are servable on real artifacts (got a Spatial stage)"
        );
        let manifest = Manifest::load(&dir)?;
        // the artifact prefix is the plan's model — serving any zoo
        // model only needs its artifacts exported under the same scheme
        anyhow::ensure!(
            manifest.model_name == plan.model,
            "artifacts at {} are for model '{}', plan schedules '{}' \
             (export the model's artifacts first)",
            dir.display(),
            manifest.model_name,
            plan.model
        );
        // fail fast if the requested variant was not exported
        anyhow::ensure!(
            manifest.segments_variant(input_hw, fast).len() == plan.segment_order.len(),
            "artifacts at {} lack the {} variant @{input_hw} (re-run `make artifacts`)",
            dir.display(),
            if fast { "fast" } else { "pallas" }
        );
        let variant = if fast { "fast_" } else { "" };
        let tag = match input_hw {
            224 => variant.to_string(),
            32 => format!("{variant}tiny_"),
            other => anyhow::bail!("no artifacts exported for input_hw={other}"),
        };
        // the request shape is whatever the first segment artifact takes
        // (NHWC for the CNNs, rank-2 for dense models) — submit() and
        // the per-artifact engine checks then enforce the same contract
        let input_shape = manifest
            .segments_variant(input_hw, fast)
            .first()
            .and_then(|a| a.inputs.first())
            .map(|io| io.shape.clone())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "first segment artifact at {} declares no inputs (re-run `make artifacts`)",
                    dir.display()
                )
            })?;

        // build stages back-to-front so each worker knows its successors
        let (done_tx, done_rx) = channel::<Completion>();
        let mut next_stage_txs: Option<Arc<Vec<Sender<StageMsg>>>> = None;
        let mut workers = Vec::new();
        let mut all_senders = Vec::new();
        let mut entry = Vec::new();

        for (si, stage) in plan.stages.iter().enumerate().rev() {
            let artifact_names: Vec<String> = stage
                .segments
                .iter()
                .map(|seg| format!("{}_{tag}seg_{seg}", plan.model))
                .collect();
            let mut this_stage_txs = Vec::new();
            for replica in 0..stage.replicas.len() {
                let (tx, rx) = channel::<StageMsg>();
                this_stage_txs.push(tx.clone());
                all_senders.push(tx);
                let names = artifact_names.clone();
                let dir2 = dir.clone();
                let forward = next_stage_txs.clone();
                let done = done_tx.clone();
                let rr = Arc::new(AtomicU64::new(0));
                let handle = std::thread::Builder::new()
                    .name(format!("stage{si}-r{replica}"))
                    .spawn(move || {
                        stage_worker(dir2, names, rx, forward, done, rr);
                    })
                    .expect("spawn worker");
                workers.push(handle);
            }
            if si == 0 {
                entry = this_stage_txs.clone();
            }
            next_stage_txs = Some(Arc::new(this_stage_txs));
        }
        drop(done_tx);
        Ok(Coordinator {
            model: plan.model.clone(),
            entry,
            all_senders,
            results: done_rx,
            workers,
            next_id: AtomicU64::new(0),
            rr: AtomicU64::new(0),
            input_shape,
        })
    }

    /// The model this pipeline serves.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// The request tensor shape this pipeline accepts (from the model's
    /// artifact manifest).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Submit one image (NHWC int8). Returns its request id.
    pub fn submit(&self, image: TensorData) -> anyhow::Result<u64> {
        anyhow::ensure!(
            image.shape == self.input_shape,
            "image shape {:?}, expected {:?}",
            image.shape,
            self.input_shape
        );
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let slot = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % self.entry.len();
        self.entry[slot]
            .send(StageMsg::Work(Job { id, tensor: image, submitted: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("pipeline closed"))?;
        Ok(id)
    }

    /// Serve a whole batch and wait for every completion. Results come
    /// back in submission order regardless of completion order. The
    /// whole set is dispatched as one wave; see
    /// [`Coordinator::run_batch_chunked`] to cap in-flight work.
    pub fn run_batch(&self, images: Vec<TensorData>) -> anyhow::Result<(Vec<TensorData>, ServingReport)> {
        self.run_batch_chunked(images, BatchConfig::unbounded())
    }

    /// [`Coordinator::run_batch`] through the serve-layer chunker
    /// (DESIGN.md §16): at most `cfg.max_size` images are in flight at
    /// once, and wave k+1 is not submitted until wave k has drained.
    pub fn run_batch_chunked(
        &self,
        images: Vec<TensorData>,
        cfg: BatchConfig,
    ) -> anyhow::Result<(Vec<TensorData>, ServingReport)> {
        let n = images.len();
        let mut metrics = Metrics::new();
        metrics.start();
        let t0 = Instant::now();
        let mut out: Vec<Option<TensorData>> = (0..n).map(|_| None).collect();
        let mut base = 0usize;
        for wave in chunk(images, cfg.max_size) {
            let k = wave.len();
            let mut slot_of = std::collections::HashMap::with_capacity(k);
            for (off, img) in wave.into_iter().enumerate() {
                let id = self.submit(img)?;
                slot_of.insert(id, base + off);
            }
            for _ in 0..k {
                let c = self
                    .results
                    .recv()
                    .map_err(|_| anyhow::anyhow!("pipeline closed mid-batch"))?;
                metrics.record(c.submitted.elapsed());
                let slot = *slot_of
                    .get(&c.id)
                    .ok_or_else(|| anyhow::anyhow!("completion for unknown request {}", c.id))?;
                out[slot] = Some(c.logits);
            }
            base += k;
        }
        let wall = t0.elapsed();
        let report = ServingReport {
            model: self.model.clone(),
            images: n as u64,
            throughput_img_per_sec: n as f64 / wall.as_secs_f64(),
            mean_latency_ms: metrics.latency_ms().mean(),
            // zero-completion runs report 0 instead of crashing
            p99_latency_ms: metrics.latency_ms().percentile(99.0).unwrap_or(0.0),
            wall_ms: wall.as_secs_f64() * 1e3,
        };
        Ok((out.into_iter().map(|o| o.expect("missing completion")).collect(), report))
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(&mut self) {
        for tx in &self.all_senders {
            let _ = tx.send(StageMsg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn stage_worker(
    dir: PathBuf,
    artifact_names: Vec<String>,
    rx: Receiver<StageMsg>,
    forward: Option<Arc<Vec<Sender<StageMsg>>>>,
    done: Sender<Completion>,
    rr: Arc<AtomicU64>,
) {
    // engine is constructed inside the thread: PjRtClient is not Send
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("worker: manifest load failed: {e}");
            return;
        }
    };
    let mut engine = match Engine::new(manifest) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("worker: engine init failed: {e}");
            return;
        }
    };
    // compile this stage's segments up front (bitstream load)
    for name in &artifact_names {
        if let Err(e) = engine.load(name) {
            eprintln!("worker: compiling {name} failed: {e}");
            return;
        }
    }
    while let Ok(msg) = rx.recv() {
        let job = match msg {
            StageMsg::Work(j) => j,
            StageMsg::Shutdown => break,
        };
        match engine.run_chain(&artifact_names, &job.tensor) {
            Ok(out) => match &forward {
                Some(next) => {
                    let slot = (rr.fetch_add(1, Ordering::Relaxed) as usize) % next.len();
                    if next[slot]
                        .send(StageMsg::Work(Job {
                            id: job.id,
                            tensor: out,
                            submitted: job.submitted,
                        }))
                        .is_err()
                    {
                        break;
                    }
                }
                None => {
                    if done
                        .send(Completion {
                            id: job.id,
                            logits: out,
                            submitted: job.submitted,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
            },
            Err(e) => {
                eprintln!("worker: inference failed for job {}: {e}", job.id);
                break;
            }
        }
    }
}
