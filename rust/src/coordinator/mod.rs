//! The serving coordinator: the master-host role of §II-C, deployable.
//!
//! Turns an [`crate::sched::ExecutionPlan`] into a running pipeline of
//! worker threads, each owning a private PJRT engine with its stage's
//! compiled segments and weights (a real FPGA node owns its bitstream
//! the same way). Images stream through stage channels; data-parallel
//! replicas are fed round-robin — the scatter/gather and pipeline
//! dataflows of the paper, executing the *actual* AOT artifacts.
//!
//! * [`service`] — worker topology, submission, collection
//! * [`metrics`] — latency/throughput accounting

pub mod metrics;
pub mod service;

pub use metrics::Metrics;
pub use service::{Coordinator, ServingReport};
