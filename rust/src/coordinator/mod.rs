//! The serving coordinator: the master-host role of §II-C, deployable.
//!
//! Turns an [`crate::sched::ExecutionPlan`] into a running pipeline of
//! worker threads, each owning a private PJRT engine with its stage's
//! compiled segments and weights (a real FPGA node owns its bitstream
//! the same way). Images stream through stage channels; data-parallel
//! replicas are fed round-robin — the scatter/gather and pipeline
//! dataflows of the paper, executing the *actual* AOT artifacts.
//!
//! Multi-tenancy: [`multi::MultiCoordinator`] runs one such pipeline per
//! model/tenant concurrently over a shared node budget, with per-tenant
//! request routing and per-tenant [`ServingReport`]s (DESIGN.md §7).
//!
//! * [`service`] — worker topology, submission, collection
//! * [`multi`]   — multi-tenant coordination and budget allocation
//! * [`metrics`] — latency/throughput accounting

pub mod metrics;
pub mod multi;
pub mod service;

pub use metrics::Metrics;
pub use multi::{allocate_nodes, simulate_tenants, MultiCoordinator, TenantRequest, TenantSim, TenantSpec};
pub use service::{Coordinator, ServingReport};
