//! Calibrated node cost model.
//!
//! Maps graph operators to wall-clock time on one FPGA node by lowering
//! them to real VTA programs (autotuned tilings for GEMM ops, ALU passes
//! for element-wise ops) and pricing with the cycle model. Results are
//! memoized — the same conv shape appears many times across strategies
//! and cluster sizes.
//!
//! The per-family anchor κ (see `config::calibration`) scales modeled
//! compute time so the single-node totals match the paper's measured
//! 27.34 ms / 25.15 ms; scaling *shapes* across N are then predictions.

use crate::compiler::{autotune_gemm, lower_alu_pass, GemmShape};
use crate::config::{BoardFamily, BoardProfile, Calibration, VtaConfig};
use crate::graph::ops::Op;
use crate::graph::tensor::TensorDesc;
use crate::graph::Graph;
use crate::util::units::{cycles_to_ns, Nanos};
use crate::vta::isa::AluOp;
use crate::vta::timing::TimingModel;
use std::collections::HashMap;

pub struct CostModel {
    pub model: TimingModel,
    gemm_cache: HashMap<GemmShape, u64>,
    alu_cache: HashMap<(u64, usize), u64>,
    /// Keyed by (graph name, segment label, split) — segment labels like
    /// `head` repeat across zoo models, so one CostModel can be shared by
    /// every workload of a multi-tenant run without collisions.
    seg_cache: HashMap<(String, String, u64), Nanos>,
    /// Batched variant of `seg_cache`, keyed additionally by batch size
    /// (DESIGN.md §16); batch 1 never lands here — it delegates to the
    /// unbatched path so the two stay bit-identical.
    seg_batch_cache: HashMap<(String, String, u64, u64), Nanos>,
}

impl CostModel {
    pub fn new(cfg: VtaConfig, board: BoardProfile, calib: Calibration) -> Self {
        CostModel {
            model: TimingModel::new(cfg, board, calib),
            gemm_cache: HashMap::new(),
            alu_cache: HashMap::new(),
            seg_cache: HashMap::new(),
            seg_batch_cache: HashMap::new(),
        }
    }

    fn kappa(&self) -> f64 {
        match self.model.board.family {
            BoardFamily::Zynq7000 => self.model.calib.kappa_zynq,
            BoardFamily::UltraScalePlus => self.model.calib.kappa_ultrascale,
        }
    }

    /// Autotuned makespan cycles for a GEMM shape (memoized).
    pub fn gemm_cycles(&mut self, shape: GemmShape) -> anyhow::Result<u64> {
        if let Some(&c) = self.gemm_cache.get(&shape) {
            return Ok(c);
        }
        let tuned = autotune_gemm(&self.model, shape)?;
        let c = tuned.report.total_cycles;
        self.gemm_cache.insert(shape, c);
        Ok(c)
    }

    /// Cycles for an element-wise ALU pass of `n_ops` sequential ops over
    /// `elems` accumulators (memoized).
    pub fn alu_pass_cycles(&mut self, elems: u64, n_ops: usize) -> anyhow::Result<u64> {
        if elems == 0 {
            return Ok(0);
        }
        if let Some(&c) = self.alu_cache.get(&(elems, n_ops)) {
            return Ok(c);
        }
        // representative op sequence — cost depends only on count
        let ops: Vec<(AluOp, i16)> = (0..n_ops).map(|_| (AluOp::Max, 0)).collect();
        let prog = lower_alu_pass("alu", elems, &ops, &self.model.cfg)?;
        let c = self.model.price(&prog)?.total_cycles;
        self.alu_cache.insert((elems, n_ops), c);
        Ok(c)
    }

    /// Cycles for one graph op, with the work optionally spatial-split
    /// `split` ways (AI-core / fused replicas: each replica runs the op
    /// on ~1/split of the output rows).
    pub fn op_cycles(
        &mut self,
        op: &Op,
        inputs: &[TensorDesc],
        split: u64,
    ) -> anyhow::Result<u64> {
        debug_assert!(split >= 1);
        match op {
            Op::Conv2d { .. } | Op::Dense { .. } => {
                let (m, k, n) = op
                    .gemm_shape(inputs)
                    .expect("conv/dense always has a GEMM shape");
                let shape = GemmShape { m: m.div_ceil(split), k, n };
                self.gemm_cycles(shape)
            }
            Op::Relu | Op::Requantize { .. } => {
                // requantize = 4-op sequence (add, shr, min, max); relu = 1
                let n_ops = if matches!(op, Op::Relu) { 1 } else { 4 };
                self.alu_pass_cycles(inputs[0].shape.elems().div_ceil(split), n_ops)
            }
            Op::Add => self.alu_pass_cycles(inputs[0].shape.elems().div_ceil(split), 1),
            Op::MaxPool { k, .. } => {
                let out = op.infer(inputs)?;
                self.alu_pass_cycles(
                    (out.shape.elems() * k * k).div_ceil(split),
                    1,
                )
            }
            Op::GlobalAvgPool => {
                self.alu_pass_cycles(inputs[0].shape.elems().div_ceil(split), 1)
            }
            Op::Input { .. } => Ok(0),
        }
    }

    /// Wall-clock compute time of one graph segment on this node, spatial
    /// split `split` ways. Excludes the per-launch driver overhead (the
    /// cluster simulator adds it once per stage launch) but includes the
    /// family anchor κ.
    pub fn segment_time_ns(
        &mut self,
        g: &Graph,
        label: &str,
        split: u64,
    ) -> anyhow::Result<Nanos> {
        let key = (g.name.clone(), label.to_string(), split);
        if let Some(&t) = self.seg_cache.get(&key) {
            return Ok(t);
        }
        let mut cycles = 0u64;
        let node_ids: Vec<usize> = g.segment_nodes(label).iter().map(|n| n.id).collect();
        for id in node_ids {
            let descs = g.input_descs(id);
            cycles += self.op_cycles(&g.node(id).op.clone(), &descs, split)?;
        }
        let t = (cycles_to_ns(cycles, self.model.cfg.clock_hz) as f64 * self.kappa())
            .round() as Nanos;
        self.seg_cache.insert(key, t);
        Ok(t)
    }

    /// Cycles for one graph op computing a batch of `batch` images in a
    /// single launch (DESIGN.md §16). GEMM ops fold the batch into the
    /// output-row dimension — one autotuned program, weights fetched
    /// once — so cycles grow sub-linearly in `batch`; element-wise ALU
    /// work is linear. `batch == 1` is exactly [`CostModel::op_cycles`].
    pub fn op_cycles_batched(
        &mut self,
        op: &Op,
        inputs: &[TensorDesc],
        split: u64,
        batch: u64,
    ) -> anyhow::Result<u64> {
        debug_assert!(batch >= 1);
        if batch <= 1 {
            return self.op_cycles(op, inputs, split);
        }
        match op {
            Op::Conv2d { .. } | Op::Dense { .. } => {
                let (m, k, n) = op
                    .gemm_shape(inputs)
                    .expect("conv/dense always has a GEMM shape");
                let shape = GemmShape { m: (m * batch).div_ceil(split), k, n };
                self.gemm_cycles(shape)
            }
            Op::Relu | Op::Requantize { .. } => {
                let n_ops = if matches!(op, Op::Relu) { 1 } else { 4 };
                self.alu_pass_cycles(
                    (inputs[0].shape.elems() * batch).div_ceil(split),
                    n_ops,
                )
            }
            Op::Add => {
                self.alu_pass_cycles((inputs[0].shape.elems() * batch).div_ceil(split), 1)
            }
            Op::MaxPool { k, .. } => {
                let out = op.infer(inputs)?;
                self.alu_pass_cycles(
                    (out.shape.elems() * k * k * batch).div_ceil(split),
                    1,
                )
            }
            Op::GlobalAvgPool => {
                self.alu_pass_cycles((inputs[0].shape.elems() * batch).div_ceil(split), 1)
            }
            Op::Input { .. } => Ok(0),
        }
    }

    /// Wall-clock compute time of one segment processing `batch` images
    /// in a single launch, spatial split `split` ways (DESIGN.md §16).
    /// `batch == 1` delegates to [`CostModel::segment_time_ns`] — same
    /// cache, bit-identical result — which is what makes
    /// `batch.max_size = 1` byte-identical to batching-off end to end.
    pub fn segment_time_batched_ns(
        &mut self,
        g: &Graph,
        label: &str,
        split: u64,
        batch: u64,
    ) -> anyhow::Result<Nanos> {
        if batch <= 1 {
            return self.segment_time_ns(g, label, split);
        }
        let key = (g.name.clone(), label.to_string(), split, batch);
        if let Some(&t) = self.seg_batch_cache.get(&key) {
            return Ok(t);
        }
        let mut cycles = 0u64;
        let node_ids: Vec<usize> = g.segment_nodes(label).iter().map(|n| n.id).collect();
        for id in node_ids {
            let descs = g.input_descs(id);
            cycles += self.op_cycles_batched(&g.node(id).op.clone(), &descs, split, batch)?;
        }
        let t = (cycles_to_ns(cycles, self.model.cfg.clock_hz) as f64 * self.kappa())
            .round() as Nanos;
        self.seg_batch_cache.insert(key, t);
        Ok(t)
    }

    /// Per-segment planning cost table: single-split wall time (ns, as
    /// f64) for every segment of `g`, in graph order — the oracle the
    /// §II-C planners consume. One shared implementation so the plans
    /// the controller candidates are built from and the plans tenants
    /// are scheduled with can never use divergent pricing.
    pub fn seg_cost_table(&mut self, g: &Graph) -> anyhow::Result<Vec<(String, f64)>> {
        g.segment_order()
            .into_iter()
            .map(|l| {
                let t = self.segment_time_ns(g, &l, 1)?;
                Ok((l, t as f64))
            })
            .collect()
    }

    /// Batch-aware planning cost table (DESIGN.md §17): per-**image**
    /// single-split wall time when segments run `batch` images per
    /// launch, i.e. `segment_time_batched_ns(…, 1, batch) / batch`.
    /// `batch <= 1` delegates to [`CostModel::seg_cost_table`]
    /// bit-identically, so planners that thread the scenario's
    /// `batch.max_size` through price the batching knee instead of
    /// batch=1 without perturbing unbatched runs.
    pub fn seg_cost_table_batched(
        &mut self,
        g: &Graph,
        batch: u64,
    ) -> anyhow::Result<Vec<(String, f64)>> {
        if batch <= 1 {
            return self.seg_cost_table(g);
        }
        g.segment_order()
            .into_iter()
            .map(|l| {
                let t = self.segment_time_batched_ns(g, &l, 1, batch)?;
                Ok((l, t as f64 / batch as f64))
            })
            .collect()
    }

    /// Whole-graph single-node compute time (no driver overhead).
    pub fn graph_time_ns(&mut self, g: &Graph) -> anyhow::Result<Nanos> {
        let mut total = 0;
        for label in g.segment_order() {
            total += self.segment_time_ns(g, &label, 1)?;
        }
        Ok(total)
    }

    /// Per-launch PS driver overhead (ns).
    pub fn driver_overhead_ns(&self) -> Nanos {
        crate::util::units::us_to_ns(self.model.calib.driver_overhead_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::resnet::build_resnet18;

    fn cm(cfg: VtaConfig, board: BoardProfile) -> CostModel {
        CostModel::new(cfg, board, Calibration::default())
    }

    #[test]
    fn segment_times_sum_to_graph_time() {
        let g = build_resnet18(224).unwrap();
        let mut c = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        let total = c.graph_time_ns(&g).unwrap();
        let sum: Nanos = g
            .segment_order()
            .iter()
            .map(|l| c.segment_time_ns(&g, l, 1).unwrap())
            .sum();
        assert_eq!(total, sum);
        assert!(total > 0);
    }

    #[test]
    fn split_reduces_segment_time() {
        let g = build_resnet18(224).unwrap();
        let mut c = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        let t1 = c.segment_time_ns(&g, "s1b1", 1).unwrap();
        let t2 = c.segment_time_ns(&g, "s1b1", 2).unwrap();
        let t4 = c.segment_time_ns(&g, "s1b1", 4).unwrap();
        assert!(t2 < t1, "split 2 not faster: {t2} vs {t1}");
        assert!(t4 < t2);
        // at least 1.5× from a 2-way split (sublinear due to fixed costs)
        assert!(t1 as f64 / t2 as f64 > 1.5, "{t1} / {t2}");
    }

    #[test]
    fn clock_scaling_is_sublinear_on_fixed_board() {
        // 3× clock on the same board/DRAM must give >1× and <3× speedup:
        // the memory-bound share does not scale with clock (the §III
        // "US+ only ≈6 % better" mechanism).
        let g = build_resnet18(224).unwrap();
        let mut slow = cm(VtaConfig::table1_at_clock(100_000_000), BoardProfile::zynq7020());
        let mut fast = cm(VtaConfig::table1_at_clock(300_000_000), BoardProfile::zynq7020());
        let ts = slow.graph_time_ns(&g).unwrap() as f64;
        let tf = fast.graph_time_ns(&g).unwrap() as f64;
        assert!(tf < ts, "3× clock not faster: {tf} vs {ts}");
        assert!(tf > ts / 3.0, "3× clock scaled superlinearly: {tf} vs {ts}");
    }

    #[test]
    fn ultrascale_board_is_faster() {
        let g = build_resnet18(224).unwrap();
        let mut z = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        let mut u = cm(VtaConfig::table1_ultrascale(), BoardProfile::zu_mpsoc());
        let tz = z.graph_time_ns(&g).unwrap();
        let tu = u.graph_time_ns(&g).unwrap();
        assert!(tu < tz, "US+ not faster: {tu} vs {tz}");
    }

    #[test]
    fn caches_are_hit() {
        let g = build_resnet18(224).unwrap();
        let mut c = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        let t0 = std::time::Instant::now();
        c.graph_time_ns(&g).unwrap();
        let cold = t0.elapsed();
        let t1 = std::time::Instant::now();
        c.graph_time_ns(&g).unwrap();
        let warm = t1.elapsed();
        assert!(warm < cold / 10, "cache ineffective: {warm:?} vs {cold:?}");
    }

    #[test]
    fn batched_segment_time_amortizes_sublinearly() {
        let g = build_resnet18(32).unwrap();
        let mut c = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        for label in ["head", "s1b1"] {
            let t1 = c.segment_time_batched_ns(&g, label, 1, 1).unwrap();
            let t8 = c.segment_time_batched_ns(&g, label, 1, 8).unwrap();
            // More total work than one image, but less than 8 separate
            // launches: weights and fixed costs are fetched once.
            assert!(t8 > t1, "{label}: batch 8 not slower: {t8} vs {t1}");
            assert!(t8 < 8 * t1, "{label}: batch 8 superlinear: {t8} vs 8×{t1}");
        }
    }

    #[test]
    fn batch_one_is_bit_identical_to_unbatched() {
        let g = build_resnet18(32).unwrap();
        let mut c = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        for label in g.segment_order() {
            for split in [1u64, 2] {
                assert_eq!(
                    c.segment_time_batched_ns(&g, &label, split, 1).unwrap(),
                    c.segment_time_ns(&g, &label, split).unwrap()
                );
            }
        }
    }

    #[test]
    fn batched_cost_table_prices_the_knee() {
        let g = build_resnet18(32).unwrap();
        let mut c = cm(VtaConfig::table1_zynq7000(), BoardProfile::zynq7020());
        // batch ≤ 1 is bit-identical to the unbatched table …
        assert_eq!(c.seg_cost_table_batched(&g, 1).unwrap(), c.seg_cost_table(&g).unwrap());
        // … and a real batch amortizes: cheaper per image, but not free
        let t1 = c.seg_cost_table(&g).unwrap();
        let t8 = c.seg_cost_table_batched(&g, 8).unwrap();
        assert_eq!(t1.len(), t8.len());
        let s1: f64 = t1.iter().map(|(_, t)| t).sum();
        let s8: f64 = t8.iter().map(|(_, t)| t).sum();
        assert!(s8 < s1, "batch 8 per-image not cheaper: {s8} vs {s1}");
        assert!(s8 > s1 / 8.0, "batch 8 per-image implausibly cheap: {s8} vs {s1}");
    }

    #[test]
    fn kappa_scales_times() {
        let g = build_resnet18(32).unwrap();
        let mut base = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration { kappa_zynq: 1.0, ..Default::default() },
        );
        let mut scaled = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration { kappa_zynq: 2.0, ..Default::default() },
        );
        let a = base.graph_time_ns(&g).unwrap() as f64;
        let b = scaled.graph_time_ns(&g).unwrap() as f64;
        assert!((b / a - 2.0).abs() < 0.01, "kappa not applied: {b} / {a}");
    }
}
