//! Cluster performance model: steady-state throughput + unloaded latency.
//!
//! The paper's metric (average inference time over 10 000 streamed
//! images) is a **throughput** figure: in steady state a FIFO pipeline's
//! per-image time equals the service demand of its busiest resource.
//! We therefore compute, per image:
//!
//! * **node demand** — compute time of every stage hosted by the node
//!   (divided by the replica count for data-parallel stages) plus the
//!   `ps_serial_frac` share of every blocking transfer touching the node
//!   (§III: the PS CPU stages DMA buffers and drives blocking MPI);
//! * **port demand** — wire time through each endpoint's switch port
//!   (master egress serializes the scatter, master ingress the gather);
//!
//! and take `ms_per_image = max(all demands)`. Unloaded end-to-end
//! latency comes from booking a single image through the internal
//! `Booker` (transfers + computes along the critical path). Both parts
//! are exact,
//! deterministic and fast — no Monte-Carlo noise on top of the paper
//! comparison.

use crate::config::ClusterConfig;
use crate::graph::partition::atomic_segments;
use crate::graph::Graph;
use crate::net::link::LinkModel;
use crate::net::mpi::MpiModel;
use crate::net::switch::{Endpoint, Flow, SwitchSim};
use crate::power::{analytic_power, PowerModel, PowerReport};
use crate::sched::{ExecutionPlan, SplitMode, StagePlan};
use crate::sim::cost::CostModel;
use crate::util::stats::Summary;
use crate::util::units::{ns_to_ms, Nanos};
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Images in the modeled stream (affects the makespan estimate only;
    /// demands are per-image and exact).
    pub images: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { images: 64 }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    /// The paper's metric: steady-state time per image (ms).
    pub ms_per_image: f64,
    /// Unloaded end-to-end latency of one image (ms) and distribution
    /// stats (deterministic model: the summary holds the one latency).
    pub latency_ms: Summary,
    /// Estimated makespan for the configured image count (ms).
    pub makespan_ms: f64,
    /// Per-node demand relative to the bottleneck resource.
    pub node_utilization: Vec<f64>,
    /// Bytes through the switch per image × images.
    pub network_bytes: u64,
    /// Steady-state power figures (J/image, per-node watts, images/s/W)
    /// from the board-family [`PowerModel`] — DESIGN.md §11.
    pub power: PowerReport,
}

/// Books transfers/computes for the latency path.
struct Booker<'a> {
    node_free: Vec<Nanos>,
    switch: SwitchSim,
    mpi: MpiModel,
    cluster: &'a ClusterConfig,
    serial_frac: f64,
    network_bytes: u64,
}

impl Booker<'_> {
    fn transfer(&mut self, src: Endpoint, dst: Endpoint, bytes: u64, ready: Nanos) -> Nanos {
        if src == dst {
            return ready;
        }
        let mut t0 = ready;
        if let Endpoint::Node(n) = src {
            t0 = t0.max(self.node_free[n]);
        }
        if let Endpoint::Node(n) = dst {
            t0 = t0.max(self.node_free[n]);
        }
        let timing = self.switch.schedule(&Flow { src, dst, bytes, ready_ns: t0 });
        let src_board = match src {
            Endpoint::Node(n) => Some(&self.cluster.boards[n]),
            Endpoint::Master => None,
        };
        let dst_board = match dst {
            Endpoint::Node(n) => Some(&self.cluster.boards[n]),
            Endpoint::Master => None,
        };
        let overhead = self.mpi.transfer_ns(bytes, src_board, dst_board)
            - self.mpi.link.serialize_ns(bytes);
        let arrival = timing.arrival_ns + overhead;
        for ep in [src, dst] {
            if let Endpoint::Node(n) = ep {
                let start = t0.max(self.node_free[n]);
                let occupied =
                    (arrival.saturating_sub(start) as f64 * self.serial_frac).round() as Nanos;
                self.node_free[n] = self.node_free[n].max(start + occupied);
            }
        }
        self.network_bytes += bytes;
        arrival
    }

    fn compute(&mut self, node: usize, ready: Nanos, dur: Nanos) -> Nanos {
        let start = ready.max(self.node_free[node]);
        let done = start + dur;
        self.node_free[node] = done;
        done
    }
}

/// Per-image transfer between consecutive stages: list of
/// (src, dst, bytes, images_fraction) tuples. `images_fraction` is the
/// fraction of the image stream that takes this route (data-parallel
/// replicas each see 1/r of images).
fn stage_transfers(
    prev: Option<&StagePlan>,
    cur: &StagePlan,
    in_bytes: u64,
) -> Vec<(Endpoint, Endpoint, u64, f64)> {
    let producers: Vec<Endpoint> = match prev {
        None => vec![Endpoint::Master],
        Some(p) => p.replicas.iter().map(|&r| Endpoint::Node(r)).collect(),
    };
    let prev_dp = prev.map(|p| p.split == SplitMode::DataParallel).unwrap_or(true);
    let cur_dp = cur.split == SplitMode::DataParallel;
    let consumers: Vec<Endpoint> =
        cur.replicas.iter().map(|&r| Endpoint::Node(r)).collect();
    let mut out = Vec::new();
    match (prev_dp, cur_dp) {
        (true, true) => {
            // each image: one producer replica → one consumer replica;
            // pair (i, j) carries the images where both round-robins hit
            let kp = producers.len();
            let kc = consumers.len();
            let period = lcm(kp, kc);
            for t in 0..period {
                out.push((
                    producers[t % kp],
                    consumers[t % kc],
                    in_bytes,
                    1.0 / period as f64,
                ));
            }
        }
        (true, false) => {
            // scatter: the producer of each image sends a slice to every
            // spatial consumer
            let kp = producers.len();
            let kc = consumers.len();
            for (i, &p) in producers.iter().enumerate() {
                let _ = i;
                for &c in &consumers {
                    out.push((p, c, in_bytes / kc as u64, 1.0 / kp as f64));
                }
            }
        }
        (false, true) => {
            // gather: every spatial producer sends its slice to the
            // image's consumer replica
            let kp = producers.len();
            let kc = consumers.len();
            for &p in &producers {
                for &c in &consumers {
                    out.push((p, c, in_bytes / kp as u64, 1.0 / kc as f64));
                }
            }
        }
        (false, false) => {
            // spatial → spatial: each consumer's row range overlaps a
            // window of producers
            let kp = producers.len();
            let kc = consumers.len();
            for ci in 0..kc {
                let p_lo = ci * kp / kc;
                let p_hi = ((ci + 1) * kp).div_ceil(kc).min(kp);
                let share = (in_bytes / kc as u64) / (p_hi - p_lo) as u64;
                for &p in &producers[p_lo..p_hi] {
                    out.push((p, consumers[ci], share.max(1), 1.0));
                }
            }
        }
    }
    // local hops are free
    out.retain(|(s, d, _, _)| s != d);
    out
}

fn lcm(a: usize, b: usize) -> usize {
    fn gcd(a: usize, b: usize) -> usize {
        if b == 0 {
            a
        } else {
            gcd(b, a % b)
        }
    }
    a / gcd(a, b) * b
}

/// Per-stage service time (ns) of `plan` on the calibrated cost model:
/// the stage's segment computes at its split factor plus one driver
/// launch. Shared by the steady-state model and the discrete-event
/// simulator ([`crate::sim::des`]) so the two cost bases cannot drift.
pub fn stage_service_times(
    plan: &ExecutionPlan,
    cost: &mut CostModel,
    g: &Graph,
) -> anyhow::Result<Vec<Nanos>> {
    let driver = cost.driver_overhead_ns();
    let mut out = Vec::with_capacity(plan.stages.len());
    for st in &plan.stages {
        let split = match st.split {
            SplitMode::Spatial => st.replicas.len() as u64,
            SplitMode::DataParallel => 1,
        };
        let mut t = 0;
        for seg in &st.segments {
            t += cost.segment_time_ns(g, seg, split)?;
        }
        out.push(t + driver);
    }
    Ok(out)
}

/// [`stage_service_times`] for a dispatch batch of `batch` images
/// computed as ONE launch per stage (DESIGN.md §16): segments price at
/// the batched GEMM/ALU cost (sub-linear — weights and fixed costs
/// amortize) and the driver overhead is paid once per stage instead of
/// once per image. `batch == 1` is bit-identical to the unbatched
/// table, which the serve-off byte-identity contract relies on.
pub fn stage_service_times_batched(
    plan: &ExecutionPlan,
    cost: &mut CostModel,
    g: &Graph,
    batch: u64,
) -> anyhow::Result<Vec<Nanos>> {
    if batch <= 1 {
        return stage_service_times(plan, cost, g);
    }
    let driver = cost.driver_overhead_ns();
    let mut out = Vec::with_capacity(plan.stages.len());
    for st in &plan.stages {
        let split = match st.split {
            SplitMode::Spatial => st.replicas.len() as u64,
            SplitMode::DataParallel => 1,
        };
        let mut t = 0;
        for seg in &st.segments {
            t += cost.segment_time_batched_ns(g, seg, split, batch)?;
        }
        out.push(t + driver);
    }
    Ok(out)
}

/// Activation bytes entering each stage of `plan`, plus the bytes
/// leaving the last stage (the logits gathered back to the master).
pub fn stage_io_bytes(plan: &ExecutionPlan, g: &Graph) -> anyhow::Result<(Vec<u64>, u64)> {
    let atoms = atomic_segments(g);
    let seg_bytes: HashMap<&str, (u64, u64)> = atoms
        .iter()
        .map(|a| (a.labels[0].as_str(), (a.in_bytes, a.out_bytes)))
        .collect();
    let lookup = |label: &str| {
        seg_bytes
            .get(label)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("segment '{label}' not in graph '{}'", g.model))
    };
    let mut ins = Vec::with_capacity(plan.stages.len());
    for st in &plan.stages {
        let first = st.segments.first().expect("validated plan stage has segments");
        ins.push(lookup(first)?.0);
    }
    let last = plan.stages.last().expect("validated plan has stages");
    let out = lookup(last.segments.last().expect("stage has segments"))?.1;
    Ok((ins, out))
}

/// Simulate a plan over the cluster; `cost` must be built from the same
/// board/VTA config as `cluster`, and `plan` must have been built for
/// `g` (any zoo model — the simulator is model-agnostic).
pub fn simulate(
    plan: &ExecutionPlan,
    cluster: &ClusterConfig,
    cost: &mut CostModel,
    g: &Graph,
    sim_cfg: &SimConfig,
) -> anyhow::Result<SimResult> {
    plan.validate_for(g)?;
    anyhow::ensure!(
        plan.n_nodes == cluster.num_nodes(),
        "plan is for {} nodes, cluster has {}",
        plan.n_nodes,
        cluster.num_nodes()
    );
    let mpi =
        MpiModel::from_calibration(&cost.model.calib, cluster.switch.forward_latency_ns);
    let link = LinkModel::new(cluster.switch.port_bits_per_sec);
    let serial_frac = cost.model.calib.ps_serial_frac;

    // stage compute times (per replica slice for spatial stages) and
    // per-stage activation sizes — shared with the DES (`sim::des`)
    let stage_time = stage_service_times(plan, cost, g)?;
    let (stage_in_bytes, final_out_bytes) = stage_io_bytes(plan, g)?;

    // ---- steady-state demands (per image) ----------------------------
    let n = cluster.num_nodes();
    let mut node_demand = vec![0.0f64; n]; // ns/image
    let mut egress = HashMap::<Endpoint, f64>::new();
    let mut ingress = HashMap::<Endpoint, f64>::new();
    let mut net_bytes_per_image = 0f64;

    for (si, st) in plan.stages.iter().enumerate() {
        // compute demand
        match st.split {
            SplitMode::DataParallel => {
                let share = 1.0 / st.replicas.len() as f64;
                for &r in &st.replicas {
                    node_demand[r] += stage_time[si] as f64 * share;
                }
            }
            SplitMode::Spatial => {
                for &r in &st.replicas {
                    node_demand[r] += stage_time[si] as f64;
                }
            }
        }
        // transfer demand into this stage
        let prev = if si == 0 { None } else { Some(&plan.stages[si - 1]) };
        for (src, dst, bytes, frac) in stage_transfers(prev, st, stage_in_bytes[si]) {
            let wire = link.serialize_ns(bytes) as f64 * frac;
            *egress.entry(src).or_default() += wire;
            *ingress.entry(dst).or_default() += wire;
            net_bytes_per_image += bytes as f64 * frac;
            let src_board = match src {
                Endpoint::Node(i) => Some(&cluster.boards[i]),
                Endpoint::Master => None,
            };
            let dst_board = match dst {
                Endpoint::Node(i) => Some(&cluster.boards[i]),
                Endpoint::Master => None,
            };
            let blocking =
                mpi.transfer_ns(bytes, src_board, dst_board) as f64 * serial_frac * frac;
            if let Endpoint::Node(i) = src {
                node_demand[i] += blocking;
            }
            if let Endpoint::Node(i) = dst {
                node_demand[i] += blocking;
            }
        }
    }
    // gather logits to master
    {
        let last = plan.stages.last().unwrap();
        let out_bytes = final_out_bytes;
        let k = last.replicas.len() as u64;
        let (bytes, frac) = match last.split {
            SplitMode::Spatial => ((out_bytes / k).max(1), 1.0),
            SplitMode::DataParallel => (out_bytes.max(1), 1.0 / k as f64),
        };
        for &r in &last.replicas {
            let wire = link.serialize_ns(bytes) as f64 * frac;
            *egress.entry(Endpoint::Node(r)).or_default() += wire;
            *ingress.entry(Endpoint::Master).or_default() += wire;
            net_bytes_per_image += bytes as f64 * frac;
            let blocking = mpi.transfer_ns(bytes, Some(&cluster.boards[r]), None) as f64
                * serial_frac
                * frac;
            node_demand[r] += blocking;
        }
    }

    let port_bottleneck = egress
        .values()
        .chain(ingress.values())
        .copied()
        .fold(0.0f64, f64::max);
    let node_bottleneck = node_demand.iter().copied().fold(0.0f64, f64::max);
    let bottleneck_ns = node_bottleneck.max(port_bottleneck);

    // ---- unloaded latency: book one image through the cluster --------
    let mut booker = Booker {
        node_free: vec![0; n],
        switch: SwitchSim::new(link.clone(), cluster.switch.forward_latency_ns),
        mpi,
        cluster,
        serial_frac,
        network_bytes: 0,
    };
    let mut holders: Vec<(Endpoint, Nanos)> = vec![(Endpoint::Master, 0)];
    for (si, st) in plan.stages.iter().enumerate() {
        let consumers: Vec<usize> = match st.split {
            SplitMode::DataParallel => vec![st.replicas[0]],
            SplitMode::Spatial => st.replicas.clone(),
        };
        let kp = holders.len();
        let kc = consumers.len();
        let in_bytes = stage_in_bytes[si];
        let mut next = Vec::with_capacity(kc);
        for (ci, &cnode) in consumers.iter().enumerate() {
            let p_lo = ci * kp / kc;
            let p_hi = ((ci + 1) * kp).div_ceil(kc).min(kp);
            let share = ((in_bytes / kc as u64).max(1) / (p_hi - p_lo) as u64).max(1);
            let mut arrival = 0;
            for (src, ready) in holders[p_lo..p_hi].iter() {
                arrival =
                    arrival.max(booker.transfer(*src, Endpoint::Node(cnode), share, *ready));
            }
            let done = booker.compute(cnode, arrival, stage_time[si]);
            next.push((Endpoint::Node(cnode), done));
        }
        holders = next;
    }
    let share = (final_out_bytes / holders.len() as u64).max(1);
    let mut latency_ns = 0;
    for &(src, ready) in &holders {
        latency_ns = latency_ns.max(booker.transfer(src, Endpoint::Master, share, ready));
    }

    let ms_per_image = ns_to_ms(bottleneck_ns.round() as Nanos).max(1e-6);
    let mut latency = Summary::new();
    latency.push(ns_to_ms(latency_ns));
    let makespan_ms =
        ns_to_ms(latency_ns) + ms_per_image * (sim_cfg.images.saturating_sub(1)) as f64;
    let node_utilization: Vec<f64> = node_demand
        .iter()
        .map(|&d| if bottleneck_ns > 0.0 { d / bottleneck_ns } else { 0.0 })
        .collect();
    let power = analytic_power(
        &PowerModel::for_family(cluster.boards[0].family),
        &cluster.vta,
        &node_utilization,
        ms_per_image,
        net_bytes_per_image,
        g.total_weight_bytes(),
        ns_to_ms(latency_ns),
    );
    Ok(SimResult {
        ms_per_image,
        latency_ms: latency,
        makespan_ms,
        node_utilization,
        network_bytes: (net_bytes_per_image * sim_cfg.images as f64) as u64,
        power,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BoardProfile, Calibration, VtaConfig};
    use crate::graph::resnet::build_resnet18;
    use crate::sched::{build_plan, Strategy};

    fn setup(n: usize) -> (Graph, ClusterConfig, CostModel) {
        let g = build_resnet18(224).unwrap();
        let cluster = ClusterConfig::zynq_stack(n);
        let cost = CostModel::new(
            VtaConfig::table1_zynq7000(),
            BoardProfile::zynq7020(),
            Calibration::default(),
        );
        (g, cluster, cost)
    }

    fn run(strategy: Strategy, n: usize, images: usize) -> SimResult {
        let (g, cluster, mut cost) = setup(n);
        let costs: Vec<(String, f64)> = g
            .segment_order()
            .into_iter()
            .map(|l| {
                let t = cost.segment_time_ns(&g, &l, 1).unwrap() as f64;
                (l, t)
            })
            .collect();
        let lookup = |l: &str| costs.iter().find(|(x, _)| x == l).unwrap().1;
        let plan = build_plan(strategy, &g, n, lookup).unwrap();
        simulate(&plan, &cluster, &mut cost, &g, &SimConfig { images }).unwrap()
    }

    #[test]
    fn single_node_all_strategies_agree() {
        let results: Vec<f64> = Strategy::all()
            .iter()
            .map(|&s| run(s, 1, 16).ms_per_image)
            .collect();
        for w in results.windows(2) {
            let rel = (w[0] - w[1]).abs() / w[0];
            assert!(rel < 0.02, "single-node strategies diverge: {results:?}");
        }
    }

    #[test]
    fn scatter_gather_scales_down() {
        let t1 = run(Strategy::ScatterGather, 1, 24).ms_per_image;
        let t4 = run(Strategy::ScatterGather, 4, 24).ms_per_image;
        let t12 = run(Strategy::ScatterGather, 12, 48).ms_per_image;
        assert!(t4 < t1 / 2.0, "SG @4 too slow: {t4} vs {t1}");
        assert!(t12 < t4, "SG @12 not faster than @4: {t12} vs {t4}");
        // but not superlinear
        assert!(t12 > t1 / 14.0, "SG @12 implausibly fast: {t12} vs {t1}");
    }

    #[test]
    fn core_assign_small_n_pays_network_penalty() {
        // the paper's headline anomaly: 2 nodes worse than one — needs the
        // fully blocking regime the paper describes
        let (g, cluster, mut cost) = setup(2);
        cost.model.calib.ps_serial_frac = 1.0;
        let costs: Vec<(String, f64)> = g
            .segment_order()
            .into_iter()
            .map(|l| (l.clone(), cost.segment_time_ns(&g, &l, 1).unwrap() as f64))
            .collect();
        let lookup = |l: &str| costs.iter().find(|(x, _)| x == l).unwrap().1;
        let plan = build_plan(Strategy::CoreAssign, &g, 2, lookup).unwrap();
        let t2 = simulate(&plan, &cluster, &mut cost, &g, &SimConfig::default())
            .unwrap()
            .ms_per_image;
        let t1 = run(Strategy::CoreAssign, 1, 16).ms_per_image;
        assert!(t2 > t1 * 0.9, "AI-core @2 should be ≈ or worse than single: {t2} vs {t1}");
    }

    #[test]
    fn pipeline_scales() {
        let t1 = run(Strategy::Pipeline, 1, 24).ms_per_image;
        let t5 = run(Strategy::Pipeline, 5, 40).ms_per_image;
        assert!(t5 < t1 / 1.8, "pipeline @5: {t5} vs {t1}");
    }

    #[test]
    fn latency_at_least_single_node_compute() {
        let r = run(Strategy::Pipeline, 4, 8);
        // pipeline latency ≥ sum of stage computes ≥ throughput figure
        assert!(r.latency_ms.mean() >= r.ms_per_image);
    }

    #[test]
    fn utilization_bounded_and_bottleneck_is_one() {
        let r = run(Strategy::Fused, 6, 24);
        assert_eq!(r.node_utilization.len(), 6);
        for &u in &r.node_utilization {
            assert!((0.0..=1.0001).contains(&u), "utilization {u}");
        }
    }

    #[test]
    fn network_bytes_grow_with_distribution() {
        let r1 = run(Strategy::Pipeline, 1, 16);
        let r4 = run(Strategy::Pipeline, 4, 16);
        assert!(r4.network_bytes > r1.network_bytes);
    }

    #[test]
    fn deterministic() {
        let a = run(Strategy::Fused, 4, 24);
        let b = run(Strategy::Fused, 4, 24);
        assert_eq!(a.ms_per_image, b.ms_per_image);
        assert_eq!(a.network_bytes, b.network_bytes);
    }

    #[test]
    fn power_report_is_bounded_and_consistent() {
        use crate::power::PowerModel;
        let pm = PowerModel::zynq7020();
        let r = run(Strategy::ScatterGather, 4, 16);
        assert_eq!(r.power.node_watts.len(), 4);
        for (&u, &w) in r.node_utilization.iter().zip(&r.power.node_watts) {
            assert!(w >= pm.idle_w() - 1e-9, "node below idle floor: {w}");
            assert!(u <= 1.0001);
        }
        assert!(r.power.cluster_peak_w >= r.power.cluster_avg_w);
        // the reciprocal identity the CLI prints
        assert!((r.power.img_per_sec_per_w * r.power.j_per_image - 1.0).abs() < 1e-9);
        // avg draw × period = J/image
        let period_s = r.ms_per_image / 1e3;
        assert!((r.power.cluster_avg_w * period_s - r.power.j_per_image).abs() < 1e-9);
    }

    #[test]
    fn scatter_gather_is_more_efficient_than_core_assign() {
        // ai-core at small N pays driver launches + blocking transfers
        // for every one of its 10 stages; that busy time is joules
        let sg = run(Strategy::ScatterGather, 4, 16);
        let ai = run(Strategy::CoreAssign, 4, 16);
        assert!(
            sg.power.j_per_image < ai.power.j_per_image,
            "sg {} J vs ai-core {} J",
            sg.power.j_per_image,
            ai.power.j_per_image
        );
    }

    #[test]
    fn plan_cluster_size_mismatch_rejected() {
        let (g, cluster, mut cost) = setup(3);
        let plan = build_plan(Strategy::ScatterGather, &g, 4, |_| 1.0).unwrap();
        assert!(simulate(&plan, &cluster, &mut cost, &g, &SimConfig::default()).is_err());
    }

    #[test]
    fn stage_transfer_routing_conserves_bytes() {
        use crate::sched::StagePlan;
        let mk = |replicas: Vec<usize>, split| StagePlan {
            segments: vec!["s".into()],
            replicas,
            split,
        };
        // DP(2) → DP(3): per-image exactly in_bytes cross (fractions sum 1)
        let prev = mk(vec![0, 1], SplitMode::DataParallel);
        let cur = mk(vec![2, 3, 4], SplitMode::DataParallel);
        let ts = stage_transfers(Some(&prev), &cur, 6000);
        let total: f64 = ts.iter().map(|(_, _, b, f)| *b as f64 * f).sum();
        assert!((total - 6000.0).abs() < 1.0, "{total}");
        // spatial(2) → spatial(4)
        let prev = mk(vec![0, 1], SplitMode::Spatial);
        let cur = mk(vec![2, 3, 4, 5], SplitMode::Spatial);
        let ts = stage_transfers(Some(&prev), &cur, 8000);
        let total: f64 = ts.iter().map(|(_, _, b, f)| *b as f64 * f).sum();
        assert!((total - 8000.0).abs() < 8.0, "{total}");
    }
}
